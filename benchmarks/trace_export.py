"""Traced fast bench -> Chrome trace-event JSON, validated (CI `trace` job).

Runs the fast DPD workload once per traceable backend (host dynamic,
single-core megakernel, grid k=2) with ``ExecutionPlan(trace=True)``,
exports each run's firing trace with ``Trace.to_perfetto``, then
validates every document against the Chrome trace-event schema
(``repro.core.validate_chrome_trace``: required keys per phase type,
monotonic timestamps per track) and cross-checks the exported per-actor
firing events against ``RunResult.fire_counts``.

Exits non-zero on any validation problem, so CI fails when the export
format drifts.  The ``.trace.json`` files land in ``--out`` (default
``results/``) and are uploaded as a CI artifact — drag one into
https://ui.perfetto.dev to inspect the firing schedule.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List

if __package__ in (None, ""):   # script invocation: PYTHONPATH=src is enough
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.core import ExecutionPlan, validate_chrome_trace
from repro.graphs.factories import make_dpd

BACKENDS = {
    "dynamic": lambda: ExecutionPlan(mode="dynamic", donate=False,
                                     trace=True),
    "megakernel": lambda: ExecutionPlan(mode="megakernel", specialize=False,
                                        trace=True),
    "grid2": lambda: ExecutionPlan(mode="megakernel", specialize=False,
                                   cores=2, trace=True),
}


def export_traces(out_dir: str) -> List[str]:
    """Write one validated ``dpd_<backend>.trace.json`` per backend;
    returns the list of validation problems (empty == all clean)."""
    os.makedirs(out_dir, exist_ok=True)
    net, _ = make_dpd(n_firings=4, block_l=256)
    problems: List[str] = []
    for backend, plan in BACKENDS.items():
        res = net.compile(plan()).run()
        path = os.path.join(out_dir, f"dpd_{backend}.trace.json")
        res.trace.to_perfetto(path)
        with open(path) as f:
            doc = json.load(f)
        for p in validate_chrome_trace(doc):
            problems.append(f"{backend}: {p}")
        names = res.trace.actor_names
        fired = {nm: 0 for nm in names}
        for ev in doc["traceEvents"]:
            if ev["ph"] == "X":
                fired[names[ev["tid"] - 1]] += 1
        want = {k: int(v) for k, v in res.fire_counts.items()}
        if fired != want:
            problems.append(f"{backend}: exported firing events {fired} "
                            f"!= fire_counts {want}")
        print(f"{backend}: {res.trace.n_events} events, "
              f"{sum(fired.values())} firings -> {path}")
    return problems


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "results"))
    args = ap.parse_args()
    problems = export_traces(args.out)
    for p in problems:
        print(f"INVALID: {p}", file=sys.stderr)
    print("trace export:", "FAILED" if problems else "ok",
          f"({len(BACKENDS)} backends)")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
