"""Serving benchmarks: continuous batching on the actor runtime vs the
fixed-batch engine, under a seeded Poisson open-loop arrival trace.

Two serving rows, same request set (variable per-request budgets, so
fixed batches strand idle slots on the short requests while the actor
network re-admits them):

  * ``serve_legacy_fixed_batch`` — ``repro.serve.Engine`` (early-stop
    enabled): groups requests into arrival-order batches, each batch
    holds every slot until its slowest member finishes.  Wall time is
    the measured ``generate`` call; per-request completion latency
    comes from the deterministic queueing timeline in decode-steps
    (batch g starts at max(last member's arrival, batch g-1's finish)).
  * ``serve_actor_continuous`` — the admission/decode/retire network of
    ``repro.graphs.serving`` under the host-dynamic plan, open-loop
    arrivals fed from the trace; latency is the retire sink's
    per-request step count.

Latency percentiles are reported in *steps* (deterministic given the
seeds — token values never matter because ``eos_id=None`` retires by
budget), so they gate as structure fields in ``check_regression.py``
alongside sweep/fire counts; only the tok/s pair is timing.  The
``serve_stream_*`` rows time ``Program.stream`` chunked vs
persistent-feed on the DPD megakernel subnetwork and record the staged
bytes from ``Program.stats()`` — the before/after table of
EXPERIMENTS.md §Serving.  Caveat: CPU numbers measure scheduling
structure (megakernel rows run Pallas interpret mode), not kernel perf.

Writes ``BENCH_serving.json`` (same contract as the other suites:
``name``/``us_per_call``/``tokens_per_s`` plus exact-compare structure
fields) for the bench-regression gate.
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List, Tuple

if __package__ in (None, ""):   # script invocation: PYTHONPATH=src is enough
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import numpy as np

from repro.configs import smoke_config
from repro.core import ExecutionPlan
from repro.graphs.factories import make_dpd
from repro.graphs.serving import poisson_trace
from repro.models import init_params
from repro.serve import ActorEngine, Engine, Request, ServeConfig

Row = Tuple[str, float, str]

JSON_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_serving.json")


def _legacy_timeline(arrivals: np.ndarray, budgets: np.ndarray,
                     batch_size: int) -> Tuple[np.ndarray, int]:
    """Deterministic queueing simulation of the fixed-batch engine, in
    decode-steps: batch g admits the next ``batch_size`` requests in
    arrival order, starts at max(its last member's arrival, batch g-1's
    finish), and runs until its slowest member's budget (the early-stop
    loop).  A request's own tokens complete at start + its budget.
    Returns (per-request completion latency in steps, total steps)."""
    order = np.argsort(arrivals, kind="stable")
    lat = np.zeros(len(arrivals), np.int64)
    finish_prev = 0
    total_steps = 0
    for lo in range(0, len(order), batch_size):
        grp = order[lo:lo + batch_size]
        start = max(int(arrivals[grp].max()), finish_prev)
        steps = int(budgets[grp].max())        # prefill + (max-1) decodes
        lat[grp] = start + budgets[grp] - arrivals[grp]
        finish_prev = start + steps
        total_steps += steps
    return lat, total_steps


def bench_serving(fast: bool = False, json_path: str = JSON_PATH) -> List[Row]:
    from benchmarks.bench_executors import _interleaved_medians

    reps = 3 if fast else 5
    rows: List[Row] = []
    records: List[Dict] = []

    def record(name: str, dt: float, tokens: int, derived: str,
               **structure) -> None:
        rows.append((name, dt * 1e6, derived))
        records.append({"name": name, "us_per_call": round(dt * 1e6, 1),
                        "tokens_per_s": round(tokens / dt, 1), **structure})

    # ---- workload: variable budgets + Poisson open-loop arrivals -------
    if fast:
        R, scfg = 6, ServeConfig(batch_size=2, max_prompt=8, max_new=6,
                                 eos_id=None)
    else:
        R, scfg = 12, ServeConfig(batch_size=4, max_prompt=16, max_new=8,
                                  eos_id=None)
    cfg = smoke_config("granite-8b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(9)
    # Long/short alternation: the idle-slot workload fixed batches waste.
    budgets = np.array([scfg.max_new if i % 2 == 0 else 1 for i in range(R)])
    reqs = [Request(prompt=rng.integers(1, cfg.vocab, size=scfg.max_prompt
                                        - 1 - (i % 3)).astype(np.int32),
                    max_new=int(budgets[i])) for i in range(R)]
    arrivals = poisson_trace(R, rate=2.0, seed=7)
    total_tokens = int(budgets.sum())

    legacy = Engine(cfg, params, scfg)
    actor = ActorEngine(cfg, params, scfg)
    net = actor.build_network(reqs, arrivals=arrivals)
    prog = net.compile(actor.plan)

    # One telemetry run (the timed reps reuse the compiled program).
    res = prog.run()
    sink = prog.collect("retire", res.state)
    assert int(np.asarray(sink["done"]).sum()) == R, "request starved"
    actor_lat = np.asarray(sink["lat"])
    sweeps = int(res.sweeps)
    decode_fires = int(res.fire_counts["decode"])
    legacy_lat, legacy_steps = _legacy_timeline(arrivals, budgets,
                                                scfg.batch_size)

    med = _interleaved_medians({
        "legacy": lambda: legacy.generate(reqs),
        "actor": lambda: jax.block_until_ready(prog.run().state),
    }, reps)

    p50_l, p99_l = np.percentile(legacy_lat, [50, 99])
    p50_a, p99_a = np.percentile(actor_lat, [50, 99])
    record("serve_legacy_fixed_batch", med["legacy"], total_tokens,
           f"{legacy_steps} steps, p50/p99 latency {p50_l:.0f}/{p99_l:.0f} "
           "steps (queueing timeline)",
           total_tokens=total_tokens, steps=legacy_steps,
           p50_latency_steps=round(float(p50_l), 1),
           p99_latency_steps=round(float(p99_l), 1))
    record("serve_actor_continuous", med["actor"], total_tokens,
           f"{decode_fires} decode firings over {sweeps} sweeps, p50/p99 "
           f"latency {p50_a:.0f}/{p99_a:.0f} steps",
           total_tokens=total_tokens, sweeps=sweeps,
           decode_fires=decode_fires,
           p50_latency_steps=round(float(p50_a), 1),
           p99_latency_steps=round(float(p99_a), 1))
    rows.append(("serve_actor_vs_legacy", 0.0,
                 f"{med['legacy'] / med['actor']:.2f}x sustained tok/s vs "
                 f"fixed batches, beats: {med['actor'] < med['legacy']} "
                 f"(continuous batching re-admits freed slots)"))

    # ---- Program.stream: chunked vs persistent-feed staging ------------
    n_firings, block_l = (8, 128) if fast else (8, 1024)
    dnet, _ = make_dpd(n_firings=n_firings, block_l=block_l, seed=1)
    accel = tuple(n for n in dnet.actors if n not in ("source", "sink"))
    sprog = dnet.compile(ExecutionPlan(mode="megakernel", n_iterations=4,
                                       accelerated=accel, specialize=False))
    sig = np.random.default_rng(0).normal(
        size=(n_firings, 1, 2, block_l)).astype(np.float32)
    feeds = {"f_in": sig}
    smed = _interleaved_medians({
        "chunked": lambda: jax.block_until_ready(
            list(sprog.stream(feeds).values())),
        "persistent": lambda: jax.block_until_ready(
            list(sprog.stream(feeds, persistent=True).values())),
    }, reps)
    sprog.stream(feeds)
    st_c = sprog.stats()
    sprog.stream(feeds, persistent=True)
    st_p = sprog.stats()
    record("serve_stream_chunked", smed["chunked"], n_firings,
           f"{st_c.last_stream_chunks} chunks, "
           f"{st_c.last_stream_staged_bytes_per_chunk} B staged/chunk",
           chunks=st_c.last_stream_chunks,
           staged_bytes_per_chunk=st_c.last_stream_staged_bytes_per_chunk,
           total_staged_bytes=st_c.last_stream_total_staged_bytes)
    record("serve_stream_persistent", smed["persistent"], n_firings,
           f"{st_p.last_stream_staged_bytes_per_chunk} B staged/chunk "
           "(rings stay resident)",
           chunks=st_p.last_stream_chunks,
           staged_bytes_per_chunk=st_p.last_stream_staged_bytes_per_chunk,
           total_staged_bytes=st_p.last_stream_total_staged_bytes)
    rows.append(("serve_stream_staging_cut", 0.0,
                 f"per-chunk staged bytes "
                 f"{st_c.last_stream_staged_bytes_per_chunk} -> "
                 f"{st_p.last_stream_staged_bytes_per_chunk}, reduces: "
                 f"{st_p.last_stream_staged_bytes_per_chunk < st_c.last_stream_staged_bytes_per_chunk}"))

    with open(json_path, "w") as f:
        json.dump(records, f, indent=2)
        f.write("\n")
    rows.append(("serve_bench_json", 0.0, json_path))
    return rows


if __name__ == "__main__":
    fast = "--fast" in sys.argv
    print("name,us_per_call,derived")
    for name, us, derived in bench_serving(fast=fast):
        print(f"{name},{us:.1f},{derived}")
