"""Kernel micro-benchmarks: XLA reference path wall time on CPU (the
Pallas kernels target TPU; interpret=True timings are not meaningful perf
numbers and are reported only as correctness artifacts)."""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Row = Tuple[str, float, str]


def _time_us(fn, reps=5) -> float:
    fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6


def bench_kernels() -> List[Row]:
    rng = np.random.default_rng(0)
    rows: List[Row] = []

    from repro.kernels.gauss5x5 import gauss5x5
    f = jnp.asarray(rng.uniform(0, 255, (240, 320)), jnp.float32)
    us = _time_us(lambda: jax.block_until_ready(gauss5x5(f, impl="xla")))
    rows.append(("kernel_gauss5x5_qvga", us, f"{240*320/us:.0f} px/us"))

    from repro.kernels.motion_post import motion_post
    g = jnp.asarray(rng.uniform(0, 255, (240, 320)), jnp.float32)
    us = _time_us(lambda: jax.block_until_ready(motion_post(f, g, impl="xla")))
    rows.append(("kernel_motion_post_qvga", us, f"{240*320/us:.0f} px/us"))

    from repro.kernels.dyn_fir import dpd_branch
    L = 32768
    xr = jnp.asarray(rng.normal(size=L + 9), jnp.float32)
    xi = jnp.asarray(rng.normal(size=L + 9), jnp.float32)
    h = jnp.asarray(rng.normal(size=10), jnp.float32)
    us = _time_us(lambda: jax.block_until_ready(
        dpd_branch(xr, xi, h, h, order=5, impl="xla")))
    rows.append(("kernel_dpd_branch_32k", us, f"{L/us:.0f} samples/us"))

    from repro.kernels.flash_attention import flash_attention
    q = jnp.asarray(rng.normal(size=(1, 512, 8, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 512, 2, 64)), jnp.bfloat16)
    us = _time_us(lambda: jax.block_until_ready(
        flash_attention(q, k, k, impl="xla")))
    rows.append(("kernel_flash_attn_512_ref", us, "GQA 8q/2kv hd64"))

    from repro.kernels.ssd import ssd
    x = jnp.asarray(rng.normal(size=(1, 512, 8, 64)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (1, 512, 8)), jnp.float32)
    A = -jnp.ones((8,), jnp.float32)
    B_ = jnp.asarray(rng.normal(size=(1, 512, 64)), jnp.float32)
    us = _time_us(lambda: jax.block_until_ready(
        ssd(x, dt, A, B_, B_, chunk=128, impl="xla")[0]))
    rows.append(("kernel_ssd_512_ref", us, "chunked jnp path"))

    from repro.kernels.rglru import rglru
    la = jnp.asarray(-rng.uniform(0.01, 2.0, (1, 512, 256)), jnp.float32)
    gx = jnp.asarray(rng.normal(size=(1, 512, 256)), jnp.float32)
    us = _time_us(lambda: jax.block_until_ready(rglru(la, gx, impl="xla")[0]))
    rows.append(("kernel_rglru_512_ref", us, "associative-scan path"))
    return rows
