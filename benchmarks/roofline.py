"""Roofline report generator.

Two sources feed it: the per-(arch x shape) model-level table read from
``results/dryrun.json`` (per-cell dominant-term analysis used in
EXPERIMENTS.md §Roofline — empty when no dry-run has been exported), and
the *actor-level* rows computed live from a compiled paper graph's
``Program.stats()`` (``actor_roofline_rows``): per-actor operational
intensity (FLOPs per firing over Eq. 1 window bytes), always exercised
so the section cannot rot when ``dryrun.json`` is absent."""
from __future__ import annotations

import json
import os
import sys
from typing import List, Tuple

if __package__ in (None, ""):   # script invocation: PYTHONPATH=src is enough
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

Row = Tuple[str, float, str]

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun.json")


def load(path: str = RESULTS):
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)


def _advice(r) -> str:
    """One sentence: what would move the dominant term down."""
    b = r["bottleneck"]
    shape = r["shape"]
    coll = r.get("collective_bytes_per_device", {})
    top_coll = max((k for k in coll if k != "total"),
                   key=lambda k: coll[k], default=None)
    if b == "collective_s":
        if r["arch"].find("moe") >= 0 or r["arch"].find("olmoe") >= 0:
            return ("local (per-data-shard) dispatch keeps the rank-cumsum "
                    "and scatter on-shard — only the expert einsum "
                    "communicates (§Perf: 39x compute / collective wins)")
        return (f"dominant collective is {top_coll}; overlap it with the "
                f"next microbatch's compute or re-shard to remove it")
    if b == "memory_s":
        if shape in ("decode_32k", "long_500k"):
            return ("decode reads the whole KV ring per token: int8 KV "
                    "(2.8x) + sequence-sharding the ring over the idle "
                    "model axis (3.8x total, §Perf)")
        if shape == "train_4k":
            return ("activation traffic dominates (CPU cost model overstates "
                    "absolute bytes): microbatching cuts peak temp ~2.7x "
                    "(§Perf); on TPU, fused remat brings the term toward the "
                    "compute roof")
        return ("prefill activation traffic: larger attention blocks / "
                "Pallas flash kernel keep the working set in VMEM")
    return ("compute-bound — at the roof for this sharding; next levers are "
            "kernel-level (Pallas attention/SSD) and per-chip batch size")


def fmt_table(records, mesh: str = "16x16") -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | bottleneck "
        "| MODEL/HLO | step bound s |",
        "|---|---|---|---|---|---|---|---|",
    ]
    notes = []
    for r in records:
        if r.get("mesh") != mesh or r.get("variant", "base") != "base":
            continue
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped | — | — |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | |")
            continue
        t = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3g} | "
            f"{t['memory_s']:.3g} | {t['collective_s']:.3g} | "
            f"{r['bottleneck'].replace('_s', '')} | "
            f"{r['useful_flops_frac']:.2f} | {r['step_time_bound_s']:.3g} |")
        notes.append(f"* **{r['arch']} × {r['shape']}** — {_advice(r)}")
    return "\n".join(lines) + "\n\n" + "\n".join(notes)


def actor_roofline_rows() -> List[Row]:
    """Per-actor intensity rows from a live compiled DPD program.

    The x-coordinate of an actor-level roofline: ``cost_flops`` per
    firing over the bytes its ports move per firing (both straight from
    ``Program.stats()``), plus the firing counts of one run so the rows
    double as a weighting for the profile-driven partition cut."""
    from repro.core import ExecutionPlan
    from repro.graphs.factories import make_dpd

    net, _ = make_dpd(n_firings=4, block_l=256)
    prog = net.compile(ExecutionPlan(mode="dynamic", donate=False))
    res = prog.run()
    st = prog.stats()
    rows: List[Row] = []
    for nm in sorted(st.actor_intensity,
                     key=st.actor_intensity.get, reverse=True):
        rows.append((f"actor_roofline_dpd_{nm}", 0.0,
                     f"intensity={st.actor_intensity[nm]:.4g} flop/B "
                     f"({st.actor_flops[nm]} flop / "
                     f"{st.actor_window_bytes[nm]} B per firing, "
                     f"{int(res.fire_counts[nm])} firings)"))
    rows.append(("actor_roofline_dpd_iteration_flops", 0.0,
                 f"{st.iteration_flops} flop per graph iteration"))
    return rows


def bench_roofline() -> List[Row]:
    records = load()
    rows: List[Row] = actor_roofline_rows()
    ok = [r for r in records if r["status"] == "ok" and r["mesh"] == "16x16"]
    for r in ok:
        t = r["roofline"]
        rows.append((f"roofline_{r['arch']}_{r['shape']}", 0.0,
                     f"bottleneck={r['bottleneck'].replace('_s','')} "
                     f"bound={r['step_time_bound_s']:.3g}s "
                     f"useful={r['useful_flops_frac']:.2f}"))
    n_multi = sum(1 for r in records
                  if r["mesh"] == "2x16x16" and r["status"] == "ok")
    n_skip = sum(1 for r in records
                 if r["mesh"] == "16x16" and r["status"] == "skipped")
    rows.append(("dryrun_cells_ok_single", 0.0, str(len(ok))))
    rows.append(("dryrun_cells_skipped_single", 0.0,
                 f"{n_skip} (documented long_500k exclusions)"))
    rows.append(("dryrun_cells_ok_multi", 0.0, str(n_multi)))
    # Hillclimb summary rows if present.
    hc = os.path.join(os.path.dirname(RESULTS), "hillclimb.json")
    if os.path.exists(hc):
        with open(hc) as f:
            hrs = [r for r in json.load(f) if r.get("status") == "ok"]
        for r in hrs:
            t = r["roofline"]
            rows.append((f"hillclimb_{r['arch']}_{r['shape']}_{r['variant']}",
                         0.0,
                         f"compute={t['compute_s']:.3g}s "
                         f"memory={t['memory_s']:.3g}s "
                         f"collective={t['collective_s']:.3g}s"))
    return rows


if __name__ == "__main__":
    print(fmt_table(load()))
