"""Sharded-executor benchmarks: ``ExecutionPlan(devices=k)`` vs the
single-device dynamic executor it is bit-identical to.

For DPD and MoE-as-actors, times the mesh-sharded dynamic executor at
``devices`` in 1/2/4 and reports the sharding structure from
``Program.stats``: barrier rounds (each one progress all-reduce),
``collective_bytes_per_sweep`` (the crossing rings + cursor pairs every
barrier exchange moves — the collective analogue of the grid
megakernel's shared-scratch polling surface) and the device partition.
Bit-identity (states + fire counts vs ``devices=1``) is checked inline
and committed as a structure field, so a silent divergence fails
``check_regression.py`` exactly like a sweep-count drift.

The parent process keeps its single CPU device (check_regression runs
suites in-process), so the measurement runs in a child process under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``; the child
writes the JSON records, the parent forms the human rows from them.

Caveat printed with the numbers: the forced host "mesh" is one CPU —
rows measure the collective schedule's overhead (ppermute exchanges +
quiescence all-reduces per round), not a parallel speedup.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import List, Tuple

if __package__ in (None, ""):   # script invocation: PYTHONPATH=src is enough
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

Row = Tuple[str, float, str]

DEVICES = (1, 2, 4)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
JSON_PATH = os.path.join(REPO_ROOT, "BENCH_shard.json")

_CHILD = """
import json, os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
json_path, fast = sys.argv[1], sys.argv[2] == "1"

import jax
from benchmarks.bench_executors import _interleaved_medians
from repro.core import ExecutionPlan
from repro.graphs.factories import make_dpd, make_moe, states_identical

reps = 3 if fast else 7
if fast:
    workloads = [
        ("dpd", *make_dpd(n_firings=4, block_l=512, seed=1), 4),
        ("moe", *make_moe(n_firings=3, n_tokens=16, d_model=32), 3),
    ]
else:
    workloads = [
        ("dpd", *make_dpd(n_firings=6, block_l=4096, seed=1), 6),
        ("moe", *make_moe(n_firings=4, n_tokens=64, d_model=64,
                          d_ff=128), 4),
    ]

records = []
for gname, net, n_iter, tokens in workloads:
    progs = {k: net.compile(ExecutionPlan(mode="dynamic", devices=k,
                                          donate=False))
             for k in (1, 2, 4)}
    runs = {k: p.run() for k, p in progs.items()}
    ref = runs[1]
    ref_counts = {n: int(v) for n, v in ref.fire_counts.items()}
    med = _interleaved_medians(
        {f"dev{k}": (lambda p=p: jax.block_until_ready(p.run().state))
         for k, p in progs.items()}, reps)
    for k in (1, 2, 4):
        r, st = runs[k], progs[k].stats()
        identical = (states_identical(ref.state, r.state)
                     and {n: int(v) for n, v in r.fire_counts.items()}
                     == ref_counts)
        rec = {"name": f"shard_{gname}_dev{k}",
               "us_per_call": round(med[f"dev{k}"] * 1e6, 1),
               "tokens_per_s": round(tokens / med[f"dev{k}"], 1),
               "devices": k, "rounds": int(r.sweeps),
               "bit_identical": bool(identical)}
        if k > 1:
            rec["collective_bytes_per_sweep"] = int(
                st.collective_bytes_per_sweep)
        records.append(rec)

with open(json_path, "w") as f:
    json.dump(records, f, indent=2)
"""


def bench_shard(fast: bool = False, json_path: str = JSON_PATH) -> List[Row]:
    env = dict(os.environ,
               PYTHONPATH=os.pathsep.join(
                   [REPO_ROOT, os.path.join(REPO_ROOT, "src")]))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _CHILD, json_path, "1" if fast else "0"],
        capture_output=True, text=True, timeout=3600, env=env)
    if out.returncode != 0:
        raise RuntimeError(
            f"bench_shard child failed:\n{out.stdout}\n{out.stderr}")
    with open(json_path) as f:
        records = json.load(f)

    rows: List[Row] = []
    by_name = {r["name"]: r for r in records}
    for rec in records:
        if rec["devices"] == 1:
            derived = (f"{rec['rounds']} sweeps, host dynamic reference, "
                       f"bit-identical: {rec['bit_identical']}")
        else:
            derived = (f"{rec['rounds']} rounds, {rec['devices']} devices, "
                       f"{rec['collective_bytes_per_sweep']} B/round "
                       f"collective, bit-identical: {rec['bit_identical']}")
        rows.append((rec["name"], rec["us_per_call"], derived))
    for gname in ("dpd", "moe"):
        d1 = by_name.get(f"shard_{gname}_dev1")
        if d1 is None:
            continue
        ratios = []
        for k in DEVICES[1:]:
            dk = by_name[f"shard_{gname}_dev{k}"]
            ratios.append(f"dev{k} {d1['us_per_call'] / dk['us_per_call']:.2f}x")
        rows.append((f"shard_{gname}_vs_dynamic", 0.0,
                     ", ".join(ratios) + " vs 1-device (forced host mesh; "
                     "collective-schedule overhead, not parallel speedup)"))
    return rows


if __name__ == "__main__":
    for name, us, derived in bench_shard(fast="--fast" in sys.argv):
        print(f"{name:36s} {us:10.1f} us  {derived}")
