"""Resilience benchmarks: what the PR 10 serving-resilience layer costs
and what it buys, under open-loop Poisson overload with injected faults.

Rows (all on the admission/decode/retire serving network of
``repro.graphs.serving``, host-dynamic plan, ``eos_id=None`` so budgets
— not token values — decide retirement and every count is seed-
deterministic):

  * ``resil_baseline`` — no deadlines, unbounded queue: every request
    completes; the throughput yardstick.
  * ``resil_deadline_light`` / ``resil_deadline_tight`` — per-request
    deadlines of ``arrival + allowance`` under a bursty Poisson trace.
    Expired or queue-overflowed requests retire as rate-0 shed firings
    (status timeout/shed); *goodput* is completed-request tokens only,
    and each cell reports p50/p99 completed-request latency in decode
    steps (seed-exact, gated as structure fields).
    The acceptance claim is the proportionality row: the goodput
    fraction tracks 1 - shed fraction, i.e. shedding costs the work
    shed and nothing more (no head-of-line blocking from doomed
    requests).
  * ``resil_quarantine`` — one poisoned request (out-of-domain prompt,
    DOMAIN write guard) under ``generate(on_fault="quarantine")``: the
    cost of fault-map + survivor re-run, vs the survivors run clean.
  * ``resil_ckpt_off`` / ``resil_ckpt_every_2`` / ``resil_ckpt_every_8``
    — ``run_checkpointed`` durability cadence sweep: segmented
    execution plus CRC'd atomic snapshots vs one plain ``run()``.

Timing rows are medians of interleaved reps (same discipline as
``bench_executors``); every structural field (status counts, shed/
goodput fractions, sweeps, segments) is exact and gates in
``check_regression.py``.  CPU caveat: numbers measure scheduling + I/O
structure, not accelerator kernel perf.

Writes ``BENCH_resilience.json`` (``name``/``us_per_call``/
``tokens_per_s`` + structure fields) for the bench-regression gate.
"""
from __future__ import annotations

import json
import os
import shutil
import sys
import tempfile
from typing import Dict, List, Tuple

if __package__ in (None, ""):   # script invocation: PYTHONPATH=src is enough
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import numpy as np

from repro.configs import smoke_config
from repro.core import ExecutionPlan
from repro.graphs.serving import (STATUS_OK, STATUS_SHED, STATUS_TIMEOUT,
                                  poisson_trace)
from repro.models import init_params
from repro.serve import ActorEngine, Request, ServeConfig

Row = Tuple[str, float, str]

JSON_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_resilience.json")

POISON = -(2 ** 20)


def _status_counts(status: np.ndarray) -> Dict[str, int]:
    return {"n_ok": int((status == STATUS_OK).sum()),
            "n_timeout": int((status == STATUS_TIMEOUT).sum()),
            "n_shed": int((status == STATUS_SHED).sum())}


def bench_resilience(fast: bool = False,
                     json_path: str = JSON_PATH) -> List[Row]:
    from benchmarks.bench_executors import _interleaved_medians

    reps = 3 if fast else 5
    rows: List[Row] = []
    records: List[Dict] = []

    def record(name: str, dt: float, tokens: int, derived: str,
               **structure) -> None:
        rows.append((name, dt * 1e6, derived))
        records.append({"name": name, "us_per_call": round(dt * 1e6, 1),
                        "tokens_per_s": round(tokens / dt, 1), **structure})

    # ---- workload: Poisson overload, variable budgets ------------------
    if fast:
        R, scfg = 6, ServeConfig(batch_size=2, max_prompt=8, max_new=6,
                                 eos_id=None)
    else:
        R, scfg = 12, ServeConfig(batch_size=2, max_prompt=12, max_new=8,
                                  eos_id=None)
    cfg = smoke_config("granite-8b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(9)
    budgets = np.array([scfg.max_new if i % 2 == 0 else 2
                        for i in range(R)])
    reqs = [Request(prompt=rng.integers(1, cfg.vocab,
                                        size=scfg.max_prompt - 1 - (i % 3))
                    .astype(np.int32), max_new=int(budgets[i]))
            for i in range(R)]
    # B=2 slots with a fast arrival trace: a deep backlog forms, so tight
    # deadlines expire waiting requests instead of merely trimming tails.
    arrivals = poisson_trace(R, rate=2.0, seed=7)
    eng = ActorEngine(cfg, params, scfg)

    def staged_run(deadlines):
        net = eng.build_network(reqs, arrivals=arrivals, deadlines=deadlines)
        prog = net.compile(eng.plan)
        res = prog.run()
        sink = prog.collect("retire", res.state)
        return prog, res, sink

    def goodput_tokens(sink) -> int:
        status = np.asarray(sink["status"])
        lens = np.asarray(sink["lens"])
        return int(lens[status == STATUS_OK].sum())

    cells = {"baseline": None}
    if fast:
        cells["deadline_light"] = arrivals + 24
        cells["deadline_tight"] = arrivals + 8
    else:
        cells["deadline_light"] = arrivals + 40
        cells["deadline_tight"] = arrivals + 12

    progs, telem = {}, {}
    for name, dls in cells.items():
        prog, res, sink = staged_run(dls)
        progs[name] = prog
        telem[name] = (res, sink)
    med = _interleaved_medians(
        {name: (lambda p=progs[name]: jax.block_until_ready(p.run().state))
         for name in cells}, reps)

    base_good = goodput_tokens(telem["baseline"][1])
    for name in cells:
        res, sink = telem[name]
        status = np.asarray(sink["status"])
        counts = _status_counts(status)
        good = goodput_tokens(sink)
        shed_frac = (counts["n_timeout"] + counts["n_shed"]) / R
        # Completed-request latency in decode steps (admission -> retire);
        # seed-exact, so both percentiles gate as structure fields.
        lat = np.asarray(sink["lat"])[status == STATUS_OK]
        record(f"resil_{name}", med[name], max(good, 1),
               f"{counts['n_ok']}/{R} completed, goodput {good} tokens "
               f"over {int(res.sweeps)} sweeps, p50/p99 "
               f"{int(np.percentile(lat, 50))}/{int(np.percentile(lat, 99))}"
               " steps",
               sweeps=int(res.sweeps), total_requests=R, **counts,
               goodput_tokens=good,
               shed_fraction=round(shed_frac, 3),
               goodput_fraction=round(good / base_good, 3),
               p50_latency_steps=int(np.percentile(lat, 50)),
               p99_latency_steps=int(np.percentile(lat, 99)))
    lt = next(r for r in records if r["name"] == "resil_deadline_tight")
    rows.append(("resil_goodput_proportional", 0.0,
                 f"shed fraction {lt['shed_fraction']} -> goodput fraction "
                 f"{lt['goodput_fraction']}; degrades proportionally: "
                 f"{abs((1 - lt['shed_fraction']) - lt['goodput_fraction']) <= 0.35}"))

    # ---- quarantine: fault-map + survivor re-run cost ------------------
    qr = min(4, R)
    qreqs = [Request(prompt=np.asarray(r.prompt), max_new=r.max_new)
             for r in reqs[:qr]]
    bad = list(qreqs)
    bad[1] = Request(prompt=np.full(4, POISON, np.int32),
                     max_new=int(budgets[1]))
    geng = ActorEngine(cfg, params, scfg,
                       plan=ExecutionPlan(mode="dynamic", guards=True))
    qmed = _interleaved_medians({
        "clean": lambda: geng.generate(
            [q for j, q in enumerate(qreqs) if j != 1]),
        "quarantine": lambda: geng.generate(bad, on_fault="quarantine"),
    }, reps)
    out = geng.generate(bad, on_fault="quarantine")
    surv_tokens = sum(len(r.tokens) for r in out)
    record("resil_survivors_clean", qmed["clean"], max(surv_tokens, 1),
           f"{qr - 1} survivors run clean (quarantine oracle)",
           requests=qr - 1, survivor_tokens=surv_tokens)
    record("resil_quarantine", qmed["quarantine"], max(surv_tokens, 1),
           f"1 poisoned of {qr} quarantined in {geng.last_retries} "
           f"retry(ies), {surv_tokens} survivor tokens",
           requests=qr, n_fault=geng.last_status.count("fault"),
           retries=geng.last_retries, survivor_tokens=surv_tokens)
    rows.append(("resil_quarantine_overhead", 0.0,
                 f"{qmed['quarantine'] / qmed['clean']:.2f}x vs survivors "
                 "clean (fault run + rebuild + re-run)"))

    # ---- durable checkpoint cadence sweep ------------------------------
    net = eng.build_network(reqs, arrivals=arrivals)
    prog = net.compile(eng.plan)
    ref = prog.run()
    sweeps = int(ref.sweeps)
    total_tokens = int(budgets.sum())
    ckroot = tempfile.mkdtemp(prefix="bench_resil_ck_")
    cprog = net.compile(eng.plan)     # segment twins cache inside
    try:
        def ckpt_run(every, tag):
            d = os.path.join(ckroot, tag)
            shutil.rmtree(d, ignore_errors=True)
            return jax.block_until_ready(
                cprog.run_checkpointed(d, every_sweeps=every).state)

        cad = {"off": lambda: jax.block_until_ready(prog.run().state),
               "every_2": lambda: ckpt_run(2, "e2"),
               "every_8": lambda: ckpt_run(8, "e8")}
        cmed = _interleaved_medians(cad, reps)
        record("resil_ckpt_off", cmed["off"], total_tokens,
               f"plain run, {sweeps} sweeps", sweeps=sweeps)
        for every in (2, 8):
            segs = -(-sweeps // every)
            record(f"resil_ckpt_every_{every}", cmed[f"every_{every}"],
                   total_tokens,
                   f"{segs} segments, CRC'd snapshot each",
                   sweeps=sweeps, segments=segs, every_sweeps=every)
        rows.append(("resil_ckpt_overhead", 0.0,
                     f"every_2 {cmed['every_2'] / cmed['off']:.2f}x, "
                     f"every_8 {cmed['every_8'] / cmed['off']:.2f}x vs "
                     "plain run (segment re-entry + snapshot I/O)"))
    finally:
        shutil.rmtree(ckroot, ignore_errors=True)

    with open(json_path, "w") as f:
        json.dump(records, f, indent=2)
        f.write("\n")
    rows.append(("resil_bench_json", 0.0, json_path))
    return rows


if __name__ == "__main__":
    fast = "--fast" in sys.argv
    print("name,us_per_call,derived")
    for name, us, derived in bench_resilience(fast=fast):
        print(f"{name},{us:.1f},{derived}")
