"""Benchmark harness: one section per paper table + kernels + roofline.

Prints ``name,us_per_call,derived`` CSV rows (harness contract).

Invocation (the one used by CI, EXPERIMENTS.md and the verify skill):
``PYTHONPATH=src python benchmarks/run.py`` — the scripts bootstrap the
repo root onto ``sys.path`` themselves, so ``PYTHONPATH=src`` alone is
enough for every bench entrypoint.
"""
from __future__ import annotations

import os
import sys

if __package__ in (None, ""):   # script invocation: PYTHONPATH=src is enough
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def main() -> None:
    rows = []
    from benchmarks.bench_paper_tables import (bench_buffers, bench_dpd,
                                               bench_motion_detection)
    from benchmarks.bench_executors import bench_executors
    from benchmarks.bench_kernels import bench_kernels
    from benchmarks.bench_megakernel import bench_megakernel
    from benchmarks.bench_serving import bench_serving
    from benchmarks.roofline import bench_roofline

    sections = [
        ("Table 1 (buffer memory)", bench_buffers),
        ("Table 3 (Motion Detection)", bench_motion_detection),
        ("Table 4 (DPD + 5x claim)", bench_dpd),
        ("Executors (specialization + multi-firing)", bench_executors),
        ("Megakernel (device-resident dynamic scheduling)", bench_megakernel),
        ("Serving (continuous batching on the actor runtime)", bench_serving),
        ("Kernels", bench_kernels),
        ("Roofline (from dry-run)", bench_roofline),
    ]
    print("name,us_per_call,derived")
    for title, fn in sections:
        print(f"# --- {title} ---", file=sys.stderr)
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.1f},{derived}")
        except Exception as e:  # noqa: BLE001
            print(f"{title}_ERROR,0,{type(e).__name__}: {e}")


if __name__ == "__main__":
    main()
