"""Benchmarks reproducing the paper's tables (CPU analogues).

Mapping of the paper's hardware columns onto this container (DESIGN.md §2):
  * "MC" (multicore GPP, per-actor threads)  -> interpreted executor
    (one jitted dispatch per actor firing, no cross-actor fusion);
  * "Heterog." (GPU-accelerated)             -> compiled executor
    (whole network fused into one XLA program, token rate raised to 4 for
    MD exactly as the paper does).
The *ratios* are the reproduction target; absolute fps are CPU numbers.
"""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax
import numpy as np

from repro.graphs.dpd import BLOCK_L, build_dpd
from repro.graphs.factories import make_dpd, make_motion_detection
from repro.graphs.motion_detection import build_motion_detection

Row = Tuple[str, float, str]


def _time(fn: Callable[[], None], reps: int = 3) -> float:
    fn()  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


# --------------------------------------------------------------------------- #
# Paper Table 1: communication-buffer memory.
# --------------------------------------------------------------------------- #
def bench_buffers() -> List[Row]:
    rows = []
    md_mc = build_motion_detection(8, rate=1).buffer_bytes() / 1e6
    md_het = build_motion_detection(8, rate=4).buffer_bytes() / 1e6
    dpd = build_dpd(4).buffer_bytes() / 1e6
    rows.append(("table1_md_mc_MB", 0.0, f"{md_mc:.3f} (paper prop.: 0.85)"))
    rows.append(("table1_md_heterog_MB", 0.0, f"{md_het:.3f} (paper prop.: 3.46)"))
    rows.append(("table1_dpd_MB", 0.0, f"{dpd:.3f} (paper prop.: 11.5)"))
    return rows


# --------------------------------------------------------------------------- #
# Paper Table 3: Motion Detection throughput (fps).
# --------------------------------------------------------------------------- #
def bench_motion_detection(n_frames: int = 24) -> List[Row]:
    # Shared factory (same seed -> same staged video for both rates).
    net1, _ = make_motion_detection(n_frames, rate=1, seed=0)
    rows: List[Row] = []

    # "MC": interpreted per-actor execution, rate 1 (paper: GPP rate 1).
    interp = net1.compile(mode="interpreted", n_iterations=n_frames)
    st1 = net1.init_state()
    dt = _time(lambda: jax.block_until_ready(
        interp.run(st1).state.actor("sink")[0]), reps=1)
    fps_mc = n_frames / dt
    rows.append(("table3_md_interpreted_mc_fps", dt / n_frames * 1e6,
                 f"{fps_mc:.0f} fps (paper MC: 485-1138)"))

    # "Heterog": whole network compiled, rate 4 (paper's GPU token rate).
    net4, _ = make_motion_detection(n_frames, rate=4, seed=0)
    run4 = net4.compile(mode="static", n_iterations=n_frames // 4)
    st4 = net4.init_state()
    dt = _time(lambda: jax.block_until_ready(
        run4.run(st4).state.actor("sink")[0]))
    fps_het = n_frames / dt
    rows.append(("table3_md_compiled_heterog_fps", dt / n_frames * 1e6,
                 f"{fps_het:.0f} fps (paper heterog: 4614-6063)"))
    rows.append(("table3_md_speedup", 0.0,
                 f"{fps_het / fps_mc:.1f}x compiled/interpreted "
                 f"(paper: 9.5x GPU/MC)"))
    return rows


# --------------------------------------------------------------------------- #
# Paper Table 4 + the 5x claim: DPD throughput (Msamples/s).
# --------------------------------------------------------------------------- #
def bench_dpd(n_firings: int = 8, block_l: int = BLOCK_L) -> List[Row]:
    # All variants share the seed-1 factory signal (one construction).
    def dpd(**kw):
        return make_dpd(n_firings, block_l=block_l, seed=1, **kw)[0]

    samples = n_firings * block_l
    rows: List[Row] = []

    def throughput(net, compiled=True) -> float:
        mode = "static" if compiled else "interpreted"
        prog = net.compile(mode=mode, n_iterations=n_firings)
        st = net.init_state()
        dt = _time(lambda: jax.block_until_ready(
            prog.run(st).state.actor("sink")[0]),
            reps=3 if compiled else 1)
        return samples / dt / 1e6

    # MC analogue: interpreted dynamic graph (avg ~6 filters active).
    mixed = np.array([2, 10, 5, 7, 3, 9, 2, 10][:n_firings], np.int32)
    net_mc = dpd(active_schedule=mixed)
    ms_mc = throughput(net_mc, compiled=False)
    rows.append(("table4_dpd_interpreted_mc_Msps", 0.0,
                 f"{ms_mc:.1f} Msamples/s (paper MC: 7-33)"))

    # DAL-GPU analogue is impossible for dynamic rates (paper: n/a): the
    # static rewrite (all 10 branches always on) is what DAL would need.
    net_static = dpd(static_all_active=True)
    ms_static = throughput(net_static)
    rows.append(("table4_dpd_compiled_static_all10_Msps", 0.0,
                 f"{ms_static:.1f} Msamples/s (DAL-style: every branch computed)"))

    # Proposed: dynamic rates on the accelerated path.
    for label, sched in [("min_active2", np.full(n_firings, 2, np.int32)),
                         ("mixed", mixed),
                         ("all10", np.full(n_firings, 10, np.int32))]:
        net = dpd(active_schedule=sched)
        ms = throughput(net)
        rows.append((f"table4_dpd_compiled_dynamic_{label}_Msps", 0.0,
                     f"{ms:.1f} Msamples/s"))
        if label == "min_active2":
            rows.append(("table4_dpd_dynamic_speedup_vs_static", 0.0,
                         f"{ms / ms_static:.1f}x at n_active=2 wall-clock "
                         f"(paper claim: up to 5x; see flops row)"))
        if label == "mixed":
            rows.append(("table4_dpd_compiled_vs_interpreted", 0.0,
                         f"{ms / ms_mc:.1f}x (paper GPU/MC: 2.6-5.4x)"))

    # Upper bound of the dynamic win on this host: structurally-2-branch
    # vs structurally-10-branch static graphs (no dynamic machinery at
    # all).  The gap between this ratio and the dynamic n_active=2 ratio
    # above is the cost of XLA's *functional* conds still moving rate-r
    # windows for disabled ports — analysis in EXPERIMENTS.md §Perf.
    net2 = dpd(n_branches=2, static_all_active=True)
    ms2 = throughput(net2)
    rows.append(("table4_dpd_structural_2branch_Msps", 0.0,
                 f"{ms2:.1f} Msamples/s -> {ms2 / ms_static:.1f}x vs 10-branch "
                 f"(compute-skip upper bound on this CPU; paper: 5x on "
                 f"compute-bound GPUs)"))
    return rows
