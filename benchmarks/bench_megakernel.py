"""Megakernel benchmarks: the device-resident scheduler vs the host-built
executors.

For the two genuinely dynamic-rate paper graphs — DPD (rate-0 branch
firings) and MoE-as-actors (idle experts) — times the persistent-Pallas
megakernel (``ExecutionPlan(mode=MEGAKERNEL)``, interpret mode on CPU)
against the token-driven dynamic executor it is bit-identical to and the
specialized static executor, and records the device-residency split
(scratch vs HBM bytes) from ``Program.stats``.

Bit-identity is *checked inline* (states, fire counts, sweeps) so a
silent divergence fails the bench contract, exactly like the dynamic
sweep-reduction rows in bench_executors.  Besides the CSV rows, writes
``BENCH_megakernel.json``: ``{name, us_per_call, tokens_per_s}`` per
executor x graph.

Caveat printed with the numbers: on CPU the megakernel runs in Pallas
*interpret* mode — the comparison measures the scheduling structure, not
a compiled-kernel win; the Mosaic TPU path is a ROADMAP open item.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Tuple

import jax

from repro.core import MEGAKERNEL, ExecutionPlan
from repro.graphs.factories import make_dpd, make_moe, states_identical

Row = Tuple[str, float, str]

JSON_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_megakernel.json")


def bench_megakernel(fast: bool = False,
                     json_path: str = JSON_PATH) -> List[Row]:
    from benchmarks.bench_executors import _interleaved_medians

    reps = 3 if fast else 7
    rows: List[Row] = []
    records: List[Dict] = []

    def record(name: str, dt: float, tokens: int, derived: str) -> None:
        rows.append((name, dt * 1e6, derived))
        records.append({"name": name, "us_per_call": round(dt * 1e6, 1),
                        "tokens_per_s": round(tokens / dt, 1)})

    if fast:
        workloads = [
            ("dpd", *make_dpd(n_firings=4, block_l=512, seed=1), 4),
            ("moe", *make_moe(n_firings=3, n_tokens=16, d_model=32), 3),
        ]
    else:
        workloads = [
            ("dpd", *make_dpd(n_firings=6, block_l=4096, seed=1), 6),
            ("moe", *make_moe(n_firings=4, n_tokens=64, d_model=64,
                              d_ff=128), 4),
        ]

    for gname, net, n_iter, tokens in workloads:
        # donate=False: time the executors, not the auto-donation copy.
        dyn = net.compile(ExecutionPlan(mode="dynamic", donate=False))
        mega = net.compile(ExecutionPlan(mode=MEGAKERNEL))
        static = net.compile(mode="static", n_iterations=n_iter,
                             donate=False)

        rd, rm = dyn.run(), mega.run()
        identical = (states_identical(rd.state, rm.state)
                     and {k: int(v) for k, v in rd.fire_counts.items()}
                     == {k: int(v) for k, v in rm.fire_counts.items()}
                     and int(rd.sweeps) == int(rm.sweeps))

        med = _interleaved_medians({
            "dyn": lambda: jax.block_until_ready(dyn.run().state),
            "mega": lambda: jax.block_until_ready(mega.run().state),
            "static": lambda: jax.block_until_ready(static.run().state),
        }, reps)
        record(f"mega_{gname}_dynamic_host", med["dyn"], tokens,
               f"{int(rd.sweeps)} sweeps")
        record(f"mega_{gname}_megakernel", med["mega"], tokens,
               f"{int(rm.sweeps)} sweeps, interpret mode")
        record(f"mega_{gname}_static_specialized", med["static"], tokens,
               "fused scan reference")
        rows.append((f"mega_{gname}_vs_dynamic", 0.0,
                     f"{med['dyn'] / med['mega']:.2f}x vs host dynamic "
                     f"(interpret-mode CPU; structure not kernel perf), "
                     f"bit-identical: {identical}"))
        st = mega.stats()
        rows.append((f"mega_{gname}_scratch_bytes", 0.0,
                     f"{st.scratch_bytes} scratch ({st.transient_scratch_bytes}"
                     f" transient-reclaimable) vs {st.hbm_state_bytes} HBM "
                     f"operands"))

    with open(json_path, "w") as f:
        json.dump(records, f, indent=2)
        f.write("\n")
    rows.append(("mega_bench_json", 0.0, json_path))
    return rows


if __name__ == "__main__":
    import sys
    fast = "--fast" in sys.argv
    print("name,us_per_call,derived")
    for name, us, derived in bench_megakernel(fast=fast):
        print(f"{name},{us:.1f},{derived}")
