"""Megakernel benchmarks: the device-resident scheduler vs the host-built
executors, including the grid-parallel multi-core sweeps.

For the two genuinely dynamic-rate paper graphs — DPD (rate-0 branch
firings) and MoE-as-actors (idle experts) — times the persistent-Pallas
megakernel (``ExecutionPlan(mode=MEGAKERNEL)``, interpret mode on CPU)
against the token-driven dynamic executor it is bit-identical to and the
specialized static executor, then sweeps the grid partition counts
(``cores`` in 1/2/4): per-core-count round (sweep) counts, tok/s, and
the private-vs-shared ring byte split from ``Program.stats``.

Bit-identity is *checked inline* (states, fire counts — and sweeps for
the single-core kernel) so a silent divergence fails the bench contract,
exactly like the dynamic sweep-reduction rows in bench_executors.
Besides the CSV rows, writes ``BENCH_megakernel.json``: ``{name,
us_per_call, tokens_per_s}`` per executor x graph, with ``sweeps`` /
``cores`` / ``scratch_bytes`` / ``shared_scratch_bytes`` /
``forwarded_fifos`` structure fields on the kernel rows (compared
exactly by ``benchmarks/check_regression.py`` — a scratch or
forwarding regression fails CI like a sweep-count drift does).  The
``mega_*_megakernel_guarded`` row times the in-kernel health layer
(``ExecutionPlan(guards=True)``) against the unguarded kernel, inline-
checking that the clean guarded run stays bit-identical and fault-free.
The ``mega_*_megakernel_traced`` row does the same for the in-kernel
trace ring (``ExecutionPlan(trace=True)``): a traced run must stay
bit-identical, its recorded firings must equal ``fire_counts``, and its
overhead is gated by the committed baseline.

Caveat printed with the numbers: on CPU the megakernel runs in Pallas
*interpret* mode — the comparison measures the scheduling structure, not
a compiled-kernel win, and the grid partition loop runs sequentially
(fixed partition-order tie-break), so multi-core rows measure the
partitioned schedule's overhead, not a parallel speedup; the Mosaic /
Megacore path is a ROADMAP open item.
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List, Tuple

if __package__ in (None, ""):   # script invocation: PYTHONPATH=src is enough
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax

from repro.core import MEGAKERNEL, ExecutionPlan
from repro.graphs.factories import make_dpd, make_moe, states_identical

Row = Tuple[str, float, str]

GRID_CORES = (1, 2, 4)

JSON_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_megakernel.json")


def bench_megakernel(fast: bool = False,
                     json_path: str = JSON_PATH) -> List[Row]:
    from benchmarks.bench_executors import _interleaved_medians

    reps = 3 if fast else 7
    rows: List[Row] = []
    records: List[Dict] = []

    def record(name: str, dt: float, tokens: int, derived: str,
               **structure) -> None:
        rows.append((name, dt * 1e6, derived))
        records.append({"name": name, "us_per_call": round(dt * 1e6, 1),
                        "tokens_per_s": round(tokens / dt, 1), **structure})

    if fast:
        workloads = [
            ("dpd", *make_dpd(n_firings=4, block_l=512, seed=1), 4),
            ("moe", *make_moe(n_firings=3, n_tokens=16, d_model=32), 3),
        ]
    else:
        workloads = [
            ("dpd", *make_dpd(n_firings=6, block_l=4096, seed=1), 6),
            ("moe", *make_moe(n_firings=4, n_tokens=64, d_model=64,
                              d_ff=128), 4),
        ]

    for gname, net, n_iter, tokens in workloads:
        # donate=False: time the executors, not the auto-donation copy.
        dyn = net.compile(ExecutionPlan(mode="dynamic", donate=False))
        static = net.compile(mode="static", n_iterations=n_iter,
                             donate=False)
        grid = {c: net.compile(ExecutionPlan(mode=MEGAKERNEL, cores=c))
                for c in GRID_CORES}
        mega = grid[1]
        guarded = net.compile(ExecutionPlan(mode=MEGAKERNEL, guards=True))
        traced = net.compile(ExecutionPlan(mode=MEGAKERNEL, trace=True))

        rd = dyn.run()
        grid_runs = {c: p.run() for c, p in grid.items()}
        rm = grid_runs[1]
        rg = guarded.run()
        rt = traced.run()
        guard_clean = (states_identical(rm.state, rg.state)
                       and int(rm.sweeps) == int(rg.sweeps)
                       and rg.diagnostics.ok)
        trace_clean = (states_identical(rm.state, rt.state)
                       and int(rm.sweeps) == int(rt.sweeps)
                       and rt.trace.firing_counts() ==
                       {k: int(v) for k, v in rt.fire_counts.items()})
        identical = (states_identical(rd.state, rm.state)
                     and {k: int(v) for k, v in rd.fire_counts.items()}
                     == {k: int(v) for k, v in rm.fire_counts.items()}
                     and int(rd.sweeps) == int(rm.sweeps))
        # Grid bit-identity: states + fire counts for every core count
        # (rounds may differ from host sweeps only under a custom assign).
        grid_identical = all(
            states_identical(rd.state, r.state)
            and {k: int(v) for k, v in rd.fire_counts.items()}
            == {k: int(v) for k, v in r.fire_counts.items()}
            for r in grid_runs.values())

        candidates = {
            "dyn": lambda dyn=dyn: jax.block_until_ready(dyn.run().state),
            "static": lambda static=static: jax.block_until_ready(
                static.run().state),
        }
        for c, p in grid.items():
            candidates[f"grid{c}"] = (
                lambda p=p: jax.block_until_ready(p.run().state))
        candidates["guarded"] = (
            lambda guarded=guarded: jax.block_until_ready(
                guarded.run().state))
        candidates["traced"] = (
            lambda traced=traced: jax.block_until_ready(
                traced.run().state))
        med = _interleaved_medians(candidates, reps)

        st1 = grid[1].stats()
        record(f"mega_{gname}_dynamic_host", med["dyn"], tokens,
               f"{int(rd.sweeps)} sweeps")
        record(f"mega_{gname}_megakernel", med["grid1"], tokens,
               f"{int(rm.sweeps)} sweeps, interpret mode, "
               f"{len(st1.forwarded_fifos)} forwarded",
               sweeps=int(rm.sweeps), cores=1,
               scratch_bytes=int(st1.scratch_bytes),
               shared_scratch_bytes=int(st1.shared_scratch_bytes),
               forwarded_fifos=len(st1.forwarded_fifos))
        record(f"mega_{gname}_megakernel_guarded", med["guarded"], tokens,
               f"{med['guarded'] / med['grid1']:.2f}x of unguarded, "
               f"clean + bit-identical: {guard_clean}",
               sweeps=int(rg.sweeps), cores=1)
        record(f"mega_{gname}_megakernel_traced", med["traced"], tokens,
               f"{med['traced'] / med['grid1']:.2f}x of untraced, "
               f"{rt.trace.n_events} events, bit-identical: {trace_clean}",
               sweeps=int(rt.sweeps), cores=1)
        record(f"mega_{gname}_static_specialized", med["static"], tokens,
               "fused scan reference")
        for c in GRID_CORES[1:]:
            st = grid[c].stats()
            record(
                f"mega_{gname}_grid{c}", med[f"grid{c}"], tokens,
                f"{int(grid_runs[c].sweeps)} rounds, {c} cores, "
                f"{st.shared_scratch_bytes} B shared rings+semaphores",
                sweeps=int(grid_runs[c].sweeps), cores=c,
                scratch_bytes=int(st.scratch_bytes),
                shared_scratch_bytes=int(st.shared_scratch_bytes),
                forwarded_fifos=len(st.forwarded_fifos))
        rows.append((f"mega_{gname}_vs_dynamic", 0.0,
                     f"{med['dyn'] / med['grid1']:.2f}x vs host dynamic "
                     f"(interpret-mode CPU; structure not kernel perf), "
                     f"bit-identical: {identical}"))
        rows.append((f"mega_{gname}_grid_vs_single", 0.0,
                     f"grid2 {med['grid1'] / med['grid2']:.2f}x / grid4 "
                     f"{med['grid1'] / med['grid4']:.2f}x vs 1-core "
                     f"(sequential partition loop; parity expected), "
                     f"grid bit-identical: {grid_identical}"))
        st = mega.stats()
        rows.append((f"mega_{gname}_scratch_bytes", 0.0,
                     f"{st.scratch_bytes} scratch after forwarding "
                     f"({st.reclaimed_scratch_bytes} reclaimed from "
                     f"{len(st.forwarded_fifos)} transient rings) vs "
                     f"{st.hbm_state_bytes} HBM operands"))
        splits = []
        for c in GRID_CORES[1:]:
            s = grid[c].stats()
            splits.append(f"{c}c: {list(s.core_scratch_bytes)} private / "
                          f"{s.shared_scratch_bytes} shared")
        rows.append((f"mega_{gname}_grid_ring_split", 0.0, "; ".join(splits)))

    with open(json_path, "w") as f:
        json.dump(records, f, indent=2)
        f.write("\n")
    rows.append(("mega_bench_json", 0.0, json_path))
    return rows


if __name__ == "__main__":
    fast = "--fast" in sys.argv
    print("name,us_per_call,derived")
    for name, us, derived in bench_megakernel(fast=fast):
        print(f"{name},{us:.1f},{derived}")
