"""Executor hot-path benchmarks (trace-time specialization PR).

Proves the two tentpole claims on real paper graphs:

  * static mode with ``ExecutionPlan(specialize=True)`` — transient-
    channel register allocation + phase-specialized ring offsets — vs the
    dynamic-cursor baseline (``specialize=False``), on the DPD network
    (paper §4.2, the dynamic-rate showcase) and motion detection (paper
    §4.1, the delay-channel showcase).  Target: >= 1.5x on DPD.
  * dynamic mode with ``ExecutionPlan(multi_firing=True)`` — occupancy-
    bounded fori_loop firing — reaches quiescence in strictly fewer
    sweeps than the one-firing-per-actor-per-sweep baseline, with
    bit-identical final states.

Timing interleaves baseline/specialized reps and takes medians so shared-
machine noise hits both arms equally.  Besides the CSV rows, writes
``BENCH_executors.json``: ``{name, us_per_call, tokens_per_s}`` per
executor x graph (tokens = MoC source-channel tokens: signal blocks for
DPD, frames for MD) so later PRs can track the throughput trajectory.

The ``exec_*_dynamic_guarded`` rows time ``ExecutionPlan(guards=True)``
(the in-kernel health layer) against the unguarded dynamic executor and
inline-check its contract: a clean guarded run must be bit-identical and
report no faults.  Their tok/s rides the same calibrated regression
floor as every other row once committed to the baseline JSON.

The ``exec_*_dynamic_traced`` rows do the same for the firing-level
trace ring (``ExecutionPlan(trace=True)``): bit-identical states/sweeps,
recorded firings equal to ``fire_counts``, and the overhead gated by the
committed baseline like every other timing row.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ExecutionPlan
from repro.graphs.factories import states_identical as _states_identical

Row = Tuple[str, float, str]

JSON_PATH = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                         "BENCH_executors.json")


def _interleaved_medians(fns: Dict[str, Callable[[], None]],
                         reps: int) -> Dict[str, float]:
    """Median seconds per call, reps interleaved across all candidates."""
    for fn in fns.values():  # compile + warm
        fn()
    times: Dict[str, List[float]] = {k: [] for k in fns}
    for _ in range(reps):
        for k, fn in fns.items():
            t0 = time.perf_counter()
            fn()
            times[k].append(time.perf_counter() - t0)
    return {k: float(np.median(v)) for k, v in times.items()}


def bench_executors(fast: bool = False,
                    json_path: str = JSON_PATH) -> List[Row]:
    from repro.graphs import dpd, motion_detection

    reps = 3 if fast else 9
    rows: List[Row] = []
    records: List[Dict] = []

    def record(name: str, dt: float, tokens: int, derived: str) -> None:
        rows.append((name, dt * 1e6, derived))
        records.append({"name": name, "us_per_call": round(dt * 1e6, 1),
                        "tokens_per_s": round(tokens / dt, 1)})

    # ------------------------------------------------------------------ #
    # Graph workloads: (name, network, n_iterations, tokens/run, unit str).
    # ------------------------------------------------------------------ #
    if fast:
        workloads = [
            ("dpd", dpd.bench_workload(4, block_l=1024), 4, 4,
             lambda dt: f"{4 * 1024 / dt / 1e6:.1f} Msamples/s"),
            ("md", motion_detection.bench_workload(8, rate=4), 2, 8,
             lambda dt: f"{8 / dt:.0f} fps"),
        ]
    else:
        workloads = [
            ("dpd", dpd.bench_workload(8), 8, 8,
             lambda dt: f"{8 * dpd.BLOCK_L / dt / 1e6:.1f} Msamples/s"),
            ("md", motion_detection.bench_workload(24, rate=4), 6, 24,
             lambda dt: f"{24 / dt:.0f} fps"),
        ]

    for gname, net, n_iter, tokens, fmt in workloads:
        # -- static executors: baseline vs specialized (+ donation) ------ #
        st = net.init_state()
        run_base = net.compile(mode="static", n_iterations=n_iter,
                               specialize=False)
        run_spec = net.compile(mode="static", n_iterations=n_iter,
                               specialize=True)
        med = _interleaved_medians({
            "base": lambda: jax.block_until_ready(run_base.run(st).state),
            "spec": lambda: jax.block_until_ready(run_spec.run(st).state),
        }, reps)
        record(f"exec_{gname}_static_baseline", med["base"], tokens,
               fmt(med["base"]))
        record(f"exec_{gname}_static_specialized", med["spec"], tokens,
               fmt(med["spec"]))
        speedup = med["base"] / med["spec"]
        rows.append((f"exec_{gname}_static_specialization_speedup", 0.0,
                     f"{speedup:.2f}x (target >= 1.5x on dpd)"))

        # Donated run: every call consumes a fresh state (in-place buffers).
        # Deep-copy each pooled state: init_state shares the staged source
        # slab across states, and donating it once would kill the pool.
        run_don = net.compile(mode="static", n_iterations=n_iter,
                              specialize=True, donate=True)
        pool = [jax.tree.map(jnp.copy, net.init_state())
                for _ in range(reps + 1)]
        med_d = _interleaved_medians(
            {"don": lambda: jax.block_until_ready(run_don.run(pool.pop()).state)},
            reps)
        record(f"exec_{gname}_static_specialized_donated", med_d["don"],
               tokens, fmt(med_d["don"]))

        # -- dynamic executors: single- vs multi-firing sweeps ----------- #
        # donate=False pins the measurement to the executor itself: the
        # "auto" default would donate run(None)'s private copy on graphs
        # passing the buffered-bytes heuristic, adding a tree copy to
        # every timed call.
        dyn_base = net.compile(ExecutionPlan(mode="dynamic",
                                             multi_firing=False,
                                             donate=False))
        dyn_mf = net.compile(ExecutionPlan(mode="dynamic", multi_firing=True,
                                           donate=False))
        dyn_grd = net.compile(ExecutionPlan(mode="dynamic", multi_firing=True,
                                            donate=False, guards=True))
        dyn_trc = net.compile(ExecutionPlan(mode="dynamic", multi_firing=True,
                                            donate=False, trace=True))
        rb, rm, rg = dyn_base.run(), dyn_mf.run(), dyn_grd.run()
        rt = dyn_trc.run()
        sb, cb, swb = rb.state, rb.fire_counts, rb.sweeps
        sm, cm, swm = rm.state, rm.fire_counts, rm.sweeps
        identical = (_states_identical(sb, sm) and
                     {k: int(v) for k, v in cb.items()} ==
                     {k: int(v) for k, v in cm.items()})
        # Health-guard contract: a clean guarded run is bit-identical to
        # the unguarded one and reports no faults.
        guard_clean = (_states_identical(sm, rg.state)
                       and int(swm) == int(rg.sweeps)
                       and rg.diagnostics.ok)
        # Trace contract: a traced run is bit-identical to the untraced
        # one, and the recorded firings agree with fire_counts.
        trace_clean = (_states_identical(sm, rt.state)
                       and int(swm) == int(rt.sweeps)
                       and rt.trace.firing_counts() ==
                       {k: int(v) for k, v in rt.fire_counts.items()})
        med = _interleaved_medians({
            "base": lambda: jax.block_until_ready(dyn_base.run().state),
            "mf": lambda: jax.block_until_ready(dyn_mf.run().state),
            "grd": lambda: jax.block_until_ready(dyn_grd.run().state),
            "trc": lambda: jax.block_until_ready(dyn_trc.run().state),
        }, reps)
        record(f"exec_{gname}_dynamic_baseline", med["base"], tokens,
               f"{int(swb)} sweeps")
        record(f"exec_{gname}_dynamic_multi_firing", med["mf"], tokens,
               f"{int(swm)} sweeps")
        record(f"exec_{gname}_dynamic_guarded", med["grd"], tokens,
               f"{med['grd'] / med['mf']:.2f}x of unguarded, "
               f"clean + bit-identical: {guard_clean}")
        record(f"exec_{gname}_dynamic_traced", med["trc"], tokens,
               f"{med['trc'] / med['mf']:.2f}x of untraced, "
               f"{rt.trace.n_events} events, bit-identical: {trace_clean}")
        rows.append((f"exec_{gname}_dynamic_sweep_reduction", 0.0,
                     f"{int(swb)} -> {int(swm)} sweeps "
                     f"(strictly fewer: {int(swm) < int(swb)}), "
                     f"bit-identical states: {identical}"))

    with open(json_path, "w") as f:
        json.dump(records, f, indent=2)
        f.write("\n")
    rows.append(("exec_bench_json", 0.0, json_path))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in bench_executors():
        print(f"{name},{us:.1f},{derived}")
