import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""§Perf hillclimb driver: run dry-run variants for the three chosen cells
and log hypothesis -> before -> after into results/hillclimb.json.

Cells (selection rationale in EXPERIMENTS.md §Perf):
  1. granite-moe-3b-a800m x train_4k   — most collective-bound cell AND the
     paper's technique (dynamic-rate experts).
  2. qwen2-72b x train_4k              — largest model, worst absolute bound.
  3. qwen2-72b x decode_32k            — memory-bound serving regime, worst
     useful-flops fraction.

Usage: PYTHONPATH=src python -m benchmarks.hillclimb [--only CELL_IDX]
"""
import argparse
import json

from repro.launch.dryrun import run_cell

PLAN = [
    # (arch, shape, variant, hypothesis)
    ("granite-moe-3b-a800m", "train_4k", "base", "baseline"),
    ("granite-moe-3b-a800m", "train_4k", "moe_local16",
     "the N-global rank-cumsum + cross-shard scatter dominate the "
     "collective term; per-data-shard dispatch keeps tokens local until "
     "the expert einsum -> expect >=2x lower collective bytes"),
    ("granite-moe-3b-a800m", "train_4k", "moe_local16+cf1",
     "capacity factor 1.25->1.0 cuts expert slab bytes ~20% on top"),
    ("granite-moe-3b-a800m", "train_4k", "moe_local16+mb4",
     "4 microbatches quarter the live dispatch buffers (memory term) at "
     "the cost of 4x smaller per-step einsums"),

    ("qwen2-72b", "train_4k", "base", "baseline"),
    ("qwen2-72b", "train_4k", "mb4",
     "activation memory (temp bytes) dominates the memory term; 4 "
     "microbatches cut live activations ~4x with <5% extra flops"),
    ("qwen2-72b", "train_4k", "f32grads",
     "negative control: f32 gradient all-reduce should ~double the "
     "cross-replica collective bytes vs the bf16-compressed baseline"),

    ("qwen2-72b", "decode_32k", "base", "baseline"),
    ("qwen2-72b", "decode_32k", "kv_int8",
     "decode is KV-bandwidth-bound; int8 cache halves bytes-per-token"),
    ("qwen2-72b", "decode_32k", "kv_int8+seqshard",
     "GQA KV replication leaves the model axis idle for the cache; "
     "seq-sharding the ring over `model` cuts per-chip cache memory 16x "
     "for one tiny per-token softmax all-reduce"),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/hillclimb.json")
    ap.add_argument("--only", default=None,
                    help="comma list of indices into PLAN")
    args = ap.parse_args()

    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["variant"]) for r in results
            if r.get("status") == "ok"}

    idxs = (range(len(PLAN)) if args.only is None
            else [int(i) for i in args.only.split(",")])
    for i in idxs:
        arch, shape, variant, hyp = PLAN[i]
        if (arch, shape, variant) in done:
            print(f"[hillclimb] skip (done): {arch}/{shape}/{variant}")
            continue
        print(f"[hillclimb] {arch}/{shape}/{variant} ...", flush=True)
        rec = run_cell(arch, shape, multi_pod=False, probes=True,
                       variant=variant)
        rec["hypothesis"] = hyp
        results = [r for r in results
                   if (r["arch"], r["shape"], r["variant"]) != (arch, shape, variant)]
        results.append(rec)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        if rec["status"] == "ok":
            t = rec["roofline"]
            print(f"[hillclimb] -> compute {t['compute_s']:.3g}s  memory "
                  f"{t['memory_s']:.3g}s  collective {t['collective_s']:.3g}s "
                  f" bottleneck={rec['bottleneck']}", flush=True)
        else:
            print(f"[hillclimb] -> {rec['status']}: {rec.get('error', '')[:200]}",
                  flush=True)


if __name__ == "__main__":
    main()
