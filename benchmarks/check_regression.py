"""Bench-regression gate: compare a fresh ``--fast`` run to the committed
``BENCH_executors.json`` / ``BENCH_megakernel.json`` /
``BENCH_serving.json`` baselines.

Two kinds of comparison, per record (keyed by ``name``):

  * **structure fields** — everything except the timing pair
    (``sweeps``, ``cores``, ``scratch_bytes``, ``shared_scratch_bytes``,
    ``forwarded_fifos``, and any future field) — compared **exactly**:
    a sweep-count change is a scheduler-semantics change and a scratch /
    forwarding-count drift is a memory-footprint regression, not noise;
    either fails the gate outright, as does a baseline row missing from
    the fresh run;
  * **tokens_per_s** — compared against a ``--floor`` (default 0.85x)
    after machine-speed calibration: the committed baselines were
    produced on one container and CI runners differ in absolute speed,
    so the gate normalizes every per-row fresh/baseline ratio by the
    **median ratio across all rows of the suite** (the machine-speed
    estimate, shared by every executor) and flags rows whose calibrated
    ratio drops below the floor.  This catches *relative* regressions —
    one executor slowing down against the fleet — which is the only
    signal absolute tok/s can carry across machines; on the baseline
    machine the median is ~1 and the gate degenerates to the plain
    0.85x floor.

Shared-CPU timing noise (±40% between runs, see the verify skill) would
make one-shot throughput floors flake, so a row only **fails** the gate
when it stays under the floor in every one of ``--attempts`` fresh runs
(default 3, early exit on a clean run): genuine regressions are
persistent, noise bounces back.  Structure mismatches are deterministic
and fail on the first attempt.

The guards-on rows (``exec_*_dynamic_guarded``,
``mega_*_megakernel_guarded``) are gated exactly like every other row:
their tok/s must hold the calibrated floor, so a PR that bloats the
health-guard overhead fails CI even if the unguarded paths are intact.

Prints a markdown comparison table (also appended to
``$GITHUB_STEP_SUMMARY`` when set, so the job summary shows the full
table) and exits non-zero on any regression.

Invocation (CI and local): ``PYTHONPATH=src python
benchmarks/check_regression.py --fast``.
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import sys
import tempfile
from typing import Dict, List

if __package__ in (None, ""):   # script invocation: PYTHONPATH=src is enough
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SUITES = ("BENCH_executors.json", "BENCH_megakernel.json",
          "BENCH_resilience.json", "BENCH_serving.json",
          "BENCH_shard.json")
TIMING_FIELDS = ("us_per_call", "tokens_per_s")


def _load(path: str) -> Dict[str, dict]:
    with open(path) as f:
        return {r["name"]: r for r in json.load(f)}


def _fresh_run(fast: bool, out_dir: str) -> Dict[str, Dict[str, dict]]:
    """Run both bench suites into ``out_dir``; returns suite -> records."""
    from benchmarks.bench_executors import bench_executors
    from benchmarks.bench_megakernel import bench_megakernel
    from benchmarks.bench_resilience import bench_resilience
    from benchmarks.bench_serving import bench_serving
    from benchmarks.bench_shard import bench_shard

    paths = {s: os.path.join(out_dir, s) for s in SUITES}
    bench_executors(fast=fast, json_path=paths["BENCH_executors.json"])
    bench_megakernel(fast=fast, json_path=paths["BENCH_megakernel.json"])
    bench_resilience(fast=fast, json_path=paths["BENCH_resilience.json"])
    bench_serving(fast=fast, json_path=paths["BENCH_serving.json"])
    bench_shard(fast=fast, json_path=paths["BENCH_shard.json"])
    return {s: _load(p) for s, p in paths.items()}


def compare(base: Dict[str, dict], fresh: Dict[str, dict],
            floor: float) -> Dict[str, dict]:
    """Per-row verdicts for one suite in one attempt.

    Returns ``name -> {status, reason, base, fresh, calibrated}`` where
    status is ``ok`` / ``slow`` (under the calibrated floor) /
    ``structure`` / ``missing``.
    """
    ratios = {n: fresh[n]["tokens_per_s"] / base[n]["tokens_per_s"]
              for n in base if n in fresh and base[n].get("tokens_per_s")}
    machine = statistics.median(ratios.values()) if ratios else 1.0
    out: Dict[str, dict] = {}
    for name, brec in base.items():
        frec = fresh.get(name)
        if frec is None:
            out[name] = dict(status="missing", base=brec["tokens_per_s"],
                             fresh=None, calibrated=None,
                             reason="row missing from fresh run")
            continue
        b_struct = {k: v for k, v in brec.items()
                    if k not in TIMING_FIELDS and k != "name"}
        f_struct = {k: v for k, v in frec.items()
                    if k not in TIMING_FIELDS and k != "name"}
        calibrated = ratios.get(name, 1.0) / machine
        rec = dict(status="ok", reason="", base=brec["tokens_per_s"],
                   fresh=frec["tokens_per_s"], calibrated=calibrated,
                   machine=machine)
        if b_struct != f_struct:
            rec.update(status="structure",
                       reason=f"structure fields changed "
                              f"{b_struct} -> {f_struct}")
        elif calibrated < floor:
            rec.update(status="slow",
                       reason=f"tokens_per_s {frec['tokens_per_s']} is "
                              f"{calibrated:.2f}x of baseline "
                              f"{brec['tokens_per_s']} (machine-calibrated; "
                              f"floor {floor}x)")
        out[name] = rec
    for name in set(fresh) - set(base):
        out[name] = dict(status="new", reason="", base=None,
                         fresh=fresh[name]["tokens_per_s"], calibrated=None)
    return out


def _merge(attempts: List[Dict[str, dict]]) -> Dict[str, dict]:
    """Best verdict per row across attempts: ``slow`` must persist in
    every attempt to stick; structure/missing verdicts are deterministic
    drifts, so they stick from the first attempt a row shows one — a
    later lucky rerun must NOT launder them back to ok."""
    merged: Dict[str, dict] = {}
    for att in attempts:
        for name, rec in att.items():
            cur = merged.get(name)
            if cur is None:
                merged[name] = dict(rec)
            elif cur["status"] in ("structure", "missing"):
                continue                      # sticky: deterministic drift
            elif rec["status"] in ("structure", "missing"):
                merged[name] = dict(rec)      # upgrade slow/ok -> sticky
            elif rec["status"] == "ok" or (
                    cur["status"] == "slow"
                    and (rec.get("calibrated") or 0)
                    > (cur.get("calibrated") or 0)):
                merged[name] = dict(rec)
    return merged


def render(suite: str, merged: Dict[str, dict], n_attempts: int) -> str:
    lines = [f"### {suite} ({n_attempts} attempt(s))", "",
             "| row | baseline tok/s | fresh tok/s | calibrated | status |",
             "|---|---|---|---|---|"]
    for name in sorted(merged):
        r = merged[name]
        cal = f"{r['calibrated']:.2f}x" if r.get("calibrated") else "—"
        status = {"ok": "ok", "new": "new (no baseline)",
                  "slow": "REGRESSION", "structure": "STRUCTURE",
                  "missing": "MISSING"}[r["status"]]
        lines.append(f"| {name} | {r['base'] if r['base'] is not None else '—'}"
                     f" | {r['fresh'] if r['fresh'] is not None else '—'}"
                     f" | {cal} | {status} |")
    return "\n".join(lines) + "\n"


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fast", action="store_true",
                    help="fast bench configuration (the CI mode)")
    ap.add_argument("--floor", type=float, default=0.85,
                    help="calibrated tok/s floor (default 0.85)")
    ap.add_argument("--attempts", type=int, default=3,
                    help="max fresh runs; a throughput row fails only if "
                         "under the floor in all of them (default 3)")
    ap.add_argument("--baseline-dir", default=REPO_ROOT,
                    help="directory holding the committed BENCH_*.json")
    ap.add_argument("--keep-fresh", default=None, metavar="DIR",
                    help="also write each attempt's fresh BENCH_*.json "
                         "under DIR/attempt<N>/ (CI uploads these as the "
                         "fresh-run artifact)")
    args = ap.parse_args()

    baselines = {s: _load(os.path.join(args.baseline_dir, s)) for s in SUITES}
    attempts: Dict[str, List[Dict[str, dict]]] = {s: [] for s in SUITES}
    for i in range(max(1, args.attempts)):
        if args.keep_fresh:
            out_dir = os.path.join(args.keep_fresh, f"attempt{i + 1}")
            os.makedirs(out_dir, exist_ok=True)
            fresh = _fresh_run(args.fast, out_dir)
        else:
            with tempfile.TemporaryDirectory() as tmp:
                fresh = _fresh_run(args.fast, tmp)
        clean = True
        retryable = False
        for s in SUITES:
            verdicts = compare(baselines[s], fresh[s], args.floor)
            attempts[s].append(verdicts)
            statuses = {v["status"] for v in verdicts.values()}
            clean &= statuses <= {"ok", "new"}
            retryable |= "slow" in statuses
        # Retrying only helps throughput noise; structure/missing drifts
        # are deterministic (and sticky in _merge), so don't burn two
        # more full bench runs on them.
        if clean or not retryable:
            break

    failures: List[str] = []
    report = []
    for s in SUITES:
        merged = _merge(attempts[s])
        failures += [f"{s}: {n}: {r['reason']}"
                     for n, r in sorted(merged.items())
                     if r["status"] not in ("ok", "new")]
        report.append(render(s, merged, len(attempts[s])))
    text = "\n".join(report)
    print(text)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write("## Bench-regression gate\n\n" + text + "\n")
    if failures:
        print("REGRESSIONS:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print(f"bench-regression gate: OK ({args.floor}x calibrated floor, "
          f"{len(attempts[SUITES[0]])} attempt(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
