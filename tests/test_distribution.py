"""Distribution tests that need >1 device: run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the dry-run pattern;
the main test process keeps its single CPU device)."""
import os
import re
import subprocess
import sys
import textwrap

import jax
import pytest

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(body: str, devices: int = 8, timeout: int = 600) -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
    """) + textwrap.dedent(body)
    env = dict(os.environ,
               PYTHONPATH=os.path.abspath(REPO_SRC))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_pipeline_spmd_matches_reference():
    """GPipe-style ppermute pipeline == sequential oracle (core/pipeline.py
    — the Eq. 1 double-buffer as a collective schedule)."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import pipeline_spmd, pipeline_reference
        mesh = jax.make_mesh((4,), ("stage",))
        def stage_fn(p, x):
            return jnp.tanh(x @ p["w"] + p["b"])
        key = jax.random.PRNGKey(0)
        params = {"w": jax.random.normal(key, (4, 16, 16)) * 0.5,
                  "b": jnp.zeros((4, 16))}
        mb = jax.random.normal(key, (8, 16))
        got = pipeline_spmd(stage_fn, params, mb, mesh, axis="stage")
        want = pipeline_reference(stage_fn, params, mb)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        print("pipeline OK")
    """)


def test_train_step_pjit_small_mesh():
    """Full sharded train step on a 4x2 (data, model) mesh: loss finite,
    params updated, batch actually sharded."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import smoke_config
        from repro.models import init_params
        from repro.optim.adamw import AdamWConfig, init_opt_state
        from repro.train import TrainOptions, make_train_step
        from repro.train import sharding as shd
        from repro.data import DataConfig, SyntheticLM

        cfg = smoke_config("granite-8b")
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        key = jax.random.PRNGKey(0)
        params = init_params(key, cfg)
        opt = init_opt_state(params)
        data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8))
        batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}

        p_specs, dropped = shd.param_specs(params, mesh)
        b_specs = shd.batch_specs(batch, mesh)
        o_specs = {"m": p_specs, "v": p_specs, "count": P()}
        step = make_train_step(cfg, AdamWConfig(lr=1e-3), TrainOptions())
        with mesh:
            jstep = jax.jit(step, in_shardings=jax.tree.map(
                lambda s: NamedSharding(mesh, s), (p_specs, o_specs, b_specs),
                is_leaf=lambda x: isinstance(x, P)))
            p2, o2, m = jstep(params, opt, batch)
        assert np.isfinite(float(m["loss"]))
        # embedding really is vocab-sharded over `model`
        emb = p2["embed"]["w"]
        assert len(emb.addressable_shards) == 8
        shard_rows = emb.addressable_shards[0].data.shape[0]
        assert shard_rows == emb.shape[0] // 2, (shard_rows, emb.shape)
        print("pjit train step OK, loss", float(m["loss"]))
    """)


@pytest.mark.skipif(
    # Leading-digit parse so pre-release strings ("0.5.0rc0") compare.
    tuple(int(re.match(r"\d*", p).group() or 0)
          for p in jax.__version__.split(".")[:2]) < (0, 5),
    reason="compiled.cost_analysis() returns a per-module LIST in jax "
           "0.4.37 (dryrun.py expects the dict of later releases) — "
           f"pre-existing version drift, running {jax.__version__}")
def test_dryrun_cell_mini_mesh():
    """The dry-run machinery end-to-end on an 8-chip (4 data x 2 model)
    mini-mesh: lower+compile+cost+collectives for one arch x shape."""
    run_sub("""
        import jax, numpy as np, json
        from jax.sharding import NamedSharding, PartitionSpec as P
        import repro.launch.dryrun as dr
        from repro.configs import smoke_config

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = smoke_config("granite-8b")
        import repro.configs.base as base
        # shrink the global shape table for the mini run
        orig = dict(base.SHAPES)
        base.SHAPES["train_4k"] = (64, 8)
        dr.SHAPES["train_4k"] = (64, 8)
        fn, args, shardings, dropped = dr.build_cell(cfg, "train_4k", mesh)
        with mesh:
            in_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), shardings,
                                 is_leaf=lambda x: isinstance(x, P))
            lowered = jax.jit(fn, in_shardings=in_sh).lower(*args)
            compiled = lowered.compile()
            cost = compiled.cost_analysis()
            coll = dr.parse_collective_bytes(compiled.as_text())
        assert cost.get("flops", 0) > 0
        assert coll["total"] > 0, "SPMD program must contain collectives"
        print("mini dryrun OK", json.dumps({k: v for k, v in coll.items()}))
    """)


def test_multipod_mesh_axes():
    run_sub("""
        from repro.launch.mesh import make_production_mesh
        m = make_production_mesh(multi_pod=True)
        assert dict(m.shape) == {"pod": 2, "data": 16, "model": 16}
        m2 = make_production_mesh()
        assert dict(m2.shape) == {"data": 16, "model": 16}
        print("mesh OK")
    """, devices=512)


def test_lm_pipeline_parallel_matches_reference():
    """Transformer blocks as pipeline stages (ppermute schedule) == the
    sequential oracle — LM-side pipeline parallelism end to end."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs import smoke_config
        from repro.models import init_params
        from repro.graphs.lm_pipeline import (pipeline_forward,
                                              pipeline_forward_reference)
        cfg = dataclasses.replace(smoke_config("granite-8b"), n_layers=4)
        key = jax.random.PRNGKey(0)
        params = init_params(key, cfg)
        mesh = jax.make_mesh((4,), ("stage",))
        toks = jax.random.randint(key, (6, 16), 0, cfg.vocab)  # 6 microbatches
        got = pipeline_forward(params, cfg, toks, mesh, n_stages=4)
        want = pipeline_forward_reference(params, cfg, toks, n_stages=4)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-2, atol=3e-2)
        print("LM pipeline OK", got.shape)
    """)
