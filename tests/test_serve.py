"""Batched serving engine tests."""
import jax
import numpy as np

from repro.configs import smoke_config
from repro.models import init_params
from repro.serve import Engine, Request, ServeConfig


def test_engine_batched_generation():
    cfg = smoke_config("granite-8b")
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    eng = Engine(cfg, params, ServeConfig(batch_size=4, max_prompt=16,
                                          max_new=8))
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, rng.integers(3, 16)).astype(np.int32),
                    max_new=8) for _ in range(6)]   # 6 requests -> 2 batches
    results = eng.generate(reqs)
    assert len(results) == 6
    for r in results:
        assert r.tokens.shape == (8,)
        assert (r.tokens >= 0).all() and (r.tokens < cfg.vocab).all()


def test_engine_greedy_deterministic():
    cfg = smoke_config("granite-8b")
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    eng = Engine(cfg, params, ServeConfig(batch_size=2, max_prompt=8, max_new=6))
    p = np.arange(5, dtype=np.int32) % cfg.vocab
    a = eng.generate([Request(p, 6)])[0].tokens
    b = eng.generate([Request(p, 6)])[0].tokens
    np.testing.assert_array_equal(a, b)
