"""Chaos suite: every injected fault class is caught and named on every
backend, and the health layer costs nothing it shouldn't.

Backends: host dynamic executor / single-core megakernel / grid megakernel
(k in {2, 4}).  Fault classes: overflow, underflow, cursor corruption,
non-finite tokens (``repro.core.faultinject``), stall (sweep-budget
exhaustion).  Megakernel plans run ``specialize=False`` so every channel
keeps a scratch ring — fault injection targets ring-resident cursors, and
forwarded channels reject non-drained entry states by design.

The flip side is pinned too: guards-on and guards-off runs of *clean*
graphs are bit-identical in states, cursors, fire counts and sweeps —
the guards observe channel operations, they never change them.
"""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ExecutionPlan, NetworkBuilder, NetworkFaultError,
                        corrupt_cursor, inject_overflow, inject_underflow,
                        map_fire, poison_tokens, static_actor, truncate_feed)
from repro.core.health import (CURSOR_INVALID, NONFINITE, OVERFLOW,
                               UNDERFLOW, fault_names)
from repro.graphs.factories import make_dpd, states_identical

BACKENDS = ("dynamic", "megakernel", "grid2", "grid4")


def _plan(backend, **kw):
    if backend == "dynamic":
        return ExecutionPlan(mode="dynamic", **kw)
    cores = {"megakernel": 1, "grid2": 2, "grid4": 4}[backend]
    return ExecutionPlan(mode="megakernel", specialize=False, cores=cores,
                        **kw)


@pytest.fixture(scope="module")
def dpd():
    net, _ = make_dpd(n_firings=6, block_l=64)
    return net


# --------------------------------------------------------------------------- #
# Clean runs: guards change nothing.
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", BACKENDS)
def test_clean_guarded_run_bit_identical(dpd, backend):
    off = dpd.compile(_plan(backend)).run()
    on = dpd.compile(_plan(backend, guards=True)).run()
    assert states_identical(off.state, on.state)
    assert int(off.sweeps) == int(on.sweeps)
    assert {k: int(v) for k, v in off.fire_counts.items()} \
        == {k: int(v) for k, v in on.fire_counts.items()}
    assert on.diagnostics.ok and not on.diagnostics.stalled
    assert not on.diagnostics.faults
    # guards-off still decodes the stall flag, but collects no health
    assert off.diagnostics is not None and not off.diagnostics.stalled
    assert off.diagnostics.high_water == {}


def test_clean_high_water_marks_within_bounds(dpd):
    on = dpd.compile(_plan("dynamic", guards=True)).run()
    hw = on.diagnostics.high_water
    assert set(hw) == set(dpd.fifos)
    for name, spec in dpd.fifos.items():
        assert 0 < hw[name] <= spec.writable_occupancy_bound, name


# --------------------------------------------------------------------------- #
# Injected faults: detected and *named* on every backend.
# --------------------------------------------------------------------------- #
FAULTS = {
    "overflow": (inject_overflow, OVERFLOW),
    "underflow": (inject_underflow, UNDERFLOW),
    "cursor": (lambda net, st, fifo: corrupt_cursor(net, st, fifo, occ=1),
               CURSOR_INVALID),
    "nonfinite": (poison_tokens, NONFINITE),
}


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("fault", sorted(FAULTS))
def test_injected_fault_detected_and_named(dpd, backend, fault):
    inject, expect_bit = FAULTS[fault]
    prog = dpd.compile(_plan(backend, guards=True))
    bad = inject(dpd, dpd.init_state(), "f_in")
    with pytest.raises(NetworkFaultError) as exc:
        prog.run(bad)
    diag = exc.value.diagnostics
    hit = {f.fifo: f for f in diag.faults}
    assert "f_in" in hit, diag.summary()
    f = hit["f_in"]
    assert set(fault_names(expect_bit)) <= set(f.faults)
    # the error names the channel end to end
    assert f.src_actor == "source" and f.dst_actor == "fork"
    assert "f_in" in str(exc.value)
    # the partial result still rides on the error for forensics
    assert exc.value.result.state is not None


def test_poison_is_pure_nonfinite(dpd):
    """Consistent-cursor poison must trip ONLY the data guard — it
    discriminates NONFINITE from the cursor guards."""
    prog = dpd.compile(_plan("dynamic", guards=True))
    bad = poison_tokens(dpd, dpd.init_state(), "f_in")
    with pytest.raises(NetworkFaultError) as exc:
        prog.run(bad)
    for f in exc.value.diagnostics.faults:
        assert f.faults == ("NONFINITE",), f.describe()


def test_faultinject_validates_targets(dpd):
    st = dpd.init_state()
    with pytest.raises(ValueError, match="unknown channel"):
        inject_overflow(dpd, st, "nosuch")
    with pytest.raises(ValueError, match="float channel"):
        poison_tokens(dpd, st, "f_c_fork")     # int32 control channel


# --------------------------------------------------------------------------- #
# Stall: surfaced loudly, with forensics.
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", BACKENDS)
def test_stall_guarded_raises_with_forensics(dpd, backend):
    prog = dpd.compile(_plan(backend, guards=True, max_sweeps=1))
    with pytest.raises(NetworkFaultError, match="STALL") as exc:
        prog.run()
    diag = exc.value.diagnostics
    assert diag.stalled and diag.stall is not None
    # mid-flight exhaustion: the forensics name who could still run /
    # who is blocked on what, plus the occupancy snapshot
    assert diag.stall.runnable or diag.stall.blocked
    assert set(diag.stall.occupancy) == set(dpd.fifos)


@pytest.mark.parametrize("backend", ("dynamic", "megakernel"))
def test_stall_unguarded_warns_not_silent(dpd, backend):
    """Satellite fix: max_sweeps exhaustion was indistinguishable from
    quiescence — now it's RunResult.diagnostics.stalled plus a warning."""
    prog = dpd.compile(_plan(backend, max_sweeps=1))
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        r = prog.run()
    assert r.diagnostics.stalled
    assert any("sweep budget" in str(w.message) for w in caught)
    # and a full run does NOT warn
    full = dpd.compile(_plan(backend))
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        r = full.run()
    assert not r.diagnostics.stalled and not caught


def test_guards_rejected_on_sweepless_modes(dpd):
    # Cross-field (guards-vs-mode) rules live in ExecutionPlan.validate,
    # invoked at compile time — the record itself constructs fine.
    with pytest.raises(ValueError, match="guards"):
        dpd.compile(ExecutionPlan(mode="static", n_iterations=4,
                                  guards=True))
    with pytest.raises(ValueError, match="guards"):
        dpd.compile(ExecutionPlan(mode="interpreted", n_iterations=4,
                                  guards=True))


# --------------------------------------------------------------------------- #
# Checkpointed streaming: on_fault policies + feed validation.
# --------------------------------------------------------------------------- #
def _stream_net():
    b = NetworkBuilder()
    b.actor(static_actor("src", (), ("out",),
                         lambda st, ins, rates: (st, {"out": jnp.zeros((4, 8))})))
    b.actor(static_actor("amp", ("in",), ("out",),
                         map_fire(lambda w: 2.0 * w, "in", "out")))
    b.actor(static_actor("sink", ("in",), (),
                         lambda st, ins, rates: (st, {})))
    b.connect("src.out", "amp.in", rate=4, token_shape=(8,), name="f_in")
    b.connect("amp.out", "sink.in", rate=4, token_shape=(8,), name="f_out")
    return b.build()


@pytest.fixture(scope="module")
def stream_setup():
    net = _stream_net()
    prog = net.compile(ExecutionPlan(mode="dynamic", n_iterations=2,
                                     accelerated=("amp",), guards=True))
    feeds = np.arange(6 * 4 * 8, dtype=np.float32).reshape(6, 4, 8)
    poisoned = feeds.copy()
    poisoned[3, 1, 2] = np.nan          # chunk 1 of 3 (windows 2..3)
    return prog, feeds, poisoned


def test_stream_clean_and_raise_policy(stream_setup):
    prog, feeds, poisoned = stream_setup
    outs = prog.stream({"f_in": feeds})
    np.testing.assert_array_equal(np.asarray(outs["f_out"]), 2 * feeds)
    assert prog.last_stream_report == []
    with pytest.raises(NetworkFaultError, match="chunk 1 of 3") as exc:
        prog.stream({"f_in": poisoned})
    assert "f_in" in str(exc.value)


def test_stream_skip_policy_degrades_gracefully(stream_setup):
    prog, feeds, poisoned = stream_setup
    outs = prog.stream({"f_in": poisoned}, on_fault="skip")
    got = np.asarray(outs["f_out"])
    np.testing.assert_array_equal(got[:2], 2 * feeds[:2])     # chunk 0 fine
    assert np.all(got[2:4] == 0)                              # chunk 1 zeroed
    np.testing.assert_array_equal(got[4:], 2 * feeds[4:])     # chunk 2 fine:
    # the checkpoint restored pre-fault state, the stream continued
    (entry,) = prog.last_stream_report
    assert entry["chunk"] == 1 and entry["action"] == "skip"
    assert "NONFINITE" in entry["fault"]


def test_stream_resume_policy_bounded_retries(stream_setup):
    prog, _, poisoned = stream_setup
    with pytest.raises(NetworkFaultError, match=r"after 3 attempt"):
        prog.stream({"f_in": poisoned}, on_fault="resume", max_retries=2)
    with pytest.raises(ValueError, match="on_fault"):
        prog.stream({"f_in": poisoned}, on_fault="retry")


def test_stream_feed_validation_names_actor(stream_setup):
    prog, feeds, _ = stream_setup
    # dtype mismatch: named error instead of an XLA trace error
    with pytest.raises(ValueError, match="__feed_f_in.*complex64"):
        prog.stream({"f_in": feeds.astype(np.complex64)})
    # widening host data still streams (int windows into a float channel)
    ints = np.arange(6 * 4 * 8, dtype=np.int32).reshape(6, 4, 8)
    outs = prog.stream({"f_in": ints})
    np.testing.assert_array_equal(np.asarray(outs["f_out"]),
                                  2.0 * ints.astype(np.float32))
    # shape mismatch names the feed actor too
    with pytest.raises(ValueError, match="__feed_f_in"):
        prog.stream({"f_in": np.zeros((6, 3, 8), np.float32)})
    # truncated capture: rejected before any chunk runs
    with pytest.raises(ValueError, match="windows do not divide"):
        prog.stream(truncate_feed({"f_in": feeds}, "f_in", drop=1))


# --------------------------------------------------------------------------- #
# Build-time bound proofs (PRUNE-style).
# --------------------------------------------------------------------------- #
def _gated_builder():
    b = NetworkBuilder()
    b.actor(static_actor("src", (), ("out",),
                         lambda st, ins, rates: (st, {"out": jnp.zeros((2, 4))})))
    b.actor(static_actor("ctl", (), ("c",),
                         lambda st, ins, rates:
                         (st, {"c": jnp.zeros((1, 1), jnp.int32)})))
    from repro.core import dynamic_actor
    b.actor(dynamic_actor(
        "gate", "cp", lambda tok: {"in": (tok[0] > 0).astype(jnp.int32)},
        ("in",), (), lambda st, ins, rates: (st, {})))
    b.connect("src.out", "gate.in", rate=2, token_shape=(4,), name="f_data")
    b.connect("ctl.c", "gate.cp", name="f_ctl")
    return b


def test_bounds_undecided_dynamic_port_passes():
    b = _gated_builder()
    rep = b.check_bounds()
    verdicts = {c.fifo: c.verdict for c in rep.channels}
    assert verdicts == {"f_data": "undecided", "f_ctl": "balanced"}
    b.build(check_bounds=True)          # undecided is runtime's problem
    assert b.bounds_report is not None


def test_bounds_rejects_provably_unbounded_channel():
    b = _gated_builder()
    b.rate_bounds("gate.in", 0.25, 0.5)     # consumer ceiling < producer
    with pytest.raises(ValueError, match="'f_data'.*unbounded") as exc:
        b.build(check_bounds=True)
    assert "rate_bounds" in str(exc.value)


def test_bounds_rejects_provably_starved_channel():
    b = _gated_builder()
    b.rate_bounds("src.out", 0.0, 0.5)      # producer ceiling < consumer
    b.rate_bounds("gate.in", 1.0, 1.0)
    rep = b.check_bounds()
    assert {c.fifo: c.verdict for c in rep.channels}["f_data"] == "starved"
    with pytest.raises(ValueError, match="starved"):
        b.build(check_bounds=True)


def test_bounds_declared_balance_and_validation():
    b = _gated_builder()
    b.rate_bounds("gate.in", 1.0, 1.0)      # declared always-on: balanced
    rep = b.check_bounds()
    assert {c.fifo: c.verdict for c in rep.channels}["f_data"] == "balanced"
    b.build(check_bounds=True)
    with pytest.raises(ValueError, match="no port"):
        b.rate_bounds("gate.nope", 0.0, 1.0)
    with pytest.raises(ValueError, match="0 <= lo <= hi <= 1"):
        b.rate_bounds("gate.in", 0.8, 0.2)


def test_bounds_static_chain_all_balanced():
    """Static SDF graph: every port is provably always-enabled, the whole
    report is balanced, and a guarded build is a no-op rejection-wise."""
    b = NetworkBuilder()
    b.actor(static_actor("src", (), ("out",),
                         lambda st, ins, rates: (st, {"out": jnp.zeros((2, 4))})))
    b.actor(static_actor("amp", ("in",), ("out",),
                         map_fire(lambda w: w + 1.0, "in", "out")))
    b.actor(static_actor("sink", ("in",), (),
                         lambda st, ins, rates: (st, {})))
    b.connect("src.out", "amp.in", rate=2, token_shape=(4,))
    b.connect("amp.out", "sink.in", rate=2, token_shape=(4,))
    rep = b.check_bounds()
    assert all(c.verdict == "balanced" for c in rep.channels), rep.describe()
    b.build(check_bounds=True)
