"""Program / ExecutionPlan: shim equivalence, stream loop, stats, plan
validation.  The acceptance pin of the API redesign: the deprecated
``compile_static`` / ``compile_dynamic`` shims and ``Network.compile``
produce bit-identical ``NetworkState``s on the paper graphs."""
import functools

import jax.numpy as jnp
import numpy as np
import pytest

from _graph_factories import (assert_states_identical, make_dpd as _make_dpd,
                              make_motion_detection as _make_md)
from repro.core import (ExecutionPlan, compile_dynamic, compile_static,
                        name_index_map, run_interpreted)

# Smaller workloads than the equivalence suite: these tests compare API
# surfaces, not executor transforms, so tiny graphs keep the suite fast.
make_dpd = functools.partial(_make_dpd, n_firings=4, block_l=128)


def make_motion_detection(n_frames=12, rate=4):
    return _make_md(n_frames=n_frames, rate=rate, frame_hw=(48, 64))


GRAPHS = {"dpd": make_dpd, "motion_detection": make_motion_detection}


@pytest.fixture(autouse=True)
def _rearm_deprecation_warnings(monkeypatch):
    """Shim warnings fire once per process; re-arm so every test (and
    every parametrization) can still assert on the first warning.  Also
    shield the warning-shape tests from a CI environment that escalates
    the shims to errors (REPRO_STRICT_DEPRECATION=1)."""
    from repro.core.executor import reset_deprecation_warnings
    monkeypatch.delenv("REPRO_STRICT_DEPRECATION", raising=False)
    reset_deprecation_warnings()
    yield


# --------------------------------------------------------------------------- #
# Shim equivalence (the deprecation is transparent).
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("graph", sorted(GRAPHS))
def test_static_shim_bit_identical_to_program(graph):
    net, n_iter = GRAPHS[graph]()
    with pytest.warns(DeprecationWarning, match="compile_static"):
        legacy = compile_static(net, n_iter)
    s_old = legacy(net.init_state())
    s_new = net.compile(mode="static", n_iterations=n_iter).run().state
    assert_states_identical(s_old, s_new)


@pytest.mark.parametrize("graph", sorted(GRAPHS))
def test_dynamic_shim_bit_identical_to_program(graph):
    net, _ = GRAPHS[graph]()
    with pytest.warns(DeprecationWarning, match="compile_dynamic"):
        legacy = compile_dynamic(net, return_sweeps=True)
    s_old, c_old, sw_old = legacy(net.init_state())
    r = net.compile(ExecutionPlan(mode="dynamic")).run()
    assert_states_identical(s_old, r.state)
    assert ({k: int(v) for k, v in c_old.items()}
            == {k: int(v) for k, v in r.fire_counts.items()})
    assert int(sw_old) == int(r.sweeps)


def test_interpreted_shim_bit_identical_to_program():
    net, n_iter = make_motion_detection()
    with pytest.warns(DeprecationWarning, match="run_interpreted"):
        s_old = run_interpreted(net, net.init_state(), n_iter)
    s_new = net.compile(mode="interpreted", n_iterations=n_iter).run().state
    assert_states_identical(s_old, s_new)


def test_shims_warn_once_per_process():
    """Benchmark loops rebuild shim runners thousands of times; the
    deprecation warning must fire on the first call only."""
    import warnings

    net, n_iter = make_dpd()
    with pytest.warns(DeprecationWarning, match="compile_static"):
        compile_static(net, n_iter)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        compile_static(net, n_iter)
    assert not [r for r in rec if issubclass(r.category, DeprecationWarning)]


# --------------------------------------------------------------------------- #
# donate="auto": the per-graph heuristic behind the MD donate regression.
# --------------------------------------------------------------------------- #
def test_donate_auto_resolves_per_graph():
    from repro.core.program import _DONATE_AUTO_BUFFERED_BYTES_MAX

    dpd_net, n_iter = make_dpd()
    # DPD registerizes its bulk channels: buffered bytes are tiny ->
    # donation on.  Full-size MD keeps MBs of frames ring-buffered ->
    # donation off (the measured 707 -> 415 tok/s regression).
    prog = dpd_net.compile(mode="static", n_iterations=n_iter)
    assert prog.donate is True
    assert prog.stats().resolved_donate is True
    from repro.graphs.motion_detection import build_motion_detection
    md_full = build_motion_detection(8, rate=4)   # QVGA frames, 3.46 MB
    buffered = sum(s.capacity_bytes for n, s in md_full.fifos.items()
                   if n not in md_full.register_fifos)
    assert buffered > _DONATE_AUTO_BUFFERED_BYTES_MAX
    assert md_full.compile(mode="static", n_iterations=2).donate is False
    # Explicit bools always win over the heuristic.
    assert md_full.compile(mode="static", n_iterations=2,
                           donate=True).donate is True
    assert dpd_net.compile(mode="static", n_iterations=n_iter,
                           donate=False).donate is False
    with pytest.raises(ValueError, match="donate"):
        ExecutionPlan(mode="dynamic", donate="always")
    # register_fifos are "free" only under the specialized static
    # executor; the same full-size DPD (11.5 MB of data rings) must
    # auto-donate there and must NOT under dynamic / unspecialized
    # static, where those rings stay live.
    from repro.graphs.dpd import build_dpd
    full_dpd = build_dpd(4)
    assert full_dpd.compile(mode="static", n_iterations=4).donate is True
    assert full_dpd.compile(ExecutionPlan(mode="dynamic")).donate is False
    assert full_dpd.compile(mode="static", n_iterations=4,
                            specialize=False).donate is False


def test_donate_threshold_bytes_is_configurable():
    """The donate="auto" 1 MiB ceiling was measured on this container;
    ExecutionPlan(donate_threshold_bytes=...) overrides it per plan and
    Program.stats() reports the resolved value."""
    from repro.core.program import _DONATE_AUTO_BUFFERED_BYTES_MAX

    net, n_iter = make_dpd()
    default = net.compile(ExecutionPlan(mode="dynamic"))
    assert default.stats().resolved_donate_threshold \
        == _DONATE_AUTO_BUFFERED_BYTES_MAX
    assert default.donate is True     # tiny rings, under the 1 MiB default
    # Threshold 0: the (nonzero) dynamic-mode ring bytes exceed it,
    # auto resolves to False.
    tight = net.compile(ExecutionPlan(mode="dynamic",
                                      donate_threshold_bytes=0))
    assert tight.donate is False
    assert tight.stats().resolved_donate_threshold == 0
    # A huge threshold flips full-size MD's auto verdict back on.
    from repro.graphs.motion_detection import build_motion_detection
    md_full = build_motion_detection(8, rate=4)
    assert md_full.compile(mode="static", n_iterations=2).donate is False
    loose = md_full.compile(mode="static", n_iterations=2,
                            donate_threshold_bytes=1 << 30)
    assert loose.donate is True
    assert loose.stats().resolved_donate_threshold == 1 << 30
    # The threshold tunes the heuristic only: explicit bools still win,
    # and the results stay bit-identical either way.
    assert net.compile(ExecutionPlan(mode="dynamic", donate=False,
                                     donate_threshold_bytes=1 << 30)) \
        .donate is False
    r_tight = tight.run()
    r_default = default.run()
    assert_states_identical(r_tight.state, r_default.state)
    with pytest.raises(ValueError, match="donate_threshold_bytes"):
        ExecutionPlan(mode="dynamic", donate_threshold_bytes=-1)
    with pytest.raises(ValueError, match="donate_threshold_bytes"):
        ExecutionPlan(mode="dynamic", donate_threshold_bytes="1MiB")
    with pytest.raises(ValueError, match="donate_threshold_bytes"):
        # bool is an int subclass; a user confusing this with donate=True
        # must get an error, not a silent 1-byte threshold.
        ExecutionPlan(mode="dynamic", donate_threshold_bytes=True)


# --------------------------------------------------------------------------- #
# Plan validation.
# --------------------------------------------------------------------------- #
def test_plan_rejects_bad_mode_and_missing_iterations():
    # Field-local: a bad mode string fails at construction.
    with pytest.raises(ValueError, match="mode must be one of"):
        ExecutionPlan(mode="jitted")
    # Cross-field: mode-vs-n_iterations is judged by ExecutionPlan
    # .validate at compile time, so the bare record constructs fine...
    net, _ = make_motion_detection()
    for plan in (ExecutionPlan(mode="static"),
                 ExecutionPlan(mode="interpreted"),
                 ExecutionPlan(mode="dynamic",
                               accelerated=("gauss",))):
        with pytest.raises(ValueError, match="n_iterations"):
            net.compile(plan)
    net.compile(ExecutionPlan(mode="dynamic"))  # quiescence needs no count


def test_strict_deprecation_env_escalates_shims(monkeypatch):
    """REPRO_STRICT_DEPRECATION=1 (set by CI) turns the legacy-shim
    DeprecationWarning into a raise, and the message routes readers to
    the consolidated plan-validation API."""
    monkeypatch.setenv("REPRO_STRICT_DEPRECATION", "1")
    net, n_iter = make_motion_detection()
    with pytest.raises(DeprecationWarning,
                       match="ExecutionPlan.*validate"):
        compile_static(net, n_iter)
    with pytest.raises(DeprecationWarning, match="compile_dynamic"):
        compile_dynamic(net)
    with pytest.raises(DeprecationWarning, match="run_interpreted"):
        run_interpreted(net, net.init_state(), n_iter)


def test_plan_rejects_unknown_accelerated_actor():
    net, _ = make_motion_detection()
    with pytest.raises(ValueError, match="unknown actors.*nosuch"):
        net.compile(mode="static", n_iterations=3, accelerated=("nosuch",))


def test_stream_requires_heterogeneous_plan():
    net, n_iter = make_motion_detection()
    prog = net.compile(mode="static", n_iterations=n_iter)
    with pytest.raises(ValueError, match="accelerated"):
        prog.stream({})


def test_stream_rejects_period_misaligned_chunk_up_front():
    """A specialized static plan whose chunk does not cover whole unroll
    periods must fail before any chunk runs (not mid-stream with a
    phase-alignment error blaming the resumed state)."""
    net, _ = make_motion_detection(n_frames=16, rate=4)
    prog = net.compile(mode="static", n_iterations=1,
                       accelerated=("gauss", "thres", "med"))
    with pytest.raises(ValueError, match="phase-unroll period"):
        prog.stream({"f_src_gauss": np.zeros((4, 4, 48, 64), np.uint8)})
    # specialize=False has no alignment constraint: same chunking runs.
    prog2 = net.compile(mode="static", n_iterations=1, specialize=False,
                        accelerated=("gauss", "thres", "med"))
    outs = prog2.stream({"f_src_gauss": np.zeros((4, 4, 48, 64), np.uint8)})
    assert outs["f_med_sink"].shape == (4, 4, 48, 64)


# --------------------------------------------------------------------------- #
# The chunked host-feed/fetch loop.
# --------------------------------------------------------------------------- #
def test_stream_equals_single_run_md():
    """Streaming the MD accelerator subnetwork chunk-by-chunk == one long
    run: actor and internal-FIFO state (the Fig. 4 delay token!) carries
    across chunk boundaries."""
    n_frames, rate = 24, 4
    net, n_iter = make_motion_detection(n_frames=n_frames, rate=rate)
    accel = ("gauss", "thres", "med")
    # Chunk of 3 iterations = one delay-channel phase cycle... but the
    # unroll period is LCM(2,3)=6, so use 6 for the specialized path.
    prog = net.compile(mode="static", n_iterations=6, accelerated=accel)
    rng = np.random.default_rng(1)
    video = jnp.asarray(
        np.clip(np.round(rng.uniform(0, 255, (n_frames, 48, 64))), 0, 255)
        .astype(np.uint8))
    feeds = {"f_src_gauss": video.reshape(n_iter, rate, 48, 64)}
    outs = prog.stream(feeds)
    assert set(outs) == {"f_med_sink"}
    assert outs["f_med_sink"].shape == (n_iter, rate, 48, 64)
    # Oracle: the full network in one compiled run.
    full = net.compile(mode="static", n_iterations=n_iter)
    st = full.run().state
    want = np.asarray(full.collect("sink", st))
    np.testing.assert_array_equal(
        np.asarray(outs["f_med_sink"]).reshape(n_frames, 48, 64), want)


def test_stream_accepts_flat_feed_and_checks_shapes():
    net, n_iter = make_motion_detection(n_frames=24, rate=4)
    prog = net.compile(mode="static", n_iterations=6,
                       accelerated=("gauss", "thres", "med"))
    with pytest.raises(ValueError, match="unknown feed channels"):
        prog.stream({"nope": np.zeros((6, 4, 48, 64))})
    with pytest.raises(ValueError, match="missing feeds"):
        prog.stream({})
    with pytest.raises(ValueError, match="expected"):
        prog.stream({"f_src_gauss": np.zeros((6, 3, 48, 64))})
    with pytest.raises(ValueError, match="do not divide"):
        prog.stream({"f_src_gauss": np.zeros((4, 4, 48, 64))})
    # Flattened token stream is reshaped into windows.
    flat = np.zeros((24, 48, 64), np.uint8)
    outs = prog.stream({"f_src_gauss": flat})
    assert outs["f_med_sink"].shape == (6, 4, 48, 64)


def test_stream_dynamic_mode_dpd():
    """Heterogeneous placement composes with the dynamic scheduler: the
    DPD compute subnetwork (all but source/sink) streamed in chunks."""
    net, n_firings = make_dpd()
    accel = tuple(n for n in net.actors if n not in ("source", "sink"))
    prog = net.compile(mode="dynamic", n_iterations=2, accelerated=accel)
    # Same windows the staged source emits: sig[:, i*L:(i+1)*L] per firing.
    rng = np.random.default_rng(0)
    sig = rng.normal(size=(2, n_firings * 128)).astype(np.float32)
    wins = np.stack([sig[:, i * 128:(i + 1) * 128]
                     for i in range(n_firings)])[:, None]
    outs = prog.stream({"f_in": jnp.asarray(wins)})
    full = net.compile(ExecutionPlan(mode="dynamic"))
    st = full.run().state
    want = np.asarray(full.collect("sink", st))      # (2, n_firings * L)
    got = np.concatenate(list(np.asarray(outs["f_out"])[:, 0]), axis=1)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_stream_per_chunk_feeds_validated_across_chunks():
    """Per-chunk feed lists: chunk 2+ drifting in dtype or shape must be
    rejected naming the chunk and channel, never silently staged (the
    cross-chunk validation gap — only chunk 0 was effectively checked)."""
    net, n_iter = make_motion_detection(n_frames=48)
    prog = net.compile(mode="static", n_iterations=6,
                       accelerated=("gauss", "thres", "med"))
    video = np.zeros((48, 48, 64), np.uint8).reshape(12, 4, 48, 64)
    ref = prog.stream({"f_src_gauss": video})
    outs = prog.stream({"f_src_gauss": [video[:6], video[6:]]})
    np.testing.assert_array_equal(np.asarray(ref["f_med_sink"]),
                                  np.asarray(outs["f_med_sink"]))
    with pytest.raises(ValueError, match=r"chunk 1 carries dtype float32"):
        prog.stream({"f_src_gauss": [video[:6],
                                     video[6:].astype(np.float32)]})
    with pytest.raises(ValueError, match=r"chunk 1 has window shape"):
        prog.stream({"f_src_gauss": [video[:6], video[6:9]]})
    with pytest.raises(ValueError, match=r"chunk 0 covers 3 windows"):
        prog.stream({"f_src_gauss": [video[:3], video[3:6]]})
    with pytest.raises(ValueError, match="empty per-chunk list"):
        prog.stream({"f_src_gauss": []})


def test_stream_persistent_feed_identical_and_stages_less():
    """Persistent-feed mode: one full-length entry, bit-identical fetch
    windows, and — on the megakernel, whose chunked loop re-stages every
    ring HBM->scratch per entry — strictly fewer staged bytes per chunk
    (reported via Program.stats().last_stream_*)."""
    net, n_firings = _make_dpd(n_firings=8, block_l=128)
    accel = tuple(n for n in net.actors if n not in ("source", "sink"))
    rng = np.random.default_rng(0)
    sig = rng.normal(size=(8, n_firings * 128)).astype(np.float32)
    wins = np.stack([sig[:2, i * 128:(i + 1) * 128]
                     for i in range(n_firings)])[:, None]
    prog = net.compile(ExecutionPlan(mode="megakernel", n_iterations=4,
                                     accelerated=accel, specialize=False))
    ref = prog.stream({"f_in": jnp.asarray(wins)})
    chunked = prog.stats()
    assert chunked.last_stream_chunks == 2
    assert chunked.last_stream_persistent is False
    outs = prog.stream({"f_in": jnp.asarray(wins)}, persistent=True)
    for name in ref:
        np.testing.assert_array_equal(np.asarray(ref[name]),
                                      np.asarray(outs[name]))
    persistent = prog.stats()
    assert persistent.last_stream_chunks == 2
    assert persistent.last_stream_persistent is True
    # The ring/cursor scratch restage disappears from the per-chunk bill.
    assert (persistent.last_stream_staged_bytes_per_chunk
            < chunked.last_stream_staged_bytes_per_chunk)
    assert (persistent.last_stream_total_staged_bytes
            < chunked.last_stream_total_staged_bytes)
    # PR 10 lifted the persistent x on_fault restriction (a faulting
    # persistent run now falls back to the chunked loop); the remaining
    # invalid combo is persistent x checkpoint_dir — a single kernel
    # entry has no chunk boundaries to snapshot at.
    outs2 = prog.stream({"f_in": jnp.asarray(wins)}, persistent=True,
                        on_fault="skip")
    for name in ref:
        np.testing.assert_array_equal(np.asarray(ref[name]),
                                      np.asarray(outs2[name]))
    with pytest.raises(ValueError, match="persistent=True.*checkpoint_dir"):
        prog.stream({"f_in": jnp.asarray(wins)}, persistent=True,
                    checkpoint_dir="/tmp/nope")
    # collect() stays guarded after a persistent stream too.
    with pytest.raises(ValueError, match="stream"):
        prog.collect("sink")


def test_donate_with_default_state_does_not_poison_network():
    """run(None) under a donate plan must copy the auto-created state:
    init_state() aliases the staged source slab, and donating it would
    delete the buffer for every later init_state() of the network."""
    net, n_iter = make_dpd()
    prog = net.compile(mode="static", n_iterations=n_iter, donate=True)
    a = np.asarray(prog.run().state.actor("sink")[0])
    b = np.asarray(prog.run().state.actor("sink")[0])  # crashed pre-fix
    np.testing.assert_array_equal(a, b)
    keep = net.compile(mode="static", n_iterations=n_iter).run().state
    np.testing.assert_array_equal(a, np.asarray(keep.actor("sink")[0]))


# --------------------------------------------------------------------------- #
# Stats.
# --------------------------------------------------------------------------- #
def test_stats_reports_roofline_and_sweeps():
    net, _ = make_dpd()
    prog = net.compile(ExecutionPlan(mode="dynamic"))
    st = prog.stats()
    assert st.last_sweeps is None                 # nothing ran yet
    prog.run()
    st = prog.stats()
    assert st.mode == "dynamic"
    assert st.n_actors == len(net.actors) and st.n_fifos == len(net.fifos)
    assert st.buffer_bytes == net.buffer_bytes()
    assert st.last_sweeps >= 1
    assert st.last_fire_counts["config"] == 4
    # Roofline coordinates: poly branches have FLOP annotations and move
    # window bytes, so their intensity is positive.
    assert st.actor_flops["poly0"] > 0
    assert st.actor_window_bytes["poly0"] > 0
    assert st.actor_intensity["poly0"] == pytest.approx(
        st.actor_flops["poly0"] / st.actor_window_bytes["poly0"])
    assert set(st.register_fifos) == set(net.register_fifos)


# --------------------------------------------------------------------------- #
# O(1) state accessors (precomputed name->index maps).
# --------------------------------------------------------------------------- #
def test_state_accessors_use_index_maps():
    net, _ = make_motion_detection()
    state = net.init_state()
    m = name_index_map(state.fifo_names)
    assert m is name_index_map(state.fifo_names)   # cached per name tuple
    for i, name in enumerate(state.fifo_names):
        assert m[name] == i
        assert state.fifo(name) is state.fifos[i]
    for i, name in enumerate(state.actor_names):
        assert state.actor(name) is state.actors[i]
