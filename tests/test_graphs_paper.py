"""Paper application graphs: Motion Detection (§4.1) + DPD (§4.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ExecutionPlan, RuntimeMode, assert_mode_allows
from repro.graphs.dpd import build_dpd
from repro.graphs.motion_detection import build_motion_detection
from repro.kernels.dyn_fir import N_TAPS, branch_ref
from repro.kernels.gauss5x5 import gauss5x5
from repro.kernels.motion_post import med_ref, thres_ref


def _md_oracle(video_np):
    u8 = lambda x: np.clip(np.round(x), 0, 255).astype(np.uint8)
    NF, H, W = video_np.shape
    vid = u8(video_np).astype(np.float32)
    g = np.stack([u8(np.asarray(gauss5x5(jnp.asarray(v), impl="xla")))
                  .astype(np.float32) for v in vid])
    prev = np.concatenate([np.zeros((1, H, W), np.float32), g[:-1]])
    return np.stack([u8(np.asarray(med_ref(thres_ref(jnp.asarray(g[i]),
                                                     jnp.asarray(prev[i])))))
                     for i in range(NF)])


@pytest.mark.parametrize("rate", [1, 4])
def test_motion_detection_matches_oracle(rng, rate):
    NF, H, W = 8, 48, 64
    video = rng.uniform(0, 255, (NF, H, W)).astype(np.float32)
    net = build_motion_detection(NF, rate=rate, frame_hw=(H, W),
                                 video=jnp.asarray(video))
    prog = net.compile(mode="static", n_iterations=NF // rate)
    prog.run()
    np.testing.assert_allclose(np.asarray(prog.collect("sink")),
                               _md_oracle(video))


def test_motion_detection_buffer_memory_table1():
    """Eq. 1 totals reproduce paper Table 1 (3.46 MB heterog config)."""
    assert abs(build_motion_detection(8, rate=4).buffer_bytes() / 1e6 - 3.456) < 1e-3
    assert abs(build_motion_detection(8, rate=1).buffer_bytes() / 1e6 - 0.922) < 1e-3


def test_dpd_buffer_memory_table1():
    assert abs(build_dpd(4).buffer_bytes() / 1e6 - 11.53) < 0.1  # paper: 11.5


def _dpd_oracle(sig_np, sched, L):
    taps = [np.random.default_rng(100 + k).normal(scale=0.3, size=(2, N_TAPS))
            .astype(np.float32) for k in range(10)]
    hist = [np.zeros((2, N_TAPS - 1), np.float32) for _ in range(10)]
    out = np.zeros_like(sig_np)
    for f in range(len(sched)):
        win = sig_np[:, f * L:(f + 1) * L]
        acc = np.zeros((2, L), np.float32)
        for k in range(10):
            if k < sched[f]:
                xin = np.concatenate([hist[k], win], axis=1)
                yr, yi = branch_ref(jnp.asarray(xin[0]), jnp.asarray(xin[1]),
                                    jnp.asarray(taps[k][0]), jnp.asarray(taps[k][1]),
                                    k + 1)
                acc[0] += np.asarray(yr)
                acc[1] += np.asarray(yi)
                hist[k] = xin[:, -(N_TAPS - 1):]
        out[:, f * L:(f + 1) * L] = acc
    return out


def test_dpd_dynamic_rates_match_oracle(rng):
    NF, L = 4, 1024
    sig = rng.normal(size=(2, NF * L)).astype(np.float32)
    sched = np.array([2, 2, 10, 5], np.int32)
    net = build_dpd(NF, active_schedule=sched, block_l=L,
                    signal=jnp.asarray(sig))
    prog = net.compile(mode="static", n_iterations=NF)
    prog.run()
    got = np.asarray(prog.collect("sink"))
    np.testing.assert_allclose(got, _dpd_oracle(sig, sched, L),
                               rtol=6e-4, atol=6e-4)
    # token-driven scheduler agrees
    dyn = net.compile(ExecutionPlan(mode="dynamic"))
    result = dyn.run()
    np.testing.assert_allclose(np.asarray(dyn.collect("sink")),
                               _dpd_oracle(sig, sched, L), rtol=6e-4, atol=6e-4)
    assert int(result.fire_counts["config"]) == NF


def test_dpd_static_variant_is_dal_compatible(rng):
    """The all-active rewrite runs under STATIC_DAL; the dynamic graph is
    rejected — reproducing the paper's 'n/a' cells in Table 4."""
    NF, L = 2, 512
    sig = rng.normal(size=(2, NF * L)).astype(np.float32)
    dyn = build_dpd(NF, block_l=L, signal=jnp.asarray(sig))
    with pytest.raises(ValueError, match="STATIC_DAL"):
        assert_mode_allows(dyn, RuntimeMode.STATIC_DAL)
    static = build_dpd(NF, block_l=L, signal=jnp.asarray(sig),
                       static_all_active=True)
    assert_mode_allows(static, RuntimeMode.STATIC_DAL)
    static.compile(mode="static", n_iterations=NF,
                   runtime_mode=RuntimeMode.STATIC_DAL).run()


def test_lm_pipeline_stage_network_matches_reference():
    """The fourth paper graph on the unified surface: LM pipeline stages as
    a builder-constructed actor network, executed by Program, == the
    sequential stage oracle."""
    from repro.configs import smoke_config
    from repro.graphs.lm_pipeline import (build_lm_stage_network,
                                          lm_stage_network_forward,
                                          pipeline_forward_reference)
    from repro.models.lm import init_params
    cfg = smoke_config("granite-8b")
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    tokens = jax.random.randint(key, (4, 16), 0, cfg.vocab)
    got = lm_stage_network_forward(params, cfg, tokens, n_stages=2)
    want = pipeline_forward_reference(params, cfg, tokens, n_stages=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2)
    # The network also streams: stages accelerated, activations fed/fetched
    # chunk-by-chunk through the boundary channels.
    net = build_lm_stage_network(params, cfg, tokens, n_stages=2)
    prog = net.compile(mode="static", n_iterations=2,
                       accelerated=("stage0", "stage1"))
    x = net.actors["source"].init()[0]               # staged activations
    outs = prog.stream({"f_s0": np.asarray(x)[:, None]})
    full = net.compile(mode="static", n_iterations=4)
    want_y = np.asarray(full.collect("sink", full.run().state))
    np.testing.assert_allclose(np.asarray(outs["f_out"])[:, 0], want_y,
                               rtol=1e-5, atol=1e-5)


def test_dpd_static_equals_dynamic_all_active(rng):
    """With every branch enabled the dynamic and static graphs agree."""
    NF, L = 3, 512
    sig = rng.normal(size=(2, NF * L)).astype(np.float32)
    sched = np.full(NF, 10, np.int32)
    dyn = build_dpd(NF, active_schedule=sched, block_l=L, signal=jnp.asarray(sig))
    sta = build_dpd(NF, block_l=L, signal=jnp.asarray(sig), static_all_active=True)
    pd = dyn.compile(mode="static", n_iterations=NF)
    ps = sta.compile(mode="static", n_iterations=NF)
    a = np.asarray(pd.collect("sink", pd.run().state))
    b = np.asarray(ps.collect("sink", ps.run().state))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)
