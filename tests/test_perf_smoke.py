"""Benchmark bit-rot guard: run every benchmarks/run.py section tiny.

The benchmark harness used to be exercised only at bench time, so API
drift in the executors/graphs surfaced weeks later as
``<section>_ERROR`` rows.  This smoke test runs each section in a fast
configuration (tiny sizes, minimal reps) inside tier-1 so a broken
section fails CI immediately.  Only the *contract* is asserted — rows of
``(name, us_per_call, derived)`` with no ERROR markers — never absolute
timings, which are meaningless on a shared CPU.
"""
import json
import os
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if REPO_ROOT not in sys.path:
    sys.path.insert(0, REPO_ROOT)  # benchmarks/ is a plain directory


def check_rows(rows):
    assert rows, "section produced no rows"
    for name, us, derived in rows:
        assert isinstance(name, str) and name, rows
        assert "ERROR" not in name, (name, derived)
        assert isinstance(us, (int, float)), rows
        assert isinstance(derived, str), rows


def test_bench_buffers():
    from benchmarks.bench_paper_tables import bench_buffers
    check_rows(bench_buffers())


def test_bench_motion_detection_fast():
    from benchmarks.bench_paper_tables import bench_motion_detection
    check_rows(bench_motion_detection(n_frames=8))


def test_bench_dpd_fast():
    from benchmarks.bench_paper_tables import bench_dpd
    check_rows(bench_dpd(n_firings=4, block_l=1024))


def test_bench_executors_fast(tmp_path):
    from benchmarks.bench_executors import bench_executors
    json_path = str(tmp_path / "BENCH_executors.json")
    rows = bench_executors(fast=True, json_path=json_path)
    check_rows(rows)
    # The dynamic-scheduler acceptance claims must hold even at tiny sizes:
    # strictly fewer sweeps, bit-identical final states.
    reductions = [d for n, _, d in rows if n.endswith("dynamic_sweep_reduction")]
    assert len(reductions) == 2
    for derived in reductions:
        assert "strictly fewer: True" in derived, derived
        assert "bit-identical states: True" in derived, derived
    # Machine-readable trajectory: one record per executor x graph.
    with open(json_path) as f:
        records = json.load(f)
    names = {r["name"] for r in records}
    for g in ("dpd", "md"):
        for e in ("static_baseline", "static_specialized",
                  "static_specialized_donated", "dynamic_baseline",
                  "dynamic_multi_firing"):
            assert f"exec_{g}_{e}" in names, sorted(names)
    for r in records:
        assert r["us_per_call"] > 0
        assert r["tokens_per_s"] > 0


def test_bench_megakernel_fast(tmp_path):
    from benchmarks.bench_megakernel import bench_megakernel
    json_path = str(tmp_path / "BENCH_megakernel.json")
    rows = bench_megakernel(fast=True, json_path=json_path)
    check_rows(rows)
    # The megakernel acceptance claim must hold at tiny sizes too:
    # bit-identical states/counts/sweeps vs the host dynamic scheduler.
    ident = [d for n, _, d in rows if n.endswith("_vs_dynamic")]
    assert len(ident) == 2
    for derived in ident:
        assert "bit-identical: True" in derived, derived
    # Grid-parallel sweeps: every core count must stay bit-identical.
    grid = [d for n, _, d in rows if n.endswith("_grid_vs_single")]
    assert len(grid) == 2
    for derived in grid:
        assert "grid bit-identical: True" in derived, derived
    scratch = [d for n, _, d in rows if n.endswith("_scratch_bytes")]
    assert len(scratch) == 2 and all("scratch" in d for d in scratch)
    splits = [d for n, _, d in rows if n.endswith("_grid_ring_split")]
    assert len(splits) == 2 and all("shared" in d for d in splits)
    with open(json_path) as f:
        records = json.load(f)
    names = {r["name"] for r in records}
    for g in ("dpd", "moe"):
        for e in ("dynamic_host", "megakernel", "static_specialized",
                  "grid2", "grid4"):
            assert f"mega_{g}_{e}" in names, sorted(names)
    for r in records:
        assert r["us_per_call"] > 0
        assert r["tokens_per_s"] > 0
    # Kernel rows carry the structure fields the regression gate compares
    # exactly (sweep/round counts, the core count, and the scratch-diet
    # telemetry: effective scratch, shared rings+semaphores, forwarded
    # channel count).
    by_name = {r["name"]: r for r in records}
    for g in ("dpd", "moe"):
        for e, cores in (("megakernel", 1), ("grid2", 2), ("grid4", 4)):
            rec = by_name[f"mega_{g}_{e}"]
            assert rec["cores"] == cores and rec["sweeps"] >= 1, rec
            assert rec["scratch_bytes"] > 0, rec
            assert rec["shared_scratch_bytes"] >= 0, rec
            assert rec["forwarded_fifos"] >= 0, rec
        # Transient forwarding is live: the single-core row forwards
        # channels and holds strictly less scratch than any no-diet
        # layout could (dpd forwards everything).
        assert by_name[f"mega_{g}_megakernel"]["forwarded_fifos"] > 0
        assert by_name[f"mega_{g}_megakernel"]["shared_scratch_bytes"] == 0


def test_bench_serving_fast(tmp_path):
    from benchmarks.bench_serving import bench_serving
    json_path = str(tmp_path / "BENCH_serving.json")
    rows = bench_serving(fast=True, json_path=json_path)
    check_rows(rows)
    # The continuous-batching acceptance claims at tiny sizes: the actor
    # engine sustains more tok/s than fixed batches, and persistent-feed
    # streaming stages fewer bytes per chunk.
    vs = [d for n, _, d in rows if n == "serve_actor_vs_legacy"]
    assert len(vs) == 1 and "beats: True" in vs[0], vs
    cut = [d for n, _, d in rows if n == "serve_stream_staging_cut"]
    assert len(cut) == 1 and "reduces: True" in cut[0], cut
    with open(json_path) as f:
        records = json.load(f)
    by_name = {r["name"]: r for r in records}
    for name in ("serve_legacy_fixed_batch", "serve_actor_continuous",
                 "serve_stream_chunked", "serve_stream_persistent"):
        assert name in by_name, sorted(by_name)
        assert by_name[name]["us_per_call"] > 0
        assert by_name[name]["tokens_per_s"] > 0
    # Latency percentiles are structure fields: deterministic in steps.
    assert (by_name["serve_actor_continuous"]["p99_latency_steps"]
            <= by_name["serve_legacy_fixed_batch"]["p99_latency_steps"])
    assert (by_name["serve_stream_persistent"]["staged_bytes_per_chunk"]
            < by_name["serve_stream_chunked"]["staged_bytes_per_chunk"])


def test_bench_resilience_fast(tmp_path):
    from benchmarks.bench_resilience import bench_resilience
    json_path = str(tmp_path / "BENCH_resilience.json")
    rows = bench_resilience(fast=True, json_path=json_path)
    check_rows(rows)
    # The resilience acceptance claim at tiny sizes: goodput degrades
    # proportionally to the shed rate — shedding costs the shed work,
    # not the survivors'.
    prop = [d for n, _, d in rows if n == "resil_goodput_proportional"]
    assert len(prop) == 1 and "proportionally: True" in prop[0], prop
    with open(json_path) as f:
        records = json.load(f)
    by_name = {r["name"]: r for r in records}
    for name in ("resil_baseline", "resil_deadline_light",
                 "resil_deadline_tight", "resil_quarantine",
                 "resil_ckpt_off", "resil_ckpt_every_2",
                 "resil_ckpt_every_8"):
        assert name in by_name, sorted(by_name)
        assert by_name[name]["us_per_call"] > 0
        assert by_name[name]["tokens_per_s"] > 0
    # Status counts are deterministic structure: the baseline sheds
    # nothing, the tight deadline sheds at least one request, and the
    # quarantine cell retires exactly the poisoned request as a fault.
    assert by_name["resil_baseline"]["n_timeout"] == 0
    assert by_name["resil_baseline"]["n_shed"] == 0
    tight = by_name["resil_deadline_tight"]
    assert tight["n_timeout"] + tight["n_shed"] >= 1
    assert tight["goodput_fraction"] < 1.0
    assert by_name["resil_quarantine"]["n_fault"] == 1
    assert by_name["resil_quarantine"]["retries"] == 1
    # Checkpoint cadence shows up as segment counts, not lost work.
    assert (by_name["resil_ckpt_every_2"]["segments"]
            > by_name["resil_ckpt_every_8"]["segments"])


def test_bench_shard_fast(tmp_path):
    from benchmarks.bench_shard import bench_shard
    json_path = str(tmp_path / "BENCH_shard.json")
    rows = bench_shard(fast=True, json_path=json_path)
    check_rows(rows)
    # The sharding acceptance claim at tiny sizes: every device count
    # stays bit-identical to the single-device dynamic executor.
    idents = [d for n, _, d in rows if "bit-identical" in d]
    assert idents and all("bit-identical: True" in d for d in idents)
    with open(json_path) as f:
        records = json.load(f)
    by_name = {r["name"]: r for r in records}
    for g in ("dpd", "moe"):
        for k in (1, 2, 4):
            rec = by_name[f"shard_{g}_dev{k}"]
            assert rec["devices"] == k and rec["rounds"] >= 1, rec
            assert rec["us_per_call"] > 0 and rec["tokens_per_s"] > 0
            assert rec["bit_identical"] is True, rec
            if k > 1:
                # Crossing rings + cursor pairs + the quiescence flag
                # move every barrier round — never free at k > 1.
                assert rec["collective_bytes_per_sweep"] > 0, rec
            else:
                assert "collective_bytes_per_sweep" not in rec
        # More devices -> more crossing channels on these contiguous
        # cuts: the exchange bill grows with the cut count.
        assert (by_name[f"shard_{g}_dev4"]["collective_bytes_per_sweep"]
                >= by_name[f"shard_{g}_dev2"]["collective_bytes_per_sweep"])


def test_check_regression_compare_logic():
    """The gate's verdict logic, on synthetic records (no bench run)."""
    from benchmarks.check_regression import _merge, compare

    base = {"a": {"name": "a", "tokens_per_s": 100.0, "sweeps": 3},
            "b": {"name": "b", "tokens_per_s": 100.0},
            "c": {"name": "c", "tokens_per_s": 100.0}}
    # Machine 2x faster across the board; "b" relatively 2.5x slower.
    fresh = {"a": {"name": "a", "tokens_per_s": 200.0, "sweeps": 3},
             "b": {"name": "b", "tokens_per_s": 80.0},
             "c": {"name": "c", "tokens_per_s": 200.0},
             "d": {"name": "d", "tokens_per_s": 50.0}}
    v = compare(base, fresh, floor=0.85)
    assert v["a"]["status"] == "ok"           # calibrated 1.0x
    assert v["b"]["status"] == "slow"
    assert v["d"]["status"] == "new"
    # Structure drift fails even when throughput looks fine.
    drift = dict(fresh, a={"name": "a", "tokens_per_s": 200.0, "sweeps": 4})
    assert compare(base, drift, floor=0.85)["a"]["status"] == "structure"
    # Scratch-diet fields gate the same way: a scratch (or forwarded
    # count) regression is a structure failure, not a timing one.
    sbase = {"k": {"name": "k", "tokens_per_s": 100.0, "sweeps": 3,
                   "scratch_bytes": 408, "forwarded_fifos": 34}}
    bloat = {"k": {"name": "k", "tokens_per_s": 100.0, "sweeps": 3,
                   "scratch_bytes": 45560, "forwarded_fifos": 0}}
    assert compare(sbase, bloat, floor=0.85)["k"]["status"] == "structure"
    assert compare(sbase, {"k": dict(sbase["k"])},
                   floor=0.85)["k"]["status"] == "ok"
    # Missing row.
    gone = {k: r for k, r in fresh.items() if k != "c"}
    assert compare(base, gone, floor=0.85)["c"]["status"] == "missing"
    # Retry semantics: a row that recovers in any attempt merges to ok;
    # a persistent slow row keeps its best (highest-ratio) verdict.
    slow1 = compare(base, fresh, floor=0.85)
    ok2 = compare(base, dict(fresh, b={"name": "b", "tokens_per_s": 200.0}),
                  floor=0.85)
    assert _merge([slow1, ok2])["b"]["status"] == "ok"
    assert _merge([slow1, slow1])["b"]["status"] == "slow"
    # Structure/missing verdicts are STICKY: a later lucky attempt must
    # not launder a deterministic drift back to ok (in either order).
    drifted = compare(base, drift, floor=0.85)
    clean = compare(base, dict(fresh, b={"name": "b", "tokens_per_s": 200.0}),
                    floor=0.85)
    assert _merge([drifted, clean])["a"]["status"] == "structure"
    assert _merge([clean, drifted])["a"]["status"] == "structure"
    lost = compare(base, gone, floor=0.85)
    assert _merge([lost, clean])["c"]["status"] == "missing"


def test_bench_kernels():
    from benchmarks.bench_kernels import bench_kernels
    check_rows(bench_kernels())


def test_bench_roofline():
    from benchmarks.roofline import bench_roofline
    check_rows(bench_roofline())


def test_actor_roofline_rows_cover_graph():
    # The live actor-level rows keep the roofline section exercised even
    # with no results/dryrun.json: one row per DPD actor, intensity
    # consistent with the stats it was computed from.
    from benchmarks.roofline import actor_roofline_rows
    from repro.graphs.factories import make_dpd

    rows = actor_roofline_rows()
    check_rows(rows)
    net, _ = make_dpd(n_firings=4, block_l=256)
    names = {f"actor_roofline_dpd_{nm}" for nm in net.actors}
    got = {name for name, _, _ in rows}
    assert names <= got
    assert "actor_roofline_dpd_iteration_flops" in got
    assert all("intensity=" in derived for name, _, derived in rows
               if name in names)
