"""Benchmark bit-rot guard: run every benchmarks/run.py section tiny.

The benchmark harness used to be exercised only at bench time, so API
drift in the executors/graphs surfaced weeks later as
``<section>_ERROR`` rows.  This smoke test runs each section in a fast
configuration (tiny sizes, minimal reps) inside tier-1 so a broken
section fails CI immediately.  Only the *contract* is asserted — rows of
``(name, us_per_call, derived)`` with no ERROR markers — never absolute
timings, which are meaningless on a shared CPU.
"""
import json
import os
import sys

import pytest

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if REPO_ROOT not in sys.path:
    sys.path.insert(0, REPO_ROOT)  # benchmarks/ is a plain directory


def check_rows(rows):
    assert rows, "section produced no rows"
    for name, us, derived in rows:
        assert isinstance(name, str) and name, rows
        assert "ERROR" not in name, (name, derived)
        assert isinstance(us, (int, float)), rows
        assert isinstance(derived, str), rows


def test_bench_buffers():
    from benchmarks.bench_paper_tables import bench_buffers
    check_rows(bench_buffers())


def test_bench_motion_detection_fast():
    from benchmarks.bench_paper_tables import bench_motion_detection
    check_rows(bench_motion_detection(n_frames=8))


def test_bench_dpd_fast():
    from benchmarks.bench_paper_tables import bench_dpd
    check_rows(bench_dpd(n_firings=4, block_l=1024))


def test_bench_executors_fast(tmp_path):
    from benchmarks.bench_executors import bench_executors
    json_path = str(tmp_path / "BENCH_executors.json")
    rows = bench_executors(fast=True, json_path=json_path)
    check_rows(rows)
    # The dynamic-scheduler acceptance claims must hold even at tiny sizes:
    # strictly fewer sweeps, bit-identical final states.
    reductions = [d for n, _, d in rows if n.endswith("dynamic_sweep_reduction")]
    assert len(reductions) == 2
    for derived in reductions:
        assert "strictly fewer: True" in derived, derived
        assert "bit-identical states: True" in derived, derived
    # Machine-readable trajectory: one record per executor x graph.
    with open(json_path) as f:
        records = json.load(f)
    names = {r["name"] for r in records}
    for g in ("dpd", "md"):
        for e in ("static_baseline", "static_specialized",
                  "static_specialized_donated", "dynamic_baseline",
                  "dynamic_multi_firing"):
            assert f"exec_{g}_{e}" in names, sorted(names)
    for r in records:
        assert r["us_per_call"] > 0
        assert r["tokens_per_s"] > 0


def test_bench_megakernel_fast(tmp_path):
    from benchmarks.bench_megakernel import bench_megakernel
    json_path = str(tmp_path / "BENCH_megakernel.json")
    rows = bench_megakernel(fast=True, json_path=json_path)
    check_rows(rows)
    # The megakernel acceptance claim must hold at tiny sizes too:
    # bit-identical states/counts/sweeps vs the host dynamic scheduler.
    ident = [d for n, _, d in rows if n.endswith("_vs_dynamic")]
    assert len(ident) == 2
    for derived in ident:
        assert "bit-identical: True" in derived, derived
    scratch = [d for n, _, d in rows if n.endswith("_scratch_bytes")]
    assert len(scratch) == 2 and all("scratch" in d for d in scratch)
    with open(json_path) as f:
        records = json.load(f)
    names = {r["name"] for r in records}
    for g in ("dpd", "moe"):
        for e in ("dynamic_host", "megakernel", "static_specialized"):
            assert f"mega_{g}_{e}" in names, sorted(names)
    for r in records:
        assert r["us_per_call"] > 0
        assert r["tokens_per_s"] > 0


def test_bench_kernels():
    from benchmarks.bench_kernels import bench_kernels
    check_rows(bench_kernels())


def test_bench_roofline():
    from benchmarks.roofline import bench_roofline
    check_rows(bench_roofline())
