"""Multi-device sharded execution (ExecutionPlan(devices=k)) and the
consolidated plan-validation API.

Validation rules run in the main (single-device) process — every
``ExecutionPlan.validate`` error path is cheap to hit because validation
precedes any build.  Bit-identity against the single-device dynamic
executor needs a visible mesh, so those tests run in a subprocess under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the
test_distribution pattern)."""
import itertools

import jax
import pytest

from _graph_factories import make_dpd, make_moe, make_motion_detection
from repro.core import ExecutionPlan
from test_distribution import run_sub


@pytest.fixture(scope="module")
def dpd():
    net, _ = make_dpd(n_firings=4, block_l=128)
    return net


# --------------------------------------------------------------------------- #
# Field-local checks (construction time).
# --------------------------------------------------------------------------- #
def test_devices_field_value_checks():
    for bad in (0, -1, 1.5, "2", True):
        with pytest.raises(ValueError, match="devices must be"):
            ExecutionPlan(mode="dynamic", devices=bad)
    # The record itself constructs at any k; device availability is a
    # compile-time concern.
    assert ExecutionPlan(mode="dynamic", devices=64).devices == 64


def test_device_assign_freezes_to_sorted_tuple():
    plan = ExecutionPlan(mode="dynamic", devices=2,
                         device_assign={"b": 1, "a": 0})
    assert plan.device_assign == (("a", 0), ("b", 1))


# --------------------------------------------------------------------------- #
# Cross-field rules: every devices-related validate() error path.
# --------------------------------------------------------------------------- #
def test_validate_rejects_devices_with_cores(dpd):
    with pytest.raises(ValueError, match="exclusive"):
        ExecutionPlan(mode="megakernel", cores=2, devices=2).validate(dpd)


def test_validate_rejects_device_assign_without_devices(dpd):
    with pytest.raises(ValueError, match="requires devices > 1"):
        ExecutionPlan(mode="dynamic",
                      device_assign={"src": 0}).validate(dpd)


def test_validate_rejects_devices_off_dynamic(dpd):
    with pytest.raises(ValueError, match="dynamic executor per device"):
        ExecutionPlan(mode="static", n_iterations=4,
                      devices=2).validate(dpd)
    with pytest.raises(ValueError, match="dynamic executor per device"):
        ExecutionPlan(mode="megakernel", devices=2).validate(dpd)


def test_validate_rejects_devices_with_accelerated(dpd):
    with pytest.raises(ValueError, match="mesh IS the accelerator"):
        ExecutionPlan(mode="dynamic", devices=2, n_iterations=2,
                      accelerated=tuple(dpd.actors)[:1]).validate(dpd)


def test_validate_device_assign_totality_and_range(dpd):
    names = list(dpd.actors)
    with pytest.raises(ValueError, match="every actor to a device"):
        ExecutionPlan(mode="dynamic", devices=2,
                      device_assign={names[0]: 0}).validate(dpd)
    bad = {n: 0 for n in names}
    bad[names[-1]] = 2
    with pytest.raises(ValueError, match=r"devices outside \[0, 2\)"):
        ExecutionPlan(mode="dynamic", devices=2,
                      device_assign=bad).validate(dpd)
    with pytest.raises(ValueError, match="unknown actors"):
        ExecutionPlan(mode="dynamic", devices=2,
                      device_assign={**{n: 0 for n in names},
                                     "ghost": 1}).validate(dpd)


def test_validate_rejects_delay_channel_crossing_devices():
    """Same partition legality as the megakernel grid, 'device' wording:
    a delay channel with delay < rate may not cross the mesh cut."""
    net, _ = make_motion_detection(n_frames=12, rate=4, frame_hw=(48, 64))
    assign = {"source": 0, "gauss": 0, "thres": 1, "med": 1, "sink": 1}
    with pytest.raises(ValueError,
                       match="may not cross partitions.*one device"):
        ExecutionPlan(mode="dynamic", devices=2,
                      device_assign=assign).validate(net)


def test_compile_routes_through_validate_and_checks_device_count(dpd):
    # Network.compile rejects invalid plans via ExecutionPlan.validate
    # before any build...
    with pytest.raises(ValueError, match="exclusive"):
        dpd.compile(ExecutionPlan(mode="megakernel", cores=2, devices=2))
    # ...and a valid plan asking for more devices than visible fails
    # fast with an actionable message naming the CI env knob.
    too_many = jax.device_count() + 1
    with pytest.raises(RuntimeError, match="XLA_FLAGS"):
        dpd.compile(ExecutionPlan(mode="dynamic", devices=too_many))


def test_devices_one_is_the_plain_dynamic_path(dpd):
    """devices=1 is not 'sharding with one shard' — it compiles the
    ordinary dynamic executor and reports inert sharding telemetry."""
    prog = dpd.compile(ExecutionPlan(mode="dynamic", devices=1))
    prog.run()
    st = prog.stats()
    assert st.devices == 1
    assert st.device_partition_actors is None
    assert st.collective_bytes_per_sweep is None
    assert st.quiescence_allreduces is None


# --------------------------------------------------------------------------- #
# Satellite: the mode x guards x trace x cores x devices matrix.
# --------------------------------------------------------------------------- #
def _plan_legal(mode, guards, trace, cores, devices):
    if cores != 1 and mode != "megakernel":
        return False
    if guards and mode not in ("dynamic", "megakernel"):
        return False
    if trace and mode not in ("dynamic", "megakernel"):
        return False
    if devices > 1 and cores != 1:
        return False
    if devices > 1 and mode != "dynamic":
        return False
    return True


def test_plan_validation_matrix(dpd):
    """Exhaustive cross-product: validate() accepts exactly the legal
    corner of the plan space, and every rejection is a single-sentence
    ValueError naming plan fields."""
    seen_valid = seen_invalid = 0
    for mode, guards, trace, cores, devices in itertools.product(
            ("dynamic", "static", "megakernel"), (False, True),
            (False, True), (1, 2), (1, 2)):
        plan = ExecutionPlan(mode=mode, guards=guards, trace=trace,
                             cores=cores, devices=devices,
                             n_iterations=4)
        if _plan_legal(mode, guards, trace, cores, devices):
            assert plan.validate(dpd) is plan
            seen_valid += 1
        else:
            with pytest.raises(ValueError) as err:
                plan.validate(dpd)
            msg = str(err.value)
            assert "ExecutionPlan" in msg and "\n\n" not in msg
            seen_invalid += 1
    assert seen_valid and seen_invalid


# --------------------------------------------------------------------------- #
# Bit-identity vs the single-device dynamic executor (forced 8-device
# subprocess).  One subprocess covers dpd + moe at k in {1, 2, 4} plus
# the guards/trace variants; a second covers serving tokens.
# --------------------------------------------------------------------------- #
def test_sharded_bit_identity_dpd_moe():
    out = run_sub("""
        import numpy as np
        from repro.core import ExecutionPlan
        from repro.graphs.factories import (make_dpd, make_moe,
                                            states_identical)

        for label, (net, _) in (
                ("dpd", make_dpd(n_firings=6)),
                ("moe", make_moe(n_firings=3, n_tokens=16, d_model=32))):
            ref = net.compile(ExecutionPlan(mode="dynamic")).run()
            ref_counts = {k: int(v) for k, v in ref.fire_counts.items()}
            for k in (1, 2, 4):
                prog = net.compile(ExecutionPlan(mode="dynamic",
                                                 devices=k))
                r = prog.run()
                assert states_identical(ref.state, r.state), (label, k)
                got = {n: int(v) for n, v in r.fire_counts.items()}
                assert got == ref_counts, (label, k, got)
                st = prog.stats()
                assert st.devices == k
                if k == 1:
                    assert st.collective_bytes_per_sweep is None
                    continue
                # stats schema v2: sharding telemetry is populated and
                # the device partition covers the network.
                assert st.collective_bytes_per_sweep > 0, (label, k)
                assert st.quiescence_allreduces == int(r.sweeps)
                flat = [a for grp in st.device_partition_actors
                        for a in grp]
                assert sorted(flat) == sorted(net.actors)
                doc = st.to_json()
                assert doc["schema_version"] == 2
                assert doc["devices"] == k

            # guards: same states, clean diagnostics across the mesh.
            rg = net.compile(ExecutionPlan(mode="dynamic", devices=2,
                                           guards=True)).run()
            assert states_identical(ref.state, rg.state), (label, "g")
            assert rg.diagnostics.ok, (label, rg.diagnostics)

            # trace: per-device rings merge into one sweep-ordered
            # trace whose firing counts equal the reference's.
            rt = net.compile(ExecutionPlan(mode="dynamic", devices=2,
                                           trace=True)).run()
            assert states_identical(ref.state, rt.state), (label, "t")
            fc = rt.trace.firing_counts()
            assert {n: fc[n] for n in fc} == ref_counts, (label, fc)
            assert rt.trace.dropped == 0
            sweeps = rt.trace.events[:, 1]
            assert (np.diff(sweeps) >= 0).all(), label

        # Explicit device_assign: a user-chosen legal cut is honored
        # verbatim and stays bit-identical.
        net, _ = make_dpd(n_firings=6)
        names = list(net.actors)
        cut = {n: (0 if i < len(names) // 2 else 1)
               for i, n in enumerate(names)}
        prog = net.compile(ExecutionPlan(mode="dynamic", devices=2,
                                         device_assign=cut))
        r = prog.run()
        ref = net.compile(ExecutionPlan(mode="dynamic")).run()
        assert states_identical(ref.state, r.state)
        grps = prog.stats().device_partition_actors
        assert set(grps[0]) == {n for n in names if cut[n] == 0}
        print("shard identity OK")
    """)
    assert "shard identity OK" in out


def test_sharded_serving_tokens_identical():
    out = run_sub("""
        import jax
        import numpy as np
        from repro.configs import smoke_config
        from repro.core import ExecutionPlan
        from repro.models import init_params
        from repro.serve import ActorEngine, Engine, Request, ServeConfig

        cfg = smoke_config("granite-8b")
        params = init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(1)
        requests = [Request(prompt=rng.integers(
                        1, cfg.vocab, size=int(n)).astype(np.int32),
                            max_new=m)
                    for n, m in [(5, 4), (3, 2), (7, 4), (4, 3), (6, 4)]]
        scfg = ServeConfig(batch_size=2, max_prompt=8, max_new=4, eos_id=7)
        legacy = [r.tokens
                  for r in Engine(cfg, params, scfg).generate(requests)]

        eng = ActorEngine(cfg, params, scfg,
                          plan=ExecutionPlan(mode="dynamic", devices=2))
        got = eng.generate(requests)
        for want, have in zip(legacy, got):
            np.testing.assert_array_equal(want, have.tokens)
        assert eng.last_collective_bytes_per_sweep > 0
        # The slot-table feedback channel (delay >= rate) crossed the
        # mesh; every actor still fired once per admission sweep.
        c = eng.last_fire_counts
        assert c["decode"] == c["admission"] == c["merge"]
        print("shard serving OK")
    """)
    assert "shard serving OK" in out
