"""Test-side shim over the shared graph factories.

The builders moved to ``repro.graphs.factories`` so benchmarks can use
them without importing from ``tests/``; this module keeps the historical
import path for the test suite and adds the asserting state comparator.
"""
import jax
import numpy as np

from repro.core import NetworkState
from repro.graphs.factories import (DPD_SCHEDULE, make_dpd, make_moe,
                                    make_motion_detection, states_identical)

__all__ = ["DPD_SCHEDULE", "assert_states_identical", "make_dpd",
           "make_moe", "make_motion_detection", "states_identical"]


def assert_states_identical(a: NetworkState, b: NetworkState,
                            ignore_fifo_bufs=()) -> None:
    """Byte-identity of two states.

    ``ignore_fifo_bufs`` names channels whose *buffer* content is
    excluded (cursors still compared): the megakernel's forwarded-
    transient dead-slot carve-out — a resumed run re-derives those
    buffers from init_state zeros, so only live tokens are contractual
    (and a drained transient has none).
    """
    assert jax.tree.structure(a) == jax.tree.structure(b)
    skip = set(ignore_fifo_bufs)
    for name, fa, fb in zip(a.fifo_names, a.fifos, b.fifos):
        if name not in skip:
            np.testing.assert_array_equal(np.asarray(fa.buf),
                                          np.asarray(fb.buf))
        for field in ("rd", "wr", "occ"):
            np.testing.assert_array_equal(
                np.asarray(getattr(fa, field)),
                np.asarray(getattr(fb, field)))
    for xa, xb in zip(a.actors, b.actors):
        assert jax.tree.structure(xa) == jax.tree.structure(xb)
        for x, y in zip(jax.tree.leaves(xa), jax.tree.leaves(xb)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
