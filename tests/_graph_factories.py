"""Test-side shim over the shared graph factories.

The builders moved to ``repro.graphs.factories`` so benchmarks can use
them without importing from ``tests/``; this module keeps the historical
import path for the test suite and adds the asserting state comparator.
"""
import jax
import numpy as np

from repro.core import NetworkState
from repro.graphs.factories import (DPD_SCHEDULE, make_dpd, make_moe,
                                    make_motion_detection, states_identical)

__all__ = ["DPD_SCHEDULE", "assert_states_identical", "make_dpd",
           "make_moe", "make_motion_detection", "states_identical"]


def assert_states_identical(a: NetworkState, b: NetworkState) -> None:
    assert jax.tree.structure(a) == jax.tree.structure(b)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
