"""Shared paper-graph factories + state comparators for the executor,
program-API and equivalence tests (one definition; callers pick sizes)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import NetworkState

DPD_SCHEDULE = np.array([2, 10, 5, 7, 3, 9], np.int32)


def assert_states_identical(a: NetworkState, b: NetworkState) -> None:
    assert jax.tree.structure(a) == jax.tree.structure(b)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def make_dpd(n_firings=6, block_l=256, seed=0):
    """DPD with rate-0 firings on most branches (active counts 2..10)."""
    from repro.graphs.dpd import build_dpd
    sched = DPD_SCHEDULE[:n_firings]
    rng = np.random.default_rng(seed)
    sig = jnp.asarray(rng.normal(size=(2, n_firings * block_l))
                      .astype(np.float32))
    return build_dpd(n_firings, active_schedule=sched, block_l=block_l,
                     signal=sig), n_firings


def make_motion_detection(n_frames=12, rate=4, frame_hw=(240, 320), seed=1):
    from repro.graphs.motion_detection import build_motion_detection
    rng = np.random.default_rng(seed)
    video = jnp.asarray(rng.uniform(0, 255, (n_frames,) + tuple(frame_hw))
                        .astype(np.float32))
    return build_motion_detection(n_frames, rate=rate, frame_hw=frame_hw,
                                  video=video), n_frames // rate


def make_moe(n_firings=3):
    from repro.graphs.moe_as_actors import build_moe_network
    from repro.models.moe import moe_init
    key = jax.random.PRNGKey(0)
    D, E, K, N = 32, 4, 2, 16
    params = moe_init(key, D, E, 64)
    xs = jax.random.normal(key, (n_firings * N, D), jnp.float32)
    return build_moe_network(params, N, D, K, 2.0, n_firings, xs), n_firings
