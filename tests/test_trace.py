"""Firing-level tracing and occupancy profiles.

Three contracts pinned here:

  * **Observer effect is zero** — trace=True runs are bit-identical in
    states / cursors / fire counts / sweeps to untraced runs on every
    traceable backend (host dynamic, single-core megakernel, grid k=2)
    across the three workload families (DPD, MoE, serving).
  * **The export is honest** — the Chrome trace-event JSON's per-actor
    firing events exactly equal ``RunResult.fire_counts``, and the
    document validates against the trace-event schema (required keys,
    monotonic timestamps per track).
  * **Profiles drive partitions** — ``cut_objective="profile"`` over a
    measured :class:`repro.core.trace.Profile` yields a valid contiguous
    partition whose results stay bit-identical (Kahn determinism: the
    cut moves work, never values).

Plus the satellite oracles: ``diagnostics.high_water`` of a clean
guarded dynamic run equals an eager queue-replay oracle, and
``ProgramStats.to_json()`` round-trips through ``json``.
"""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.core import (ExecutionPlan, NetworkBuilder, map_fire,
                        static_actor, validate_chrome_trace)
from repro.core.executor import _can_fire, _max_fireable, fire_actor
from repro.graphs.factories import make_dpd, make_moe, states_identical

BACKENDS = ("dynamic", "megakernel", "grid2")


def _plan(backend, **kw):
    if backend == "dynamic":
        return ExecutionPlan(mode="dynamic", **kw)
    cores = {"megakernel": 1, "grid2": 2}[backend]
    return ExecutionPlan(mode="megakernel", specialize=False, cores=cores,
                         **kw)


@pytest.fixture(scope="module")
def dpd():
    net, _ = make_dpd(n_firings=4, block_l=64)
    return net


@pytest.fixture(scope="module")
def moe():
    net, _ = make_moe(3)
    return net


@pytest.fixture(scope="module")
def serving():
    from repro.configs import smoke_config
    from repro.models import init_params
    from repro.serve import ActorEngine, Request, ServeConfig

    cfg = smoke_config("granite-8b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    reqs = [Request(prompt=rng.integers(1, cfg.vocab,
                                        size=int(n)).astype(np.int32),
                    max_new=m)
            for n, m in [(5, 3), (3, 2), (6, 3)]]
    eng = ActorEngine(cfg, params,
                      ServeConfig(batch_size=2, max_prompt=8, max_new=3,
                                  eos_id=7))
    return eng.build_network(reqs)


# --------------------------------------------------------------------------- #
# Off-path identity: the trace observes, it never schedules.
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("graph", ("dpd", "moe", "serving"))
def test_trace_off_path_bit_identical(request, graph, backend):
    net = request.getfixturevalue(graph)
    off = net.compile(_plan(backend)).run()
    on = net.compile(_plan(backend, trace=True)).run()
    assert states_identical(off.state, on.state)
    assert int(off.sweeps) == int(on.sweeps)
    assert {k: int(v) for k, v in off.fire_counts.items()} \
        == {k: int(v) for k, v in on.fire_counts.items()}
    assert off.trace is None
    assert on.trace is not None and on.trace.n_events > 0


@pytest.mark.parametrize("backend", BACKENDS)
def test_trace_firing_counts_match_fire_counts(dpd, backend):
    res = dpd.compile(_plan(backend, trace=True)).run()
    fc = res.trace.firing_counts()
    assert fc == {k: int(v) for k, v in res.fire_counts.items()}
    # Attempts dominate firings (skipped visits are events too).
    att = res.trace.attempt_counts()
    assert all(att[k] >= fc[k] for k in fc)


# --------------------------------------------------------------------------- #
# Perfetto export (ISSUE acceptance: exported per-actor firing events ==
# RunResult.fire_counts on a traced DPD megakernel run).
# --------------------------------------------------------------------------- #
def test_perfetto_firing_events_equal_fire_counts(dpd):
    res = dpd.compile(_plan("megakernel", trace=True)).run()
    doc = res.trace.to_perfetto()
    names = res.trace.actor_names
    fired = {nm: 0 for nm in names}
    for ev in doc["traceEvents"]:
        if ev["ph"] == "X":
            fired[names[ev["tid"] - 1]] += 1
    assert fired == {k: int(v) for k, v in res.fire_counts.items()}


def test_perfetto_export_validates_and_writes(dpd, tmp_path):
    res = dpd.compile(_plan("dynamic", trace=True)).run()
    path = tmp_path / "dpd.trace.json"
    res.trace.to_perfetto(str(path))
    doc = json.loads(path.read_text())
    assert validate_chrome_trace(doc) == []
    phs = {ev["ph"] for ev in doc["traceEvents"]}
    assert {"M", "X", "C"} <= phs          # tracks, firings, occupancy
    counters = {ev["name"] for ev in doc["traceEvents"] if ev["ph"] == "C"}
    assert counters == {f"occ:{f}" for f in res.trace.fifo_names}
    assert doc["otherData"]["dropped_events"] == 0


def test_validate_chrome_trace_flags_garbage():
    bad = {"traceEvents": [
        {"name": "a", "ph": "X", "pid": 0, "tid": 1, "ts": 5.0, "dur": 1.0},
        {"name": "a", "ph": "X", "pid": 0, "tid": 1, "ts": 2.0, "dur": 1.0},
        {"name": "b", "ph": "C", "pid": 0, "ts": 0.0},   # no args
    ]}
    problems = validate_chrome_trace(bad)
    assert any("monotonic" in p or "ts" in p for p in problems)
    assert any("args" in p for p in problems)
    assert validate_chrome_trace({"nope": 1}) != []


def test_grid_trace_carries_core_assignment(dpd):
    res = dpd.compile(_plan("grid2", trace=True)).run()
    tr = res.trace
    assert tr.actor_cores is not None
    assert set(tr.actor_cores) == {0, 1}
    doc = tr.to_perfetto()
    thread_names = [ev["args"]["name"] for ev in doc["traceEvents"]
                    if ev["ph"] == "M" and ev["name"] == "thread_name"]
    assert any("[core 1]" in n for n in thread_names)


# --------------------------------------------------------------------------- #
# Ring semantics: fixed capacity, oldest events dropped, count honest.
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", ("dynamic", "megakernel"))
def test_trace_ring_wraps_keeping_newest(dpd, backend):
    full = dpd.compile(_plan(backend, trace=True)).run().trace
    assert full.dropped == 0
    cap = 8
    small = dpd.compile(
        _plan(backend, trace=True, trace_capacity=cap)).run().trace
    assert small.n_events == cap
    assert small.dropped == full.n_events - cap
    # The survivors are exactly the newest `cap` attempts.
    np.testing.assert_array_equal(small.events, full.events[-cap:])


# --------------------------------------------------------------------------- #
# Profiles -> partition weights (ISSUE acceptance: valid contiguous cut,
# bit-identical results, k in {2, 4}).
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("cores", (2, 4))
def test_profile_cut_valid_and_bit_identical(dpd, cores):
    prof = dpd.compile(_plan("dynamic", trace=True)).run().trace.profile()
    w = prof.as_cut_weights()
    assert set(w) == {"actors", "channels"}
    assert all(v >= 1 for v in w["actors"].values())

    base = dpd.compile(ExecutionPlan(
        mode="megakernel", specialize=False, cores=cores)).run()
    prog = dpd.compile(ExecutionPlan(
        mode="megakernel", specialize=False, cores=cores,
        cut_objective="profile", profile=prof))
    res = prog.run()
    assert states_identical(base.state, res.state)
    assert {k: int(v) for k, v in base.fire_counts.items()} \
        == {k: int(v) for k, v in res.fire_counts.items()}
    st = prog.stats()
    assert st.cut_objective == "profile"
    assert st.grid_cores == cores
    # Valid partition: every core non-empty, and the concatenation is the
    # declaration order (contiguous cut).
    assert all(len(g) > 0 for g in st.partition_actors)
    flat = tuple(nm for g in st.partition_actors for nm in g)
    assert flat == tuple(dpd.actors)


def test_profile_plan_validation(dpd):
    # Cross-field rules (trace-vs-mode, trace_capacity-vs-trace,
    # profile-vs-cut_objective) are judged by ExecutionPlan.validate at
    # compile time; only value checks (trace_capacity=0) stay at
    # construction.
    with pytest.raises(ValueError, match="trace"):
        dpd.compile(ExecutionPlan(mode="static", n_iterations=4,
                                  trace=True))
    with pytest.raises(ValueError, match="trace_capacity"):
        dpd.compile(ExecutionPlan(mode="dynamic", trace_capacity=64))
    with pytest.raises(ValueError, match="trace_capacity"):
        ExecutionPlan(mode="dynamic", trace=True, trace_capacity=0)
    with pytest.raises(ValueError, match="profile"):
        dpd.compile(ExecutionPlan(mode="megakernel", cores=2,
                                  cut_objective="profile"))
    with pytest.raises(ValueError, match="profile"):
        dpd.compile(ExecutionPlan(mode="megakernel", cores=2,
                                  profile={"actors": {"a": 1}}))
    # A mapping form works, and the frozen plan survives replace().
    plan = ExecutionPlan(mode="megakernel", cores=2,
                         cut_objective="profile",
                         profile={"actors": {"a": 2}, "channels": {}})
    again = dataclasses.replace(plan, cores=4)
    assert again.profile == plan.profile


# --------------------------------------------------------------------------- #
# Streaming and serving carry traces.
# --------------------------------------------------------------------------- #
def _stream_net():
    import jax.numpy as jnp
    b = NetworkBuilder()
    b.actor(static_actor("src", (), ("out",),
                         lambda st, ins, rates: (st,
                                                 {"out": jnp.zeros((4, 8))})))
    b.actor(static_actor("amp", ("in",), ("out",),
                         map_fire(lambda w: 2.0 * w, "in", "out")))
    b.actor(static_actor("sink", ("in",), (),
                         lambda st, ins, rates: (st, {})))
    b.connect("src.out", "amp.in", rate=4, token_shape=(8,), name="f_in")
    b.connect("amp.out", "sink.in", rate=4, token_shape=(8,), name="f_out")
    return b.build()


def test_stream_merges_chunk_traces():
    net = _stream_net()
    prog = net.compile(ExecutionPlan(mode="dynamic", n_iterations=2,
                                     accelerated=("amp",), trace=True))
    feeds = np.arange(6 * 4 * 8, dtype=np.float32).reshape(6, 4, 8)
    prog.stream({"f_in": feeds})
    tr = prog.last_stream_trace
    assert tr is not None
    # 3 chunks x 2 windows each: the merged trace reads as one run.
    assert tr.firing_counts()["amp"] == 6
    sweeps = tr.events[:, 1]
    assert (np.diff(sweeps) >= 0).all()    # chunk offsets keep order
    # An untraced stream leaves no stale merged trace behind.
    prog2 = net.compile(ExecutionPlan(mode="dynamic", n_iterations=2,
                                      accelerated=("amp",)))
    prog2.stream({"f_in": feeds})
    assert prog2.last_stream_trace is None


def test_actor_engine_exposes_last_trace():
    from repro.configs import smoke_config
    from repro.models import init_params
    from repro.serve import ActorEngine, Request, ServeConfig

    cfg = smoke_config("granite-8b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    reqs = [Request(prompt=rng.integers(1, cfg.vocab, size=4).astype(np.int32),
                    max_new=2) for _ in range(2)]
    eng = ActorEngine(cfg, params,
                      ServeConfig(batch_size=2, max_prompt=8, max_new=2,
                                  eos_id=7),
                      plan=ExecutionPlan(mode="dynamic", trace=True))
    eng.generate(reqs)
    assert eng.last_trace is not None
    assert eng.last_trace.firing_counts() == eng.last_fire_counts


# --------------------------------------------------------------------------- #
# Satellite: high-water marks vs an eager queue-replay oracle.
# --------------------------------------------------------------------------- #
def _oracle_high_water(net):
    """Replay the dynamic multi-firing schedule eagerly, tracking each
    channel's max post-write occupancy — an independent queue oracle for
    the guards' ``mark_high_water`` (which records occ after every
    masked write, enabled or not, of the fired actor's out ports)."""
    state = net.init_state()
    fnames = list(net.fifos)
    hw = {f: 0 for f in fnames}
    fired_any = True
    while fired_any:
        fired_any = False
        for nm in net.actors:
            k = int(_max_fireable(net, nm, state))
            for _ in range(k):
                if not bool(_can_fire(net, nm, state)):
                    break
                state = fire_actor(net, nm, state)
                fired_any = True
                for _, _, fi in net.out_port_specs[nm]:
                    hw[fnames[fi]] = max(hw[fnames[fi]],
                                         int(state.fifos[fi].occ))
    return hw


def test_high_water_matches_queue_oracle(dpd):
    res = dpd.compile(_plan("dynamic", guards=True)).run()
    assert res.diagnostics.ok
    assert res.diagnostics.high_water == _oracle_high_water(dpd)


# --------------------------------------------------------------------------- #
# Satellite: stats().to_json() committed schema round-trips.
# --------------------------------------------------------------------------- #
def test_stats_to_json_roundtrip(dpd):
    prog = dpd.compile(_plan("grid2", trace=True))
    prog.run()
    doc = prog.stats().to_json()
    # v2 bumped for the sharding fields; v1 consumers keep working
    # because every v1 key survives unchanged (checked below).
    assert doc["schema_version"] == 2
    field_names = {f.name for f in dataclasses.fields(prog.stats())}
    assert field_names <= set(doc)
    # Grid fields exercised (tuples lowered to lists) and JSON-stable.
    assert doc["grid_cores"] == 2
    assert isinstance(doc["partition_actors"], list)
    assert json.loads(json.dumps(doc)) == doc


_STATS_V1_KEYS = {
    "schema_version", "mode", "grid_cores", "partition_actors",
    "cut_objective",
}


def test_stats_schema_v2_superset_of_v1(dpd):
    """Schema v2 adds the sharding telemetry without renaming or
    removing anything a v1 reader consumed — and the single-device
    defaults are inert (devices=1, collectives None)."""
    prog = dpd.compile(_plan("dynamic"))
    prog.run()
    doc = prog.stats().to_json()
    assert doc["schema_version"] == 2
    assert _STATS_V1_KEYS <= set(doc)
    assert {"devices", "device_partition_actors",
            "collective_bytes_per_sweep",
            "quiescence_allreduces"} <= set(doc)
    assert doc["devices"] == 1
    assert doc["device_partition_actors"] is None
    assert doc["collective_bytes_per_sweep"] is None
    assert doc["quiescence_allreduces"] is None
    assert json.loads(json.dumps(doc)) == doc
