"""Training substrate: optimizer, train step, loss goes down, compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.data import DataConfig, SyntheticLM
from repro.models import init_params
from repro.optim.adamw import AdamWConfig, init_opt_state, schedule
from repro.train import TrainOptions, make_train_step


def _to_dev(b):
    return {k: jnp.asarray(v) for k, v in b.items()}


def test_schedule_warmup_cosine():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(schedule(cfg, jnp.int32(0))) == 0.0
    assert abs(float(schedule(cfg, jnp.int32(10))) - 1e-3) < 1e-9
    assert abs(float(schedule(cfg, jnp.int32(100))) - 1e-4) < 1e-9


@pytest.mark.parametrize("opts", [
    TrainOptions(),
    TrainOptions(microbatches=2),
    TrainOptions(grad_dtype="f32"),
], ids=["default", "microbatched", "f32-grads"])
def test_loss_decreases(opts):
    cfg = smoke_config("granite-8b")
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60)
    step = jax.jit(make_train_step(cfg, opt_cfg, opts))
    opt_state = init_opt_state(params)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4))
    losses = []
    for i in range(25):
        params, opt_state, m = step(params, opt_state, _to_dev(data.batch(i)))
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    # synthetic bigram structure is learnable: loss must clearly decrease
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


def test_microbatch_equivalence():
    """Grad accumulation over 2 microbatches ~= single big batch."""
    cfg = smoke_config("granite-8b")
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    opt_cfg = AdamWConfig(lr=1e-3)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4))
    batch = _to_dev(data.batch(0))
    s1 = jax.jit(make_train_step(cfg, opt_cfg, TrainOptions(grad_dtype="f32")))
    s2 = jax.jit(make_train_step(cfg, opt_cfg,
                                 TrainOptions(microbatches=2, grad_dtype="f32")))
    p1, _, m1 = s1(params, init_opt_state(params), batch)
    p2, _, m2 = s2(params, init_opt_state(params), batch)
    # same data -> nearly identical first step
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), p1, p2)
    assert max(jax.tree.leaves(d)) < 5e-2


def test_error_feedback_state_threads():
    cfg = smoke_config("granite-8b")
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    opts = TrainOptions(grad_dtype="bf16", error_feedback=True)
    step = jax.jit(make_train_step(cfg, AdamWConfig(), opts))
    opt_state = init_opt_state(params)
    opt_state["feedback"] = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=2))
    params, opt_state, m = step(params, opt_state, _to_dev(data.batch(0)))
    assert "feedback" in opt_state
    assert np.isfinite(float(m["loss"]))


def test_data_pipeline_deterministic_replay():
    """Batch i is a pure function of (seed, i): restart replay safety."""
    cfg = DataConfig(vocab=1000, seq_len=64, global_batch=8, seed=3)
    a, b = SyntheticLM(cfg), SyntheticLM(cfg)
    for i in [0, 5, 17]:
        np.testing.assert_array_equal(a.batch(i)["tokens"], b.batch(i)["tokens"])
    assert not np.array_equal(a.batch(0)["tokens"], a.batch(1)["tokens"])
    # labels are next tokens
    ba = a.batch(2)
    np.testing.assert_array_equal(ba["tokens"][:, 1:], ba["labels"][:, :-1])
