"""Bit-identity of the specialized/multi-firing executors vs the baselines.

The trace-time cursor-specialized static path (static mode with
``ExecutionPlan(specialize=True)``) and the multi-firing dynamic scheduler
(dynamic mode with ``ExecutionPlan(multi_firing=True)``) are *performance*
transformations: on every graph — including delay channels (motion
detection's dotted Fig. 4 channel) and rate-0 firings (DPD's disabled
branches, MoE's idle experts) — the final actor states, FIFO cursors,
occupancies and live token content must match the unspecialized executors
bit for bit, not just numerically.

The one deliberate exception: channels the specialized executor
register-allocates (``network.register_fifos``) never touch their ring
buffers, so their *dead slots* keep initial zeros instead of stale window
copies.  Dead-slot content is unspecified by the MoC (callers gate on the
rate flags), and those channels are transient — they must end every run
drained (occupancy 0, no live tokens), which the comparison asserts
instead.  The multi-firing dynamic scheduler has no such carve-out: its
states are compared bit-for-bit in full.
"""
import jax
import numpy as np
import pytest

from _graph_factories import (assert_states_identical, make_dpd,
                              make_moe, make_motion_detection)
from repro.core import ExecutionPlan, NetworkState

jax.config.update("jax_platform_name", "cpu")


def assert_states_equivalent(net, base: NetworkState, spec: NetworkState) -> None:
    """Full bit-identity, except register-allocated channels' dead slots.

    For fifos in ``net.register_fifos``: cursors and occupancy must still
    match bit-for-bit and the channel must be drained (occ == 0 — there is
    no live content left to compare).  Everything else — actor states and
    buffered channels — must be byte-identical.
    """
    assert base.actor_names == spec.actor_names
    for x, y in zip(jax.tree.leaves(base.actors), jax.tree.leaves(spec.actors)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    for name, fb, fs in zip(base.fifo_names, base.fifos, spec.fifos):
        assert int(fb.rd) == int(fs.rd), name
        assert int(fb.wr) == int(fs.wr), name
        assert int(fb.occ) == int(fs.occ), name
        if name in net.register_fifos:
            assert int(fs.occ) == 0, f"{name} not drained"
        else:
            np.testing.assert_array_equal(np.asarray(fb.buf), np.asarray(fs.buf),
                                          err_msg=name)


GRAPHS = {
    "dpd": make_dpd,
    "motion_detection": make_motion_detection,
    "moe_as_actors": make_moe,
}


@pytest.mark.parametrize("graph", sorted(GRAPHS))
def test_specialized_static_bit_identical(graph):
    net, n_iter = GRAPHS[graph]()
    base = net.compile(mode="static", n_iterations=n_iter,
                       specialize=False).run().state
    spec = net.compile(mode="static", n_iterations=n_iter,
                       specialize=True).run().state
    assert_states_equivalent(net, base, spec)


@pytest.mark.parametrize("graph", sorted(GRAPHS))
def test_multi_firing_dynamic_bit_identical_and_fewer_sweeps(graph):
    net, _ = GRAPHS[graph]()
    rb = net.compile(ExecutionPlan(mode="dynamic", multi_firing=False)).run()
    rm = net.compile(ExecutionPlan(mode="dynamic", multi_firing=True)).run()
    assert_states_identical(rb.state, rm.state)
    assert ({k: int(v) for k, v in rb.fire_counts.items()}
            == {k: int(v) for k, v in rm.fire_counts.items()})
    assert int(rm.sweeps) < int(rb.sweeps)


def test_specialized_remainder_iterations():
    """n_iterations not divisible by the unroll period exercises the
    post-scan remainder unroll (MD's delay channel gives period LCM(2,3)=6,
    so 7 iterations = 1 super-iteration + 1 remainder)."""
    net, _ = make_motion_detection(n_frames=28, rate=4)
    base = net.compile(mode="static", n_iterations=7,
                       specialize=False).run().state
    spec = net.compile(mode="static", n_iterations=7,
                       specialize=True).run().state
    assert_states_equivalent(net, base, spec)


def test_specialized_rejects_phase_misaligned_state():
    """Resuming a specialized run from a non-phase-aligned state must fail
    loudly instead of silently reading the wrong buffer windows."""
    net, _ = make_motion_detection(n_frames=28, rate=4)
    run1 = net.compile(mode="static", n_iterations=1,
                       specialize=False)  # 1 iter: cursors at 1
    misaligned = run1.run().state
    spec = net.compile(mode="static", n_iterations=6, specialize=True)
    with pytest.raises(ValueError, match="phase-aligned"):
        spec.run(misaligned)


def test_specialized_accepts_full_cycle_resume():
    """A state advanced by a whole unroll period is phase-aligned and can
    be resumed under specialization, matching the baseline continuation."""
    net, _ = make_motion_detection(n_frames=48, rate=4)
    state0 = net.init_state()
    spec6 = net.compile(mode="static", n_iterations=6, specialize=True)
    base6 = net.compile(mode="static", n_iterations=6, specialize=False)
    assert_states_equivalent(
        net, base6.run(base6.run(state0).state).state,
        spec6.run(spec6.run(state0).state).state)


def test_donated_static_executor_matches():
    """donate=True must not change results (buffers reused, values equal)."""
    net, n_iter = make_dpd()
    keep = net.compile(mode="static", n_iterations=n_iter,
                       specialize=True).run().state
    donated = net.compile(mode="static", n_iterations=n_iter,
                          specialize=True, donate=True).run().state
    assert_states_identical(keep, donated)


def test_donated_dynamic_and_interpreted_match():
    net, n_iter = make_motion_detection()
    sd = net.compile(ExecutionPlan(mode="dynamic", donate=True)).run().state
    sb = net.compile(ExecutionPlan(mode="dynamic")).run().state
    assert_states_identical(sd, sb)
    ri_d = net.compile(mode="interpreted", n_iterations=n_iter,
                       donate=True).run().state
    ri_b = net.compile(mode="interpreted", n_iterations=n_iter).run().state
    assert_states_identical(ri_d, ri_b)


def test_legacy_dict_state_accepted():
    """Executors still accept the legacy {"fifos": ..., "actors": ...} dict
    and the NetworkState mapping accessors keep the old read API alive."""
    net, n_iter = make_dpd()
    state = net.init_state()
    legacy = {"fifos": state["fifos"], "actors": state["actors"]}
    prog = net.compile(mode="static", n_iterations=n_iter)
    out_legacy = prog.run(legacy).state
    out_new = prog.run(state).state
    assert_states_identical(out_legacy, out_new)
    assert set(out_new["actors"]) == set(net.actors)
    assert set(out_new["fifos"]) == set(net.fifos)
