"""Bit-identity of the specialized/multi-firing executors vs the baselines.

The trace-time cursor-specialized static path (``compile_static`` with
``specialize=True``) and the multi-firing dynamic scheduler
(``compile_dynamic`` with ``multi_firing=True``) are *performance*
transformations: on every graph — including delay channels (motion
detection's dotted Fig. 4 channel) and rate-0 firings (DPD's disabled
branches, MoE's idle experts) — the final actor states, FIFO cursors,
occupancies and live token content must match the unspecialized executors
bit for bit, not just numerically.

The one deliberate exception: channels the specialized executor
register-allocates (``network.register_fifos``) never touch their ring
buffers, so their *dead slots* keep initial zeros instead of stale window
copies.  Dead-slot content is unspecified by the MoC (callers gate on the
rate flags), and those channels are transient — they must end every run
drained (occupancy 0, no live tokens), which the comparison asserts
instead.  The multi-firing dynamic scheduler has no such carve-out: its
states are compared bit-for-bit in full.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import NetworkState, compile_dynamic, compile_static

jax.config.update("jax_platform_name", "cpu")


def assert_states_identical(a: NetworkState, b: NetworkState) -> None:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert jax.tree.structure(a) == jax.tree.structure(b)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def assert_states_equivalent(net, base: NetworkState, spec: NetworkState) -> None:
    """Full bit-identity, except register-allocated channels' dead slots.

    For fifos in ``net.register_fifos``: cursors and occupancy must still
    match bit-for-bit and the channel must be drained (occ == 0 — there is
    no live content left to compare).  Everything else — actor states and
    buffered channels — must be byte-identical.
    """
    assert base.actor_names == spec.actor_names
    for x, y in zip(jax.tree.leaves(base.actors), jax.tree.leaves(spec.actors)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    for name, fb, fs in zip(base.fifo_names, base.fifos, spec.fifos):
        assert int(fb.rd) == int(fs.rd), name
        assert int(fb.wr) == int(fs.wr), name
        assert int(fb.occ) == int(fs.occ), name
        if name in net.register_fifos:
            assert int(fs.occ) == 0, f"{name} not drained"
        else:
            np.testing.assert_array_equal(np.asarray(fb.buf), np.asarray(fs.buf),
                                          err_msg=name)


def make_dpd(n_firings=6):
    from repro.graphs.dpd import build_dpd
    # Rate-0 firings on most branches: active counts 2..10 across firings.
    sched = np.array([2, 10, 5, 7, 3, 9][:n_firings], np.int32)
    rng = np.random.default_rng(0)
    sig = jnp.asarray(rng.normal(size=(2, n_firings * 256)).astype(np.float32))
    return build_dpd(n_firings, active_schedule=sched, block_l=256,
                     signal=sig), n_firings


def make_motion_detection(n_frames=12, rate=4):
    from repro.graphs.motion_detection import build_motion_detection
    rng = np.random.default_rng(1)
    video = jnp.asarray(rng.uniform(0, 255, (n_frames, 240, 320))
                        .astype(np.float32))
    return build_motion_detection(n_frames, rate=rate, video=video), \
        n_frames // rate


def make_moe(n_firings=3):
    from repro.graphs.moe_as_actors import build_moe_network
    from repro.models.moe import moe_init
    key = jax.random.PRNGKey(0)
    D, E, K, N = 32, 4, 2, 16
    params = moe_init(key, D, E, 64)
    xs = jax.random.normal(key, (n_firings * N, D), jnp.float32)
    return build_moe_network(params, N, D, K, 2.0, n_firings, xs), n_firings


GRAPHS = {
    "dpd": make_dpd,
    "motion_detection": make_motion_detection,
    "moe_as_actors": make_moe,
}


@pytest.mark.parametrize("graph", sorted(GRAPHS))
def test_specialized_static_bit_identical(graph):
    net, n_iter = GRAPHS[graph]()
    base = compile_static(net, n_iter, specialize=False)(net.init_state())
    spec = compile_static(net, n_iter, specialize=True)(net.init_state())
    assert_states_equivalent(net, base, spec)


@pytest.mark.parametrize("graph", sorted(GRAPHS))
def test_multi_firing_dynamic_bit_identical_and_fewer_sweeps(graph):
    net, _ = GRAPHS[graph]()
    sb, cb, swb = compile_dynamic(net, multi_firing=False,
                                  return_sweeps=True)(net.init_state())
    sm, cm, swm = compile_dynamic(net, multi_firing=True,
                                  return_sweeps=True)(net.init_state())
    assert_states_identical(sb, sm)
    assert {k: int(v) for k, v in cb.items()} == {k: int(v) for k, v in cm.items()}
    assert int(swm) < int(swb)


def test_specialized_remainder_iterations():
    """n_iterations not divisible by the unroll period exercises the
    post-scan remainder unroll (MD's delay channel gives period LCM(2,3)=6,
    so 7 iterations = 1 super-iteration + 1 remainder)."""
    net, _ = make_motion_detection(n_frames=28, rate=4)
    base = compile_static(net, 7, specialize=False)(net.init_state())
    spec = compile_static(net, 7, specialize=True)(net.init_state())
    assert_states_equivalent(net, base, spec)


def test_specialized_rejects_phase_misaligned_state():
    """Resuming a specialized run from a non-phase-aligned state must fail
    loudly instead of silently reading the wrong buffer windows."""
    net, _ = make_motion_detection(n_frames=28, rate=4)
    run1 = compile_static(net, 1, specialize=False)  # 1 iter: cursors at 1
    misaligned = run1(net.init_state())
    spec = compile_static(net, 6, specialize=True)
    with pytest.raises(ValueError, match="phase-aligned"):
        spec(misaligned)


def test_specialized_accepts_full_cycle_resume():
    """A state advanced by a whole unroll period is phase-aligned and can
    be resumed under specialization, matching the baseline continuation."""
    net, _ = make_motion_detection(n_frames=48, rate=4)
    state0 = net.init_state()
    spec6 = compile_static(net, 6, specialize=True)
    base6 = compile_static(net, 6, specialize=False)
    assert_states_equivalent(net, base6(base6(state0)), spec6(spec6(state0)))


def test_donated_static_executor_matches():
    """donate=True must not change results (buffers reused, values equal)."""
    net, n_iter = make_dpd()
    keep = compile_static(net, n_iter, specialize=True)(net.init_state())
    donated = compile_static(net, n_iter, specialize=True,
                             donate=True)(net.init_state())
    assert_states_identical(keep, donated)


def test_donated_dynamic_and_interpreted_match():
    from repro.core import run_interpreted
    net, n_iter = make_motion_detection()
    sd, cd = compile_dynamic(net, donate=True)(net.init_state())
    sb, cb = compile_dynamic(net)(net.init_state())
    assert_states_identical(sd, sb)
    ri_d = run_interpreted(net, net.init_state(), n_iter, donate=True)
    ri_b = run_interpreted(net, net.init_state(), n_iter)
    assert_states_identical(ri_d, ri_b)


def test_legacy_dict_state_accepted():
    """Executors still accept the legacy {"fifos": ..., "actors": ...} dict
    and the NetworkState mapping accessors keep the old read API alive."""
    net, n_iter = make_dpd()
    state = net.init_state()
    legacy = {"fifos": state["fifos"], "actors": state["actors"]}
    out_legacy = compile_static(net, n_iter)(legacy)
    out_new = compile_static(net, n_iter)(state)
    assert_states_identical(out_legacy, out_new)
    assert set(out_new["actors"]) == set(net.actors)
    assert set(out_new["fifos"]) == set(net.fifos)
