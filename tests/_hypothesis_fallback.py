"""Minimal stand-in for ``hypothesis`` when it is not installed.

The container image does not ship ``hypothesis`` (and installing packages
is off-limits), which made ``tests/test_core_fifo.py`` and
``tests/test_core_properties.py`` fail at *collection* in the seed repo.
This shim implements just the surface those property tests use —
``given``/``settings`` decorators and the ``integers``/``booleans``/
``lists`` strategies — drawing deterministic pseudo-random examples from a
fixed seed so runs are reproducible.  When real hypothesis is available
the tests import it instead (see the try/except at their top); the shim
trades minimized counterexamples and shrinking for the ability to run the
queue-oracle and scheduler property tests at all.
"""
from __future__ import annotations

import functools
import inspect
from typing import Any, Callable, Dict, List

import numpy as np

_DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    """A draw(rng) -> value sampler."""

    def __init__(self, draw: Callable[[np.random.Generator], Any]):
        self._draw = draw

    def draw(self, rng: np.random.Generator) -> Any:
        return self._draw(rng)


class strategies:  # noqa: N801 — mimics the hypothesis module name
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        def draw(rng: np.random.Generator) -> List[Any]:
            n = int(rng.integers(min_size, max_size + 1))
            return [elements.draw(rng) for _ in range(n)]

        return _Strategy(draw)


st = strategies


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline: Any = None,
             **_: Any) -> Callable[[Callable], Callable]:
    """Record ``max_examples`` for a subsequent ``given`` (order-agnostic)."""

    def wrap(fn: Callable) -> Callable:
        fn._fallback_max_examples = max_examples  # type: ignore[attr-defined]
        return fn

    return wrap


def given(**strat_kwargs: _Strategy) -> Callable[[Callable], Callable]:
    """Run the test repeatedly with examples drawn from a fixed-seed rng."""

    def wrap(fn: Callable) -> Callable:
        @functools.wraps(fn)
        def runner(*args: Any, **kwargs: Any) -> None:
            n = getattr(fn, "_fallback_max_examples", None)
            if n is None:
                n = getattr(runner, "_fallback_max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = np.random.default_rng(0xC0FFEE)
            for i in range(n):
                example: Dict[str, Any] = {
                    name: s.draw(rng) for name, s in strat_kwargs.items()
                }
                try:
                    fn(*args, **example, **kwargs)
                except Exception as e:  # noqa: BLE001 — re-raise with context
                    raise AssertionError(
                        f"falsifying example ({i + 1}/{n}): {example}"
                    ) from e

        # Strip the strategy-bound parameters so pytest does not treat them
        # as fixtures.
        sig = inspect.signature(fn)
        params = [p for name, p in sig.parameters.items()
                  if name not in strat_kwargs]
        runner.__signature__ = sig.replace(parameters=params)  # type: ignore[attr-defined]
        return runner

    return wrap
