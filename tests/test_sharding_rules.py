"""Sharding rule unit tests (no devices needed — specs only)."""
import re

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models import abstract_params
from repro.train import sharding as shd

# This module builds its abstract device grid with the
# ``AbstractMesh(axis_sizes, axis_names)`` constructor, which landed
# after jax 0.4.37 (0.4.37's AbstractMesh takes ``(name, size)`` pair
# tuples instead, and the mesh fixture errors on construction).  The
# pinned dev/CI environment is 0.4.37, so these 6 tests are skipped
# there — the version-sensitive drift formerly handled with a CI
# ``--ignore`` flag, now self-describing in the file itself.
# Leading-digit parse so pre-release strings ("0.5.0rc0") still compare.
pytestmark = pytest.mark.skipif(
    tuple(int(re.match(r"\d*", p).group() or 0)
          for p in jax.__version__.split(".")[:2]) < (0, 5),
    reason="AbstractMesh(axis_sizes, axis_names) constructor needs "
           f"jax >= 0.5 (running {jax.__version__})")


@pytest.fixture(scope="module")
def mesh():
    # Spec construction only — a fake 16x16 abstract device grid is fine.
    from jax.sharding import AbstractMesh
    return AbstractMesh((16, 16), ("data", "model"))


def test_param_specs_qwen(mesh):
    cfg = get_config("qwen2-72b")
    params = abstract_params(cfg)
    specs, dropped = shd.param_specs(params, mesh)
    assert specs["embed"]["w"] == P("model", None)
    g = specs["groups"]["c0"]
    assert g["attn"]["wq"] == P(None, None, "model")   # group-stacked
    assert g["attn"]["wk"] == P(None, None, None)      # GQA KV replicated
    assert g["attn"]["wo"] == P(None, "model", None)
    assert g["mlp"]["w_gate"] == P(None, None, "model")
    assert not dropped   # padded vocab + aligned dims: nothing dropped


def test_param_specs_moe_expert_parallel(mesh):
    cfg = get_config("olmoe-1b-7b")
    specs, _ = shd.param_specs(abstract_params(cfg), mesh)
    g = specs["groups"]["c0"]["mlp"]
    assert g["we_gate"] == P(None, "model", None, None)  # experts over model
    assert g["router"] == P(None, None, None)


def test_divisibility_drops_are_recorded(mesh):
    cfg = get_config("recurrentgemma-2b")   # 10 heads: wq col dim 2560 OK
    specs, dropped = shd.param_specs(abstract_params(cfg), mesh)
    # whisper: 12 heads * 64 = 768 divisible; biases etc fine — check the
    # recording machinery with a synthetic case instead:
    fake = {"attn": {"wq": jax.ShapeDtypeStruct((100, 33), jnp.bfloat16)}}
    specs2, dropped2 = shd.param_specs(fake, mesh)
    assert specs2["attn"]["wq"] == P(None, None)
    assert dropped2 and "33" in dropped2[0]


def test_batch_specs(mesh):
    batch = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32)}
    specs = shd.batch_specs(batch, mesh)
    assert specs["tokens"] == P(("data",))
    odd = {"tokens": jax.ShapeDtypeStruct((3, 7), jnp.int32)}
    assert shd.batch_specs(odd, mesh)["tokens"] == P()


def test_cache_specs_batch_vs_seq_fallback(mesh):
    # decode_32k-like: batch shards
    caches = {"groups": {"k": jax.ShapeDtypeStruct((8, 128, 32768, 8, 128),
                                                   jnp.bfloat16)}}
    specs = shd.cache_specs(caches, mesh)
    assert specs["groups"]["k"] == P(None, ("data",), None, None, None)
    # long_500k-like: batch 1 -> sequence-parallel fallback
    caches2 = {"rest": ({"k": jax.ShapeDtypeStruct((1, 524288, 8, 128),
                                                   jnp.bfloat16)},)}
    specs2 = shd.cache_specs(caches2, mesh)
    assert specs2["rest"][0]["k"] == P(None, "data", None, None)


def test_zero1_and_fsdp_upgrade(mesh):
    cfg = get_config("qwen2-72b")
    params = abstract_params(cfg)
    specs, _ = shd.param_specs(params, mesh)
    up = shd.shard_over_data(specs, params, mesh)
    # a big replicated-dim tensor picked up the data axis
    assert up["groups"]["c0"]["attn"]["wk"] != specs["groups"]["c0"]["attn"]["wk"]
    # tiny tensors (the unstacked final norm) stay replicated
    assert up["final_norm"]["scale"] == P()
