"""Cross-implementation consistency: decode==forward, chunked==naive,
actor-network MoE == fused MoE, pallas==xla model paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import decode_step, forward, init_params, prefill
from repro.models.ssm import ssd_chunked, ssd_naive
from repro.models.rglru import rglru_naive, rglru_scan
from repro.models.attention import _flash_scan
from repro.kernels.flash_attention import flash_attention_ref

CONSISTENCY_ARCHS = ["gemma3-12b", "qwen2-72b", "olmoe-1b-7b",
                     "recurrentgemma-2b", "mamba2-780m", "whisper-small",
                     "internvl2-1b", "h2o-danube-3-4b"]


def _batch(cfg, key, B, toks):
    batch = {"tokens": toks}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            key, (B, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder.n_ctx, cfg.encoder.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", CONSISTENCY_ARCHS)
def test_decode_matches_full_forward(arch):
    """Next-token logits from (prefill -> decode_step) must equal the last
    position of a full forward over prompt+token — validates every cache
    layout (ring KV, SWA ring, SSD state, RG-LRU state, cross-attn KV)."""
    cfg = smoke_config(arch)
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    B, S = 2, 24
    n_txt = S - cfg.n_vision_tokens if cfg.family == "vlm" else S
    toks = jax.random.randint(key, (B, n_txt + 1), 0, cfg.vocab)
    batch = _batch(cfg, key, B, toks[:, :-1])
    _, caches = prefill(params, cfg, batch, max_cache_len=S + 8)
    lg_dec, _ = decode_step(params, cfg, toks[:, -1:],
                            jnp.full((B,), S, jnp.int32), caches)
    batch2 = dict(batch)
    batch2["tokens"] = toks
    lg_full, _, _ = forward(params, cfg, batch2, mode="train", remat=False)
    np.testing.assert_allclose(np.asarray(lg_dec), np.asarray(lg_full[:, -1]),
                               rtol=3e-2, atol=3e-2)


def test_ssd_chunked_matches_naive(rng):
    B, L, H, P, N = 2, 64, 3, 8, 16
    x = jnp.asarray(rng.normal(size=(B, L, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (B, L, H)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, (H,)), jnp.float32)
    B_ = jnp.asarray(rng.normal(size=(B, L, N)), jnp.float32)
    C_ = jnp.asarray(rng.normal(size=(B, L, N)), jnp.float32)
    y1, h1 = ssd_naive(x, dt, A, B_, C_)
    y2, h2 = ssd_chunked(x, dt, A, B_, C_, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=2e-4, atol=2e-4)


def test_rglru_scan_matches_naive(rng):
    la = jnp.asarray(-rng.uniform(0.01, 2.0, (2, 48, 32)), jnp.float32)
    gx = jnp.asarray(rng.normal(size=(2, 48, 32)), jnp.float32)
    a1, t1 = rglru_naive(la, gx)
    a2, t2 = rglru_scan(la, gx)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(t1), np.asarray(t2), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("causal,window", [(True, None), (True, 64), (False, None)])
def test_flash_scan_matches_dense(rng, causal, window):
    B, S, H, Hkv, hd = 2, 256, 4, 2, 32
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, hd)), jnp.float32)
    a = flash_attention_ref(q, k, v, causal=causal, window=window)
    b = _flash_scan(q, k, v, causal=causal, window=window, bq=64, bk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)


def test_moe_actor_network_equals_fused_layer():
    """The paper-MoC expression of MoE == the fused einsum implementation
    (DESIGN.md §3 — router is the control actor, experts dynamic actors)."""
    from repro.core import ExecutionPlan
    from repro.graphs.moe_as_actors import build_moe_network
    from repro.models.moe import moe_init, moe_layer
    key = jax.random.PRNGKey(0)
    D, E, K, N, F = 32, 4, 2, 16, 3
    params = moe_init(key, D, E, 64)
    xs = jax.random.normal(key, (F * N, D), jnp.float32)
    outs = []
    for f in range(F):
        y, _ = moe_layer(params, xs[f * N:(f + 1) * N][None], top_k=K,
                         capacity_factor=2.0)
        outs.append(np.asarray(y[0]))
    expect = np.concatenate(outs)
    net = build_moe_network(params, N, D, K, 2.0, F, xs)
    sta = net.compile(mode="static", n_iterations=F)
    np.testing.assert_allclose(np.asarray(sta.collect("sink", sta.run().state)),
                               expect, rtol=2e-2, atol=2e-2)
    dyn = net.compile(ExecutionPlan(mode="dynamic"))
    result = dyn.run()
    np.testing.assert_allclose(np.asarray(dyn.collect("sink", result.state)),
                               expect, rtol=2e-2, atol=2e-2)
    assert int(result.fire_counts["router"]) == F


def test_unroll_matches_scan():
    cfg = smoke_config("gemma3-12b")
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab)}
    a, _, _ = forward(params, cfg, batch, mode="train", remat=False)
    b, _, _ = forward(params, cfg, batch, mode="train", remat=False, unroll=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-2, atol=3e-2)
