"""Megakernel backend: bit-identity with the dynamic executor + Program
integration.

The acceptance bar of the megakernel PR: running a network as one
persistent Pallas kernel (``ExecutionPlan(mode=Mode.MEGAKERNEL)``, rings
in scratch, in-kernel sweep loop) must be *bit-identical* to the
token-driven dynamic executor — final actor states, every ring buffer
byte (stale slots included), cursors, fire counts AND sweep counts — on
the graphs with genuinely dynamic rates: DPD (rate-0 firings on most
branches), MoE-as-actors (idle experts), and motion detection (the Fig. 4
delay channel with its initial token and copy-back).  All runs use Pallas
interpret mode on CPU (the tier-1 fallback; ``interpret=None``
auto-selects it off-TPU).
"""
import jax
import numpy as np
import pytest

from _graph_factories import (assert_states_identical, make_dpd, make_moe,
                              make_motion_detection)
from repro.core import (MEGAKERNEL, ExecutionPlan, Mode, compile_megakernel,
                        lower_network)

jax.config.update("jax_platform_name", "cpu")


GRAPHS = {
    "dpd": lambda: make_dpd(n_firings=4, block_l=128),
    "moe_as_actors": lambda: make_moe(3),
    "motion_detection": lambda: make_motion_detection(
        n_frames=12, rate=4, frame_hw=(48, 64)),
}


def _run_both(net):
    dyn = net.compile(ExecutionPlan(mode="dynamic")).run()
    mega = net.compile(ExecutionPlan(mode=Mode.MEGAKERNEL)).run()
    return dyn, mega


@pytest.mark.parametrize("graph", sorted(GRAPHS))
def test_megakernel_bit_identical_to_dynamic(graph):
    net, _ = GRAPHS[graph]()
    dyn, mega = _run_both(net)
    assert_states_identical(dyn.state, mega.state)
    assert ({k: int(v) for k, v in dyn.fire_counts.items()}
            == {k: int(v) for k, v in mega.fire_counts.items()})
    assert int(dyn.sweeps) == int(mega.sweeps)


def test_megakernel_single_firing_sweeps_match_baseline():
    """multi_firing=False mirrors the one-firing-per-visit baseline
    scheduler: more sweeps, same final state (Kahn determinism)."""
    net, _ = make_dpd(n_firings=4, block_l=128)
    dyn = net.compile(ExecutionPlan(mode="dynamic", multi_firing=False)).run()
    mega = net.compile(ExecutionPlan(mode=MEGAKERNEL,
                                     multi_firing=False)).run()
    assert_states_identical(dyn.state, mega.state)
    assert int(dyn.sweeps) == int(mega.sweeps)
    mf = net.compile(ExecutionPlan(mode=MEGAKERNEL)).run()
    assert int(mf.sweeps) < int(mega.sweeps)
    assert_states_identical(mf.state, mega.state)


def test_megakernel_resumes_from_partial_state():
    """The kernel is a pure state transformer: feeding a quiescent state
    back in fires nothing (one empty sweep), and resuming a fresh source
    continues exactly like the dynamic executor would.  Forwarded
    (transient) channels carry the dead-slot carve-out: a resumed run
    re-derives their buffers from init_state zeros, so their stale bytes
    are excluded — cursors and everything else stay contractual."""
    net, _ = make_moe(2)
    prog = net.compile(ExecutionPlan(mode=MEGAKERNEL))
    forwarded = prog.stats().forwarded_fifos
    assert forwarded                                 # moe proves transients
    r1 = prog.run()
    r2 = prog.run(r1.state)
    assert int(r2.sweeps) == 1                      # quiescent: empty sweep
    assert all(int(v) == 0 for v in r2.fire_counts.values())
    assert_states_identical(r1.state, r2.state, ignore_fifo_bufs=forwarded)
    for name in forwarded:
        # The carve-out, pinned: nothing fired, so the resumed run's
        # forwarded buffers are exactly the dead-slot zeros (and the
        # channel is drained, so no live token is lost).
        assert int(r2.state.fifo(name).occ) == 0
        assert not np.asarray(r2.state.fifo(name).buf).any()


def test_megakernel_unspecialized_resume_keeps_every_byte():
    """specialize=False keeps every ring in scratch: no carve-out at
    all, resumed states stay byte-identical including transient bufs."""
    net, _ = make_moe(2)
    prog = net.compile(ExecutionPlan(mode=MEGAKERNEL, specialize=False))
    assert prog.stats().forwarded_fifos == ()
    assert prog.stats().reclaimed_scratch_bytes == 0
    r1 = prog.run()
    r2 = prog.run(r1.state)
    assert int(r2.sweeps) == 1
    assert_states_identical(r1.state, r2.state)


def test_megakernel_forwarding_rejects_undrained_entry():
    """The static specializer's drained-entry rule, per run: live tokens
    on a forwarded channel would be dropped by the zeros-initialized
    window, so the runner rejects them (specialize=False is the escape
    hatch)."""
    import dataclasses

    import jax.numpy as jnp

    net, _ = make_moe(2)
    prog = net.compile(ExecutionPlan(mode=MEGAKERNEL))
    fwd = prog.stats().forwarded_fifos[0]
    state = net.init_state()
    fi = net.fifo_index[fwd]
    spec = net.fifos[fwd]
    dirty = state.fifos[:fi] + (dataclasses.replace(
        state.fifos[fi], occ=jnp.int32(spec.rate),
        wr=jnp.int32(1)),) + state.fifos[fi + 1:]
    dirty_state = dataclasses.replace(state, fifos=dirty)
    with pytest.raises(ValueError, match="must be drained"):
        prog.run(dirty_state)
    # Escape hatch: the unspecialized kernel accepts the same state.
    net.compile(ExecutionPlan(mode=MEGAKERNEL,
                              specialize=False)).run(dirty_state)


def test_megakernel_collect_and_output_match_dynamic():
    net, _ = GRAPHS["motion_detection"]()
    dyn_prog = net.compile(ExecutionPlan(mode="dynamic"))
    mega_prog = net.compile(ExecutionPlan(mode=MEGAKERNEL))
    want = np.asarray(dyn_prog.collect("sink", dyn_prog.run().state))
    mega_prog.run()
    got = np.asarray(mega_prog.collect("sink"))
    np.testing.assert_array_equal(got, want)


def test_megakernel_forwarding_scratch_reduction_dpd():
    """Acceptance bar of the scratch-diet PR: transient forwarding
    shrinks DPD's single-core scratch footprint >= 5x (every DPD channel
    is provably transient, so only the cursor block survives)."""
    net, _ = GRAPHS["dpd"]()
    prog = net.compile(ExecutionPlan(mode=MEGAKERNEL))
    st = prog.stats()
    before = lower_network(net).scratch_bytes
    assert st.scratch_bytes * 5 <= before
    assert st.scratch_bytes == before - st.reclaimed_scratch_bytes
    assert st.scratch_bytes == lower_network(net).cursor_bytes  # rings: 0
    assert len(st.forwarded_fifos) == len(net.fifos)


# --------------------------------------------------------------------------- #
# Lowering pass.
# --------------------------------------------------------------------------- #
def test_lowering_layout_tables():
    net, _ = make_dpd(n_firings=4, block_l=128)
    layout = lower_network(net)
    assert layout.fifo_names == tuple(net.fifos)
    assert len(layout.firing_table) == len(net.actors)
    # Firing table preserves the dynamic executor's visit order and
    # resolves every port to its flat channel index.
    for row, (name, a) in zip(layout.firing_table, net.actors.items()):
        assert row.name == name
        assert row.is_dynamic == a.is_dynamic
        assert [pb.port for pb in row.inputs] == list(a.in_ports)
        assert [pb.port for pb in row.outputs] == list(a.out_ports)
        for pb in row.inputs:
            assert layout.fifo_names[pb.fifo] == net.in_fifo[(name, pb.port)]
        if a.control_port is not None:
            assert (layout.fifo_names[row.control]
                    == net.in_fifo[(name, a.control_port)])
        else:
            assert row.control is None
    # Scratch layout is the Eq. 1 capacity law verbatim.
    for i, spec in enumerate(layout.fifo_specs):
        assert layout.scratch_shape(i) == ((spec.capacity_tokens,)
                                           + tuple(spec.token_shape))
    assert layout.ring_scratch_bytes == net.buffer_bytes()
    assert layout.transient_fifos == net.register_fifos
    assert layout.scratch_bytes == (layout.ring_scratch_bytes
                                    + 3 * 4 * len(net.fifos))


def test_megakernel_stats_scratch_vs_hbm():
    net, _ = make_moe(2)
    prog = net.compile(ExecutionPlan(mode=MEGAKERNEL))
    st = prog.stats()
    layout = lower_network(net)
    assert st.mode == "megakernel"
    # Transient forwarding reclaims every core-private register fifo's
    # ring from scratch (single core: all of them).
    assert set(st.forwarded_fifos) == set(net.register_fifos)
    assert st.reclaimed_scratch_bytes == st.transient_scratch_bytes == sum(
        net.fifos[n].capacity_bytes for n in net.register_fifos)
    assert st.scratch_bytes == layout.scratch_bytes - st.reclaimed_scratch_bytes
    assert st.reclaimed_scratch_bytes > 0
    assert st.hbm_state_bytes is None                 # nothing ran yet
    assert st.resolved_donate is False                # scratch-staged anyway
    prog.run()
    st = prog.stats()
    # HBM operands carry the ring copies plus actor states (source/sink
    # slabs), so they dominate the scratch-resident footprint here.
    assert st.hbm_state_bytes > st.scratch_bytes - layout.cursor_bytes
    assert st.last_sweeps >= 1
    # The unspecialized plan reports the pre-forwarding footprint.
    st0 = net.compile(ExecutionPlan(mode=MEGAKERNEL,
                                    specialize=False)).stats()
    assert st0.scratch_bytes == layout.scratch_bytes
    assert st0.scratch_bytes > net.buffer_bytes()     # rings + cursor block


# --------------------------------------------------------------------------- #
# Plan plumbing.
# --------------------------------------------------------------------------- #
def test_mode_enum_and_string_interchangeable():
    assert ExecutionPlan(mode=Mode.MEGAKERNEL).mode == "megakernel"
    assert ExecutionPlan(mode="megakernel").mode == MEGAKERNEL.value
    assert ExecutionPlan(mode=Mode.DYNAMIC).mode == "dynamic"
    # Megakernel runs to quiescence: no n_iterations required.
    ExecutionPlan(mode=MEGAKERNEL)


def test_megakernel_rejected_under_static_dal():
    """The reference framework cannot put dynamic actors on the
    accelerator; the megakernel IS the accelerator path."""
    from repro.core import RuntimeMode
    net, _ = make_dpd(n_firings=4, block_l=128)
    with pytest.raises(ValueError, match="STATIC_DAL"):
        net.compile(ExecutionPlan(mode=MEGAKERNEL,
                                  runtime_mode=RuntimeMode.STATIC_DAL))


def test_compile_megakernel_accepts_legacy_dict_state():
    net, _ = make_moe(2)
    state = net.init_state()
    legacy = {"fifos": state["fifos"], "actors": state["actors"]}
    runner = compile_megakernel(net)
    s_legacy, _, _ = runner(legacy)
    s_new, _, _ = runner(state)
    assert_states_identical(s_legacy, s_new)
