"""Grid-parallel megakernel sweeps: bit-identity for every core count.

The acceptance bar of the grid PR: ``ExecutionPlan(mode=Mode.MEGAKERNEL,
cores=k)`` for k in {1, 2, 4} must be *bit-identical* — final actor
states, every ring buffer byte (stale slots included), cursors and fire
counts — to the host dynamic executor on the three paper graphs (DPD,
motion detection, MoE-as-actors).  In interpret mode the core loop is
traced in fixed partition order (the sequential-grid tie-break on the
shared cursor block), so determinism holds by construction; with the
default *contiguous* cut the multi-core visit order equals the
single-core sweep's and even the sweep (round) counts match.  A
scrambled explicit ``assign`` changes the schedule — more rounds — but
Kahn determinism keeps the final state byte-for-byte equal, which is
exactly what a genuinely parallel grid mapping would be allowed to do.
"""
import jax
import numpy as np
import pytest

from _graph_factories import (assert_states_identical, make_dpd, make_moe,
                              make_motion_detection)
from repro.core import (MEGAKERNEL, ExecutionPlan, GridPartition, Mode,
                        lower_network, partition_layout)
from repro.core.megakernel import SHARED, default_assignment

jax.config.update("jax_platform_name", "cpu")

CORE_COUNTS = (1, 2, 4)

GRAPHS = {
    "dpd": lambda: make_dpd(n_firings=4, block_l=128),
    "moe_as_actors": lambda: make_moe(3),
    "motion_detection": lambda: make_motion_detection(
        n_frames=12, rate=4, frame_hw=(48, 64)),
}

#: A deliberately non-contiguous actor -> core map per graph (round-robin
#: over the parallel middle stage), exercising shared-ring semaphores in
#: both directions between the cores.
SCRAMBLED = {
    "dpd": lambda net: {n: (i % 2) for i, n in enumerate(net.actors)},
    "moe_as_actors": lambda net: {n: (i % 2) for i, n in enumerate(net.actors)},
    # MD's delay channel glues gauss+thres; scramble the rest.
    "motion_detection": lambda net: {"source": 1, "gauss": 0, "thres": 0,
                                     "med": 1, "sink": 0},
}


def _fire_counts(result):
    return {k: int(v) for k, v in result.fire_counts.items()}


@pytest.fixture(scope="module")
def runs():
    """One dynamic-reference run per graph, shared across the suite."""
    out = {}
    for gname, factory in GRAPHS.items():
        net, _ = factory()
        out[gname] = (net, net.compile(ExecutionPlan(mode="dynamic")).run())
    return out


# --------------------------------------------------------------------------- #
# Bit-identity: every core count vs the host dynamic executor.
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("cores", CORE_COUNTS)
@pytest.mark.parametrize("graph", sorted(GRAPHS))
def test_grid_bit_identical_to_dynamic(graph, cores, runs):
    net, dyn = runs[graph]
    r = net.compile(ExecutionPlan(mode=Mode.MEGAKERNEL, cores=cores)).run()
    # States cover actor states, every ring byte (stale slots included)
    # and all three cursors per channel (FifoState rd/wr/occ).
    assert_states_identical(dyn.state, r.state)
    assert _fire_counts(dyn) == _fire_counts(r)


@pytest.mark.parametrize("graph", sorted(GRAPHS))
def test_grid_contiguous_cut_preserves_sweep_counts(graph, runs):
    """The default cut is contiguous in visit order, so iterating cores
    then rows reproduces the single-core visit order exactly — rounds
    equal single-core sweeps (the determinism-by-construction claim)."""
    net, dyn = runs[graph]
    sweeps = {
        cores: int(net.compile(
            ExecutionPlan(mode=MEGAKERNEL, cores=cores)).run().sweeps)
        for cores in CORE_COUNTS
    }
    assert sweeps[2] == sweeps[1] == int(dyn.sweeps)
    assert sweeps[4] == sweeps[1]


@pytest.mark.parametrize("graph", sorted(GRAPHS))
def test_grid_scrambled_assign_kahn_identical(graph, runs):
    """A non-contiguous assignment changes the schedule (round count may
    grow — tokens cross partitions backwards) but never the final bytes:
    the Kahn-determinism guarantee a parallel grid mapping relies on."""
    net, dyn = runs[graph]
    assign = SCRAMBLED[graph](net)
    r = net.compile(ExecutionPlan(mode=MEGAKERNEL, cores=2,
                                  assign=assign)).run()
    assert_states_identical(dyn.state, r.state)
    assert _fire_counts(dyn) == _fire_counts(r)


def test_grid_resumes_quiescent_state():
    net, _ = GRAPHS["moe_as_actors"]()
    prog = net.compile(ExecutionPlan(mode=MEGAKERNEL, cores=2))
    r1 = prog.run()
    r2 = prog.run(r1.state)
    assert int(r2.sweeps) == 1          # one empty round: global quiescence
    assert all(int(v) == 0 for v in r2.fire_counts.values())
    # Forwarded transients carry the dead-slot carve-out on resume
    # (drained, so no live tokens are involved).
    assert_states_identical(r1.state, r2.state,
                            ignore_fifo_bufs=prog.stats().forwarded_fifos)


# --------------------------------------------------------------------------- #
# Partitioner: default heuristic, channel placement, validation.
# --------------------------------------------------------------------------- #
def test_default_assignment_glues_delay_channel_endpoints():
    net, _ = GRAPHS["motion_detection"]()
    for cores in (2, 4):
        assign = default_assignment(net, cores)
        assert assign["gauss"] == assign["thres"]   # delay < rate: glued
        assert set(assign.values()) == set(range(cores))  # no empty core


def test_partition_layout_channel_placement():
    net, _ = GRAPHS["dpd"]()
    layout = lower_network(net)
    part = partition_layout(net, layout, cores=2)
    assert isinstance(part, GridPartition)
    names = list(net.actors)
    # Every actor appears in exactly one core slice, in visit order.
    flat = [i for rows in part.core_rows for i in rows]
    assert sorted(flat) == list(range(len(names)))
    for rows in part.core_rows:
        assert list(rows) == sorted(rows)
    # A channel is private to core c iff both endpoints live on c.
    for fi, fname in enumerate(layout.fifo_names):
        e = net.edge_of(fname)
        src = part.assignment[names.index(e.src_actor)]
        dst = part.assignment[names.index(e.dst_actor)]
        if src == dst:
            assert part.fifo_cores[fi] == src
        else:
            assert part.fifo_cores[fi] == SHARED
    # Byte accounting: private blocks + shared block + forwarded
    # (ring-less) channels = all rings of the no-forwarding layout.
    assert (sum(part.private_ring_bytes(layout))
            + part.shared_ring_bytes(layout)
            + part.reclaimed_ring_bytes(layout)) == layout.ring_scratch_bytes
    assert part.semaphore_bytes() == 12 * len(part.shared_fifos)
    # Forwarded channels are core-private transients, never crossing.
    assert set(part.forwarded_fifos) <= set(
        i for i, c in enumerate(part.fifo_cores) if c != SHARED)
    assert all(layout.fifo_names[i] in layout.transient_fifos
               for i in part.forwarded_fifos)
    # Cursor-block split: every channel's cursor row lives in exactly one
    # block — its owning core's private block, or the shared semaphore
    # block for crossing channels.
    flat_rows = [fi for rows in part.cursor_rows for fi in rows]
    assert sorted(flat_rows) == list(range(len(layout.fifo_names)))
    assert part.cursor_rows[-1] == part.shared_fifos
    assert part.core_cursor_rows == tuple(
        len(part.private_fifos(c)) for c in range(part.n_cores))


def test_partition_rejects_delay_channel_crossing():
    net, _ = GRAPHS["motion_detection"]()
    layout = lower_network(net)
    assign = {"source": 0, "gauss": 0, "thres": 1, "med": 1, "sink": 1}
    with pytest.raises(ValueError, match="may not cross partitions"):
        partition_layout(net, layout, cores=2, assign=assign)
    with pytest.raises(ValueError, match="may not cross partitions"):
        net.compile(ExecutionPlan(mode=MEGAKERNEL, cores=2, assign=assign))


def test_partition_rejects_partial_or_out_of_range_assign():
    net, _ = GRAPHS["moe_as_actors"]()
    layout = lower_network(net)
    with pytest.raises(ValueError, match="must map every actor"):
        partition_layout(net, layout, cores=2, assign={"source": 0})
    bad = {n: 0 for n in net.actors}
    bad["sink"] = 2
    with pytest.raises(ValueError, match=r"outside \[0, 2\)"):
        partition_layout(net, layout, cores=2, assign=bad)
    with pytest.raises(ValueError, match="unknown actors"):
        partition_layout(net, layout, cores=2,
                         assign={**{n: 0 for n in net.actors}, "ghost": 1})


def test_partition_rejects_more_cores_than_units():
    net, _ = GRAPHS["motion_detection"]()    # 5 actors, 4 units after glue
    layout = lower_network(net)
    with pytest.raises(ValueError, match="partition units"):
        partition_layout(net, layout, cores=5)


def test_plan_rejects_grid_knobs_off_megakernel():
    # Mode-vs-knob rules moved to ExecutionPlan.validate (compile time);
    # pure value checks like cores=0 stay at construction.
    net, _ = GRAPHS["moe_as_actors"]()
    with pytest.raises(ValueError, match="grid-partition knobs"):
        net.compile(ExecutionPlan(mode="dynamic", cores=2))
    with pytest.raises(ValueError, match="grid-partition knobs"):
        net.compile(ExecutionPlan(mode="static", n_iterations=4,
                                  assign={"a": 0}))
    with pytest.raises(ValueError, match="cores must be"):
        ExecutionPlan(mode=MEGAKERNEL, cores=0)


# --------------------------------------------------------------------------- #
# Per-partition telemetry (Program.stats).
# --------------------------------------------------------------------------- #
def test_grid_stats_telemetry():
    net, _ = GRAPHS["motion_detection"]()
    prog = net.compile(ExecutionPlan(mode=MEGAKERNEL, cores=4))
    st = prog.stats()
    assert st.grid_cores == 4
    assert [a for core in st.partition_actors for a in core] \
        == list(net.actors)
    layout = lower_network(net)
    assert (sum(st.core_scratch_bytes) + st.shared_scratch_bytes
            + st.reclaimed_scratch_bytes) \
        == layout.ring_scratch_bytes + 12 * len(st.shared_fifos)
    assert st.partition_fire_counts is None        # nothing ran yet
    r = prog.run()
    st = prog.stats()
    assert sum(st.partition_fire_counts) == sum(_fire_counts(r).values())
    # Single-core programs report the degenerate partition, not None —
    # the telemetry shape is stable across core counts.
    st1 = net.compile(ExecutionPlan(mode=MEGAKERNEL)).stats()
    assert st1.grid_cores == 1
    assert st1.shared_fifos == ()
    assert st1.shared_scratch_bytes == 0


def test_grid_collect_matches_dynamic(runs):
    net, dyn = runs["motion_detection"]
    dyn_prog = net.compile(ExecutionPlan(mode="dynamic"))
    want = np.asarray(dyn_prog.collect("sink", dyn.state))
    prog = net.compile(ExecutionPlan(mode=MEGAKERNEL, cores=4))
    prog.run()
    np.testing.assert_array_equal(np.asarray(prog.collect("sink")), want)
