"""Crossing-bytes partition cut + cursor-block split: the scratch-diet
PR's grid-side acceptance tests.

The default megakernel partition cut now minimizes partition-crossing
ring bytes (the shared-scratch / semaphore coherence surface) among
contiguous cuts whose ``cost_flops`` bottleneck stays within the balance
slack — ``ExecutionPlan(cut_objective="flops")`` keeps the legacy pure
load-balance cut.  Both objectives produce *contiguous* cuts of the
visit order, so bit-identity with the host dynamic executor (states,
live ring bytes, cursors, fire counts AND round counts) holds for
either; the crossing cut must strictly shrink ``shared_scratch_bytes``
on DPD, whose flops-only cut lands mid-fork/adder fan-out.  A
property-style sweep of scrambled explicit ``assign`` maps (which
ring-buffer any crossing transients) pins Kahn determinism under the
forwarding + split-cursor-block kernel.
"""
import jax
import pytest

from _graph_factories import (assert_states_identical, make_dpd,
                              make_motion_detection, states_identical)
from repro.core import (MEGAKERNEL, ExecutionPlan, lower_network,
                        partition_layout)
from repro.core.megakernel import CUT_OBJECTIVES, default_assignment

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def dpd():
    net, _ = make_dpd(n_firings=4, block_l=128)
    # trace=True is off-path bit-identical (test_trace.py) and hands the
    # "profile" cut objective its measured weights for free.
    return net, net.compile(ExecutionPlan(mode="dynamic", trace=True)).run()


# --------------------------------------------------------------------------- #
# Crossing-bytes objective: strictly less shared scratch, same semantics.
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("cores", (2, 4))
def test_crossing_cut_reduces_shared_scratch_on_dpd(cores, dpd):
    net, dyn = dpd
    progs = {obj: net.compile(ExecutionPlan(
                 mode=MEGAKERNEL, cores=cores, cut_objective=obj,
                 profile=(dyn.trace.profile() if obj == "profile" else None)))
             for obj in CUT_OBJECTIVES}
    stats = {obj: p.stats() for obj, p in progs.items()}
    assert stats["crossing"].cut_objective == "crossing"
    assert stats["flops"].cut_objective == "flops"
    # The acceptance claim: strictly fewer shared ring+semaphore bytes.
    assert (stats["crossing"].shared_scratch_bytes
            < stats["flops"].shared_scratch_bytes)
    assert (len(stats["crossing"].shared_fifos)
            <= len(stats["flops"].shared_fifos))
    # Core-local channels stay forwardable: the crossing cut reclaims at
    # least as much transient scratch as the flops cut.
    assert (stats["crossing"].reclaimed_scratch_bytes
            >= stats["flops"].reclaimed_scratch_bytes)
    # Both cuts are contiguous, so both stay bit-identical to the host
    # dynamic executor — states, fire counts AND round counts.
    for obj, prog in progs.items():
        r = prog.run()
        assert_states_identical(dyn.state, r.state)
        assert ({k: int(v) for k, v in r.fire_counts.items()}
                == {k: int(v) for k, v in dyn.fire_counts.items()})
        assert int(r.sweeps) == int(dyn.sweeps), obj


def test_crossing_cut_is_default_and_validated(dpd):
    net, _ = dpd
    assert net.compile(ExecutionPlan(mode=MEGAKERNEL, cores=2)).stats() \
        .cut_objective == "crossing"
    with pytest.raises(ValueError, match="cut_objective"):
        ExecutionPlan(mode=MEGAKERNEL, cut_objective="min-cut")
    # Cross-field (knob-vs-mode) rules live in ExecutionPlan.validate,
    # so the misuse surfaces at compile time, not construction.
    with pytest.raises(ValueError, match="grid-partition knobs"):
        net.compile(ExecutionPlan(mode="dynamic", cut_objective="flops"))
    layout = lower_network(net)
    with pytest.raises(ValueError, match="objective"):
        partition_layout(net, layout, cores=2, objective="bogus")
    with pytest.raises(ValueError, match="objective"):
        default_assignment(net, 2, objective="bogus")


def test_default_assignment_without_layout_degrades_to_flops(dpd):
    """The crossing objective needs ring bytes; with no layout it falls
    back to the flops cut instead of failing."""
    net, _ = dpd
    assert default_assignment(net, 2) == default_assignment(
        net, 2, objective="flops")
    layout = lower_network(net)
    crossing = default_assignment(net, 2, layout=layout)
    flops = default_assignment(net, 2, objective="flops", layout=layout)
    assert crossing != flops        # DPD: the cut actually moves


def test_crossing_cut_respects_delay_glue():
    """MD's window-uncovered delay channel glues gauss+thres under the
    crossing objective exactly as under flops."""
    net, _ = make_motion_detection(n_frames=12, rate=4, frame_hw=(48, 64))
    layout = lower_network(net)
    for cores in (2, 4):
        assign = default_assignment(net, cores, layout=layout)
        assert assign["gauss"] == assign["thres"]
        assert set(assign.values()) == set(range(cores))


# --------------------------------------------------------------------------- #
# Property-style scrambled assigns: Kahn determinism under forwarding +
# split cursor blocks (crossing transients fall back to shared rings).
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("stride", (1, 2, 3))
def test_scrambled_assign_kahn_determinism(stride, dpd):
    net, dyn = dpd
    names = list(net.actors)
    assign = {n: ((i * stride) + (i % 2)) % 2 for i, n in enumerate(names)}
    prog = net.compile(ExecutionPlan(mode=MEGAKERNEL, cores=2,
                                     assign=assign))
    st = prog.stats()
    # An explicit map ran no cut heuristic — stats say so.
    assert st.cut_objective == "assign"
    # Non-contiguous scrambles force transient channels across cores:
    # those must lose forwarding (shared rings), the rest keep it.
    assert set(st.forwarded_fifos).isdisjoint(st.shared_fifos)
    r = prog.run()
    # Schedule changes (rounds may grow); final bytes never do.
    assert states_identical(dyn.state, r.state)
    assert ({k: int(v) for k, v in r.fire_counts.items()}
            == {k: int(v) for k, v in dyn.fire_counts.items()})


# --------------------------------------------------------------------------- #
# to_dot(partition): reviewable cut rendering.
# --------------------------------------------------------------------------- #
def test_to_dot_renders_partition_clusters(dpd):
    net, _ = dpd
    layout = lower_network(net)
    part = partition_layout(net, layout, cores=2)
    dot = net.to_dot(part)
    for core in range(2):
        assert f"subgraph cluster_core{core}" in dot
        assert f'label="core {core}"' in dot
    # Every crossing channel is highlighted; forwarded ones are marked.
    assert dot.count("[shared]") == len(part.shared_fifos)
    assert dot.count("color=red") == len(part.shared_fifos)
    assert dot.count("[fwd]") == len(part.forwarded_fifos)
    # The plain render is unchanged by the feature.
    plain = net.to_dot()
    assert "cluster_core" not in plain and "[shared]" not in plain
    # A partition from another network is rejected, not mis-rendered.
    other, _ = make_motion_detection(n_frames=12, rate=4, frame_hw=(48, 64))
    with pytest.raises(ValueError, match="GridPartition built from"):
        other.to_dot(part)
