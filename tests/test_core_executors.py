"""Executor equivalence + dynamic-rate semantics (paper §3.3), on the
unified ``NetworkBuilder`` + ``Network.compile(ExecutionPlan)`` surface."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ExecutionPlan, NetworkBuilder, RuntimeMode,
                        dynamic_actor, map_fire, static_actor)


def make_chain(n_iter=8, rate=2, delay=True):
    tok = (3,)

    def src_fire(state, inputs, rates):
        data, idx = state
        return (data, idx + 1), {
            "out": jax.lax.dynamic_slice_in_dim(data, idx * rate, rate, 0)}

    src = static_actor(
        "src", (), ("out",), src_fire,
        init=lambda: (jnp.arange(n_iter * rate * 3, dtype=jnp.float32)
                      .reshape(n_iter * rate, 3), jnp.int32(0)),
        ready=lambda st: st[1] < n_iter)
    dbl = static_actor("dbl", ("in",), ("out",),
                       map_fire(lambda w: w * 2.0, "in", "out"))

    def sink_fire(state, inputs, rates):
        data, idx = state
        return (jax.lax.dynamic_update_slice_in_dim(data, inputs["in"],
                                                    idx * rate, 0), idx + 1), {}

    snk = static_actor(
        "snk", ("in",), (), sink_fire,
        init=lambda: (jnp.zeros((n_iter * rate, 3), jnp.float32), jnp.int32(0)),
        finish=lambda st: st[0])
    b = NetworkBuilder()
    b.actors(src, dbl, snk)
    b.connect("src.out", "dbl.in", rate=rate, token_shape=tok, name="f1")
    b.connect("dbl.out", "snk.in", rate=rate, token_shape=tok,
              delay=1 if delay else 0, name="f2")
    net = b.build()
    data = 2 * np.arange(n_iter * rate * 3, dtype=np.float32).reshape(-1, 3)
    expect = (np.concatenate([np.zeros((1, 3), np.float32), data[:-1]])
              if delay else data)
    return net, expect


@pytest.mark.parametrize("delay", [False, True])
def test_three_executors_agree(delay):
    net, expect = make_chain(delay=delay)
    p1 = net.compile(mode="static", n_iterations=8)
    np.testing.assert_allclose(
        np.asarray(p1.collect("snk", p1.run().state)), expect)
    p2 = net.compile(ExecutionPlan(mode="dynamic"))
    r2 = p2.run()
    np.testing.assert_allclose(np.asarray(p2.collect("snk", r2.state)), expect)
    assert all(int(v) == 8 for v in r2.fire_counts.values())
    p3 = net.compile(mode="interpreted", n_iterations=8)
    np.testing.assert_allclose(
        np.asarray(p3.collect("snk", p3.run().state)), expect)


def make_gated(n=9, period=3):
    """ctl enables gate every `period`-th firing (dynamic data rates)."""
    r, tok = 2, (3,)

    def ctl_fire(state, inputs, rates):
        return state + 1, {"out": (state % period == 0).astype(jnp.int32).reshape(1)}

    ctl = static_actor("ctl", (), ("out",), ctl_fire, init=lambda: jnp.int32(0),
                       ready=lambda st: st < n)

    def gctl(tok):
        on = tok[0] > 0
        return {"in": on, "out": on}

    gate = dynamic_actor("gate", "c", gctl, ("in",), ("out",),
                         map_fire(lambda w: w + 100.0, "in", "out"))
    n_pass = (n + period - 1) // period

    def src_fire(state, inputs, rates):
        data, idx = state
        return (data, idx + 1), {
            "out": jax.lax.dynamic_slice_in_dim(data, idx * r, r, 0)}

    src = static_actor(
        "src", (), ("out",), src_fire,
        init=lambda: (jnp.arange(n * r * 3, dtype=jnp.float32).reshape(n * r, 3),
                      jnp.int32(0)),
        ready=lambda st: st[1] < n_pass)

    def sink_fire(state, inputs, rates):
        data, idx = state
        return (jax.lax.dynamic_update_slice_in_dim(data, inputs["in"],
                                                    idx * r, 0), idx + 1), {}

    snk = static_actor(
        "snk", ("in",), (), sink_fire,
        init=lambda: (jnp.zeros((n * r, 3), jnp.float32), jnp.int32(0)),
        finish=lambda st: st[0])
    b = NetworkBuilder()
    b.actors(ctl, src, gate, snk)
    b.connect("ctl.out", "gate.c", name="fc")          # control: inferred
    b.connect("src.out", "gate.in", rate=r, token_shape=tok, name="f1")
    b.connect("gate.out", "snk.in", rate=r, token_shape=tok, name="f2")
    return b.build(), n_pass


def test_dynamic_gate_consumes_only_when_enabled():
    net, n_pass = make_gated()
    prog = net.compile(ExecutionPlan(mode="dynamic"))
    result = prog.run()
    counts = result.fire_counts
    # gate fires on every control token; src only supplies enabled windows
    assert int(counts["gate"]) == 9
    assert int(counts["src"]) == n_pass
    assert int(counts["snk"]) == n_pass
    out = np.asarray(prog.collect("snk", result.state))
    data = np.arange(9 * 2 * 3, dtype=np.float32).reshape(-1, 3)
    np.testing.assert_allclose(out[:2], data[0:2] + 100.0)


def test_static_dal_mode_rejects_dynamic_actors():
    """DAL's OpenCL path is SDF-only (paper §2.3) — dynamic actors must be
    rejected on the accelerated path."""
    net, _ = make_gated()
    with pytest.raises(ValueError, match="STATIC_DAL"):
        net.compile(ExecutionPlan(mode="dynamic",
                                  runtime_mode=RuntimeMode.STATIC_DAL))
    # ... but a static network passes.
    chain, _ = make_chain()
    chain.compile(mode="static", n_iterations=2,
                  runtime_mode=RuntimeMode.STATIC_DAL)


def test_heterogeneous_split():
    """GPP/GPU partition (paper §3.3): middle actor accelerated, source and
    sink on host; boundary channels become feed/fetch actors.  The raw
    mapping API — Program.stream wraps this (tests/test_program_api.py)."""
    from repro.core import collect_sink, heterogeneous_split, stage_feed
    net, expect = make_chain(delay=False)
    sub, feeds, fetches = heterogeneous_split(net, ["dbl"], n_iterations=8)
    assert feeds == ["__feed_f1"] and fetches == ["__fetch_f2"]
    state = sub.init_state()
    data = jnp.arange(8 * 2 * 3, dtype=jnp.float32).reshape(8, 2, 3)
    state = stage_feed(state, "__feed_f1", data)
    out_state = sub.compile(mode="static", n_iterations=8).run(state).state
    got = np.asarray(collect_sink(sub, out_state, "__fetch_f2"))
    np.testing.assert_allclose(got.reshape(-1, 3),
                               2 * np.asarray(data).reshape(-1, 3))
