"""Network construction, validation, scheduling — paper §2.2 rules."""
import jax.numpy as jnp
import pytest

from repro.core import (Edge, FifoSpec, Network, map_fire,
                        repetition_vector, static_actor)


def _passthrough(name, in_p="in", out_p="out"):
    return static_actor(name, (in_p,), (out_p,), map_fire(lambda w: w, in_p, out_p))


def _source(name="src"):
    def fire(state, inputs, rates):
        return state, {"out": jnp.zeros((1, 1))}
    return static_actor(name, (), ("out",), fire)


def _sink(name="snk"):
    def fire(state, inputs, rates):
        return state, {}
    return static_actor(name, ("in",), (), fire)


def _chain():
    a, b, c = _source(), _passthrough("mid"), _sink()
    fifos = [FifoSpec("f1", 1, (1,)), FifoSpec("f2", 1, (1,))]
    edges = [Edge("f1", "src", "out", "mid", "in"),
             Edge("f2", "mid", "out", "snk", "in")]
    return Network([a, b, c], fifos, edges)


def test_topological_order_and_repetition_vector():
    net = _chain()
    assert net.topological_order() == ["src", "mid", "snk"]
    # Single-rate-per-channel MoC -> all-ones repetition vector.
    assert repetition_vector(net) == {"src": 1, "mid": 1, "snk": 1}


def test_deadlock_detection():
    """A feedback cycle without a delay token can never fire (paper §2.2:
    initial tokens model feedback, e.g. IIR filters)."""
    a = _passthrough("a")
    b = _passthrough("b")
    fifos = [FifoSpec("f1", 1, (1,)), FifoSpec("f2", 1, (1,))]
    edges = [Edge("f1", "a", "out", "b", "in"),
             Edge("f2", "b", "out", "a", "in")]
    net = Network([a, b], fifos, edges)
    with pytest.raises(ValueError, match="deadlock"):
        net.topological_order()
    # With a delay token the cycle schedules.
    fifos2 = [FifoSpec("f1", 1, (1,)), FifoSpec("f2", 1, (1,), delay=1)]
    net2 = Network([a, b], fifos2, edges)
    assert set(net2.topological_order()) == {"a", "b"}


def test_delay_lt_rate_keeps_precedence():
    """delay=1 < rate=4: the consumer still needs the producer first
    (Fig. 2: read 1 overlaps write 1)."""
    a, b = _source(), _sink()
    f = FifoSpec("f", 4, (1,), delay=1)
    net = Network([a, b], [f], [Edge("f", "src", "out", "snk", "in")])
    assert net.topological_order() == ["src", "snk"]


def test_validation_errors():
    with pytest.raises(ValueError, match="connected twice"):
        a, b, c = _source(), _sink("s1"), _sink("s2")
        Network([a, b, c],
                [FifoSpec("f1", 1, (1,)), FifoSpec("f2", 1, (1,))],
                [Edge("f1", "src", "out", "s1", "in"),
                 Edge("f2", "src", "out", "s2", "in")])
    with pytest.raises(ValueError, match="not connected"):
        Network([_source(), _sink()], [], [])
    with pytest.raises(ValueError, match="is_control"):
        # control port fed by a non-control fifo
        from repro.core import dynamic_actor
        dyn = dynamic_actor("d", "c", lambda t: {"in": 1, "out": 1},
                            ("in",), ("out",), map_fire(lambda w: w, "in", "out"))
        Network([_source(), _source("src2"), dyn, _sink()],
                [FifoSpec("fc", 1, (1,)), FifoSpec("f1", 1, (1,)),
                 FifoSpec("f2", 1, (1,))],
                [Edge("fc", "src2", "out", "d", "c"),
                 Edge("f1", "src", "out", "d", "in"),
                 Edge("f2", "d", "out", "snk", "in")])


def test_schedule_feasibility_respects_eq1():
    net = _chain()
    net.check_schedule_feasible()  # passes: Eq. 1 double buffers suffice


def test_buffer_bytes_accounting():
    net = _chain()
    assert net.buffer_bytes() == 2 * (2 * 1 * 4)  # two rate-1 f32 channels
