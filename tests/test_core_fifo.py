"""FIFO channel unit + property tests (paper Eq. 1 + Fig. 2)."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container image ships no hypothesis
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import FifoSpec


def test_capacity_law_eq1():
    """C_f = S_f*(3r+1) with delay, S_f*2r otherwise — paper Eq. 1."""
    f = FifoSpec("f", 4, (10,), jnp.float32)
    assert f.capacity_tokens == 8
    assert f.capacity_bytes == 8 * 10 * 4
    d = FifoSpec("d", 4, (10,), jnp.float32, delay=1)
    assert d.capacity_tokens == 13          # 3*4 + 1 — Fig. 2's 13 slots
    assert d.capacity_bytes == 13 * 10 * 4


def test_motion_detection_table1_number():
    """The delayed QVGA channel at r=4 reproduces the paper's accounting."""
    tok = (240, 320)
    regular = FifoSpec("r", 4, tok, jnp.uint8)
    delayed = FifoSpec("d", 4, tok, jnp.uint8, delay=1)
    assert regular.token_size_bytes == 76800          # paper §4.1
    total = 4 * regular.capacity_bytes + delayed.capacity_bytes
    assert abs(total / 1e6 - 3.456) < 1e-3            # paper Table 1: 3.46 MB


def test_control_fifo_rules():
    with pytest.raises(ValueError):
        FifoSpec("c", 2, (1,), jnp.int32, is_control=True)   # rate must be 1
    with pytest.raises(ValueError):
        FifoSpec("c", 1, (1,), jnp.int32, is_control=True, delay=1)
    with pytest.raises(ValueError):
        FifoSpec("f", 1, (1,), jnp.float32, delay=2)         # MoC: 0 or 1


def test_delay_channel_shifts_by_one_token():
    """Fig. 2 semantics: reads lag writes by exactly one token."""
    r = 4
    spec = FifoSpec("d", r, (2,), jnp.float32, delay=1)
    st_ = spec.init_state(initial_token=jnp.array([7.0, 7.0]))
    writes = [np.arange(r * 2, dtype=np.float32).reshape(r, 2) + 10 * i
              for i in range(6)]
    out = []
    for i, w in enumerate(writes):
        assert bool(spec.can_write(st_)), i
        st_ = spec.write(st_, jnp.asarray(w))
        assert bool(spec.can_read(st_))
        win, st_ = spec.read(st_)
        out.append(np.asarray(win))
    flat = np.concatenate(out).reshape(-1, 2)
    expect = np.concatenate([[np.array([7.0, 7.0])],
                             np.concatenate(writes)])[:len(flat)]
    np.testing.assert_allclose(flat, expect)


@settings(max_examples=40, deadline=None)
@given(rate=st.integers(1, 5), delay=st.integers(0, 1),
       ops=st.lists(st.booleans(), min_size=1, max_size=40))
def test_fifo_matches_queue_oracle(rate, delay, ops):
    """Any blocking-legal interleaving of reads/writes behaves exactly like
    an unbounded FIFO queue initialized with the delay token."""
    spec = FifoSpec("f", rate, (1,), jnp.float32, delay=delay)
    st_ = spec.init_state()
    oracle = [0.0] * delay           # delay token = zeros
    counter = [1.0]
    for want_write in ops:
        if want_write:
            if not bool(spec.can_write(st_)):
                continue
            toks = np.array([counter[0] + i for i in range(rate)],
                            np.float32).reshape(rate, 1)
            counter[0] += rate
            st_ = spec.write(st_, jnp.asarray(toks))
            oracle.extend(toks[:, 0].tolist())
        else:
            if not bool(spec.can_read(st_)):
                continue
            win, st_ = spec.read(st_)
            expect = [oracle.pop(0) for _ in range(rate)]
            np.testing.assert_allclose(np.asarray(win)[:, 0], expect)
    assert int(st_.occ) == len(oracle)


@pytest.mark.parametrize("delay", [0, 1])
def test_static_phase_api_matches_dynamic_cursors(delay):
    """read_static/write_static/peek_static with trace-time phases produce
    bit-identical buffers, windows and counters to the cursor-driven API
    when driven through whole phase cycles from init_state."""
    r = 3
    spec = FifoSpec("f", r, (2,), jnp.float32, delay=delay)
    sd = spec.init_state()
    ss = spec.init_state()
    n_phases = spec.n_write_phases
    for i in range(2 * n_phases):
        toks = jnp.arange(r * 2, dtype=jnp.float32).reshape(r, 2) + 10 * i
        sd = spec.write(sd, toks)
        ss = spec.write_static(ss, toks, i % n_phases)
        np.testing.assert_array_equal(np.asarray(sd.buf), np.asarray(ss.buf))
        assert int(sd.wr) == int(ss.wr) and int(sd.occ) == int(ss.occ)
        np.testing.assert_array_equal(np.asarray(spec.peek(sd)),
                                      np.asarray(spec.peek_static(ss, i % n_phases)))
        wd, sd = spec.read(sd)
        ws, ss = spec.read_static(ss, i % n_phases)
        np.testing.assert_array_equal(np.asarray(wd), np.asarray(ws))
        assert int(sd.rd) == int(ss.rd) and int(sd.occ) == int(ss.occ)


def test_matched_rates_rejected_on_delay_channel():
    with pytest.raises(ValueError, match="matched_rates"):
        FifoSpec("f", 2, (1,), jnp.float32, delay=1, matched_rates=True)


def test_phase_unroll_period():
    from repro.core import phase_unroll_period
    assert phase_unroll_period([]) == 1
    assert phase_unroll_period([2, 2]) == 2
    assert phase_unroll_period([2, 3]) == 6
    assert phase_unroll_period([3]) == 3
    # Above the bound: pick the period covering the most channels.
    assert phase_unroll_period([2, 2, 3], bound=3) == 2
    assert phase_unroll_period([3, 3, 2], bound=3) == 3
    with pytest.raises(ValueError):
        phase_unroll_period([0])


@settings(max_examples=40, deadline=None)
@given(rate=st.integers(1, 5), delay=st.integers(0, 1),
       ops=st.lists(st.integers(0, 3), min_size=1, max_size=40))
def test_masked_fifo_matches_queue_oracle(rate, delay, ops):
    """The masked (rate-0/r) API behaves exactly like the queue oracle.

    Ops: 0 = enabled write, 1 = enabled read, 2 = disabled write,
    3 = disabled read.  Disabled ops must be pure no-ops observationally;
    enabled ops must match the unbounded queue.  This pins the delay
    channel's masked write path — a masked r-token window update plus a
    predicated slot-0 copy-back, with no full-buffer cond copy (the old
    ``lax.cond`` identity arm) — against Fig. 2 semantics.
    """
    spec = FifoSpec("f", rate, (1,), jnp.float32, delay=delay)
    st_ = spec.init_state()
    oracle = [0.0] * delay
    counter = [1.0]
    for op in ops:
        enabled = op < 2
        if op % 2 == 0:  # write
            if enabled and not bool(spec.can_write(st_)):
                continue
            toks = np.array([counter[0] + i for i in range(rate)],
                            np.float32).reshape(rate, 1)
            st2 = spec.write_masked(st_, jnp.asarray(toks),
                                    jnp.bool_(enabled))
            if enabled:
                counter[0] += rate
                oracle.extend(toks[:, 0].tolist())
            else:
                assert int(st2.occ) == int(st_.occ)
                assert int(st2.wr) == int(st_.wr)
            st_ = st2
        else:  # read
            if enabled and not bool(spec.can_read(st_)):
                continue
            win, st2 = spec.read_masked(st_, jnp.bool_(enabled))
            if enabled:
                expect = [oracle.pop(0) for _ in range(rate)]
                np.testing.assert_allclose(np.asarray(win)[:, 0], expect)
            else:
                assert int(st2.occ) == int(st_.occ)
                assert int(st2.rd) == int(st_.rd)
            st_ = st2
    assert int(st_.occ) == len(oracle)


@settings(max_examples=20, deadline=None)
@given(rate=st.integers(1, 4), n=st.integers(1, 12))
def test_masked_rate0_freezes_cursor(rate, n):
    """Rate-0 reads/writes (dynamic ports) leave the channel untouched."""
    spec = FifoSpec("f", rate, (1,), jnp.float32)
    st_ = spec.init_state()
    st_ = spec.write(st_, jnp.ones((rate, 1)))
    for _ in range(n):
        _, st2 = spec.read_masked(st_, jnp.bool_(False))
        assert int(st2.occ) == int(st_.occ)
        assert int(st2.rd) == int(st_.rd)
        st3 = spec.write_masked(st_, jnp.zeros((rate, 1)), jnp.bool_(False))
        assert int(st3.occ) == int(st_.occ)
