"""Property tests: the megakernel's channel-storage ops vs the
``repro.core.fifo`` functional API and the unbounded-queue oracle.

The in-kernel helpers (``_chan_read_masked`` / ``_chan_write_masked`` /
``_chan_peek`` in ``repro.core.megakernel.kernel``) re-express
``FifoSpec``'s masked API on the kernel's channel store — a Pallas
scratch ref for buffered channels, a **loop-carried token window** for
forwarded (transient) ones — plus a packed cursor row; the bit-identity
of the whole backend rests on them matching *exactly*: offsets, masked
no-op writes, the Fig. 2 delay copy-back.  Each drawn op sequence is
applied through a tiny interpret-mode ``pallas_call`` driving the
helpers in BOTH storage modes (forwarded only for delay-free specs —
transients are delay-free by construction) and through the functional
``FifoSpec`` state — final buffers, cursors and every read window must
be byte-identical, and both must agree with a plain Python queue.  The
forwarded window starts from the same initial buffer, pinning the
carve-out argument: from identical initial bytes the carried window
evolves byte-identically to a ring.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container image ships no hypothesis
    from _hypothesis_fallback import given, settings, strategies as st

from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import FifoSpec
from repro.core.megakernel.kernel import (_ChannelStore, _chan_peek,
                                          _chan_read, _chan_read_masked,
                                          _chan_write_masked)

jax.config.update("jax_platform_name", "cpu")

# Op codes for the driver kernel (mirrors test_core_fifo's masked oracle
# test): 0 = enabled write, 1 = enabled read, 2 = disabled write,
# 3 = disabled read.
W_ON, R_ON, W_OFF, R_OFF = 0, 1, 2, 3


def _store(spec: FifoSpec, ring, forwarded: bool) -> _ChannelStore:
    """One-channel store: scratch ring or loop-carried window."""
    if forwarded:
        return _ChannelStore(specs=(spec,), rings=(), ring_pos={},
                             fwd_pos={0: 0}, cursor_slot=((0, 0),))
    return _ChannelStore(specs=(spec,), rings=(ring,), ring_pos={0: 0},
                         fwd_pos={}, cursor_slot=((0, 0),))


def _drive_chan(spec: FifoSpec, ops, tokens, forwarded: bool):
    """Apply ``ops`` to one channel inside a pallas_call; return
    (final buf, final cursors, read windows log)."""
    n_ops = len(ops)
    cap = spec.capacity_tokens
    tok = tuple(spec.token_shape)

    def kernel(buf_in, cur_in, toks_in, buf_out, cur_out, reads_out, *ring):
        store = _store(spec, ring[0] if ring else None, forwarded)
        if forwarded:
            wins = (buf_in[...],)   # same start as the ring path
        else:
            ring[0][...] = buf_in[...]
            wins = ()
        curs = (cur_in[...],)
        for t, op in enumerate(ops):           # static unroll: ops are data
            enabled = jnp.bool_(op in (W_ON, R_ON))
            if op in (W_ON, W_OFF):
                wins, curs = _chan_write_masked(
                    store, wins, curs, 0, toks_in[t], enabled)
            else:
                win, curs = _chan_read_masked(store, wins, curs, 0, enabled)
                reads_out[t] = win
        buf_out[...] = wins[0] if forwarded else ring[0][...]
        cur_out[...] = curs[0]

    buf0 = spec.init_state().buf
    cur0 = jnp.zeros((1, 3), jnp.int32).at[0, 2].set(spec.delay)
    buf, cur, reads = pl.pallas_call(
        kernel,
        out_shape=[jax.ShapeDtypeStruct((cap,) + tok, spec.dtype),
                   jax.ShapeDtypeStruct((1, 3), jnp.int32),
                   jax.ShapeDtypeStruct((n_ops, spec.rate) + tok,
                                        spec.dtype)],
        scratch_shapes=([] if forwarded
                        else [pltpu.VMEM((cap,) + tok, spec.dtype)]),
        interpret=True,
    )(buf0, cur0, tokens)
    return buf, cur, reads


@settings(max_examples=30, deadline=None)
@given(rate=st.integers(1, 4), delay=st.integers(0, 1),
       raw_ops=st.lists(st.integers(0, 3), min_size=1, max_size=30))
def test_chan_store_matches_fifo_api_and_queue_oracle(rate, delay, raw_ops):
    spec = FifoSpec("f", rate, (1,), jnp.float32, delay=delay)
    # Pre-filter the drawn ops exactly like the fifo oracle test: enabled
    # ops that would violate blocking semantics are dropped (the MoC
    # schedulers never issue them), disabled ops always pass through.
    fs = spec.init_state()
    ops, oracle, counter = [], [0.0] * delay, 1.0
    expected_reads = []
    for op in raw_ops:
        if op == W_ON and not bool(spec.can_write(fs)):
            continue
        if op == R_ON and not bool(spec.can_read(fs)):
            continue
        if op in (W_ON, W_OFF):
            toks = np.arange(rate, dtype=np.float32).reshape(rate, 1) + counter
            fs = spec.write_masked(fs, jnp.asarray(toks),
                                   jnp.bool_(op == W_ON))
            if op == W_ON:
                counter += rate
                oracle.extend(toks[:, 0].tolist())
        else:
            win, fs = spec.read_masked(fs, jnp.bool_(op == R_ON))
            expected_reads.append((len(ops), np.asarray(win)))
            if op == R_ON:
                expect = [oracle.pop(0) for _ in range(rate)]
                # functional API vs queue oracle (re-pins fifo.py)
                np.testing.assert_allclose(np.asarray(win)[:, 0], expect)
        ops.append(op)
    if not ops:
        return  # every drawn op was blocking-illegal; nothing to drive
    # Token streams for the kernel: the write at step t uses tokens[t].
    tokens = np.zeros((len(ops), rate, 1), np.float32)
    c = 1.0
    for t, op in enumerate(ops):
        if op in (W_ON, W_OFF):
            tokens[t] = np.arange(rate, dtype=np.float32).reshape(rate, 1) + c
            if op == W_ON:
                c += rate
    # Forwarded storage only exists for delay-free channels (transients
    # are delay-free by construction — partition_layout asserts it).
    modes = (False,) if delay else (False, True)
    for forwarded in modes:
        buf, cur, reads = _drive_chan(spec, ops, jnp.asarray(tokens),
                                      forwarded)
        # Channel storage state == functional FifoState, byte for byte.
        np.testing.assert_array_equal(np.asarray(buf), np.asarray(fs.buf))
        assert int(cur[0, 0]) == int(fs.rd)
        assert int(cur[0, 1]) == int(fs.wr)
        assert int(cur[0, 2]) == int(fs.occ)
        assert int(fs.occ) == len(oracle)      # and both match the queue
        # Every read window (enabled AND disabled/stale) byte-identical.
        for t, want in expected_reads:
            np.testing.assert_array_equal(np.asarray(reads)[t], want)


@pytest.mark.parametrize("forwarded", [False, True])
@pytest.mark.parametrize("delay", [0, 1])
@pytest.mark.parametrize("tok_shape", [(1,), (2, 3)])
def test_chan_peek_and_unconditional_read(delay, tok_shape, forwarded):
    """_chan_peek/_chan_read (the control-port path) vs FifoSpec.peek/read
    across whole phase cycles, on multi-dimensional tokens."""
    if forwarded and delay:
        pytest.skip("forwarded channels are delay-free by construction")
    r = 2
    spec = FifoSpec("f", r, tok_shape, jnp.float32, delay=delay)
    n_steps = 2 * spec.n_write_phases

    def kernel(buf_in, cur_in, toks_in, peeks_out, wins_out, cur_out, *ring):
        store = _store(spec, ring[0] if ring else None, forwarded)
        if forwarded:
            wins = (buf_in[...],)
        else:
            ring[0][...] = buf_in[...]
            wins = ()
        curs = (cur_in[...],)
        for t in range(n_steps):
            wins, curs = _chan_write_masked(store, wins, curs, 0,
                                            toks_in[t], jnp.bool_(True))
            peeks_out[t] = _chan_peek(store, wins, curs, 0)
            win, curs = _chan_read(store, wins, curs, 0)
            wins_out[t] = win
        cur_out[...] = curs[0]

    toks = jnp.asarray(
        np.arange(n_steps * r * int(np.prod(tok_shape)), dtype=np.float32)
        .reshape((n_steps, r) + tok_shape))
    fs = spec.init_state()
    cap = spec.capacity_tokens
    peeks, wins, cur = pl.pallas_call(
        kernel,
        out_shape=[jax.ShapeDtypeStruct((n_steps,) + tok_shape, jnp.float32),
                   jax.ShapeDtypeStruct((n_steps, r) + tok_shape, jnp.float32),
                   jax.ShapeDtypeStruct((1, 3), jnp.int32)],
        scratch_shapes=([] if forwarded
                        else [pltpu.VMEM((cap,) + tok_shape, jnp.float32)]),
        interpret=True,
    )(fs.buf, jnp.zeros((1, 3), jnp.int32).at[0, 2].set(spec.delay), toks)
    for t in range(n_steps):
        fs = spec.write(fs, toks[t])
        np.testing.assert_array_equal(np.asarray(peeks)[t],
                                      np.asarray(spec.peek(fs)))
        win, fs = spec.read(fs)
        np.testing.assert_array_equal(np.asarray(wins)[t], np.asarray(win))
    assert (int(cur[0, 0]), int(cur[0, 1]), int(cur[0, 2])) \
        == (int(fs.rd), int(fs.wr), int(fs.occ))
