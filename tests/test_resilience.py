"""PR 10 serving-resilience chaos suite.

Three fault classes x three planes:

  * **Admission policy** — an expired deadline retires as
    ``status="timeout"`` (partial tokens kept), queue overflow beyond
    ``queue_depth`` sheds as ``status="shed"``; both are rate-0
    admission firings, never health faults, and the survivors' tokens
    are bit-identical to a run without the shed requests.
  * **Quarantine** — a poisoned request trips the slot-table channels'
    DOMAIN write guard; ``ActorEngine.generate(on_fault="quarantine")``
    maps the :class:`NetworkFaultError` back to exactly that request,
    retires it with ``status="fault"``, and re-runs the survivors from
    the pre-run checkpoint with bounded retries — survivor tokens again
    bit-identical.
  * **Durability** — ``stream(checkpoint_dir=...)`` /
    ``run_checkpointed`` commit CRC'd atomically-renamed snapshots; a
    child process is SIGKILLed mid-run and a fresh process resumes from
    the newest intact snapshot, with final outputs, states, fire counts,
    sweeps and the merged trace ring bit-identical to the uninterrupted
    run.  The kill is real (``os.kill(pid, SIGKILL)`` from a snapshot
    hook), not an exception.

The matrix runs on the host dynamic executor, the megakernel, and (in a
subprocess with a forced 8-device host mesh, the test_shard pattern) on
``devices=2``.
"""
import os
import signal
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core import (ExecutionPlan, NetworkBuilder, NetworkFaultError,
                        expire_deadline, map_fire, poison_request,
                        static_actor)
from repro.core.faultinject import POISON_VALUE
from repro.models import init_params
from repro.serve import ActorEngine, Request, ServeConfig

import jax.numpy as jnp

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# --------------------------------------------------------------------------- #
# Shared serving fixtures (module-scoped: one model init for the file).
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def lm():
    cfg = smoke_config("granite-8b")
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


@pytest.fixture(scope="module")
def scfg():
    return ServeConfig(batch_size=2, max_prompt=8, max_new=4, eos_id=7)


@pytest.fixture(scope="module")
def reqs(lm):
    cfg, _ = lm
    rng = np.random.default_rng(3)
    return [Request(prompt=rng.integers(1, cfg.vocab,
                                        size=int(rng.integers(2, 8)))
                    .astype(np.int32), max_new=4) for _ in range(5)]


@pytest.fixture(scope="module")
def baseline(lm, reqs, scfg):
    """Fault-free oracle tokens (backend-independent by the serving
    bit-identity contract, so one dynamic run serves every cell)."""
    cfg, params = lm
    eng = ActorEngine(cfg, params, scfg)
    out = eng.generate(list(reqs))
    assert eng.last_status == ["ok"] * len(reqs)
    return [r.tokens.tolist() for r in out]


def _plan(mode, **kw):
    if mode == "megakernel":
        kw.setdefault("specialize", False)
    return ExecutionPlan(mode=mode, **kw)


# --------------------------------------------------------------------------- #
# Chaos matrix: poison / deadline / overflow x dynamic / megakernel.
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("mode", ("dynamic", "megakernel"))
def test_poison_quarantined_survivors_bit_identical(lm, reqs, scfg,
                                                    baseline, mode):
    cfg, params = lm
    eng = ActorEngine(cfg, params, scfg, plan=_plan(mode, guards=True))
    bad = list(reqs)
    bad[1] = Request(prompt=np.full(4, POISON_VALUE, np.int32), max_new=4)
    out = eng.generate(bad, on_fault="quarantine")
    assert eng.last_status[1] == "fault"
    assert out[1].tokens.size == 0
    assert eng.last_retries == 1
    for i in (0, 2, 3, 4):
        assert eng.last_status[i] == "ok"
        assert out[i].tokens.tolist() == baseline[i], i


@pytest.mark.parametrize("mode", ("dynamic", "megakernel"))
def test_expired_deadline_sheds_as_timeout(lm, reqs, scfg, baseline, mode):
    cfg, params = lm
    eng = ActorEngine(cfg, params, scfg, plan=_plan(mode))
    dls = np.full(len(reqs), 2 ** 30 - 1, np.int32)
    dls[2] = -1                       # expired before the first firing
    out = eng.generate(list(reqs), deadlines=dls)
    assert eng.last_status[2] == "timeout"
    assert out[2].tokens.size == 0    # never admitted, nothing produced
    for i in (0, 1, 3, 4):
        assert eng.last_status[i] == "ok"
        assert out[i].tokens.tolist() == baseline[i], i


@pytest.mark.parametrize("mode", ("dynamic", "megakernel"))
def test_queue_overflow_sheds_excess_requests(lm, reqs, scfg, baseline,
                                              mode):
    cfg, params = lm
    eng = ActorEngine(cfg, params, scfg, plan=_plan(mode), queue_depth=0)
    out = eng.generate(list(reqs))     # all 5 arrive at step 0, B=2 slots
    assert eng.last_status == ["ok", "ok", "shed", "shed", "shed"]
    for i in (0, 1):
        assert out[i].tokens.tolist() == baseline[i], i
    for i in (2, 3, 4):
        assert out[i].tokens.size == 0


def test_mid_flight_deadline_keeps_token_prefix(lm, reqs, scfg, baseline):
    """A deadline that expires mid-generation retires the request with
    ``status="timeout"`` and the tokens it produced so far — a strict
    prefix of its fault-free tokens (progress is never un-published)."""
    cfg, params = lm
    eng = ActorEngine(cfg, params, scfg)
    dls = np.full(len(reqs), 2 ** 30 - 1, np.int32)
    # The serving clock ticks once per decode step, so deadline 1 admits
    # the request and retires it after its second token (of four).
    dls[0] = 1
    out = eng.generate(list(reqs), deadlines=dls)
    assert eng.last_status[0] == "timeout"
    got = out[0].tokens.tolist()
    assert len(got) < len(baseline[0])
    assert got == baseline[0][:len(got)]
    for i in (1, 2, 3, 4):
        assert out[i].tokens.tolist() == baseline[i], i


def test_injector_validation(lm, reqs, scfg):
    cfg, params = lm
    eng = ActorEngine(cfg, params, scfg)
    wl, _ = eng._stage(reqs, None, None)
    with pytest.raises(ValueError, match="out of range"):
        poison_request(wl, 99)
    with pytest.raises(ValueError, match="not a poison"):
        poison_request(wl, 0, value=3)
    with pytest.raises(ValueError, match="out of range"):
        expire_deadline(wl, -1)
    # pure: the staged workload is untouched
    pw = poison_request(wl, 1)
    assert not np.array_equal(np.asarray(pw.prompts),
                              np.asarray(wl.prompts))
    ew = expire_deadline(wl, 2)
    assert wl.deadlines is None and int(ew.deadlines[2]) == -1


def test_quarantine_needs_guards_and_reraises_unmapped(lm, reqs, scfg):
    cfg, params = lm
    with pytest.raises(ValueError, match="guarded plan"):
        ActorEngine(cfg, params, scfg).generate(list(reqs),
                                                on_fault="quarantine")
    # retries exhausted -> the fault surfaces instead of looping forever
    eng = ActorEngine(cfg, params, scfg,
                      plan=ExecutionPlan(mode="dynamic", guards=True))
    bad = list(reqs)
    bad[0] = Request(prompt=np.full(4, POISON_VALUE, np.int32), max_new=4)
    with pytest.raises(NetworkFaultError):
        eng.generate(bad, on_fault="quarantine", max_retries=0)


# --------------------------------------------------------------------------- #
# Feed-domain validation (satellite: the stream error names chunk AND
# offending request id).
# --------------------------------------------------------------------------- #
def test_stream_feed_domain_error_names_chunk_and_request():
    b = NetworkBuilder()
    b.actor(static_actor("src", (), ("out",),
                         lambda st, ins, rates: (st,
                                                 {"out": jnp.zeros((4, 2,
                                                                    8))})))
    b.actor(static_actor("amp", ("in",), ("out",),
                         map_fire(lambda w: 2.0 * w, "in", "out")))
    b.actor(static_actor("sink", ("in",), (),
                         lambda st, ins, rates: (st, {})))
    b.connect("src.out", "amp.in", rate=4, token_shape=(2, 8), name="f_in",
              domain=(0.0, 100.0), row_id_col=0)
    b.connect("amp.out", "sink.in", rate=4, token_shape=(2, 8),
              name="f_out")
    net = b.build()
    prog = net.compile(ExecutionPlan(mode="dynamic", n_iterations=2,
                                     accelerated=("amp",)))
    feeds = np.ones((6, 4, 2, 8), np.float32)
    feeds[:, :, :, 0] = 7.0            # row id column
    clean = prog.stream({"f_in": feeds})
    np.testing.assert_array_equal(np.asarray(clean["f_out"]), 2 * feeds)
    bad = feeds.copy()
    bad[3, 1, 0, 2] = -5.0             # window 3 -> chunk 1; row id 7
    with pytest.raises(ValueError, match=r"chunk 1.*request id 7"):
        prog.stream({"f_in": bad})
    # NaN is out of every domain, even one with infinite-looking bounds
    nan = feeds.copy()
    nan[0, 0, 1, 3] = np.nan
    with pytest.raises(ValueError, match=r"chunk 0"):
        prog.stream({"f_in": nan})


# --------------------------------------------------------------------------- #
# Kill -> resume: a real SIGKILL mid-run, bit-identical continuation.
# --------------------------------------------------------------------------- #
def _archive_checkpoint(ck: str, tag: str) -> None:
    """Copy a kill-resume snapshot directory to the CI artifact root
    (RESIL_CKPT_ARTIFACT_DIR), so the raw manifests + CRC'd leaves the
    killed child left behind are inspectable after the run."""
    import shutil
    root = os.environ.get("RESIL_CKPT_ARTIFACT_DIR")
    if not root:
        return
    os.makedirs(root, exist_ok=True)
    shutil.copytree(ck, os.path.join(root, tag), dirs_exist_ok=True)


def _run_child(body: str, devices: int = 1, expect_kill: bool = False,
               timeout: int = 600) -> str:
    code = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = "
        f"'--xla_force_host_platform_device_count={devices}'\n"
        + textwrap.dedent(body))
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    if expect_kill:
        assert out.returncode == -signal.SIGKILL, (
            f"child exited {out.returncode}, expected SIGKILL\n"
            f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}")
    else:
        assert out.returncode == 0, (
            f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}")
    return out.stdout


_KILL_HOOK = """
import os, signal
import repro.core.program as P
_orig_save = P.save_stream_checkpoint
_n = [0]
def _hooked(*a, **k):
    r = _orig_save(*a, **k)
    _n[0] += 1
    if _n[0] == @KILL_AFTER@:
        os.kill(os.getpid(), signal.SIGKILL)
    return r
P.save_stream_checkpoint = _hooked
"""

_DPD_SETUP = """
import numpy as np, jax.numpy as jnp
from repro.core import ExecutionPlan
from repro.graphs.factories import make_dpd
net, nf = make_dpd(n_firings=8, block_l=64)
accel = tuple(n for n in net.actors if n not in ("source", "sink"))
rng = np.random.default_rng(0)
sig = rng.normal(size=(2, nf * 64)).astype(np.float32)
wins = np.stack([sig[:, i * 64:(i + 1) * 64] for i in range(nf)])[:, None]
feeds = {"f_in": jnp.asarray(wins)}
plan = @PLAN@
prog = net.compile(plan)
"""

_SERVING_SETUP = """
import numpy as np, jax
from repro.configs import smoke_config
from repro.models import init_params
from repro.serve import ActorEngine, Request, ServeConfig
from repro.core import ExecutionPlan
cfg = smoke_config("granite-8b")
params = init_params(jax.random.PRNGKey(0), cfg)
scfg = ServeConfig(batch_size=2, max_prompt=6, max_new=3, eos_id=7)
rng = np.random.default_rng(5)
reqs = [Request(prompt=rng.integers(1, cfg.vocab, size=4).astype(np.int32),
                max_new=3) for _ in range(4)]
net = ActorEngine(cfg, params, scfg).build_network(reqs)
plan = @PLAN@
prog = net.compile(plan)
"""


DPD_STREAM_PLANS = {
    "dynamic": "ExecutionPlan(mode='dynamic', n_iterations=2, "
               "accelerated=accel, trace=True)",
    "megakernel": "ExecutionPlan(mode='megakernel', n_iterations=2, "
                  "accelerated=accel, specialize=False)",
}


@pytest.mark.parametrize("mode", sorted(DPD_STREAM_PLANS))
def test_kill_resume_stream_dpd_bit_identical(tmp_path, mode):
    """Child streams the dpd graph with per-chunk snapshots and is
    SIGKILLed after chunk 2 of 4; a fresh process resumes and its
    outputs, fire counts, sweeps and merged trace are bit-identical to
    an uninterrupted stream."""
    ck = str(tmp_path / "ck")
    setup = _DPD_SETUP.replace("@PLAN@", DPD_STREAM_PLANS[mode])
    _run_child(setup + _KILL_HOOK.replace("@KILL_AFTER@", "2") + f"""
prog.stream(feeds, checkpoint_dir={ck!r}, checkpoint_every=1)
raise SystemExit("stream finished without being killed")
""", expect_kill=True)
    assert any(d.startswith("chunk_") for d in os.listdir(ck))
    _archive_checkpoint(ck, f"stream_dpd_{mode}")
    out = _run_child(setup + f"""
ref = prog.stream(feeds)
ref_fc, ref_sw = prog.last_stream_fire_counts, prog.last_stream_sweeps
ref_tr = prog.last_stream_trace
prog2 = net.compile(plan)
got = prog2.resume_stream({ck!r}, feeds, checkpoint_every=1)
for f in ref:
    np.testing.assert_array_equal(np.asarray(ref[f]), np.asarray(got[f]))
assert prog2.last_stream_fire_counts == ref_fc
assert prog2.last_stream_sweeps == ref_sw
if ref_tr is not None:
    np.testing.assert_array_equal(ref_tr.events,
                                  prog2.last_stream_trace.events)
    assert ref_tr.actor_names == prog2.last_stream_trace.actor_names
print("RESUME_STREAM_OK")
""")
    assert "RESUME_STREAM_OK" in out


SERVING_RUN_PLANS = {
    "dynamic-1dev": ("ExecutionPlan(mode='dynamic')", 1),
    "dynamic-2dev": ("ExecutionPlan(mode='dynamic', devices=2)", 8),
    "megakernel": ("ExecutionPlan(mode='megakernel', specialize=False)", 1),
}


@pytest.mark.parametrize("cell", sorted(SERVING_RUN_PLANS))
def test_kill_resume_run_serving_bit_identical(tmp_path, cell):
    """Child runs the serving graph via run_checkpointed (segments of 5
    sweeps) and is SIGKILLed after the first snapshot; a fresh process
    resumes via resume_run and the final state / fire counts / sweeps
    are bit-identical to an uninterrupted run — including at devices=2,
    where each segment re-enters the sharded runner through the exit-
    merged host state."""
    plan_expr, devices = SERVING_RUN_PLANS[cell]
    ck = str(tmp_path / "ck")
    setup = _SERVING_SETUP.replace("@PLAN@", plan_expr)
    _run_child(setup + _KILL_HOOK.replace("@KILL_AFTER@", "1") + f"""
prog.run_checkpointed({ck!r}, every_sweeps=5)
raise SystemExit("run finished without being killed")
""", devices=devices, expect_kill=True)
    assert any(d.startswith("chunk_") for d in os.listdir(ck))
    _archive_checkpoint(ck, f"run_serving_{cell}")
    out = _run_child(setup + f"""
ref = prog.run()
got = net.compile(plan).resume_run({ck!r})
assert int(got.sweeps) == int(ref.sweeps), (got.sweeps, ref.sweeps)
assert {{k: int(v) for k, v in got.fire_counts.items()}} == \\
    {{k: int(v) for k, v in ref.fire_counts.items()}}
for a, b in zip(jax.tree.leaves(ref.state), jax.tree.leaves(got.state)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("RESUME_RUN_OK")
""", devices=devices)
    assert "RESUME_RUN_OK" in out


def test_kill_resume_run_dpd_devices2(tmp_path):
    """The dpd graph under devices=2: kill after the first segment
    snapshot, resume on a fresh mesh, bit-identical final state."""
    ck = str(tmp_path / "ck")
    setup = """
import numpy as np, jax
from repro.core import ExecutionPlan
from repro.graphs.factories import make_dpd
net, nf = make_dpd(n_firings=6, block_l=64)
plan = ExecutionPlan(mode="dynamic", devices=2)
prog = net.compile(plan)
"""
    _run_child(setup + _KILL_HOOK.replace("@KILL_AFTER@", "1") + f"""
prog.run_checkpointed({ck!r}, every_sweeps=3)
raise SystemExit("run finished without being killed")
""", devices=8, expect_kill=True)
    out = _run_child(setup + f"""
ref = prog.run()
got = net.compile(plan).resume_run({ck!r})
assert int(got.sweeps) == int(ref.sweeps)
for a, b in zip(jax.tree.leaves(ref.state), jax.tree.leaves(got.state)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("RESUME_RUN_OK")
""", devices=8)
    assert "RESUME_RUN_OK" in out


# --------------------------------------------------------------------------- #
# Snapshot integrity: CRC failure falls back to the previous snapshot.
# --------------------------------------------------------------------------- #
def test_torn_snapshot_falls_back_to_previous(tmp_path):
    from repro.checkpoint import (CheckpointIntegrityError,
                                  load_stream_checkpoint,
                                  save_stream_checkpoint)
    d = str(tmp_path / "ck")
    save_stream_checkpoint(d, 1, {"x": np.arange(4)}, {"kind": "t"})
    save_stream_checkpoint(d, 2, {"x": np.arange(8)}, {"kind": "t"})
    # tear the newest snapshot's leaf file (simulated torn write)
    leaf = os.path.join(d, "chunk_00000002", "leaf_0000.npy")
    with open(leaf, "r+b") as f:
        f.seek(0, 2)
        f.truncate(f.tell() - 3)
    payload, meta, step = load_stream_checkpoint(d)
    assert step == 1 and np.asarray(payload["x"]).shape == (4,)
    # with no intact snapshot at all, the failure is loud and typed
    leaf1 = os.path.join(d, "chunk_00000001", "leaf_0000.npy")
    with open(leaf1, "r+b") as f:
        f.write(b"\xff" * 8)
    with pytest.raises(CheckpointIntegrityError):
        load_stream_checkpoint(d)


def test_resume_rejects_mismatched_kind_and_geometry(tmp_path):
    """resume_stream refuses a run snapshot and a geometry drift."""
    import jax.numpy as jnp
    from repro.graphs.factories import make_dpd
    net, nf = make_dpd(n_firings=4, block_l=64)
    plan = ExecutionPlan(mode="dynamic")
    ck = str(tmp_path / "ck")
    prog = net.compile(plan)
    prog.run_checkpointed(ck, every_sweeps=100)
    accel = tuple(n for n in net.actors if n not in ("source", "sink"))
    sprog = net.compile(ExecutionPlan(mode="dynamic", n_iterations=2,
                                      accelerated=accel))
    rng = np.random.default_rng(0)
    sig = rng.normal(size=(2, nf * 64)).astype(np.float32)
    wins = np.stack([sig[:, i * 64:(i + 1) * 64] for i in range(nf)])[:, None]
    with pytest.raises(ValueError, match="resume via"):
        sprog.resume_stream(ck, {"f_in": jnp.asarray(wins)})
    with pytest.raises(ValueError, match="resume via"):
        sprog2 = net.compile(plan)
        sck = str(tmp_path / "sck")
        sprog.stream({"f_in": jnp.asarray(wins)}, checkpoint_dir=sck)
        sprog2.resume_run(sck)


def test_resume_run_of_completed_run_returns_final_result(tmp_path):
    from repro.graphs.factories import make_dpd
    net, _ = make_dpd(n_firings=4, block_l=64)
    plan = ExecutionPlan(mode="dynamic")
    ck = str(tmp_path / "ck")
    ref = net.compile(plan).run()
    got = net.compile(plan).run_checkpointed(ck, every_sweeps=2)
    assert int(got.sweeps) == int(ref.sweeps)
    # the final snapshot is marked done: resume reconstructs the result
    again = net.compile(plan).resume_run(ck)
    assert int(again.sweeps) == int(ref.sweeps)
    for a, b in zip(jax.tree.leaves(ref.state), jax.tree.leaves(again.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
