"""Per-arch smoke tests (required by the assignment): reduced same-family
config, one forward/train step on CPU, output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, get_config, smoke_config
from repro.configs.base import input_specs
from repro.models import decode_step, init_params, prefill, train_loss

ARCHS = sorted(REGISTRY)


def _batch(cfg, key, B, S):
    n_txt = S - cfg.n_vision_tokens if cfg.family == "vlm" else S
    batch = {"tokens": jax.random.randint(key, (B, n_txt), 0, cfg.vocab),
             "labels": jax.random.randint(key, (B, n_txt), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            key, (B, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder.n_ctx, cfg.encoder.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_train_step(arch):
    cfg = smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    batch = _batch(cfg, key, B=2, S=32)
    loss, parts = jax.jit(lambda p, b: train_loss(p, cfg, b))(params, batch)
    assert np.isfinite(float(loss)), arch
    assert float(parts["ce"]) > 0

    grads = jax.grad(lambda p: train_loss(p, cfg, batch)[0])(params)
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_prefill_decode(arch):
    cfg = smoke_config(arch)
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    B, S = 2, 32
    batch = _batch(cfg, key, B, S)
    batch.pop("labels")
    logits, caches = prefill(params, cfg, batch, max_cache_len=S + 8)
    assert logits.shape == (B, cfg.vocab_padded)
    assert np.all(np.isfinite(np.asarray(logits)))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    assert int(jnp.max(tok)) < cfg.vocab  # padded logits masked out
    lg2, caches2 = decode_step(params, cfg, tok, jnp.full((B,), S, jnp.int32),
                               caches)
    assert lg2.shape == (B, cfg.vocab_padded)
    assert np.all(np.isfinite(np.asarray(lg2)))


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_declares_shapes(arch):
    cfg = get_config(arch)
    shapes = cfg.shapes()
    assert "train_4k" in shapes
    for name in shapes:
        specs = input_specs(cfg, name)
        assert specs["tokens"].dtype == jnp.int32
    # long_500k skips are documented (DESIGN.md §6)
    if "long_500k" in cfg.skip_shapes:
        assert cfg.notes


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_sane(arch):
    cfg = get_config(arch)
    n = cfg.param_count()
    expected_order = {
        "gemma3-12b": 12e9, "h2o-danube-3-4b": 4e9, "qwen2-72b": 72e9,
        "granite-8b": 8e9, "whisper-small": 0.24e9,
        "granite-moe-3b-a800m": 3e9, "olmoe-1b-7b": 7e9,
        "recurrentgemma-2b": 2.7e9, "internvl2-1b": 0.8e9,
        "mamba2-780m": 0.78e9,
    }[arch]
    assert 0.4 * expected_order < n < 2.6 * expected_order, (arch, n)
    assert cfg.active_param_count() <= n
