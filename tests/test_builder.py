"""NetworkBuilder: validation errors, matched-rates derivation, to_dot."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Edge, FifoSpec, Network, NetworkBuilder,
                        dynamic_actor, map_fire, static_actor)


def _source(name="src", ports=("out",)):
    def fire(state, inputs, rates):
        return state, {p: jnp.zeros((1, 1)) for p in ports}
    return static_actor(name, (), ports, fire)


def _sink(name="snk"):
    def fire(state, inputs, rates):
        return state, {}
    return static_actor(name, ("in",), (), fire)


def _gate(name="gate"):
    return dynamic_actor(name, "c", lambda t: {"in": t[0] > 0, "out": t[0] > 0},
                         ("in",), ("out",), map_fire(lambda w: w, "in", "out"))


# --------------------------------------------------------------------------- #
# Actionable validation errors, reported at the offending call.
# --------------------------------------------------------------------------- #
def test_unknown_actor_is_reported_with_suggestion():
    b = NetworkBuilder()
    b.actor(_source())
    b.actor(_sink())
    with pytest.raises(ValueError, match=r"unknown actor 'sr'.*did you mean 'src'"):
        b.connect("sr.out", "snk.in", token_shape=(1,))


def test_unknown_port_is_reported_with_suggestion():
    b = NetworkBuilder()
    b.actor(_source())
    b.actor(_sink())
    with pytest.raises(ValueError, match=r"no output port 'ot'.*did you mean 'out'"):
        b.connect("src.ot", "snk.in", token_shape=(1,))
    with pytest.raises(ValueError, match=r"no input port 'inn'.*did you mean 'in'"):
        b.connect("src.out", "snk.inn", token_shape=(1,))


def test_double_connected_port_names_existing_channel():
    b = NetworkBuilder()
    b.actors(_source(), _sink(), _sink("snk2"))
    b.connect("src.out", "snk.in", token_shape=(1,), name="first")
    with pytest.raises(ValueError, match=r"already connected by channel 'first'.*fork"):
        b.connect("src.out", "snk2.in", token_shape=(1,))


def test_double_connected_input_port():
    b = NetworkBuilder()
    b.actors(_source(), _source("src2"), _sink())
    b.connect("src.out", "snk.in", token_shape=(1,), name="first")
    with pytest.raises(ValueError, match=r"already connected by channel 'first'.*merge"):
        b.connect("src2.out", "snk.in", token_shape=(1,))


def test_control_rate_violation():
    b = NetworkBuilder()
    b.actors(_source("ctl"), _source(), _gate(), _sink())
    with pytest.raises(ValueError, match=r"control channels must have token rate 1"):
        b.connect("ctl.out", "gate.c", rate=2)
    with pytest.raises(ValueError, match=r"cannot carry delay tokens"):
        b.connect("ctl.out", "gate.c", delay=1)


def test_control_flag_mismatches():
    b = NetworkBuilder()
    b.actors(_source("ctl"), _source(), _gate(), _sink())
    with pytest.raises(ValueError, match=r"control=True but 'in' is not the control port"):
        b.connect("src.out", "gate.in", token_shape=(1,), control=True)
    with pytest.raises(ValueError, match=r"control=False but 'c' IS the control port"):
        b.connect("ctl.out", "gate.c", control=False)


def test_dangling_port_reported_at_build():
    b = NetworkBuilder()
    b.actors(_source(), _sink())
    with pytest.raises(ValueError, match=r"dangling ports.*\['snk.in', 'src.out'\]"):
        b.build()


def test_duplicate_actor_and_channel_names():
    b = NetworkBuilder()
    b.actor(_source())
    with pytest.raises(ValueError, match="already registered"):
        b.actor(_source())
    b.actor(_sink())
    b.actor(_sink("snk2"))
    b2 = NetworkBuilder()
    b2.actors(_source(), _source("src2"), _sink(), _sink("snk2"))
    b2.connect("src.out", "snk.in", token_shape=(1,), name="f")
    with pytest.raises(ValueError, match="channel name 'f' already used"):
        b2.connect("src2.out", "snk2.in", token_shape=(1,), name="f")


def test_capacity_is_derived_not_chosen():
    b = NetworkBuilder()
    b.actors(_source(), _sink())
    # Correct Eq. 1 expectation passes ...
    b.connect("src.out", "snk.in", rate=2, token_shape=(1,), capacity=4)
    net = b.build()
    assert net.fifos["src.out->snk.in"].capacity_tokens == 4
    # ... a wrong one is contradicted with the law.
    b2 = NetworkBuilder()
    b2.actors(_source(), _sink())
    with pytest.raises(ValueError, match=r"contradicts the Eq. 1 law"):
        b2.connect("src.out", "snk.in", rate=2, token_shape=(1,), capacity=7)


def test_missing_token_shape_and_bad_endpoint_syntax():
    b = NetworkBuilder()
    b.actors(_source(), _sink())
    with pytest.raises(ValueError, match="token_shape"):
        b.connect("src.out", "snk.in")
    with pytest.raises(ValueError, match="'actor.port'"):
        b.connect("src", "snk.in", token_shape=(1,))


def test_initial_token_requires_delay():
    b = NetworkBuilder()
    b.actors(_source(), _sink())
    with pytest.raises(ValueError, match="initial_token needs delay=1"):
        b.connect("src.out", "snk.in", token_shape=(1,),
                  initial_token=np.zeros((1,)))


# --------------------------------------------------------------------------- #
# Builder output == hand-assembled Network (same names, order, semantics).
# --------------------------------------------------------------------------- #
def test_builder_emits_equivalent_network():
    b = NetworkBuilder()
    b.actors(_source(), _sink())
    b.connect("src.out", "snk.in", rate=2, token_shape=(3,), delay=1,
              name="f", initial_token=np.ones((2, 3))[0])
    built = b.build()
    manual = Network(
        [_source(), _sink()],
        [FifoSpec("f", 2, (3,), jnp.float32, delay=1)],
        [Edge("f", "src", "out", "snk", "in")],
        initial_tokens={"f": np.ones((3,))})
    assert list(built.actors) == list(manual.actors)
    assert list(built.fifos) == list(manual.fifos)
    assert built.edges == manual.edges
    s1, s2 = built.init_state(), manual.init_state()
    for x, y in zip(jax.tree.leaves(s1), jax.tree.leaves(s2)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_auto_naming_and_control_defaults():
    b = NetworkBuilder()
    b.actors(_source("ctl"), _source(), _gate(), _sink())
    cname = b.connect("ctl.out", "gate.c")
    assert cname == "ctl.out->gate.c"
    b.connect("src.out", "gate.in", token_shape=(1,))
    b.connect("gate.out", "snk.in", token_shape=(1,))
    net = b.build()
    cspec = net.fifos[cname]
    assert cspec.is_control and cspec.rate == 1
    assert cspec.token_shape == (1,) and cspec.dtype == jnp.int32


# --------------------------------------------------------------------------- #
# matched_rates derivation.
# --------------------------------------------------------------------------- #
def test_derivation_pins_dpd_register_set():
    """All 22 DPD data channels + 12 control channels register-allocate:
    the derivation proves what the hand flags used to declare."""
    from repro.graphs.dpd import build_dpd
    net = build_dpd(4, active_schedule=np.array([2, 10, 5, 7], np.int32),
                    block_l=64)
    expect = ({"f_in", "f_out", "f_c_fork", "f_c_add"}
              | {f"f_b{k}" for k in range(10)}
              | {f"f_y{k}" for k in range(10)}
              | {f"f_c{k}" for k in range(10)})
    assert set(net.register_fifos) == expect
    # The static rewrite keeps every channel ring-buffered (static-static
    # registerization is the measured XLA mega-fusion pathology).
    sta = build_dpd(4, block_l=64, static_all_active=True)
    assert not sta.register_fifos


def test_derivation_is_conservative_for_unmatched_enables():
    """A dynamic consumer whose enable depends on the token must NOT be
    matched against an unconditional static producer (occupancy drifts)."""
    b = NetworkBuilder()
    b.actors(_source("ctl"), _source(), _gate(), _sink())
    b.connect("ctl.out", "gate.c")
    f_in = b.connect("src.out", "gate.in", token_shape=(1,))
    f_out = b.connect("gate.out", "snk.in", token_shape=(1,))
    net = b.build()
    assert f_in not in net.register_fifos
    assert f_out not in net.register_fifos


def test_derivation_override():
    b = NetworkBuilder()
    b.actors(_source("ctl"), _source(), _gate(), _sink())
    b.connect("ctl.out", "gate.c")
    f_in = b.connect("src.out", "gate.in", token_shape=(1,))
    # Caller may assert the invariant the derivation cannot prove.
    f_out = b.connect("gate.out", "snk.in", token_shape=(1,),
                      matched_rates=True)
    net = b.build()
    assert f_out in net.register_fifos and f_in not in net.register_fifos


def test_delay_channels_never_matched():
    b = NetworkBuilder()
    b.actors(_source(), _sink())
    f = b.connect("src.out", "snk.in", token_shape=(1,), delay=1)
    net = b.build()
    assert f not in net.register_fifos


# --------------------------------------------------------------------------- #
# Graphviz export.
# --------------------------------------------------------------------------- #
def test_to_dot_marks_control_dashed_and_delay_labels():
    b = NetworkBuilder()
    b.actors(_source("ctl"), _source(), _gate(), _sink())
    b.connect("ctl.out", "gate.c", name="fc")
    b.connect("src.out", "gate.in", token_shape=(1,), name="fi")
    b.connect("gate.out", "snk.in", token_shape=(1,), delay=1, name="fo")
    dot = b.build().to_dot()
    assert dot.startswith("digraph network {") and dot.endswith("}")
    assert '"ctl" -> "gate"' in dot and "style=dashed" in dot
    assert "delay=1" in dot and "cap=4" in dot       # Eq. 1: 3r+1 with delay
    assert "peripheries=2" in dot                    # dynamic actor marker
    # one edge line per channel
    assert dot.count(" -> ") == 3
