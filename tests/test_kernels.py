"""Per-kernel correctness sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.gauss5x5 import gauss5x5
from repro.kernels.motion_post import median5, motion_post
from repro.kernels.dyn_fir import dpd_branch
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd import ssd, ssd_naive
from repro.kernels.rglru import rglru, rglru_naive


@pytest.mark.parametrize("hw", [(240, 320), (480, 640), (120, 160), (64, 48)])
def test_gauss5x5(rng, hw):
    H, W = hw
    f = jnp.asarray(rng.uniform(0, 255, (H, W)), jnp.float32)
    a = gauss5x5(f, impl="xla")
    b = gauss5x5(f, impl="pallas", block_h=H // 4, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-3)


def test_median5_vs_numpy(rng):
    vals = rng.normal(size=(5, 2000)).astype(np.float32)
    m = np.asarray(median5(*[jnp.asarray(v) for v in vals]))
    np.testing.assert_allclose(m, np.median(vals, axis=0))


@pytest.mark.parametrize("hw,block_h", [((240, 320), 60), ((120, 160), 30),
                                        ((64, 64), 16)])
def test_motion_post(rng, hw, block_h):
    H, W = hw
    cur = jnp.asarray(rng.uniform(0, 255, (H, W)), jnp.float32)
    prev = jnp.asarray(rng.uniform(0, 255, (H, W)), jnp.float32)
    a = motion_post(cur, prev, impl="xla")
    b = motion_post(cur, prev, impl="pallas", block_h=block_h, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("order", [1, 2, 5, 10])
@pytest.mark.parametrize("L,block", [(2048, 512), (1024, 1024)])
def test_dyn_fir(rng, order, L, block):
    xr = jnp.asarray(rng.normal(size=L + 9), jnp.float32)
    xi = jnp.asarray(rng.normal(size=L + 9), jnp.float32)
    hr = jnp.asarray(rng.normal(size=10), jnp.float32)
    hi = jnp.asarray(rng.normal(size=10), jnp.float32)
    ar, ai = dpd_branch(xr, xi, hr, hi, order=order, impl="xla")
    br, bi = dpd_branch(xr, xi, hr, hi, order=order, impl="pallas",
                        block=block, interpret=True)
    np.testing.assert_allclose(np.asarray(ar), np.asarray(br), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(ai), np.asarray(bi), rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize(
    "B,S,H,Hkv,hd,causal,window,bq,bk",
    [(2, 128, 4, 2, 32, True, None, 32, 32),
     (1, 256, 8, 8, 16, True, 64, 64, 64),
     (2, 64, 4, 1, 32, False, None, 32, 16),
     (1, 128, 2, 2, 64, True, 32, 32, 32),
     (1, 128, 6, 3, 16, True, None, 64, 32)])
def test_flash_attention(rng, B, S, H, Hkv, hd, causal, window, bq, bk):
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, hd)), jnp.float32)
    a = flash_attention(q, k, v, causal=causal, window=window, impl="xla")
    b = flash_attention(q, k, v, causal=causal, window=window, impl="pallas",
                        bq=bq, bk=bk, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16(rng):
    q = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), jnp.bfloat16)
    a = flash_attention(q, k, v, impl="xla")
    b = flash_attention(q, k, v, impl="pallas", bq=32, bk=32, interpret=True)
    np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                               rtol=3e-2, atol=3e-2)


@pytest.mark.parametrize("B,L,H,P,N,chunk",
                         [(2, 64, 3, 8, 16, 16), (1, 100, 2, 16, 8, 32),
                          (2, 32, 1, 4, 4, 8)])
def test_ssd_kernel(rng, B, L, H, P, N, chunk):
    x = jnp.asarray(rng.normal(size=(B, L, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (B, L, H)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, (H,)), jnp.float32)
    B_ = jnp.asarray(rng.normal(size=(B, L, N)), jnp.float32)
    C_ = jnp.asarray(rng.normal(size=(B, L, N)), jnp.float32)
    y0, h0 = ssd_naive(x, dt, A, B_, C_)
    y1, h1 = ssd(x, dt, A, B_, C_, chunk=chunk, impl="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(h0), np.asarray(h1), rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("B,L,W,chunk",
                         [(2, 64, 32, 16), (1, 100, 8, 32), (3, 33, 16, 8)])
def test_rglru_kernel(rng, B, L, W, chunk):
    la = jnp.asarray(-rng.uniform(0.01, 2.0, (B, L, W)), jnp.float32)
    gx = jnp.asarray(rng.normal(size=(B, L, W)), jnp.float32)
    a0, t0 = rglru_naive(la, gx)
    a1, t1 = rglru(la, gx, chunk=chunk, impl="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(a0), np.asarray(a1), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(t0), np.asarray(t1), rtol=1e-5, atol=1e-5)
