"""Fault tolerance: checkpoint/restore, crash-restart replay, stragglers."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.configs import smoke_config
from repro.data import DataConfig, SyntheticLM
from repro.models import init_params
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.train import (StragglerMonitor, Trainer, TrainerConfig,
                         TrainOptions, make_train_step)


def test_checkpoint_roundtrip(tmp_path):
    ckpt = Checkpointer(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.int32(7), "d": jnp.ones((5,), jnp.bfloat16)}}
    ckpt.save(3, tree, blocking=True)
    restored = ckpt.restore(3, jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_and_retention(tmp_path):
    ckpt = Checkpointer(str(tmp_path), keep=2)
    tree = {"x": jnp.ones((4, 4))}
    for s in [1, 2, 3, 4]:
        ckpt.save(s, tree)          # async
    ckpt.wait()
    assert ckpt.all_steps() == [3, 4]          # retention
    assert ckpt.latest_step() == 4


def test_checkpoint_atomic_commit(tmp_path):
    """A .tmp directory never shadows a committed checkpoint."""
    ckpt = Checkpointer(str(tmp_path), keep=3)
    ckpt.save(1, {"x": jnp.ones(3)}, blocking=True)
    os.makedirs(os.path.join(str(tmp_path), "step_00000002.tmp"))
    assert ckpt.latest_step() == 1


def _mk_trainer(tmp_path, failure_hook=None, total=12):
    cfg = smoke_config("granite-8b")
    key = jax.random.PRNGKey(0)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3),
                                   TrainOptions(grad_dtype="f32")))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=2))

    def init_state():
        p = init_params(key, cfg)
        return {"params": p, "opt": init_opt_state(p)}

    tcfg = TrainerConfig(total_steps=total, checkpoint_every=4,
                         checkpoint_dir=str(tmp_path), log_every=100,
                         max_restarts=3)
    return Trainer(tcfg, step, data, init_state, failure_hook=failure_hook,
                   to_device=lambda b: {k: jnp.asarray(v) for k, v in b.items()},
                   log=lambda s: None)


def test_trainer_recovers_from_injected_failure(tmp_path):
    """Node failure at step 6 -> restore step-4 checkpoint -> identical
    final state to an uninterrupted run (deterministic batch replay)."""
    fired = {"done": False}

    def boom(step):
        if step == 6 and not fired["done"]:
            fired["done"] = True
            raise RuntimeError("injected device failure")

    t1 = _mk_trainer(tmp_path / "a", failure_hook=boom)
    p1, _ = t1.run()
    assert t1.restarts == 1

    t2 = _mk_trainer(tmp_path / "b")
    p2, _ = t2.run()
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=1e-5,
                                   atol=1e-5)


def test_trainer_gives_up_after_max_restarts(tmp_path):
    def always_boom(step):
        raise RuntimeError("permanent failure")

    t = _mk_trainer(tmp_path, failure_hook=always_boom)
    with pytest.raises(RuntimeError, match="permanent"):
        t.run()


def test_straggler_monitor_flags_outlier():
    mon = StragglerMonitor(n_hosts=4, window=5, zmax=2.0)
    for _ in range(5):
        for h in range(3):
            mon.record(h, 0.10 + 0.001 * h)
        mon.record(3, 0.50)                     # persistent straggler
    assert mon.check() == [3]


def test_straggler_monitor_single_host_spike():
    mon = StragglerMonitor(n_hosts=1, window=5, zmax=3.0)
    for _ in range(5):
        mon.record(0, 0.1)
    mon.record(0, 10.0)
    assert mon.check() == [0]


def test_elastic_restore_into_different_sharding(tmp_path):
    """Checkpoint written under one 'mesh' restores under another (the
    single-device container: restore with explicit NamedSharding onto the
    1-device mesh exercising make_array_from_callback resharding)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    ckpt = Checkpointer(str(tmp_path))
    x = jnp.arange(64.0).reshape(8, 8)
    ckpt.save(1, {"x": x}, blocking=True)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    sh = {"x": NamedSharding(mesh, P("data", None))}
    restored = ckpt.restore(1, {"x": jax.ShapeDtypeStruct((8, 8), jnp.float32)},
                            shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["x"]), np.asarray(x))
    assert restored["x"].sharding == sh["x"]
