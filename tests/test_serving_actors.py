"""Continuous-batching serving actors: identity vs the legacy Engine,
rate-0 idle firings, re-admission, declared-bound verdicts, early stop."""
import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core import ExecutionPlan
from repro.graphs.serving import (ServingWorkload, build_serving_network,
                                  left_pad_prompts, poisson_trace)
from repro.models import init_params
from repro.serve import ActorEngine, Engine, Request, ServeConfig


@pytest.fixture(scope="module")
def lm():
    cfg = smoke_config("granite-8b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def requests(lm):
    cfg, _ = lm
    rng = np.random.default_rng(1)
    return [Request(prompt=rng.integers(1, cfg.vocab,
                                        size=int(n)).astype(np.int32),
                    max_new=m)
            for n, m in [(5, 4), (3, 2), (7, 4), (4, 3), (6, 4)]]


@pytest.fixture(scope="module")
def scfg():
    # eos_id inside the argmax range so some slots retire via EOS and
    # others via budget — both rate-0 paths exercised.
    return ServeConfig(batch_size=2, max_prompt=8, max_new=4, eos_id=7)


@pytest.fixture(scope="module")
def legacy_tokens(lm, requests, scfg):
    cfg, params = lm
    return [r.tokens for r in Engine(cfg, params, scfg).generate(requests)]


# --------------------------------------------------------------------------- #
# Token-for-token identity oracle (ISSUE acceptance: both plans, guards
# on and off).
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("mode,guards", [
    ("dynamic", False), ("dynamic", True),
    ("megakernel", False), ("megakernel", True),
])
def test_actor_engine_matches_legacy(lm, requests, scfg, legacy_tokens,
                                     mode, guards):
    cfg, params = lm
    eng = ActorEngine(cfg, params, scfg,
                      plan=ExecutionPlan(mode=mode, guards=guards))
    got = eng.generate(requests)
    for want, have in zip(legacy_tokens, got):
        np.testing.assert_array_equal(want, have.tokens)
    # Every actor fires once per admission sweep — the idle/EOS firings
    # are real (control token consumed) rate-0 firings, not skips.
    counts = eng.last_fire_counts
    assert counts["decode"] == counts["admission"] == counts["merge"]


def test_admission_timing_does_not_change_tokens(lm, requests, scfg,
                                                 legacy_tokens):
    """Open-loop arrivals delay admission but never change a request's
    greedy tokens (dense rows are batch-independent)."""
    cfg, params = lm
    eng = ActorEngine(cfg, params, scfg)
    got = eng.generate(requests, arrivals=np.array([0, 1, 2, 5, 9],
                                                   np.int32))
    for want, have in zip(legacy_tokens, got):
        np.testing.assert_array_equal(want, have.tokens)
    lat = eng.last_latency_steps
    assert lat is not None and (lat >= 0).all()


# --------------------------------------------------------------------------- #
# Rate-0 firings and re-admission.
# --------------------------------------------------------------------------- #
def test_idle_steps_are_rate0_firings_in_fire_counts(lm, scfg):
    """An arrival gap leaves steps with no active slot: decode still
    fires (consuming its control token, body skipped), so its fire count
    exceeds the number of tokens it produced."""
    cfg, params = lm
    reqs = [Request(prompt=np.array([3, 4, 5], np.int32), max_new=2),
            Request(prompt=np.array([6, 8, 9], np.int32), max_new=2)]
    eng = ActorEngine(cfg, params, scfg)
    got = eng.generate(reqs, arrivals=np.array([0, 6], np.int32))
    total_tokens = sum(len(r.tokens) for r in got)
    assert eng.last_fire_counts["decode"] > total_tokens
    # The retire sink fired every sweep too — most of them rate-0.
    assert eng.last_fire_counts["retire"] == eng.last_fire_counts["decode"]


def test_no_request_starves_under_bursty_arrivals(lm, scfg):
    """R >> B with a bursty Poisson trace: every freed slot is re-admitted
    and every request eventually retires with its full budget."""
    cfg, params = lm
    rng = np.random.default_rng(3)
    R = 7                                   # vs batch_size=2
    reqs = [Request(prompt=rng.integers(1, cfg.vocab, size=4)
                    .astype(np.int32), max_new=3) for _ in range(R)]
    arrivals = poisson_trace(R, rate=1.5, seed=11)
    eng = ActorEngine(cfg, params, scfg)
    got = eng.generate(reqs, arrivals=arrivals)
    assert len(got) == R
    for r, res in zip(reqs, got):
        assert 1 <= len(res.tokens) <= r.max_new
    assert (eng.last_latency_steps >= 1).all()


# --------------------------------------------------------------------------- #
# Declared bounds: build(check_bounds=True) verdicts pinned.
# --------------------------------------------------------------------------- #
def test_serving_bounds_all_balanced(lm, requests, scfg):
    cfg, params = lm
    slab, lens = left_pad_prompts([r.prompt for r in requests],
                                  scfg.max_prompt)
    wl = ServingWorkload(
        prompts=slab, prompt_lens=lens,
        budgets=np.array([r.max_new for r in requests], np.int32),
        arrivals=np.zeros(len(requests), np.int32))
    _, report = build_serving_network(
        cfg, params, wl, batch_size=scfg.batch_size,
        max_prompt=scfg.max_prompt, max_new=scfg.max_new,
        eos_id=scfg.eos_id, check_bounds=True, return_bounds=True)
    verdicts = {c.fifo: c.verdict for c in report.channels}
    assert verdicts == {
        "fb": "balanced", "table": "balanced", "x": "balanced",
        "fin": "balanced", "xa": "balanced", "y": "balanced",
        "fina": "balanced", "ctl_gate": "balanced",
        "ctl_decode": "balanced", "ctl_merge": "balanced",
        "ctl_retire": "balanced",
    }


# --------------------------------------------------------------------------- #
# Legacy-engine early stop (satellite): fewer decode steps, same tokens.
# --------------------------------------------------------------------------- #
def test_engine_early_stop_same_tokens_fewer_steps(lm):
    cfg, params = lm
    rng = np.random.default_rng(5)
    reqs = [Request(prompt=rng.integers(1, cfg.vocab, size=5)
                    .astype(np.int32), max_new=2) for _ in range(2)]
    base = dict(batch_size=2, max_prompt=8, max_new=8, eos_id=None)
    slow = Engine(cfg, params, ServeConfig(early_stop=False, **base))
    want = slow.generate(reqs)
    assert slow.last_decode_steps == 8 - 1      # the historical fixed loop
    fast = Engine(cfg, params, ServeConfig(early_stop=True, **base))
    got = fast.generate(reqs)
    # Budgets (max_new=2) exhaust after one decode step: 1 vs 7 steps.
    assert fast.last_decode_steps == 1
    for a, b in zip(want, got):
        np.testing.assert_array_equal(a.tokens, b.tokens)
        assert a.prompt_len == b.prompt_len
