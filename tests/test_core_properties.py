"""Deeper MoC property tests: random networks, token conservation."""
import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container image ships no hypothesis
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import (Edge, ExecutionPlan, FifoSpec, Network, collect_sink,
                        dynamic_actor, map_fire, static_actor)


def build_random_chain(depth: int, rate: int, gate_mask: int, n: int = 6):
    """Source -> depth x (alternating static scale / dynamic gate) -> sink.

    gate_mask bit i enables the dynamic actor on firing i (all gates share
    one control stream), so the expected output is computable in numpy.
    """
    tok = (2,)
    actors, fifos, edges = [], [], []

    def src_fire(state, inputs, rates):
        data, idx = state
        return (data, idx + 1), {
            "out": jax.lax.dynamic_slice_in_dim(data, idx * rate, rate, 0)}

    data0 = np.arange(n * rate * 2, dtype=np.float32).reshape(n * rate, 2)
    n_enabled = bin(gate_mask & ((1 << n) - 1)).count("1")
    actors.append(static_actor(
        "src", (), ("out",), src_fire,
        init=lambda: (jnp.asarray(data0), jnp.int32(0)),
        ready=lambda st: st[1] < (n_enabled if depth_has_gate else n)))

    def ctl_fire(state, inputs, rates):
        idx = state
        bit = (gate_mask >> jnp.clip(idx, 0, n - 1)) & 1
        return idx + 1, {p: jnp.asarray(bit, jnp.int32).reshape(1)
                         for p in ctl_ports}

    depth_has_gate = any(d % 2 == 1 for d in range(depth))
    ctl_ports = [f"c{d}" for d in range(depth) if d % 2 == 1]
    if ctl_ports:
        actors.append(static_actor("ctl", (), tuple(ctl_ports), ctl_fire,
                                   init=lambda: jnp.int32(0),
                                   ready=lambda st: st < n))

    prev_port = ("src", "out")
    for d in range(depth):
        nm = f"a{d}"
        fname = f"f{d}"
        fifos.append(FifoSpec(fname, rate, tok))
        if d % 2 == 0:
            actors.append(static_actor(
                nm, ("in",), ("out",),
                map_fire(lambda w, _d=d: w * (1.0 + _d), "in", "out")))
        else:
            actors.append(dynamic_actor(
                nm, "c", lambda t: {"in": t[0] > 0, "out": t[0] > 0},
                ("in",), ("out",),
                map_fire(lambda w, _d=d: w + 10.0 * (_d + 1), "in", "out")))
            cf = f"fc{d}"
            fifos.append(FifoSpec(cf, 1, (1,), jnp.int32, is_control=True))
            edges.append(Edge(cf, "ctl", f"c{d}", nm, "c"))
        edges.append(Edge(fname, prev_port[0], prev_port[1], nm, "in"))
        prev_port = (nm, "out")

    def sink_fire(state, inputs, rates):
        data, idx = state
        return (jax.lax.dynamic_update_slice_in_dim(
            data, inputs["in"], idx * rate, 0), idx + 1), {}

    actors.append(static_actor(
        "snk", ("in",), (), sink_fire,
        init=lambda: (jnp.zeros((n * rate, 2), jnp.float32), jnp.int32(0)),
        finish=lambda st: st[0]))
    fifos.append(FifoSpec("fout", rate, tok))
    edges.append(Edge("fout", prev_port[0], prev_port[1], "snk", "in"))
    return Network(actors, fifos, edges), data0, n_enabled, depth_has_gate


def numpy_oracle(data0, depth, rate, gate_mask, n_windows):
    """Push enabled windows through the chain in numpy."""
    outs = []
    widx = 0
    for i in range(n_windows):
        if not ((gate_mask >> i) & 1):
            continue
        w = data0[widx * rate:(widx + 1) * rate].copy()
        widx += 1
        for d in range(depth):
            if d % 2 == 0:
                w = w * (1.0 + d)
            else:
                w = w + 10.0 * (d + 1)
        outs.append(w)
    return np.concatenate(outs) if outs else np.zeros((0, 2), np.float32)


@settings(max_examples=8, deadline=None)
@given(depth=st.integers(2, 4), rate=st.integers(1, 3),
       gate_mask=st.integers(1, 63))
def test_random_dynamic_chain_matches_numpy_oracle(depth, rate, gate_mask):
    """Token-driven scheduler on randomized dynamic chains == numpy oracle.

    All gates share the control stream, so window i survives iff bit i is
    set; surviving windows pass through every stage's transform in order
    (FIFO order preservation + rate-0 cursor freezing, end to end)."""
    n = 6
    net, data0, n_enabled, has_gate = build_random_chain(depth, rate, gate_mask, n)
    result = net.compile(ExecutionPlan(mode="dynamic")).run()
    got = np.asarray(collect_sink(net, result.state, "snk"))
    if has_gate:
        expect = numpy_oracle(data0, depth, rate, gate_mask, n)
    else:
        expect = numpy_oracle(data0, depth, rate, (1 << n) - 1, n)
    np.testing.assert_allclose(got[:len(expect)], expect, rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(top_k=st.integers(1, 3), seed=st.integers(0, 100))
def test_moe_token_conservation(top_k, seed):
    """Every kept (token, k) assignment lands in exactly one slab slot and
    returns with its combine weight: sum of combine weights == 1 per token
    (drop-free capacity), and the layer is a linear combination of expert
    outputs (checked via output norm bound)."""
    from repro.models.moe import moe_init, moe_layer
    key = jax.random.PRNGKey(seed)
    params = moe_init(key, 16, 4, 32)
    x = jax.random.normal(key, (2, 8, 16), jnp.float32)
    y, aux = moe_layer(params, x, top_k=top_k, capacity_factor=8.0)
    assert float(aux["dropped_frac"]) == 0.0
    assert np.isfinite(np.asarray(y)).all()
    # rate-0 path: zero input rows produce zero output rows
    x0 = x.at[0, 0].set(0.0)
    y0, _ = moe_layer(params, x0, top_k=top_k, capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(y0[0, 0]), 0.0, atol=1e-6)
