"""§Perf hillclimb variants keep semantics: int8 KV, MoE local dispatch."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import decode_step, init_params, prefill
from repro.models.moe import moe_init, moe_layer


def test_int8_kv_cache_close_to_fp():
    cfg = smoke_config("qwen2-72b")
    cfgq = dataclasses.replace(cfg, kv_quant_int8=True)
    key = jax.random.PRNGKey(1)
    p = init_params(key, cfg)
    B, S = 2, 24
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab)
    batch = {"tokens": toks[:, :-1]}
    _, c0 = prefill(p, cfg, batch, max_cache_len=S + 8)
    lg0, _ = decode_step(p, cfg, toks[:, -1:], jnp.full((B,), S, jnp.int32), c0)
    _, cq = prefill(p, cfgq, batch, max_cache_len=S + 8)
    assert cq["groups"]["c0"]["k"].dtype == jnp.int8
    assert "k_scale" in cq["groups"]["c0"]
    lgq, _ = decode_step(p, cfgq, toks[:, -1:], jnp.full((B,), S, jnp.int32), cq)
    # int8 absmax-per-(slot,head): small logit perturbation only
    assert float(jnp.max(jnp.abs(lg0 - lgq))) < 0.15


def test_int8_cache_halves_bytes():
    from repro.models.attention import cache_spec
    a = cache_spec(4, 128, 2, 64)
    b = cache_spec(4, 128, 2, 64, quant=True)
    bytes_a = sum(np.prod(s.shape) * s.dtype.itemsize for s in jax.tree.leaves(a))
    bytes_b = sum(np.prod(s.shape) * s.dtype.itemsize for s in jax.tree.leaves(b))
    assert bytes_b < 0.75 * bytes_a


@pytest.mark.parametrize("groups", [2, 4])
def test_moe_local_dispatch_matches_global_when_dropfree(groups):
    key = jax.random.PRNGKey(0)
    params = moe_init(key, 32, 8, 64)
    x = jax.random.normal(key, (4, 16, 32), jnp.float32)
    y0, _ = moe_layer(params, x, top_k=2, capacity_factor=8.0)
    y1, aux = moe_layer(params, x, top_k=2, capacity_factor=8.0,
                        local_groups=groups)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=3e-2, atol=3e-2)
    assert float(aux["dropped_frac"]) == 0.0


def test_dryrun_variants_resolve():
    from repro.launch.dryrun import apply_variant
    from repro.configs import get_config
    cfg = apply_variant(get_config("granite-moe-3b-a800m"), "moe_local16+cf1")
    assert cfg.moe.local_groups == 16
    assert cfg.moe.capacity_factor == 1.0
    cfg2 = apply_variant(get_config("qwen2-72b"), "kv_int8")
    assert cfg2.kv_quant_int8
    with pytest.raises(ValueError):
        apply_variant(get_config("qwen2-72b"), "bogus")
