"""Model substrate: composable blocks + unified LM assembly."""
from repro.models.lm import (abstract_params, decode_step, forward,
                             init_params, layer_plan, prefill, serve_state,
                             train_loss)

__all__ = ["abstract_params", "decode_step", "forward", "init_params",
           "layer_plan", "prefill", "serve_state", "train_loss"]
