"""Mamba2 SSD (state-space duality) blocks — arXiv:2405.21060.

Three implementations, in ascending performance order:
  * ``ssd_naive``   — per-step recurrence via lax.scan (the oracle).
  * ``ssd_chunked`` — the SSD chunked algorithm in pure jnp (model default:
    MXU-shaped einsums within chunks, scan over chunk states).
  * ``repro.kernels.ssd`` — Pallas TPU kernel of the chunked algorithm.

State layout per head: h in R^{P x N} (P = head_dim, N = state_dim), with
scalar-per-head decay A (mamba2 restriction).  The decode state FIFO is the
paper's delay-token feedback channel (DESIGN.md §6).
"""
from __future__ import annotations

import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.layers import DTYPE, F32, dense_init, rmsnorm, rmsnorm_init, split


# ---------------------------------------------------------------------- #
# Core SSD math.  x: (B, L, H, P); dt: (B, L, H); B_, C_: (B, L, N).
# ---------------------------------------------------------------------- #
def ssd_naive(x, dt, A, B_, C_):
    """Oracle: h_t = exp(A dt_t) h_{t-1} + dt_t * (B_t ⊗ x_t); y_t = C_t h_t."""
    Bsz, L, H, P = x.shape
    N = B_.shape[-1]

    def step(h, inp):
        xt, dtt, bt, ct = inp  # (B,H,P), (B,H), (B,N), (B,N)
        decay = jnp.exp(dtt.astype(F32) * A.astype(F32))       # (B,H)
        upd = (dtt.astype(F32)[..., None, None]
               * xt.astype(F32)[..., :, None] * bt.astype(F32)[:, None, None, :])
        h = h * decay[..., None, None] + upd                    # (B,H,P,N)
        y = jnp.einsum("bhpn,bn->bhp", h, ct.astype(F32))
        return h, y

    h0 = jnp.zeros((Bsz, H, P, N), F32)
    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(B_, 1, 0), jnp.moveaxis(C_, 1, 0))
    hT, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), hT


def _segsum(a):
    """Causal segment sums: out[i, j] = sum_{j < u <= i} a[u] (−inf above)."""
    L = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    i = jnp.arange(L)[:, None]
    j = jnp.arange(L)[None, :]
    return jnp.where(j <= i, diff, -jnp.inf)


def ssd_chunked(x, dt, A, B_, C_, chunk: int, h0: Optional[jax.Array] = None):
    """Chunked SSD: intra-chunk attention-form + inter-chunk state scan.

    L is padded up to a chunk multiple with dt=0 tokens (decay exp(0)=1,
    zero update — state and outputs are unaffected)."""
    Bsz, L, H, P = x.shape
    N = B_.shape[-1]
    L_orig = L
    if L % chunk:
        pad = chunk - L % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0)))
        L = L + pad
    nc = L // chunk
    xc = x.reshape(Bsz, nc, chunk, H, P).astype(F32)
    dtc = dt.reshape(Bsz, nc, chunk, H).astype(F32)
    Bc = B_.reshape(Bsz, nc, chunk, N).astype(F32)
    Cc = C_.reshape(Bsz, nc, chunk, N).astype(F32)

    dA = dtc * A.astype(F32)                                   # (B,nc,c,H)
    dA = jnp.moveaxis(dA, -1, 2)                               # (B,nc,H,c)
    seg = _segsum(dA)                                          # (B,nc,H,c,c)
    Lmat = jnp.exp(seg)

    # Intra-chunk (attention-like): Y1[t] = sum_s<=t C_t.B_s L[t,s] dt_s x_s
    G = jnp.einsum("bztn,bzsn->bzts", Cc, Bc)                  # (B,nc,c,c)
    M = G[:, :, None] * Lmat                                   # (B,nc,H,t,s)
    Y1 = jnp.einsum("bzhts,bzsh,bzshp->bzthp", M, dtc, xc)

    # Chunk-final states: S_z = sum_s exp(sum_{s<u} dA) B_s dt_s x_s
    dA_cum = jnp.cumsum(dA, axis=-1)                           # (B,nc,H,c)
    total = dA_cum[..., -1:]                                   # (B,nc,H,1)
    decay_out = jnp.exp(total - dA_cum)                        # (B,nc,H,c)
    S = jnp.einsum("bzhs,bzsh,bzshp,bzsn->bzhpn", decay_out, dtc, xc, Bc)

    # Inter-chunk scan over states.
    chunk_decay = jnp.exp(total[..., 0])                       # (B,nc,H)

    def scan_fn(h, inp):
        s_z, dec_z = inp                                       # (B,H,P,N), (B,H)
        h_new = h * dec_z[..., None, None] + s_z
        return h_new, h                                        # emit state *entering* chunk

    init = h0.astype(F32) if h0 is not None else jnp.zeros((Bsz, H, P, N), F32)
    hT, h_in = jax.lax.scan(scan_fn, init,
                            (jnp.moveaxis(S, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_in = jnp.moveaxis(h_in, 0, 1)                            # (B,nc,H,P,N)

    # Inter-chunk contribution: Y2[t] = C_t exp(dA_cum_t) h_in
    decay_in = jnp.exp(dA_cum)                                 # (B,nc,H,c)
    Y2 = jnp.einsum("bztn,bzht,bzhpn->bzthp", Cc, decay_in, h_in)

    y = (Y1 + Y2).reshape(Bsz, L, H, P)[:, :L_orig]
    return y.astype(x.dtype), hT


# ---------------------------------------------------------------------- #
# Full Mamba2 block (in_proj -> conv -> SSD -> gated norm -> out_proj).
# ---------------------------------------------------------------------- #
def mamba2_init(rng, d_model: int, s: SSMConfig) -> Dict[str, jax.Array]:
    di = s.d_inner(d_model)
    nh = s.n_heads(d_model)
    conv_dim = di + 2 * s.state_dim
    r = split(rng, 4)
    return {
        "in_proj": dense_init(r[0], d_model, 2 * di + 2 * s.state_dim + nh),
        "conv_w": (jax.random.normal(r[1], (s.conv_width, conv_dim), F32)
                   * (1.0 / math.sqrt(s.conv_width))).astype(DTYPE),
        "conv_b": jnp.zeros((conv_dim,), DTYPE),
        "dt_bias": jnp.zeros((nh,), F32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=F32)),
        "D": jnp.ones((nh,), F32),
        "gate_norm": rmsnorm_init(di),
        "out_proj": dense_init(r[2], di, d_model),
    }


def _causal_conv(x, w, b, state=None):
    """x: (B, L, C); w: (K, C) depthwise. state: (B, K-1, C) history or None.
    Returns (y (B,L,C), new_state (B, K-1, C))."""
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = jnp.zeros_like(x, dtype=F32)
    L = x.shape[1]
    for t in range(K):
        y = y + w[t].astype(F32) * xp[:, t:t + L].astype(F32)
    new_state = xp[:, -(K - 1):] if K > 1 else state
    return (jax.nn.silu(y + b.astype(F32))).astype(x.dtype), new_state


def _split_proj(z, di, nstate, nh):
    zx = z[..., :di]
    gate = z[..., di:2 * di]
    B_ = z[..., 2 * di:2 * di + nstate]
    C_ = z[..., 2 * di + nstate:2 * di + 2 * nstate]
    dt = z[..., 2 * di + 2 * nstate:]
    return zx, gate, B_, C_, dt


def mamba2_block(params, x, s: SSMConfig, *, mode: str = "train",
                 state=None, kernel_impl: str = "xla"):
    """x: (B, L, D). mode train/prefill: full seq (L % chunk == 0);
    mode decode: L == 1 with state = {'conv': ..., 'ssm': ...}.

    Returns (y, new_state) — new_state is None for train."""
    B, L, D = x.shape
    di = s.d_inner(D)
    nh = s.n_heads(D)
    N = s.state_dim

    z = x @ params["in_proj"]
    zx, gate, B_, C_, dtr = _split_proj(z, di, N, nh)
    conv_in = jnp.concatenate([zx, B_, C_], axis=-1)

    if mode == "decode":
        conv_out, conv_state = _causal_conv(conv_in, params["conv_w"],
                                            params["conv_b"], state["conv"])
    else:
        conv_out, conv_state = _causal_conv(conv_in, params["conv_w"],
                                            params["conv_b"])
    zx = conv_out[..., :di]
    B_ = conv_out[..., di:di + N]
    C_ = conv_out[..., di + N:]

    dt = jax.nn.softplus(dtr.astype(F32) + params["dt_bias"])   # (B, L, nh)
    A = -jnp.exp(params["A_log"])                               # (nh,)
    xh = zx.reshape(B, L, nh, s.head_dim)

    if mode == "decode":
        # Single recurrence step with carried state (L == 1).
        decay = jnp.exp(dt[:, 0].astype(F32) * A)               # (B, nh)
        upd = (dt[:, 0].astype(F32)[..., None, None]
               * xh[:, 0].astype(F32)[..., :, None]
               * B_[:, 0].astype(F32)[:, None, None, :])
        h_new = state["ssm"] * decay[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", h_new, C_[:, 0].astype(F32))[:, None]
        y = y.reshape(B, 1, nh, s.head_dim).astype(x.dtype)
        new_state = {"conv": conv_state, "ssm": h_new}
    elif kernel_impl == "pallas" and mode != "decode":
        from repro.kernels.ssd import ssd as ssd_kernel
        y, hT = ssd_kernel(xh, dt, A, B_, C_, chunk=s.chunk)
        new_state = {"conv": conv_state, "ssm": hT} if mode == "prefill" else None
    else:
        y, hT = ssd_chunked(xh, dt, A, B_, C_, chunk=s.chunk)
        new_state = {"conv": conv_state, "ssm": hT} if mode == "prefill" else None

    y = y + params["D"].astype(F32)[None, None, :, None] * xh.astype(F32)
    y = y.reshape(B, L, di).astype(x.dtype)
    y = rmsnorm(params["gate_norm"], y * jax.nn.silu(gate.astype(F32)).astype(x.dtype))
    return y @ params["out_proj"], new_state


def mamba2_state_init(batch: int, d_model: int, s: SSMConfig):
    di = s.d_inner(d_model)
    nh = s.n_heads(d_model)
    conv_dim = di + 2 * s.state_dim
    return {
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_dim), DTYPE),
        "ssm": jnp.zeros((batch, nh, s.head_dim, s.state_dim), F32),
    }


def mamba2_state_spec(batch: int, d_model: int, s: SSMConfig):
    di = s.d_inner(d_model)
    nh = s.n_heads(d_model)
    conv_dim = di + 2 * s.state_dim
    return {
        "conv": jax.ShapeDtypeStruct((batch, s.conv_width - 1, conv_dim), DTYPE),
        "ssm": jax.ShapeDtypeStruct((batch, nh, s.head_dim, s.state_dim), F32),
    }
