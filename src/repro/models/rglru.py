"""RecurrentGemma RG-LRU recurrent block — arXiv:2402.19427.

The Real-Gated Linear Recurrent Unit:

    r_t = sigmoid(W_a x_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)          (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t)           (c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t^2) ⊙ (i_t ⊙ x_t)

An elementwise (diagonal) linear recurrence — the paper's delay-token IIR
feedback loop, and a textbook associative-scan on TPU.  Three paths:
  * ``rglru_naive`` — lax.scan oracle;
  * ``rglru_scan``  — log-space associative scan (model default);
  * ``repro.kernels.rglru`` — Pallas chunked kernel.

The recurrent *block* wraps it recurrentgemma-style: two input linears
(recurrent branch + gate branch), a short causal conv on the recurrent
branch, the RG-LRU, and a gated output projection.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import RGLRUConfig
from repro.models.layers import DTYPE, F32, dense_init, split

_C = 8.0


def rglru_gates(params, x):
    """x: (B, L, W) -> (log_a, gated_x) both (B, L, W) f32."""
    r = jax.nn.sigmoid((x @ params["w_a"] + params["b_a"]).astype(F32))
    i = jax.nn.sigmoid((x @ params["w_x"] + params["b_x"]).astype(F32))
    log_a = -_C * jax.nn.softplus(params["lam"].astype(F32)) * r
    gx = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i * x.astype(F32))
    return log_a, gx


def rglru_naive(log_a, gx, h0=None):
    """Oracle recurrence. log_a, gx: (B, L, W) f32."""
    B, L, W = gx.shape
    h0 = h0 if h0 is not None else jnp.zeros((B, W), F32)

    def step(h, inp):
        la, g = inp
        h = jnp.exp(la) * h + g
        return h, h

    hT, hs = jax.lax.scan(step, h0, (jnp.moveaxis(log_a, 1, 0),
                                     jnp.moveaxis(gx, 1, 0)))
    return jnp.moveaxis(hs, 0, 1), hT


def rglru_scan(log_a, gx, h0=None):
    """Associative scan: compose (a, b) pairs of h -> a*h + b."""
    B, L, W = gx.shape
    if h0 is not None:
        # Fold the carried state into the first step's offset.
        gx = gx.at[:, 0].add(jnp.exp(log_a[:, 0]) * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 + a2, jnp.exp(a2) * b1 + b2

    la, b = jax.lax.associative_scan(combine, (log_a, gx), axis=1)
    return b, b[:, -1]


# ---------------------------------------------------------------------- #
# Full recurrent block.
# ---------------------------------------------------------------------- #
def rglru_block_init(rng, d_model: int, cfg: RGLRUConfig) -> Dict[str, jax.Array]:
    w = cfg.lru_width or d_model
    r = split(rng, 5)
    return {
        "in_x": dense_init(r[0], d_model, w),
        "in_gate": dense_init(r[1], d_model, w),
        "conv_w": (jax.random.normal(r[2], (cfg.conv_width, w), F32)
                   * (1.0 / math.sqrt(cfg.conv_width))).astype(DTYPE),
        "conv_b": jnp.zeros((w,), DTYPE),
        "w_a": dense_init(r[3], w, w),
        "b_a": jnp.zeros((w,), DTYPE),
        "w_x": dense_init(r[4], w, w),
        "b_x": jnp.zeros((w,), DTYPE),
        "lam": jnp.linspace(0.5, 4.0, w, dtype=F32),  # Lambda init
        "out": dense_init(jax.random.fold_in(rng, 9), w, d_model),
    }


def _conv(x, w, b, state=None):
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    L = x.shape[1]
    y = jnp.zeros_like(x, dtype=F32)
    for t in range(K):
        y = y + w[t].astype(F32) * xp[:, t:t + L].astype(F32)
    return (y + b.astype(F32)).astype(x.dtype), xp[:, -(K - 1):]


def rglru_block(params, x, cfg: RGLRUConfig, *, mode: str = "train",
                state=None, kernel_impl: str = "xla"):
    """x: (B, L, D). decode: L == 1 with state {'conv', 'h'}."""
    gate = jax.nn.gelu((x @ params["in_gate"]).astype(F32)).astype(x.dtype)
    u = x @ params["in_x"]
    conv_state = state["conv"] if mode == "decode" else None
    u, new_conv = _conv(u, params["conv_w"], params["conv_b"], conv_state)
    log_a, gx = rglru_gates(params, u)

    if mode == "decode":
        h = jnp.exp(log_a[:, 0]) * state["h"] + gx[:, 0]
        hs = h[:, None]
        new_state = {"conv": new_conv, "h": h}
    elif kernel_impl == "pallas":
        from repro.kernels.rglru import rglru as rglru_kernel
        hs, hT = rglru_kernel(log_a, gx)
        new_state = {"conv": new_conv, "h": hT} if mode == "prefill" else None
    else:
        hs, hT = rglru_scan(log_a, gx)
        new_state = {"conv": new_conv, "h": hT} if mode == "prefill" else None

    y = hs.astype(x.dtype) * gate
    return y @ params["out"], new_state


def rglru_state_init(batch: int, d_model: int, cfg: RGLRUConfig):
    w = cfg.lru_width or d_model
    return {"conv": jnp.zeros((batch, cfg.conv_width - 1, w), DTYPE),
            "h": jnp.zeros((batch, w), F32)}


def rglru_state_spec(batch: int, d_model: int, cfg: RGLRUConfig):
    w = cfg.lru_width or d_model
    return {"conv": jax.ShapeDtypeStruct((batch, cfg.conv_width - 1, w), DTYPE),
            "h": jax.ShapeDtypeStruct((batch, w), F32)}
