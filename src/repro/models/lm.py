"""Unified LM assembly for all assigned architectures.

A model is a stack of *blocks* arranged in a cyclic layer pattern (the
CSDF rate table of DESIGN.md §3): uniform models have cycle length 1,
gemma3 has (local x5, global), recurrentgemma has (rec, rec, local).
Full cycles are scanned (``lax.scan`` over stacked group params — keeps
the HLO small enough that the 512-device dry-run of an 80-layer model
lowers in seconds); remainder layers are unrolled.

Three entry points per model, matching the assigned shapes:
  * ``train_loss``    — full-seq causal LM loss (train_4k),
  * ``prefill``       — full-seq forward building serve state (prefill_32k),
  * ``decode_step``   — one token against ring caches (decode_32k/long_500k).

Block kinds: ``attn_local`` / ``attn_global`` (dense or MoE MLP),
``rec`` (RG-LRU), ``ssd`` (mamba2), ``xdec`` (whisper decoder w/ cross
attention), ``enc`` (whisper encoder, bidirectional).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as att
from repro.models import moe as moe_mod
from repro.models import rglru as rg_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (BATCH_AXES, DTYPE, cross_entropy,
                                 embed_init, embed_lookup, gelu_mlp,
                                 gelu_mlp_init, maybe_constrain, rmsnorm,
                                 rmsnorm_init, split, swiglu, swiglu_init,
                                 unembed)

PyTree = Any


# ---------------------------------------------------------------------- #
# Layer plan.
# ---------------------------------------------------------------------- #
def layer_plan(cfg: ArchConfig) -> Tuple[List[str], int, List[str]]:
    """(cycle kinds, n_groups, remainder kinds)."""
    if cfg.family == "ssm":
        cycle = ["ssd"]
    elif cfg.rglru is not None:
        cycle = ["rec" if p == 0 else "attn_local" for p in cfg.rglru.pattern]
    elif cfg.family == "audio":
        cycle = ["xdec"]
    else:
        cycle = ["attn_global" if p == 1 else "attn_local"
                 for p in cfg.attn_pattern]
    n_groups, rest = divmod(cfg.n_layers, len(cycle))
    return cycle, n_groups, cycle[:rest]


def _is_attn(kind: str) -> bool:
    return kind.startswith("attn") or kind == "xdec"


# ---------------------------------------------------------------------- #
# Block init.
# ---------------------------------------------------------------------- #
def _block_init(rng, cfg: ArchConfig, kind: str) -> Dict[str, PyTree]:
    d = cfg.d_model
    r = split(rng, 4)
    p: Dict[str, PyTree] = {}
    if kind == "ssd":
        p["norm"] = rmsnorm_init(d)
        p["mixer"] = ssm_mod.mamba2_init(r[0], d, cfg.ssm)
        return p
    p["norm1"] = rmsnorm_init(d)
    if kind == "rec":
        p["mixer"] = rg_mod.rglru_block_init(r[0], d, cfg.rglru)
    else:
        p["attn"] = att.attn_init(r[0], d, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                                  cfg.qkv_bias)
    if kind == "xdec":
        p["normx"] = rmsnorm_init(d)
        p["xattn"] = att.xattn_init(r[1], d, cfg.n_heads, cfg.hd)
    p["norm2"] = rmsnorm_init(d)
    if cfg.moe is not None and kind != "xdec":
        p["mlp"] = moe_mod.moe_init(r[2], d, cfg.moe.n_experts,
                                    cfg.moe.d_ff_expert)
    elif kind == "xdec":
        p["mlp"] = gelu_mlp_init(r[2], d, cfg.d_ff)
    else:
        p["mlp"] = swiglu_init(r[2], d, cfg.d_ff)
    return p


def _enc_block_init(rng, cfg: ArchConfig) -> Dict[str, PyTree]:
    e = cfg.encoder
    r = split(rng, 2)
    return {
        "norm1": rmsnorm_init(e.d_model),
        "attn": att.attn_init(r[0], e.d_model, e.n_heads, e.n_heads,
                              e.d_model // e.n_heads),
        "norm2": rmsnorm_init(e.d_model),
        "mlp": gelu_mlp_init(r[1], e.d_model, e.d_ff),
    }


# ---------------------------------------------------------------------- #
# Block apply.
# ---------------------------------------------------------------------- #
def _attn_kw(cfg: ArchConfig, kind: str):
    window = None
    if kind == "attn_local":
        window = cfg.swa_window
    return dict(n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.hd, rope_theta=cfg.rope_theta, window=window)


def _block_apply(cfg: ArchConfig, kind: str, params, x, *, mode: str,
                 cache=None, pos=None, enc_kv=None, kernel_impl="xla",
                 max_cache_len=None):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.float32(0.0)
    if kind == "ssd":
        h = rmsnorm(params["norm"], x, cfg.rms_eps)
        y, new_cache = ssm_mod.mamba2_block(params["mixer"], h, cfg.ssm,
                                            mode=mode, state=cache,
                                            kernel_impl=kernel_impl)
        return x + y, new_cache, aux

    kw = _attn_kw(cfg, kind)
    h = rmsnorm(params["norm1"], x, cfg.rms_eps)
    if kind == "rec":
        y, new_cache = rg_mod.rglru_block(params["mixer"], h, cfg.rglru,
                                          mode=mode, state=cache,
                                          kernel_impl=kernel_impl)
    elif mode == "decode":
        y, new_cache = att.attention_decode(
            params["attn"], h, cache["kv"] if kind == "xdec" else cache,
            pos, **{k: v for k, v in kw.items()})
        if kind == "xdec":
            new_cache = {"kv": new_cache, "cross": cache["cross"]}
    else:
        y = att.attention(params["attn"], h, causal=(cfg.family != "vlm_enc"),
                          kernel_impl=kernel_impl, **kw)
        new_cache = None
        if mode == "prefill":
            cache_len = _cache_len(cfg, kind, max_cache_len or x.shape[1])
            new_cache = att.cache_prefill(
                params["attn"], h, n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
                rope_theta=cfg.rope_theta, cache_len=cache_len,
                quant=cfg.kv_quant_int8)
    x = x + y

    if kind == "xdec":
        hx = rmsnorm(params["normx"], x, cfg.rms_eps)
        if mode == "decode":
            xkv = cache["cross"]
        else:
            xkv = att.cross_kv(params["xattn"], enc_kv, n_heads=cfg.n_heads,
                               head_dim=cfg.hd)
            if mode == "prefill":
                new_cache = {"kv": new_cache, "cross": xkv}
        x = x + att.cross_attention(params["xattn"], hx, xkv,
                                    n_heads=cfg.n_heads, head_dim=cfg.hd)

    h = rmsnorm(params["norm2"], x, cfg.rms_eps)
    if cfg.moe is not None and kind != "xdec":
        y, moe_aux = moe_mod.moe_layer(params["mlp"], h, top_k=cfg.moe.top_k,
                                       capacity_factor=cfg.moe.capacity_factor,
                                       local_groups=cfg.moe.local_groups)
        aux = aux + moe_aux["load_balance_loss"]
    elif kind == "xdec":
        y = gelu_mlp(params["mlp"], h)
    else:
        y = swiglu(params["mlp"], h)
    return x + y, new_cache, aux


def _cache_len(cfg: ArchConfig, kind: str, max_seq: int) -> int:
    if kind == "attn_local" and cfg.swa_window is not None:
        return min(cfg.swa_window, max_seq)
    return max_seq


# ---------------------------------------------------------------------- #
# Model: init.
# ---------------------------------------------------------------------- #
def init_params(rng, cfg: ArchConfig) -> PyTree:
    cycle, n_groups, rest = layer_plan(cfg)
    r = split(rng, 6)
    params: Dict[str, PyTree] = {
        # vocab_padded: clean model-axis sharding (see ArchConfig docstring)
        "embed": {"w": embed_init(r[0], cfg.vocab_padded, cfg.d_model)},
        "final_norm": rmsnorm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {"w": embed_init(r[1], cfg.vocab_padded, cfg.d_model)}

    def init_group(rng_g):
        rr = split(rng_g, len(cycle))
        return {f"c{i}": _block_init(rr[i], cfg, kind)
                for i, kind in enumerate(cycle)}

    params["groups"] = jax.vmap(init_group)(split(r[2], n_groups))
    params["rest"] = tuple(_block_init(rk, cfg, kind)
                           for rk, kind in zip(split(r[3], max(len(rest), 1)), rest))
    if cfg.family == "audio":
        e = cfg.encoder
        params["encoder"] = {
            "blocks": jax.vmap(lambda rr: _enc_block_init(rr, cfg))(
                split(r[4], e.n_layers)),
            "norm": rmsnorm_init(e.d_model),
        }
    return params


def abstract_params(cfg: ArchConfig) -> PyTree:
    """ShapeDtypeStruct pytree — dry-run init without allocation."""
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


# ---------------------------------------------------------------------- #
# Encoder (audio stub frontend -> transformer encoder).
# ---------------------------------------------------------------------- #
def encode(params, cfg: ArchConfig, frames: jax.Array,
           kernel_impl="xla") -> jax.Array:
    e = cfg.encoder
    x = frames.astype(DTYPE)

    def body(x, bp):
        h = rmsnorm(bp["norm1"], x, cfg.rms_eps)
        y = att.attention(bp["attn"], h, n_heads=e.n_heads, n_kv_heads=e.n_heads,
                          head_dim=e.d_model // e.n_heads,
                          rope_theta=cfg.rope_theta, causal=False,
                          kernel_impl=kernel_impl)
        x = x + y
        h = rmsnorm(bp["norm2"], x, cfg.rms_eps)
        return x + gelu_mlp(bp["mlp"], h), None

    x, _ = jax.lax.scan(lambda c, bp: body(c, bp), x, params["encoder"]["blocks"])
    return rmsnorm(params["encoder"]["norm"], x, cfg.rms_eps)


def _unembed_masked(x, head_w, cfg: ArchConfig):
    """Logits over the padded vocab with padding columns forced to -inf
    (so softmax/argmax/CE never see them)."""
    logits = unembed(x, head_w)
    if cfg.vocab_padded != cfg.vocab:
        col = jnp.arange(cfg.vocab_padded)
        logits = jnp.where(col >= cfg.vocab, jnp.float32(-1e30), logits)
    return logits


# ---------------------------------------------------------------------- #
# Full-sequence forward (train / prefill).
# ---------------------------------------------------------------------- #
def _embed_inputs(params, cfg: ArchConfig, batch: Dict[str, jax.Array]):
    x = embed_lookup(params["embed"]["w"], batch["tokens"]).astype(DTYPE)
    if cfg.family == "vlm" and "vision_embeds" in batch:
        x = jnp.concatenate([batch["vision_embeds"].astype(DTYPE), x], axis=1)
    if cfg.tie_embeddings:
        x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(DTYPE)  # gemma scaling
    return x


def forward(params, cfg: ArchConfig, batch: Dict[str, jax.Array], *,
            mode: str = "train", kernel_impl: str = "xla",
            remat: bool = True, max_cache_len: Optional[int] = None,
            unroll: bool = False):
    """Full-sequence forward. Returns (logits f32, caches|None, aux).

    ``unroll=True`` replaces the lax.scan over layer groups with a Python
    loop — used by the dry-run's depth-probe compiles, where XLA cost
    analysis must see every layer (it counts a while body only once)."""
    cycle, n_groups, rest = layer_plan(cfg)
    x = _embed_inputs(params, cfg, batch)
    act_spec = (BATCH_AXES, "model" if cfg.act_seq_shard else None, None)
    x = maybe_constrain(x, act_spec)
    enc_kv = None
    if cfg.family == "audio":
        enc_kv = encode(params, cfg, batch["frames"], kernel_impl)
    S = x.shape[1]

    def group_body(carry, gp):
        x, aux = carry
        caches = {}
        for i, kind in enumerate(cycle):
            x, c, a = _block_apply(cfg, kind, gp[f"c{i}"], x, mode=mode,
                                   enc_kv=enc_kv, kernel_impl=kernel_impl,
                                   max_cache_len=max_cache_len)
            x = maybe_constrain(x, act_spec)
            caches[f"c{i}"] = c
            aux = aux + a
        return (x, aux), caches

    body = group_body
    if remat and mode == "train":
        body = jax.checkpoint(group_body, prevent_cse=False)

    n_groups_actual = jax.tree.leaves(params["groups"])[0].shape[0] \
        if jax.tree.leaves(params["groups"]) else 0
    if unroll:
        carry = (x, jnp.float32(0.0))
        caches_list = []
        for gi in range(n_groups_actual):
            gp = jax.tree.map(lambda l: l[gi], params["groups"])
            carry, gc = body(carry, gp)
            caches_list.append(gc)
        (x, aux) = carry
        group_caches = jax.tree.map(lambda *ls: jnp.stack(ls), *caches_list) \
            if (caches_list and mode == "prefill") else None
    else:
        (x, aux), group_caches = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                              params["groups"])
    rest_caches = []
    for bp, kind in zip(params["rest"], rest):
        x, c, a = _block_apply(cfg, kind, bp, x, mode=mode, enc_kv=enc_kv,
                               kernel_impl=kernel_impl,
                               max_cache_len=max_cache_len)
        rest_caches.append(c)
        aux = aux + a

    x = rmsnorm(params["final_norm"], x, cfg.rms_eps)
    head_w = params["embed"]["w"] if cfg.tie_embeddings else params["lm_head"]["w"]
    if mode == "prefill":
        # Serving only needs the last position's logits.
        logits = _unembed_masked(x[:, -1:], head_w, cfg)
    else:
        logits = _unembed_masked(x, head_w, cfg)
    logits = maybe_constrain(logits, (BATCH_AXES, None, "model"))
    caches = None
    if mode == "prefill":
        caches = {"groups": group_caches, "rest": tuple(rest_caches)}
    return logits, caches, aux


def train_loss(params, cfg: ArchConfig, batch: Dict[str, jax.Array], *,
               kernel_impl: str = "xla", remat: bool = True,
               aux_weight: float = 0.01,
               unroll: bool = False) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, _, aux = forward(params, cfg, batch, mode="train",
                             kernel_impl=kernel_impl, remat=remat,
                             unroll=unroll)
    labels = batch["labels"]
    if cfg.family == "vlm":
        logits = logits[:, cfg.n_vision_tokens:]
    loss = cross_entropy(logits, labels)
    total = loss + aux_weight * aux
    return total, {"ce": loss, "aux": aux}


# ---------------------------------------------------------------------- #
# Serving: prefill + decode.
# ---------------------------------------------------------------------- #
def prefill(params, cfg: ArchConfig, batch, *, kernel_impl="xla",
            max_cache_len: Optional[int] = None, unroll: bool = False):
    """``max_cache_len``: ring size for full-attention layers — must cover
    prompt + planned decode budget (defaults to the prompt length, which
    leaves NO room to decode; serving always passes a budget)."""
    logits, caches, _ = forward(params, cfg, batch, mode="prefill",
                                kernel_impl=kernel_impl, remat=False,
                                max_cache_len=max_cache_len, unroll=unroll)
    return logits[:, 0], caches


def decode_step(params, cfg: ArchConfig, tokens, pos, caches, *,
                kernel_impl: str = "xla", unroll: bool = False):
    """tokens: (B, 1); pos: (B,). Returns (logits (B, V) f32, new caches)."""
    cycle, n_groups, rest = layer_plan(cfg)
    x = embed_lookup(params["embed"]["w"], tokens).astype(DTYPE)
    if cfg.tie_embeddings:
        x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(DTYPE)
    x = maybe_constrain(x, (BATCH_AXES, None, None))

    def group_body(carry, xs):
        x = carry
        gp, gc = xs
        new_c = {}
        for i, kind in enumerate(cycle):
            x, c, _ = _block_apply(cfg, kind, gp[f"c{i}"], x, mode="decode",
                                   cache=gc[f"c{i}"], pos=pos,
                                   kernel_impl=kernel_impl)
            x = maybe_constrain(x, (BATCH_AXES, None, None))
            new_c[f"c{i}"] = c
        return x, new_c

    if unroll:
        n_g = jax.tree.leaves(params["groups"])[0].shape[0] \
            if jax.tree.leaves(params["groups"]) else 0
        ncs = []
        for gi in range(n_g):
            gp = jax.tree.map(lambda l: l[gi], params["groups"])
            gc = jax.tree.map(lambda l: l[gi], caches["groups"])
            x, nc = group_body(x, (gp, gc))
            ncs.append(nc)
        new_group_caches = jax.tree.map(lambda *ls: jnp.stack(ls), *ncs) \
            if ncs else caches["groups"]
    else:
        x, new_group_caches = jax.lax.scan(group_body, x,
                                           (params["groups"], caches["groups"]))
    new_rest = []
    for bp, kind, c in zip(params["rest"], rest, caches["rest"]):
        x, nc, _ = _block_apply(cfg, kind, bp, x, mode="decode", cache=c,
                                pos=pos, kernel_impl=kernel_impl)
        new_rest.append(nc)

    x = rmsnorm(params["final_norm"], x, cfg.rms_eps)
    head_w = params["embed"]["w"] if cfg.tie_embeddings else params["lm_head"]["w"]
    logits = _unembed_masked(x[:, 0], head_w, cfg)
    return logits, {"groups": new_group_caches, "rest": tuple(new_rest)}


# ---------------------------------------------------------------------- #
# Serve-state construction (concrete + abstract).
# ---------------------------------------------------------------------- #
def _block_cache_spec(cfg: ArchConfig, kind: str, batch: int, max_seq: int,
                      abstract: bool):
    def mk_att(*a, **kw):
        fn = att.cache_spec if abstract else att.cache_init
        return fn(*a, quant=cfg.kv_quant_int8, **kw)
    if kind == "ssd":
        fn = ssm_mod.mamba2_state_spec if abstract else ssm_mod.mamba2_state_init
        return fn(batch, cfg.d_model, cfg.ssm)
    if kind == "rec":
        fn = rg_mod.rglru_state_spec if abstract else rg_mod.rglru_state_init
        return fn(batch, cfg.d_model, cfg.rglru)
    cl = _cache_len(cfg, kind, max_seq)
    c = mk_att(batch, cl, cfg.n_kv_heads, cfg.hd)
    if kind == "xdec":
        e = cfg.encoder
        if abstract:
            cross = {
                "k": jax.ShapeDtypeStruct((batch, e.n_ctx, cfg.n_heads, cfg.hd), DTYPE),
                "v": jax.ShapeDtypeStruct((batch, e.n_ctx, cfg.n_heads, cfg.hd), DTYPE),
            }
        else:
            cross = {
                "k": jnp.zeros((batch, e.n_ctx, cfg.n_heads, cfg.hd), DTYPE),
                "v": jnp.zeros((batch, e.n_ctx, cfg.n_heads, cfg.hd), DTYPE),
            }
        return {"kv": c, "cross": cross}
    return c


def serve_state(cfg: ArchConfig, batch: int, max_seq: int,
                abstract: bool = False) -> PyTree:
    """Ring caches / recurrent states for every layer (grouped like params)."""
    cycle, n_groups, rest = layer_plan(cfg)

    def one_group():
        return {f"c{i}": _block_cache_spec(cfg, kind, batch, max_seq, abstract)
                for i, kind in enumerate(cycle)}

    if abstract:
        def stack(spec):
            return jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((n_groups,) + s.shape, s.dtype),
                spec)
        groups = stack(one_group())
    else:
        groups = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_groups,) + x.shape).copy()
            if hasattr(x, "shape") else x,
            one_group())
    rest_caches = tuple(
        _block_cache_spec(cfg, kind, batch, max_seq, abstract) for kind in rest)
    return {"groups": groups, "rest": rest_caches}
