"""Mixture-of-Experts layer — the LM-side incarnation of the paper's
dynamic data rates.

Mapping (DESIGN.md §3): the router is the *control actor* — its top-k
decision is the control token; every expert is a *dynamic actor* whose
per-firing token rate is 0..capacity.  Capacity-and-drop dispatch is
exactly the paper's {0, r} two-rate restriction: an expert consumes at
most ``capacity`` tokens per firing, overflow tokens take the rate-0 path
(residual passthrough).  ``graphs/moe_as_actors.py`` expresses the same
layer literally as a repro.core actor network and the equivalence is
tested.

Implementation is scatter/gather dispatch (TPU-friendly: contiguous
(E, C, D) expert slabs — again the Eq. 1 contiguous-window discipline):
  1. router logits -> top-k experts + normalized weights per token;
  2. rank tokens per expert via cumsum; tokens over capacity are dropped;
  3. scatter tokens to (E*C, D) slots, einsum the expert FFNs, gather back
     with combine weights.
Expert weights are sharded over the ``model`` mesh axis (expert
parallelism); XLA SPMD materializes the token all-to-all.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import DTYPE, F32, dense_init, split


def moe_init(rng, d_model: int, n_experts: int, d_ff: int) -> Dict[str, jax.Array]:
    r1, r2, r3, r4 = split(rng, 4)
    scale_in = 1.0 / math.sqrt(d_model)
    scale_out = 1.0 / math.sqrt(d_ff)
    return {
        "router": dense_init(r1, d_model, n_experts),
        "we_gate": (jax.random.normal(r2, (n_experts, d_model, d_ff), F32)
                   * scale_in).astype(DTYPE),
        "we_up": (jax.random.normal(r3, (n_experts, d_model, d_ff), F32)
                 * scale_in).astype(DTYPE),
        "we_down": (jax.random.normal(r4, (n_experts, d_ff, d_model), F32)
                   * scale_out).astype(DTYPE),
    }


def capacity_for(n_tokens: int, n_experts: int, top_k: int,
                 capacity_factor: float) -> int:
    c = int(math.ceil(n_tokens * top_k * capacity_factor / n_experts))
    return max(8, -(-c // 8) * 8)  # round up to 8 for TPU-friendly shapes


def _maybe_constrain(x, spec):
    from repro.models.layers import maybe_constrain
    return maybe_constrain(x, spec)


def _dispatch_combine(params, xt, top_k, C, x_dtype):
    """Shared scatter/einsum/gather core. xt: (N, D) -> (y (N, D), aux)."""
    N, D = xt.shape
    E = params["router"].shape[1]
    logits = (xt @ params["router"]).astype(F32)            # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_e = jax.lax.top_k(probs, top_k)            # (N, k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # Rank of each (token, k) assignment within its expert (GShard-style).
    onehot = jax.nn.one_hot(gate_e, E, dtype=jnp.int32)     # (N, k, E)
    flat = onehot.reshape(N * top_k, E)
    ranks = (jnp.cumsum(flat, axis=0) - flat).reshape(N, top_k, E)
    rank = jnp.sum(ranks * onehot, axis=-1)                 # (N, k)
    keep = rank < C

    # Scatter to expert slabs: slot = e * C + rank (dropped -> dummy slot).
    slot = jnp.where(keep, gate_e * C + rank, E * C)
    dispatch = jnp.zeros((E * C + 1, D), x_dtype)
    dispatch = dispatch.at[slot.reshape(-1)].add(
        jnp.repeat(xt, top_k, axis=0).reshape(N * top_k, D))
    slabs = dispatch[:-1].reshape(E, C, D)
    # Expert slabs: experts over `model` (EP), capacity over `data` — keeps
    # the (E, C, D) buffer at E*C*D/(16*16) bytes per chip on the big MoE
    # train cells (43 GB global for olmoe train_4k without this).
    slabs = _maybe_constrain(slabs, ("model", "data", None))

    # Expert FFNs (SwiGLU), expert axis sharded over `model`.
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", slabs, params["we_gate"])
                    .astype(F32)).astype(x_dtype)
    u = jnp.einsum("ecd,edf->ecf", slabs, params["we_up"])
    y_slabs = jnp.einsum("ecf,efd->ecd", g * u, params["we_down"])

    # Gather back with combine weights.
    y_flat = jnp.concatenate([y_slabs.reshape(E * C, D),
                              jnp.zeros((1, D), x_dtype)], axis=0)
    per_k = y_flat[slot.reshape(-1)].reshape(N, top_k, D)
    w = (gate_w * keep.astype(F32)).astype(x_dtype)
    y = jnp.einsum("nkd,nk->nd", per_k, w)

    # Aux: switch-style load-balance loss + stats.
    density = jnp.mean(jax.nn.one_hot(gate_e[:, 0], E, dtype=F32), axis=0)
    router_prob = jnp.mean(probs, axis=0)
    aux = {
        "load_balance_loss": E * jnp.sum(density * router_prob),
        "dropped_frac": 1.0 - jnp.mean(keep.astype(F32)),
    }
    return y, aux


def moe_layer(params: Dict[str, jax.Array], x: jax.Array, *, top_k: int,
              capacity_factor: float = 1.25, local_groups: int = 0
              ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, S, D) -> (y, aux) with load-balance loss in aux.

    Dropped (over-capacity) tokens contribute 0 — the residual connection
    outside this layer carries them through (rate-0 path).

    ``local_groups > 0`` enables **local dispatch** (§Perf hillclimb):
    tokens are ranked/dropped within ``local_groups`` independent groups
    aligned with the data shards (group capacity C/G), so the rank cumsum
    and the scatter never cross data shards — only the expert einsum
    communicates.  GShard per-group-capacity semantics; drop behaviour
    differs marginally under imbalance (visible in aux.dropped_frac).
    """
    B, S, D = x.shape
    N = B * S
    C = capacity_for(N, top_k=top_k, n_experts=params["router"].shape[1],
                     capacity_factor=capacity_factor)
    xt = x.reshape(N, D)

    if local_groups and N % local_groups == 0:
        y, aux = _dispatch_combine_grouped(params, xt, top_k, C, x.dtype,
                                           local_groups)
        return y.reshape(B, S, D), aux

    y, aux = _dispatch_combine(params, xt, top_k, C, x.dtype)
    return y.reshape(B, S, D), aux


def _dispatch_combine_grouped(params, xt, top_k, C, x_dtype, G):
    """Local dispatch with explicit group-leading ops (no vmap) so the
    sharding constraints bind to the *physical* (G, E, C, D) arrays —
    under vmap they silently miss (measured: 64 GB f32 slab all-gathers,
    EXPERIMENTS.md §Perf iteration on the MoE cell)."""
    N, D = xt.shape
    E = params["router"].shape[1]
    Ng = N // G
    Cg = max(8, -(-(C // G) // 8) * 8)
    xg = _maybe_constrain(xt.reshape(G, Ng, D), ("data", None, None))

    logits = (xg @ params["router"]).astype(F32)            # (G, Ng, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_e = jax.lax.top_k(probs, top_k)            # (G, Ng, k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # Per-group expert ranks: cumsum stays inside the group (data shard).
    onehot = jax.nn.one_hot(gate_e, E, dtype=jnp.int32)     # (G, Ng, k, E)
    flat = onehot.reshape(G, Ng * top_k, E)
    ranks = (jnp.cumsum(flat, axis=1) - flat).reshape(G, Ng, top_k, E)
    rank = jnp.sum(ranks * onehot, axis=-1)                 # (G, Ng, k)
    keep = rank < Cg

    # Scatter: one flat buffer, group-major slots -> (G, E, Cg, D) slabs.
    stride = E * Cg + 1
    gidx = jnp.arange(G, dtype=jnp.int32)[:, None, None]
    slot = jnp.where(keep, gidx * stride + gate_e * Cg + rank,
                     gidx * stride + E * Cg)
    upd = jnp.repeat(xg.reshape(G * Ng, D), top_k, axis=0)
    upd = _maybe_constrain(upd, ("data", None))
    # The flat scatter buffer is G-major: rows shard over `data` exactly
    # like the groups.  Left unconstrained, GSPMD's choice diverges with
    # expert count (E=64 measured 8x the collectives of E=40 — §Perf).
    dispatch = jnp.zeros((G * stride, D), x_dtype)
    dispatch = _maybe_constrain(dispatch, ("data", None))
    dispatch = dispatch.at[slot.reshape(-1)].add(upd)
    dispatch = _maybe_constrain(dispatch, ("data", None))
    slabs = dispatch.reshape(G, stride, D)[:, :E * Cg].reshape(G, E, Cg, D)
    slabs = _maybe_constrain(slabs, ("data", "model", None, None))

    g = jax.nn.silu(jnp.einsum("gecd,edf->gecf", slabs, params["we_gate"])
                    .astype(F32)).astype(x_dtype)
    u = jnp.einsum("gecd,edf->gecf", slabs, params["we_up"])
    y_slabs = jnp.einsum("gecf,efd->gecd", g * u, params["we_down"])
    y_slabs = _maybe_constrain(y_slabs, ("data", "model", None, None))

    pad = jnp.zeros((G, 1, D), x_dtype)
    y_flat = jnp.concatenate([y_slabs.reshape(G, E * Cg, D), pad],
                             axis=1).reshape(G * stride, D)
    y_flat = _maybe_constrain(y_flat, ("data", None))
    per_k = y_flat[slot.reshape(-1)].reshape(G, Ng, top_k, D)
    per_k = _maybe_constrain(per_k, ("data", None, None, None))
    w = (gate_w * keep.astype(F32)).astype(x_dtype)
    y = jnp.einsum("gnkd,gnk->gnd", per_k, w).reshape(N, D)

    density = jnp.mean(jax.nn.one_hot(gate_e[..., 0], E, dtype=F32), axis=(0, 1))
    router_prob = jnp.mean(probs, axis=(0, 1))
    aux = {
        "load_balance_loss": E * jnp.sum(density * router_prob),
        "dropped_frac": 1.0 - jnp.mean(keep.astype(F32)),
    }
    return y, aux
