"""GQA attention with RoPE, causal + sliding-window masking, KV caches.

Cache discipline: every layer's KV cache is a **ring buffer** of
``cache_len`` slots — full-attention layers size it to the max context,
sliding-window layers to the window.  Slot = ``pos % cache_len``; a
parallel ``pos`` plane records the absolute position held by each slot
(-1 = empty).  This is the paper's Fig. 2 contiguous-window buffer
discipline applied to serving state: contiguous slabs, cursor arithmetic,
no reallocation (DESIGN.md §3).

Keys are stored *RoPE'd at their absolute position*, so ring wraparound
never needs re-rotation.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import DTYPE, F32, apply_rope, dense_init, split

NEG_INF = -1e30


# ---------------------------------------------------------------------- #
# Params.
# ---------------------------------------------------------------------- #
def attn_init(rng, d_model: int, n_heads: int, n_kv_heads: int, head_dim: int,
              qkv_bias: bool = False) -> Dict[str, jax.Array]:
    r1, r2, r3, r4 = split(rng, 4)
    p = {
        "wq": dense_init(r1, d_model, n_heads * head_dim),
        "wk": dense_init(r2, d_model, n_kv_heads * head_dim),
        "wv": dense_init(r3, d_model, n_kv_heads * head_dim),
        "wo": dense_init(r4, n_heads * head_dim, d_model),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), DTYPE)
        p["bk"] = jnp.zeros((n_kv_heads * head_dim,), DTYPE)
        p["bv"] = jnp.zeros((n_kv_heads * head_dim,), DTYPE)
    return p


def _project_qkv(params, x, n_heads, n_kv_heads, head_dim):
    B, S, _ = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    return (q.reshape(B, S, n_heads, head_dim),
            k.reshape(B, S, n_kv_heads, head_dim),
            v.reshape(B, S, n_kv_heads, head_dim))


# ---------------------------------------------------------------------- #
# Full-sequence attention (train / prefill).
# ---------------------------------------------------------------------- #
# Above this many query positions the dense S^2 score tensor is replaced
# by the chunked online-softmax scan (memory O(S * block)) — mandatory for
# the 32k/512k shapes (32k dense would be ~4 GB *per head pair* in f32).
FLASH_SCAN_THRESHOLD = 2048


def _flash_scan(q, k, v, *, causal: bool, window: Optional[int],
                bq: int = 512, bk: int = 512) -> jax.Array:
    """Pure-jnp blocked flash attention (GQA): scan over q blocks; SWA
    layers slice only the in-window KV span, making them O(S*W) in both
    memory AND flops — the property long_500k banks on."""
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    bq = min(bq, S)
    if S % bq:
        bq = next(b for b in range(bq, 0, -1) if S % b == 0)
    nq = S // bq
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    qb = q.reshape(B, nq, bq, Hkv, G, hd).astype(F32)

    if window is not None:
        # KV span for q block i: [i*bq + bq - 1 - (window-1) - pad, i*bq + bq)
        span = window + bq
        span = min(span, S)
        kp = jnp.pad(k.astype(F32), ((0, 0), (span, 0), (0, 0), (0, 0)))
        vp = jnp.pad(v.astype(F32), ((0, 0), (span, 0), (0, 0), (0, 0)))

        def blk(i):
            qi = qb[:, i]                               # (B,bq,Hkv,G,hd)
            start = i * bq + bq - span + span           # offset in padded
            ks = jax.lax.dynamic_slice_in_dim(kp, start, span, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(vp, start, span, axis=1)
            rows = i * bq + jnp.arange(bq)[:, None]
            cols = (i * bq + bq - span) + jnp.arange(span)[None, :]
            mask = (cols >= 0) & (cols <= rows) & (rows - cols < window)
            s = jnp.einsum("bqkgh,btkh->bkgqt", qi, ks) * scale
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            p = jax.nn.softmax(s, axis=-1)
            return jnp.einsum("bkgqt,btkh->bqkgh", p, vs)

        out = jax.lax.map(blk, jnp.arange(nq))          # (nq,B,bq,Hkv,G,hd)
        out = jnp.moveaxis(out, 0, 1).reshape(B, S, H, hd)
        return out.astype(q.dtype)

    bk = min(bk, S)
    if S % bk:
        bk = next(b for b in range(bk, 0, -1) if S % b == 0)
    nk = S // bk
    kb = k.reshape(B, nk, bk, Hkv, hd).astype(F32)
    vb = v.reshape(B, nk, bk, Hkv, hd).astype(F32)

    def q_block(i):
        qi = qb[:, i]                                   # (B,bq,Hkv,G,hd)

        def kv_step(carry, j):
            m, l, acc = carry
            ks, vs = kb[:, j], vb[:, j]
            s = jnp.einsum("bqkgh,btkh->bkgqt", qi, ks) * scale
            rows = i * bq + jnp.arange(bq)[:, None]
            cols = j * bk + jnp.arange(bk)[None, :]
            if causal:
                s = jnp.where((cols <= rows)[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = alpha * l + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum("bkgqt,btkh->bkgqh", p, vs)
            return (m_new, l, acc), None

        m0 = jnp.full((B, Hkv, G, bq), NEG_INF, F32)
        l0 = jnp.zeros((B, Hkv, G, bq), F32)
        a0 = jnp.zeros((B, Hkv, G, bq, hd), F32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        o = acc / jnp.maximum(l, 1e-20)[..., None]      # (B,Hkv,G,bq,hd)
        return jnp.moveaxis(o, 3, 1)                    # (B,bq,Hkv,G,hd)

    out = jax.lax.map(q_block, jnp.arange(nq))
    out = jnp.moveaxis(out, 0, 1).reshape(B, S, H, hd)
    return out.astype(q.dtype)


def attention(params: Dict[str, jax.Array], x: jax.Array, *,
              n_heads: int, n_kv_heads: int, head_dim: int,
              rope_theta: float, causal: bool = True,
              window: Optional[int] = None, pos0: int = 0,
              kernel_impl: str = "xla") -> jax.Array:
    """x: (B, S, D) -> (B, S, D). ``window``: SWA size (None = full)."""
    B, S, D = x.shape
    q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, head_dim)
    pos = pos0 + jnp.arange(S, dtype=jnp.int32)[None, :]
    q = apply_rope(q, pos, rope_theta)
    k = apply_rope(k, pos, rope_theta)

    if kernel_impl == "pallas":
        from repro.kernels.flash_attention import flash_attention
        o = flash_attention(q, k, v, causal=causal, window=window)
    elif S > FLASH_SCAN_THRESHOLD or kernel_impl == "flash_scan":
        o = _flash_scan(q, k, v, causal=causal, window=window)
    else:
        G = n_heads // n_kv_heads
        qg = q.reshape(B, S, n_kv_heads, G, head_dim)
        scores = jnp.einsum("bskgh,btkh->bkgst", qg.astype(F32), k.astype(F32))
        scores = scores / jnp.sqrt(jnp.float32(head_dim))
        i = jnp.arange(S)[:, None]
        j = jnp.arange(S)[None, :]
        mask = jnp.ones((S, S), bool)
        if causal:
            mask &= j <= i
        if window is not None:
            mask &= (i - j) < window
        scores = jnp.where(mask, scores, NEG_INF)
        p = jax.nn.softmax(scores, axis=-1)
        og = jnp.einsum("bkgst,btkh->bskgh", p.astype(v.dtype), v)
        o = og.reshape(B, S, n_heads, head_dim)
    return o.reshape(B, S, n_heads * head_dim) @ params["wo"]


# ---------------------------------------------------------------------- #
# Ring KV cache.  Optional int8 quantization (§Perf hillclimb): K/V stored
# as int8 with one f32 absmax scale per (slot, kv head) — halves the
# decode memory term; dequantization fuses into the score einsum.
# ---------------------------------------------------------------------- #
def cache_init(batch: int, cache_len: int, n_kv_heads: int, head_dim: int,
               dtype=DTYPE, quant: bool = False) -> Dict[str, jax.Array]:
    kv_dtype = jnp.int8 if quant else dtype
    c = {
        "k": jnp.zeros((batch, cache_len, n_kv_heads, head_dim), kv_dtype),
        "v": jnp.zeros((batch, cache_len, n_kv_heads, head_dim), kv_dtype),
        "pos": jnp.full((batch, cache_len), -1, jnp.int32),
    }
    if quant:
        c["k_scale"] = jnp.zeros((batch, cache_len, n_kv_heads), F32)
        c["v_scale"] = jnp.zeros((batch, cache_len, n_kv_heads), F32)
    return c


def cache_spec(batch: int, cache_len: int, n_kv_heads: int, head_dim: int,
               dtype=DTYPE, quant: bool = False) -> Dict[str, jax.ShapeDtypeStruct]:
    kv_dtype = jnp.int8 if quant else dtype
    c = {
        "k": jax.ShapeDtypeStruct((batch, cache_len, n_kv_heads, head_dim),
                                  jnp.dtype(kv_dtype)),
        "v": jax.ShapeDtypeStruct((batch, cache_len, n_kv_heads, head_dim),
                                  jnp.dtype(kv_dtype)),
        "pos": jax.ShapeDtypeStruct((batch, cache_len), jnp.int32),
    }
    if quant:
        c["k_scale"] = jax.ShapeDtypeStruct((batch, cache_len, n_kv_heads), F32)
        c["v_scale"] = jax.ShapeDtypeStruct((batch, cache_len, n_kv_heads), F32)
    return c


def _quantize(x):
    """x: (..., hd) -> (int8 values, f32 absmax scale over hd)."""
    scale = jnp.max(jnp.abs(x.astype(F32)), axis=-1) / 127.0
    q = jnp.round(x.astype(F32) / jnp.maximum(scale, 1e-9)[..., None])
    return q.astype(jnp.int8), scale


def _deq_k(cache):
    if "k_scale" in cache:
        return cache["k"].astype(F32) * cache["k_scale"][..., None]
    return cache["k"].astype(F32)


def _deq_v(cache):
    if "v_scale" in cache:
        return (cache["v"].astype(F32) * cache["v_scale"][..., None]).astype(DTYPE)
    return cache["v"]


def cache_prefill(params, x, *, n_heads, n_kv_heads, head_dim, rope_theta,
                  cache_len: int, quant: bool = False) -> Dict[str, jax.Array]:
    """Build a ring cache from a full prefill pass (keeps the last
    ``cache_len`` tokens; slots = abs_pos % cache_len)."""
    B, S, _ = x.shape
    _, k, v = _project_qkv(params, x, n_heads, n_kv_heads, head_dim)
    pos = jnp.arange(S, dtype=jnp.int32)[None, :]
    k = apply_rope(k, pos, rope_theta)
    keep = min(S, cache_len)
    k_keep = k[:, S - keep:]
    v_keep = v[:, S - keep:]
    p_keep = jnp.broadcast_to(pos[:, S - keep:], (B, keep))
    slots = (jnp.arange(S - keep, S, dtype=jnp.int32) % cache_len)
    cache = cache_init(B, cache_len, n_kv_heads, head_dim, k.dtype, quant=quant)
    if quant:
        kq, ks = _quantize(k_keep)
        vq, vs = _quantize(v_keep)
        return {
            "k": cache["k"].at[:, slots].set(kq),
            "v": cache["v"].at[:, slots].set(vq),
            "k_scale": cache["k_scale"].at[:, slots].set(ks),
            "v_scale": cache["v_scale"].at[:, slots].set(vs),
            "pos": cache["pos"].at[:, slots].set(p_keep),
        }
    return {
        "k": cache["k"].at[:, slots].set(k_keep),
        "v": cache["v"].at[:, slots].set(v_keep),
        "pos": cache["pos"].at[:, slots].set(p_keep),
    }


def attention_decode(params, x, cache, pos, *, n_heads, n_kv_heads, head_dim,
                     rope_theta, window: Optional[int] = None
                     ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode step.

    x: (B, 1, D); pos: (B,) absolute position of the new token.
    Returns (out (B,1,D), updated cache).
    """
    B, _, D = x.shape
    cache_len = cache["k"].shape[1]
    q, k_new, v_new = _project_qkv(params, x, n_heads, n_kv_heads, head_dim)
    q = apply_rope(q, pos[:, None], rope_theta)
    k_new = apply_rope(k_new, pos[:, None], rope_theta)

    slot = (pos % cache_len).astype(jnp.int32)       # (B,)
    bidx = jnp.arange(B)
    if "k_scale" in cache:
        kq, ks = _quantize(k_new[:, 0])
        vq, vs = _quantize(v_new[:, 0])
        cache = {
            "k": cache["k"].at[bidx, slot].set(kq),
            "v": cache["v"].at[bidx, slot].set(vq),
            "k_scale": cache["k_scale"].at[bidx, slot].set(ks),
            "v_scale": cache["v_scale"].at[bidx, slot].set(vs),
            "pos": cache["pos"].at[bidx, slot].set(pos),
        }
    else:
        cache = {
            "k": cache["k"].at[bidx, slot].set(k_new[:, 0]),
            "v": cache["v"].at[bidx, slot].set(v_new[:, 0]),
            "pos": cache["pos"].at[bidx, slot].set(pos),
        }

    G = n_heads // n_kv_heads
    qg = q.reshape(B, n_kv_heads, G, head_dim)
    scores = jnp.einsum("bkgh,btkh->bkgt", qg.astype(F32),
                        _deq_k(cache)) / jnp.sqrt(jnp.float32(head_dim))
    valid = (cache["pos"] >= 0) & (cache["pos"] <= pos[:, None])
    if window is not None:
        valid &= cache["pos"] > (pos[:, None] - window)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    vv = _deq_v(cache)
    og = jnp.einsum("bkgt,btkh->bkgh", p.astype(vv.dtype), vv)
    o = og.reshape(B, 1, n_heads * head_dim)
    return o @ params["wo"], cache


# ---------------------------------------------------------------------- #
# Cross attention (whisper decoder). Encoder K/V precomputed at prefill.
# ---------------------------------------------------------------------- #
def xattn_init(rng, d_model: int, n_heads: int, head_dim: int):
    r1, r2, r3, r4 = split(rng, 4)
    return {
        "wq": dense_init(r1, d_model, n_heads * head_dim),
        "wk": dense_init(r2, d_model, n_heads * head_dim),
        "wv": dense_init(r3, d_model, n_heads * head_dim),
        "wo": dense_init(r4, n_heads * head_dim, d_model),
    }


def cross_attention(params, x, enc_kv, *, n_heads, head_dim) -> jax.Array:
    """x: (B, S, D); enc_kv: dict k/v (B, T, H, hd) precomputed."""
    B, S, _ = x.shape
    q = (x @ params["wq"]).reshape(B, S, n_heads, head_dim)
    scores = jnp.einsum("bshd,bthd->bhst", q.astype(F32),
                        enc_kv["k"].astype(F32)) / jnp.sqrt(jnp.float32(head_dim))
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhst,bthd->bshd", p.astype(enc_kv["v"].dtype), enc_kv["v"])
    return o.reshape(B, S, n_heads * head_dim) @ params["wo"]


def cross_kv(params, enc_out, *, n_heads, head_dim):
    B, T, _ = enc_out.shape
    return {
        "k": (enc_out @ params["wk"]).reshape(B, T, n_heads, head_dim),
        "v": (enc_out @ params["wv"]).reshape(B, T, n_heads, head_dim),
    }
