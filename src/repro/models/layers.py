"""Common model layers: norms, RoPE, embeddings, MLPs, init helpers.

All modules are pure functions over explicit param pytrees (dicts), so the
whole model is jit/shard-friendly and abstract-init (jax.eval_shape) works
for the dry-run without allocating 72B parameters.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any
DTYPE = jnp.bfloat16      # activation/param dtype on TPU
F32 = jnp.float32


# ---------------------------------------------------------------------- #
# Initializers (explicit rng threading; cheap enough for smoke configs,
# never executed by the dry-run thanks to eval_shape).
# ---------------------------------------------------------------------- #
def dense_init(rng, in_dim: int, out_dim: int, dtype=DTYPE) -> jax.Array:
    scale = 1.0 / np.sqrt(in_dim)
    return (jax.random.normal(rng, (in_dim, out_dim), F32) * scale).astype(dtype)


def embed_init(rng, vocab: int, dim: int, dtype=DTYPE) -> jax.Array:
    return (jax.random.normal(rng, (vocab, dim), F32) * 0.02).astype(dtype)


def split(rng, n: int):
    return jax.random.split(rng, n)


# ---------------------------------------------------------------------- #
# RMSNorm (computed in f32, cast back).
# ---------------------------------------------------------------------- #
def rmsnorm_init(dim: int) -> Dict[str, jax.Array]:
    return {"scale": jnp.zeros((dim,), DTYPE)}  # gemma-style (1 + scale)


def rmsnorm(params: Dict[str, jax.Array], x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(F32))).astype(x.dtype)


# ---------------------------------------------------------------------- #
# Rotary position embeddings.
# ---------------------------------------------------------------------- #
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=F32) / half))


def apply_rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); pos: broadcastable to (..., S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = pos[..., :, None].astype(F32) * freqs          # (..., S, hd/2)
    cos = jnp.cos(ang)[..., :, None, :]                  # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------- #
# MLPs.
# ---------------------------------------------------------------------- #
def swiglu_init(rng, d: int, f: int) -> Dict[str, jax.Array]:
    r1, r2, r3 = split(rng, 3)
    return {"w_gate": dense_init(r1, d, f), "w_up": dense_init(r2, d, f),
            "w_down": dense_init(r3, f, d)}


def swiglu(params, x):
    g = jax.nn.silu((x @ params["w_gate"]).astype(F32)).astype(x.dtype)
    return (g * (x @ params["w_up"])) @ params["w_down"]


def gelu_mlp_init(rng, d: int, f: int) -> Dict[str, jax.Array]:
    r1, r2 = split(rng, 2)
    return {"w_in": dense_init(r1, d, f), "b_in": jnp.zeros((f,), DTYPE),
            "w_out": dense_init(r2, f, d), "b_out": jnp.zeros((d,), DTYPE)}


def gelu_mlp(params, x):
    h = jax.nn.gelu((x @ params["w_in"] + params["b_in"]).astype(F32)).astype(x.dtype)
    return h @ params["w_out"] + params["b_out"]


# ---------------------------------------------------------------------- #
# Embedding / unembedding.
# ---------------------------------------------------------------------- #
def embed_lookup(embed_w: jax.Array, tokens: jax.Array) -> jax.Array:
    return jnp.take(embed_w, tokens, axis=0)


def maybe_constrain(x: jax.Array, spec) -> jax.Array:
    """``with_sharding_constraint`` that degrades gracefully: no-ops when no
    mesh is in scope (unit tests), drops axes absent from the mesh, and
    drops axes that do not divide the dim (never relies on GSPMD padding).

    Load-bearing: without an explicit batch constraint after the embedding,
    GSPMD propagates the (model-sharded) embed table's layout into the
    activations and silently drops data parallelism — measured as a fully
    batch-replicated network in the dry-run (EXPERIMENTS.md §Dry-run).
    """
    axes = None
    try:
        import jax.sharding as jshard
        env = jshard.get_abstract_mesh()
        if env is not None and not env.empty:
            axes = dict(zip(env.axis_names, env.axis_sizes))
    except Exception:
        pass
    if axes is None:
        try:  # legacy `with mesh:` context (what pjit-with-P uses)
            from jax._src import mesh as _mesh_lib
            pm = _mesh_lib.thread_resources.env.physical_mesh
            if pm is not None and not pm.empty:
                axes = dict(pm.shape)
        except Exception:
            pass
    if axes is None:
        return x
    parts = []
    for d, p in enumerate(spec):
        if p is None:
            parts.append(None)
            continue
        cand = p if isinstance(p, tuple) else (p,)
        cand = tuple(a for a in cand if a in axes)
        size = 1
        for a in cand:
            size *= axes[a]
        if cand and x.shape[d] % size == 0 and x.shape[d] >= size:
            parts.append(cand if len(cand) > 1 else cand[0])
        else:
            parts.append(None)
    import jax.sharding as jshard
    return jax.lax.with_sharding_constraint(x, jshard.PartitionSpec(*parts))


BATCH_AXES = ("pod", "data")


def unembed(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (..., D); w: (V, D) (tied) -> logits (..., V) in f32."""
    return (x.astype(F32) @ w.astype(F32).T)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  ignore_id: int = -1) -> jax.Array:
    """Mean CE over non-ignored positions. logits f32 (..., V).

    The gold logit is extracted with a masked sum instead of
    ``take_along_axis``: a gather along a vocab-sharded axis forces GSPMD
    to re-shard the whole logits tensor (measured: +1.3 TB/device of
    collective traffic on the dry-run), while the elementwise mask+reduce
    partitions cleanly (partial sums -> one tiny (B, S) all-reduce).
    """
    logz = jax.nn.logsumexp(logits, axis=-1)
    col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    sel = (col == labels[..., None].clip(0))
    gold = jnp.sum(jnp.where(sel, logits, 0.0), axis=-1)
    nll = logz - gold
    mask = (labels != ignore_id).astype(F32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
