from repro.train.train_step import TrainOptions, make_train_step, train_shardings
from repro.train.trainer import StragglerMonitor, Trainer, TrainerConfig
from repro.train import sharding

__all__ = ["TrainOptions", "make_train_step", "train_shardings",
           "StragglerMonitor", "Trainer", "TrainerConfig", "sharding"]
