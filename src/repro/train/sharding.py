"""Sharding rules: parameter-path patterns -> PartitionSpec.

This is the actor-to-core mapping of paper §3.3 at pod scale: every tensor
gets a *placement* on the fixed production mesh.  Rules are name-pattern
based (like t5x/MaxText "logical axis rules"), with an explicit
divisibility check: a mesh axis that does not divide the dim is dropped
(replicated) and recorded — never silently padded, so the roofline
analysis sees the real layout (DESIGN.md §5: GQA KV tensors are replicated
by rule, not by fallback).
"""
from __future__ import annotations

import re
from typing import Any, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# (regex on "/"-joined path, spec template applied to the *trailing* dims).
# Templates may be shorter than the rank: missing leading dims replicate
# (covers the stacked group axis automatically).
PARAM_RULES: List[Tuple[str, Tuple[Optional[str], ...]]] = [
    (r"embed/w$",              ("model", None)),      # vocab-sharded
    (r"lm_head/w$",            ("model", None)),
    (r"attn/wq$",              (None, "model")),      # q heads TP
    (r"attn/wo$",              ("model", None)),
    (r"attn/wk$",              (None, None)),         # GQA KV replicated
    (r"attn/wv$",              (None, None)),
    (r"attn/bq$",              ("model",)),
    (r"attn/b[kv]$",           (None,)),
    (r"xattn/w[qkv]$",         (None, "model")),
    (r"xattn/wo$",             ("model", None)),
    (r"mlp/w_gate$",           (None, "model")),
    (r"mlp/w_up$",             (None, "model")),
    (r"mlp/w_down$",           ("model", None)),
    (r"mlp/w_in$",             (None, "model")),
    (r"mlp/b_in$",             ("model",)),
    (r"mlp/w_out$",            ("model", None)),
    (r"mlp/b_out$",            (None,)),
    (r"mlp/router$",           (None, None)),
    # MoE experts: expert-parallel over `model` (E, D, F).
    (r"mlp/we_(gate|up|down)$", ("model", None, None)),
    # Mamba2
    (r"mixer/in_proj$",        (None, "model")),
    (r"mixer/out_proj$",       ("model", None)),
    (r"mixer/conv_w$",         (None, "model")),
    (r"mixer/conv_b$",         ("model",)),
    # RG-LRU
    (r"mixer/in_x$",           (None, "model")),
    (r"mixer/in_gate$",        (None, "model")),
    (r"mixer/w_[ax]$",         (None, "model")),
    (r"mixer/b_[ax]$",         ("model",)),
    (r"mixer/lam$",            ("model",)),
    (r"mixer/out$",            ("model", None)),
]


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Data-parallel axes: (pod, data) when present."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _apply_template(shape: Tuple[int, ...],
                    template: Sequence[Optional[str]],
                    mesh: Mesh, dropped: List[str], path: str) -> P:
    spec: List[Optional[str]] = [None] * len(shape)
    # Right-align the template on the shape (leading stacked dims replicate).
    off = len(shape) - len(template)
    for i, ax in enumerate(template):
        if ax is None:
            continue
        d = off + i
        if d < 0:
            continue
        if shape[d] % mesh.shape[ax] == 0:
            spec[d] = ax
        else:
            dropped.append(f"{path}: dim {d} ({shape[d]}) % {ax} "
                           f"({mesh.shape[ax]}) != 0 -> replicated")
    return P(*spec)


def param_specs(params: PyTree, mesh: Mesh,
                verbose: bool = False) -> Tuple[PyTree, List[str]]:
    """PartitionSpec pytree for a parameter pytree (works on
    ShapeDtypeStructs too — dry-run safe)."""
    dropped: List[str] = []

    def spec_for(path_elems, leaf) -> P:
        path = "/".join(str(getattr(e, "key", getattr(e, "idx", e)))
                        for e in path_elems)
        shape = leaf.shape
        for pat, tmpl in PARAM_RULES:
            if re.search(pat, path):
                return _apply_template(shape, tmpl, mesh, dropped, path)
        return P()  # norms, biases, scalars: replicated

    specs = jax.tree_util.tree_map_with_path(spec_for, params)
    if verbose and dropped:
        for d in dropped:
            print(f"[sharding] {d}")
    return specs, dropped


def shard_over_data(specs: PyTree, tree: PyTree, mesh: Mesh,
                    min_size: int = 2 ** 16) -> PyTree:
    """Additionally shard each (large-enough) leaf over the data axis on
    the first dimension that is still replicated and divisible.

    Applied to optimizer moments this is ZeRO-1; applied to params (and
    hence grads) it is FSDP/ZeRO-3 — XLA inserts the just-in-time
    all-gather of weights per scanned layer and the reduce-scatter of
    grads, both overlapped with compute by the latency-hiding scheduler.
    """
    data = "data" if "data" in mesh.axis_names else None
    if data is None:
        return specs

    def upgrade(spec: P, leaf) -> P:
        if not hasattr(leaf, "shape") or len(leaf.shape) == 0:
            return spec
        if int(np.prod(leaf.shape)) < min_size:
            return spec  # tiny tensors: all-gather latency > memory win
        parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
        for d in range(len(parts)):
            if parts[d] is None and leaf.shape[d] % mesh.shape[data] == 0 \
                    and leaf.shape[d] >= mesh.shape[data]:
                parts[d] = data
                return P(*parts)
        return spec

    return jax.tree.map(upgrade, specs, tree)


def zero1_specs(opt_specs: PyTree, params: PyTree, mesh: Mesh) -> PyTree:
    """ZeRO-1: shard optimizer moments over the data axis."""
    return shard_over_data(opt_specs, params, mesh)


def batch_specs(batch: PyTree, mesh: Mesh) -> PyTree:
    """Shard every batch input's leading (batch) dim over the DP axes."""
    dp = dp_axes(mesh)

    def spec_for(leaf) -> P:
        bs = leaf.shape[0]
        if bs % int(np.prod([mesh.shape[a] for a in dp])) == 0:
            return P(dp)
        return P()

    return jax.tree.map(spec_for, batch)


def cache_specs(caches: PyTree, mesh: Mesh,
                seq_axes: Tuple[str, ...] = ()) -> PyTree:
    """Serve-state sharding: shard the batch dim over DP axes when it
    divides; otherwise (long_500k: batch 1) shard the longest divisible
    dim (the KV sequence) over `data` — sequence-parallel decode.

    ``seq_axes``: additionally shard the KV sequence dim over these axes
    (§Perf hillclimb: ('model',) sequence-shards the ring caches across
    the TP axis that GQA KV replication leaves idle — 16x less cache
    memory per chip for one tiny per-token softmax all-reduce)."""
    dp = dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    data_size = mesh.shape["data"]

    def spec_for(path_elems, leaf) -> P:
        shape = leaf.shape
        parts: List[Optional[str]] = [None] * len(shape)
        # group-stacked leaves: (n_groups, B, ...); rest leaves: (B, ...)
        path = "/".join(str(getattr(e, "key", getattr(e, "idx", e)))
                        for e in path_elems)
        b_dim = 1 if path.startswith("groups") else 0
        seq_dim = b_dim + 1   # ring caches: (B, S, ...); states: (B, ...)
        if len(shape) > b_dim and shape[b_dim] % dp_size == 0 and shape[b_dim] > 1:
            parts[b_dim] = dp
            if seq_axes and len(shape) > seq_dim + 1:  # k/v/pos rings only
                size = int(np.prod([mesh.shape[a] for a in seq_axes]))
                if shape[seq_dim] % size == 0 and shape[seq_dim] >= 4 * size:
                    parts[seq_dim] = seq_axes if len(seq_axes) > 1 else seq_axes[0]
            return P(*parts)
        # sequence-parallel fallback: shard the largest remaining dim.
        cand = sorted(range(b_dim + 1, len(shape)),
                      key=lambda d: -shape[d])
        for d in cand:
            if shape[d] % data_size == 0 and shape[d] >= 4 * data_size:
                parts[d] = "data"
                return P(*parts)
        return P(*parts)

    return jax.tree_util.tree_map_with_path(spec_for, caches)


def named(mesh: Mesh, specs: PyTree) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def constrain(x, mesh: Mesh, spec: P):
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
