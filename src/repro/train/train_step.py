"""The pjit'd training step: microbatched grad accumulation, remat,
compressed DP collectives, ZeRO-1 — the distributed-optimization layer.

Overlap note: gradient accumulation is a ``lax.scan`` over microbatches;
because each microbatch's backward ends in the (implicit) DP reduction of
its grad contribution, XLA's latency-hiding scheduler overlaps microbatch
k+1's compute with microbatch k's reduce-scatter/all-reduce — the paper's
"concurrent actors hide FIFO transfer latency" at pod scale.

Gradient compression: with ``grad_dtype=bf16`` the cross-replica
all-reduce moves half the bytes (measured in §Perf); the f32 master Adam
moments make this a safe compression in practice, and the optional error-
feedback residual closes the loop exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import lm as lm_mod
from repro.optim.adamw import AdamWConfig, adamw_update
from repro.train import sharding as shd

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainOptions:
    microbatches: int = 1
    remat: bool = True
    grad_dtype: str = "bf16"       # "bf16" (compressed collectives) | "f32"
    error_feedback: bool = False   # residual accumulation for bf16 grads
    zero1: bool = False            # shard optimizer moments over data axis
    kernel_impl: str = "xla"       # "pallas" on real TPU
    aux_weight: float = 0.01
    unroll: bool = False           # dry-run depth probes: unroll layer scan


def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig,
                    opts: TrainOptions = TrainOptions()):
    """Returns ``train_step(params, opt_state, batch) -> (params, opt_state,
    metrics)`` — pure, pjit-ready (callers attach shardings)."""

    gdt = jnp.bfloat16 if opts.grad_dtype == "bf16" else jnp.float32

    def loss_fn(params, mb):
        total, parts = lm_mod.train_loss(params, cfg, mb,
                                         kernel_impl=opts.kernel_impl,
                                         remat=opts.remat,
                                         aux_weight=opts.aux_weight,
                                         unroll=opts.unroll)
        return total, parts

    def train_step(params, opt_state, batch):
        n_mb = opts.microbatches
        if n_mb > 1:
            mbs = jax.tree.map(
                lambda x: x.reshape((n_mb, x.shape[0] // n_mb) + x.shape[1:]),
                batch)

            def mb_step(acc, mb):
                (loss, parts), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                g = jax.tree.map(lambda x: x.astype(gdt), g)
                acc_g, acc_loss = acc
                return (jax.tree.map(jnp.add, acc_g, g), acc_loss + loss), parts

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, gdt), params)
            (grads, loss_sum), parts = jax.lax.scan(mb_step,
                                                    (zero, jnp.float32(0.0)), mbs)
            grads = jax.tree.map(lambda g: (g / n_mb).astype(gdt), grads)
            loss = loss_sum / n_mb
            parts = jax.tree.map(lambda x: x[-1], parts)
        else:
            (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
            grads = jax.tree.map(lambda g: g.astype(gdt), grads)

        if opts.error_feedback and opts.grad_dtype == "bf16":
            fb = opt_state.get("feedback")
            if fb is not None:
                corrected = jax.tree.map(
                    lambda g, r: g.astype(jnp.float32) + r, grads, fb)
                grads_q = jax.tree.map(lambda c: c.astype(jnp.bfloat16), corrected)
                new_fb = jax.tree.map(
                    lambda c, q: c - q.astype(jnp.float32), corrected, grads_q)
                grads = grads_q
                opt_state = dict(opt_state, feedback=new_fb)

        core_state = {k: v for k, v in opt_state.items() if k != "feedback"}
        new_params, new_core, om = adamw_update(opt_cfg, params, grads, core_state)
        new_opt = dict(new_core)
        if "feedback" in opt_state:
            new_opt["feedback"] = opt_state["feedback"]
        metrics = {"loss": loss, **parts, **om}
        return new_params, new_opt, metrics

    return train_step


# --------------------------------------------------------------------------- #
# Sharding assembly for a full training state on the production mesh.
# --------------------------------------------------------------------------- #
def train_shardings(cfg: ArchConfig, mesh: Mesh, params_abs: PyTree,
                    opt_abs: PyTree, batch_abs: PyTree,
                    opts: TrainOptions = TrainOptions()):
    """(in_shardings, out_shardings) PartitionSpec pytrees for pjit."""
    p_specs, dropped = shd.param_specs(params_abs, mesh)
    o_specs = {
        "m": jax.tree.map(lambda s: s, p_specs),
        "v": jax.tree.map(lambda s: s, p_specs),
        "count": P(),
    }
    if opts.zero1:
        o_specs["m"] = shd.zero1_specs(o_specs["m"], params_abs, mesh)
        o_specs["v"] = shd.zero1_specs(o_specs["v"], params_abs, mesh)
    if opts.error_feedback:
        o_specs = dict(o_specs, feedback=jax.tree.map(lambda s: s, p_specs))
    b_specs = shd.batch_specs(batch_abs, mesh)
    metrics_specs = None  # scalars, replicated
    return (p_specs, o_specs, b_specs), dropped
