"""Fault-tolerant training loop: checkpoint/restart, straggler monitoring,
elastic restore.

Failure model (1000-node posture): a step may raise (device loss, network
partition surfacing as XLA error, preemption).  The trainer catches it,
restores the last committed checkpoint, rebuilds the (stateless,
index-seeded) data source at the restored step and continues — replaying
identical batches.  Tests inject failures via ``failure_hook``.

Straggler mitigation: per-step wall times feed an online z-score monitor;
hosts whose trailing-window mean exceeds ``zmax`` are flagged (at real
scale: reported to the coordinator for exclusion / re-sharding — here the
policy output is recorded and asserted in tests).  Elastic restarts reuse
``Checkpointer.restore`` with the new mesh's shardings.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import Checkpointer

PyTree = Any


class StragglerMonitor:
    """Online per-host step-time tracker with z-score flagging."""

    def __init__(self, n_hosts: int = 1, window: int = 20, zmax: float = 3.0):
        self.n_hosts = n_hosts
        self.window = window
        self.zmax = zmax
        self.times: List[collections.deque] = [
            collections.deque(maxlen=window) for _ in range(n_hosts)]
        self.flagged: List[int] = []

    def record(self, host: int, dt: float) -> None:
        self.times[host].append(dt)

    def check(self) -> List[int]:
        """Hosts whose mean step time is a zmax outlier vs the *other*
        hosts (leave-one-out — a straggler must not dilute its own
        baseline)."""
        means = np.array([np.mean(t) if t else 0.0 for t in self.times])
        if self.n_hosts < 2 or np.all(means == 0):
            # Single-host container: flag the last step against history.
            t = list(self.times[0])
            if len(t) >= 3:
                hist = np.array(t[:-1])
                mu, sd = hist.mean(), hist.std() + 1e-6 * max(hist.mean(), 1e-9)
                if t[-1] > mu + self.zmax * max(sd, 0.05 * mu):
                    self.flagged.append(0)
                    return [0]
            return []
        out = []
        for h, m in enumerate(means):
            others = np.delete(means, h)
            mu, sd = others.mean(), others.std()
            if m > mu + self.zmax * max(sd, 0.05 * mu, 1e-9):
                out.append(h)
        self.flagged.extend(out)
        return out


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 20
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    max_restarts: int = 3
    log_every: int = 10


class Trainer:
    def __init__(self, cfg: TrainerConfig, step_fn, data_source,
                 init_state_fn: Callable[[], Dict[str, PyTree]],
                 failure_hook: Optional[Callable[[int], None]] = None,
                 to_device: Optional[Callable[[Dict], Dict]] = None,
                 log: Callable[[str], None] = print):
        """``step_fn(params, opt_state, batch) -> (params, opt_state, metrics)``
        (already jitted/pjitted).  ``init_state_fn() -> {"params", "opt"}``.
        ``failure_hook(step)`` may raise to simulate node failure."""
        self.cfg = cfg
        self.step_fn = step_fn
        self.data = data_source
        self.init_state_fn = init_state_fn
        self.failure_hook = failure_hook
        self.to_device = to_device or (lambda b: b)
        self.log = log
        self.ckpt = Checkpointer(cfg.checkpoint_dir, keep=cfg.keep)
        self.monitor = StragglerMonitor()
        self.restarts = 0
        self.metrics_history: List[Dict[str, float]] = []

    # ------------------------------------------------------------------ #
    def _restore_or_init(self):
        latest = self.ckpt.latest_step()
        if latest is None:
            state = self.init_state_fn()
            return 0, state["params"], state["opt"]
        self.log(f"[trainer] restoring step {latest}")
        template = self.init_state_fn()
        tree = {"params": template["params"], "opt": template["opt"]}
        restored = self.ckpt.restore(latest, tree)
        return latest, restored["params"], restored["opt"]

    def run(self):
        step, params, opt_state = self._restore_or_init()
        while step < self.cfg.total_steps:
            try:
                batch = self.to_device(self.data.batch(step))
                t0 = time.perf_counter()
                if self.failure_hook is not None:
                    self.failure_hook(step)
                params, opt_state, metrics = self.step_fn(params, opt_state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0
                self.monitor.record(0, dt)
                self.monitor.check()
                step += 1
                if step % self.cfg.log_every == 0 or step == 1:
                    m = {k: float(v) for k, v in metrics.items()}
                    m["step"] = step
                    m["dt"] = dt
                    self.metrics_history.append(m)
                    self.log(f"[trainer] step {step} loss {m['loss']:.4f} "
                             f"({dt*1e3:.0f} ms)")
                if step % self.cfg.checkpoint_every == 0:
                    self.ckpt.save(step, {"params": params, "opt": opt_state})
            except KeyboardInterrupt:
                raise
            except Exception as e:  # noqa: BLE001 — any step failure
                self.restarts += 1
                self.log(f"[trainer] step {step} FAILED ({type(e).__name__}: "
                         f"{e}); restart {self.restarts}/{self.cfg.max_restarts}")
                if self.restarts > self.cfg.max_restarts:
                    raise
                self.ckpt.wait()
                step, params, opt_state = self._restore_or_init()
        self.ckpt.wait()
        self.ckpt.save(step, {"params": params, "opt": opt_state}, blocking=True)
        return params, opt_state
