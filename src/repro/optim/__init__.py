from repro.optim.adamw import (AdamWConfig, abstract_opt_state, adamw_update,
                               global_norm, init_opt_state, schedule)

__all__ = ["AdamWConfig", "abstract_opt_state", "adamw_update", "global_norm",
           "init_opt_state", "schedule"]
