"""AdamW + cosine schedule + global-norm clipping, as explicit pytree math.

Optimizer state mirrors the parameter pytree (m, v in f32 regardless of
param dtype — bf16 params keep f32 master moments), so the sharding rules
for params apply verbatim, and ZeRO-1 upgrades (shard m/v over the data
axis) are a spec change only (``repro.train.sharding.zero1_specs``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

PyTree = Any
F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    clip_norm: float = 1.0


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_frac * lr."""
    step = step.astype(F32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init_opt_state(params: PyTree) -> Dict[str, PyTree]:
    zeros = lambda p: jnp.zeros(p.shape, F32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def abstract_opt_state(params: PyTree) -> Dict[str, PyTree]:
    sds = lambda p: jax.ShapeDtypeStruct(p.shape, F32)
    return {
        "m": jax.tree.map(sds, params),
        "v": jax.tree.map(sds, params),
        "count": jax.ShapeDtypeStruct((), jnp.int32),
    }


def global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(F32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, params: PyTree, grads: PyTree,
                 state: Dict[str, PyTree]) -> Tuple[PyTree, Dict[str, PyTree], Dict[str, jax.Array]]:
    """One AdamW step. Grads may be bf16 (compressed DP all-reduce); moments
    and the update math run in f32."""
    count = state["count"] + 1
    b1, b2 = cfg.betas
    lr = schedule(cfg, count)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    def upd(p, g, m, v):
        g = g.astype(F32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / (1 - b1 ** count.astype(F32))
        vhat = v / (1 - b2 ** count.astype(F32))
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(F32)
        return (p.astype(F32) - lr * step_).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "count": count}, metrics
