"""The Dynamic Predistortion application — paper §4.2, Fig. 5.

Configuration (C) actor periodically reconfigures which of the 10 parallel
Poly (P) branches (nonlinear basis + 10-tap complex FIR) are active; the
Adder (A) sums the active branches.  The number of active filters changes
arbitrarily between 2 and 10 at run time — dynamic data rates that CSDF
cannot model (paper §4.2) and that DAL cannot put on the accelerator.

Wiring (22 complex data channels + 11 control channels):

    source --f_in--> fork --b_k--> poly_k --y_k--> adder --f_out--> sink
    config --c_fork--> fork, --c_k--> poly_k, --c_add--> adder

Complex samples are carried as (2, L) f32 tokens (re/im planes) instead of
the paper's separate re/im channel pairs — identical bytes, half the graph
clutter (DESIGN.md §8).  Token = 32 768 complex samples (256 KB) so that
Eq. 1 over the 22 data channels reproduces Table 1's 11.5 MB, and the
reconfiguration period of 65 536 samples = a new control value every 2
firings (paper §4.2).

The token rate of the dynamic part is 1 — the paper's own restriction
(§5: ports have at most two rates {0, r}; arbitrary run-time data paths
need r=1 to avoid deadlock).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Network, NetworkBuilder, dynamic_actor, static_actor
from repro.core.actor import apply_rate_gate
from repro.kernels.dyn_fir import N_BRANCHES, N_TAPS
from repro.kernels.dyn_fir.ops import dpd_branch

BLOCK_L = 32768                 # complex samples per token (256 KB)
RECONF_PERIOD_SAMPLES = 65536   # paper §4.2
RECONF_PERIOD_FIRINGS = RECONF_PERIOD_SAMPLES // BLOCK_L


def _branch_on(k: int, tok: jax.Array) -> jax.Array:
    """0/1 enable of branch ``k`` given the configuration token.

    One shared predicate for every port the configuration value drives
    (fork.b_k, poly_k.in/out, adder.y_k): identical control *expressions*
    fed by provably-equal control tokens are what lets
    ``NetworkBuilder.build`` derive ``matched_rates`` — and thus transient-
    channel register allocation — instead of taking it on declaration.
    """
    return (jnp.int32(k) < tok[0]).astype(jnp.int32)


def default_active_schedule(n_firings: int, seed: int = 0,
                            lo: int = 2, hi: int = N_BRANCHES) -> np.ndarray:
    """Number of active filters per firing: changes every RECONF period,
    arbitrary in [lo, hi] (paper: 2..10, externally defined)."""
    rng = np.random.default_rng(seed)
    n_periods = -(-n_firings // RECONF_PERIOD_FIRINGS)
    per = rng.integers(lo, hi + 1, n_periods)
    return np.repeat(per, RECONF_PERIOD_FIRINGS)[:n_firings].astype(np.int32)


def build_dpd(n_firings: int,
              active_schedule: Optional[np.ndarray] = None,
              block_l: int = BLOCK_L,
              n_branches: int = N_BRANCHES,
              signal: Optional[jax.Array] = None,
              fir_impl: str = "xla",
              static_all_active: bool = False) -> Network:
    """Build the DPD network.

    ``static_all_active=True`` builds the *static* variant (every branch
    always on, no control ports) — the DAL-compatible baseline used for
    the Table 4 comparison and the 5x measurement.
    """
    L = block_l
    tok = (2, L)
    if active_schedule is None:
        active_schedule = default_active_schedule(n_firings)
    sched = jnp.asarray(active_schedule, jnp.int32)
    branch_names = [f"poly{k}" for k in range(n_branches)]

    # ---------------------------------------------------------------- #
    # Source / sink.
    # ---------------------------------------------------------------- #
    def src_fire(state, inputs, rates):
        data, idx = state
        win = jax.lax.dynamic_slice_in_dim(data, idx * L, L, axis=1)
        return (data, idx + 1), {"out": win[None]}

    def src_init():
        data = (signal if signal is not None
                else jnp.zeros((2, n_firings * L), jnp.float32))
        return (jnp.asarray(data, jnp.float32), jnp.int32(0))

    source = static_actor("source", (), ("out",), src_fire, init=src_init,
                          ready=lambda st: st[1] < n_firings)

    def sink_fire(state, inputs, rates):
        data, idx = state
        data = jax.lax.dynamic_update_slice_in_dim(
            data, inputs["in"][0], idx * L, axis=1)
        return (data, idx + 1), {}

    sink = static_actor("sink", ("in",), (), sink_fire,
                        init=lambda: (jnp.zeros((2, n_firings * L), jnp.float32),
                                      jnp.int32(0)),
                        finish=lambda st: st[0])

    # ---------------------------------------------------------------- #
    # Configuration actor: emits the active-count token to 12 controls.
    # ---------------------------------------------------------------- #
    ctrl_ports = ["c_fork", "c_add"] + [f"c{k}" for k in range(n_branches)]

    def config_fire(state, inputs, rates):
        idx = state
        n_active = sched[jnp.clip(idx, 0, sched.shape[0] - 1)]
        tok_out = n_active.reshape(1, 1)
        return idx + 1, {p: tok_out for p in ctrl_ports}

    config = static_actor("config", (), tuple(ctrl_ports), config_fire,
                          init=lambda: jnp.int32(0),
                          ready=lambda st: st < n_firings)

    # ---------------------------------------------------------------- #
    # Fork: broadcast the input window to enabled branches only.
    # ---------------------------------------------------------------- #
    fork_outs = tuple(f"b{k}" for k in range(n_branches))

    def fork_control(tok):
        d = {"in": jnp.int32(1)}
        for k in range(n_branches):
            d[f"b{k}"] = _branch_on(k, tok)
        return d

    def fork_fire(state, inputs, rates):
        return state, {p: inputs["in"] for p in fork_outs}

    if static_all_active:
        fork = static_actor("fork", ("in",), fork_outs, fork_fire)
    else:
        fork = dynamic_actor("fork", "c", fork_control, ("in",), fork_outs,
                             fork_fire)

    # ---------------------------------------------------------------- #
    # Poly branches: basis + 10-tap complex FIR, 9-sample history state.
    # ---------------------------------------------------------------- #
    def make_poly(k: int):
        order = k + 1

        def init():
            hist = jnp.zeros((2, N_TAPS - 1), jnp.float32)
            # Deterministic per-branch taps (benchmark/repro friendly).
            rng = np.random.default_rng(100 + k)
            taps = jnp.asarray(rng.normal(scale=0.3, size=(2, N_TAPS)), jnp.float32)
            return (hist, taps)

        def fire(state, inputs, rates):
            hist, taps = state
            win = inputs["in"][0]                      # (2, L)
            x = jnp.concatenate([hist, win], axis=1)   # (2, L + T - 1)
            yr, yi = dpd_branch(x[0], x[1], taps[0], taps[1], order=order,
                                impl=fir_impl)
            new_hist = x[:, -(N_TAPS - 1):]
            return (new_hist, taps), {"out": jnp.stack([yr, yi])[None]}

        def control(tok):
            on = _branch_on(k, tok)
            return {"in": on, "out": on}

        flops = 2 * L * (4 * N_TAPS + 2 * order)  # complex MACs + basis
        if static_all_active:
            return static_actor(f"poly{k}", ("in",), ("out",), fire, init=init,
                                cost_flops=flops)
        return dynamic_actor(f"poly{k}", "c", control, ("in",), ("out",), fire,
                             init=init, cost_flops=flops)

    polys = [make_poly(k) for k in range(n_branches)]

    # ---------------------------------------------------------------- #
    # Adder: sum of enabled branch outputs.
    # ---------------------------------------------------------------- #
    add_ins = tuple(f"y{k}" for k in range(n_branches))

    def adder_fire(state, inputs, rates):
        acc = jnp.zeros((1, 2, L), jnp.float32)
        for k in range(n_branches):
            # Disabled windows hold stale data — gate by the rate flag
            # (folded away at trace time in the static-rewrite build).
            term = apply_rate_gate(rates[f"y{k}"], inputs[f"y{k}"])
            if term is not None:
                acc = acc + term
        return state, {"out": acc}

    def adder_control(tok):
        d = {"out": jnp.int32(1)}
        for k in range(n_branches):
            d[f"y{k}"] = _branch_on(k, tok)
        return d

    if static_all_active:
        adder = static_actor("adder", add_ins, ("out",), adder_fire)
    else:
        adder = dynamic_actor("adder", "c", adder_control, add_ins, ("out",),
                              adder_fire)

    # ---------------------------------------------------------------- #
    # Wiring (declarative; Eq. 1 capacities derived per channel).
    # ---------------------------------------------------------------- #
    # In the dynamic build, every data channel's two ports are driven by
    # the same configuration value (fork.b_k, poly_k and adder.y_k all
    # evaluate `_branch_on(k, tok)`; f_in and f_out are unconditionally
    # enabled), so builder derivation proves them matched-rate transient
    # channels: the specialized static executor register-allocates them
    # instead of paying the masked ring writes' read-modify-write on
    # 256 KB windows.  The static rewrite has static actors at both ends,
    # where the buffered static-offset path is already optimal (the
    # contiguous ring write doubles as the materialization point between
    # actor bodies) — derivation never marks static-static channels.
    b = NetworkBuilder()
    if not static_all_active:
        b.actor(config)
    b.actors(source, fork, *polys, adder, sink)
    b.connect("source.out", "fork.in", token_shape=tok, name="f_in")
    b.connect("adder.out", "sink.in", token_shape=tok, name="f_out")
    for k in range(n_branches):
        b.connect(f"fork.b{k}", f"poly{k}.in", token_shape=tok, name=f"f_b{k}")
        b.connect(f"poly{k}.out", f"adder.y{k}", token_shape=tok,
                  name=f"f_y{k}")
    if not static_all_active:
        b.connect("config.c_fork", "fork.c", name="f_c_fork")
        b.connect("config.c_add", "adder.c", name="f_c_add")
        for k in range(n_branches):
            b.connect(f"config.c{k}", f"poly{k}.c", name=f"f_c{k}")
    return b.build()


def bench_workload(n_firings: int, block_l: int = BLOCK_L, seed: int = 1,
                   **build_kw) -> Network:
    """DPD network staged with a reproducible random signal.

    Shared by benchmarks/bench_executors.py and tests/test_perf_smoke.py so
    the measured workload (and its Msamples accounting: ``n_firings *
    block_l`` complex samples end to end) is defined in one place.
    Delegates the signal staging to ``repro.graphs.factories.make_dpd``
    (single source of truth), keeping this module's historical
    ``default_active_schedule`` reconfiguration pattern.
    """
    from repro.graphs.factories import make_dpd
    build_kw.setdefault("active_schedule", default_active_schedule(n_firings))
    net, _ = make_dpd(n_firings, block_l=block_l, seed=seed, **build_kw)
    return net
