"""Shared paper-graph factories: one definition of each benchmark/test
workload, with reproducible staged data.

Grew out of ``tests/_graph_factories.py`` (which now re-exports from
here): the same builders were being re-implemented inline by
``benchmarks/bench_paper_tables.py`` / ``bench_executors.py`` /
``bench_megakernel.py``, and benchmarks must not import from ``tests/``.
Callers pick sizes; every factory returns ``(network, n_iterations)``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Network, NetworkState

#: Active-filter counts exercising rate-0 firings on most branches
#: (2..10 active of 10) — the equivalence suites' DPD schedule.
DPD_SCHEDULE = np.array([2, 10, 5, 7, 3, 9], np.int32)


def states_identical(a: NetworkState, b: NetworkState) -> bool:
    """Bit-identity of two network states (structure and every leaf)."""
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return (jax.tree.structure(a) == jax.tree.structure(b)
            and all(np.array_equal(np.asarray(x), np.asarray(y))
                    for x, y in zip(la, lb)))


def make_dpd(n_firings: int = 6, block_l: int = 256, seed: int = 0,
             active_schedule: Optional[np.ndarray] = None,
             **build_kw) -> Tuple[Network, int]:
    """DPD (paper §4.2) with a reproducible random signal staged.

    Defaults to :data:`DPD_SCHEDULE` truncated to ``n_firings`` so rate-0
    firings hit most branches; pass ``active_schedule`` (or
    ``static_all_active=True``) for the benchmark variants.
    """
    from repro.graphs.dpd import build_dpd
    if active_schedule is None:
        active_schedule = DPD_SCHEDULE[:n_firings]
    rng = np.random.default_rng(seed)
    sig = jnp.asarray(rng.normal(size=(2, n_firings * block_l))
                      .astype(np.float32))
    return build_dpd(n_firings, active_schedule=active_schedule,
                     block_l=block_l, signal=sig, **build_kw), n_firings


def make_motion_detection(n_frames: int = 12, rate: int = 4,
                          frame_hw: Tuple[int, int] = (240, 320),
                          seed: int = 1) -> Tuple[Network, int]:
    """Motion detection (paper §4.1) with a reproducible random video —
    the delay-channel (Fig. 4 dotted edge) workload."""
    from repro.graphs.motion_detection import build_motion_detection
    rng = np.random.default_rng(seed)
    video = jnp.asarray(rng.uniform(0, 255, (n_frames,) + tuple(frame_hw))
                        .astype(np.float32))
    return build_motion_detection(n_frames, rate=rate, frame_hw=frame_hw,
                                  video=video), n_frames // rate


def make_moe(n_firings: int = 3, n_tokens: int = 16, d_model: int = 32,
             n_experts: int = 4, top_k: int = 2, d_ff: int = 64,
             capacity_factor: float = 2.0, seed: int = 0
             ) -> Tuple[Network, int]:
    """MoE-as-actors (idle experts = rate-0 firings on the compiled path)."""
    from repro.graphs.moe_as_actors import build_moe_network
    from repro.models.moe import moe_init
    key = jax.random.PRNGKey(seed)
    params = moe_init(key, d_model, n_experts, d_ff)
    xs = jax.random.normal(key, (n_firings * n_tokens, d_model), jnp.float32)
    return build_moe_network(params, n_tokens, d_model, top_k,
                             capacity_factor, n_firings, xs), n_firings
