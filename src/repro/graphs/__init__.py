"""Paper application graphs (§4) plus the LM-substrate bridges, all
constructed through the declarative ``repro.core.NetworkBuilder`` and
executed through ``Network.compile(ExecutionPlan) -> Program``."""
from repro.graphs.motion_detection import build_motion_detection
from repro.graphs.dpd import build_dpd

__all__ = ["build_motion_detection", "build_dpd", "build_moe_network",
           "build_lm_stage_network", "lm_stage_network_forward"]


def __getattr__(name):
    # moe_as_actors / lm_pipeline pull in the model stack; import lazily so
    # the light paper graphs stay importable without it.
    if name == "build_moe_network":
        from repro.graphs.moe_as_actors import build_moe_network
        return build_moe_network
    if name in ("build_lm_stage_network", "lm_stage_network_forward"):
        from repro.graphs import lm_pipeline
        return getattr(lm_pipeline, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
