"""Paper application graphs (§4): video Motion Detection and Dynamic
Predistortion, expressed as repro.core actor networks."""
from repro.graphs.motion_detection import build_motion_detection
from repro.graphs.dpd import build_dpd

__all__ = ["build_motion_detection", "build_dpd"]
