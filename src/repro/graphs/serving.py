"""Continuous-batching LM serving as a dynamic-rate actor network.

The serving loop is the paper's adaptive-application pattern (§2.2/§4.3)
applied to the ROADMAP's top new direction: requests arrive mid-flight,
decode lengths are data-dependent, and a slot that hits EOS (or its
budget) is a **rate-0 firing** whose freed slot is re-admitted on the
next sweep.  The graph::

            +--------------------- fb (delay=1) ------------------+
            v                                                     |
      admission ---- table ------------------------------> merge -+
       (static) ---- x -----> gate ---- xa ----> decode --- y ---^
            |                  |      (dynamic: skips the model
            |                  |       when no slot is active)
            |                  +---- fina ----> retire (dynamic sink)
            +-- c_gate / c_dec / c_merge / c_ret  (one control token
                broadcast to every dynamic actor, MoC rate 1)

    * **admission** (static, the loop head): consumes the slot-table
      feedback, extracts the slots the previous step finished (their
      freed rows become admissible again — the re-admission loop),
      admits 0..k waiting arrivals into free slots (data-dependent
      production, realized as a masked fixed-capacity window plus the
      control token's admit count, exactly the MoE-router idiom), and
      broadcasts ONE control token ``[n_active, n_finished, n_admitted]``
      to every dynamic actor.  Its ``ready`` predicate retires the
      network once every request has been collected.
    * **gate** (dynamic): forwards the slot table to the decode actor and
      the finished rows to the retire actor — but only when the matching
      count is non-zero.  The gate exists so *both* endpoints of the
      ``xa``/``fina`` channels are enabled by the same control value:
      a producer that writes while its consumer skips would drift the
      FIFO occupancy and break window pairing (the MoC hazard the
      matched-rates derivation exists to rule out).
    * **decode** (dynamic): one ``decode_step`` per firing over the B
      slots, plus a ``lax.cond``-gated ``prefill`` on firings that admit
      new requests; KV caches are the actor state.  When ``n_active ==
      0`` every regular port is rate 0 and the whole body is skipped —
      the EOS/idle rate-0 firing the paper's 5x comes from (it still
      counts in ``fire_counts``: the control token is consumed).
    * **merge** (dynamic): folds the decoded tokens back into the slot
      table (append, advance pos, detect EOS/budget exhaustion) and
      writes the feedback token.  The per-slot decode state rides this
      delay-token feedback FIFO — the KV/decode loop-carry the legacy
      engine kept implicit.
    * **retire** (dynamic sink): collects finished sequences (tokens,
      lengths, step latency) keyed by request id; fires rate-0 when the
      step finished nothing.

    Every delay-free channel between a static producer and a dynamic
    consumer (or between two dynamic actors) is *provably* matched: the
    shared enable predicates below trace to identical jaxprs and the
    control channels all feed from one broadcast token
    (``derive_matched_rates``), so ``build(check_bounds=True)`` proves
    every channel ``balanced`` — the PRUNE-style decidability the ISSUE
    asks for, declared via ``rate_bounds`` for the data-dependent ports.

Bit-identity contract: per-request greedy tokens equal the legacy
``repro.serve.Engine`` output token-for-token.  Both engines call the
same ``prefill``/``decode_step`` at the same shapes — (B, P) prompts,
(B, 1) decode tokens — and dense-model rows are computed independently
of their batchmates, so *when* a request is admitted cannot change its
tokens.  (MoE configs couple rows through expert capacity; the identity
oracle holds for dense families only.)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import Network, NetworkBuilder, dynamic_actor, static_actor
from repro.models import lm as lm_mod

PyTree = Any

# Slot-table header columns (one row per slot, i32 everywhere; tokens,
# positions and counters are all ints).  After the header: P prompt
# columns (left-padded), then max_new generated-token columns.
C_ACTIVE = 0    # slot holds a live request
C_REQ = 1       # request id (index into the staged request slabs)
C_POS = 2       # next decode_step position (P + produced - 1)
C_PROD = 3      # tokens produced so far (includes the prefill token)
C_BUDGET = 4    # per-request max_new
C_FIN = 5       # finished last step (freed + collected next firing)
C_LAST = 6      # last produced token (decode_step input)
C_NEW = 7       # admitted this firing (decode runs prefill for the row)
C_LAT = 8       # scratch: completion latency in steps (finish extraction)
C_STATUS = 9    # retirement status code (STATUS_*)
C_DEADLINE = 10  # absolute retire-by step (NO_DEADLINE = unconstrained)
C_AGE = 11      # decode steps survived in a slot (admission resets to 0)
HEADER = 12

# Retirement status codes carried in C_STATUS and collected per request
# by the retire sink.
STATUS_OK = 0        # finished normally (EOS or budget)
STATUS_TIMEOUT = 1   # deadline expired (in flight or while waiting)
STATUS_SHED = 2      # shed by admission under queue overflow
STATUS_FAULT = 3     # quarantined after a guarded-run fault

# Every slot-table value — token ids, positions, counters, deadlines —
# is a non-negative i32 below 2**30; the channels declare this as their
# guard domain, so a poisoned row trips the DOMAIN fault bit on write.
NO_DEADLINE = 2**30 - 1
SLOT_DOMAIN = (0.0, float(2**30))


@dataclasses.dataclass(frozen=True)
class ServingWorkload:
    """The staged request set of one serving run (host-fed arrival queue)."""

    prompts: np.ndarray       # (R, P) i32, left-padded
    prompt_lens: np.ndarray   # (R,) i32
    budgets: np.ndarray       # (R,) i32 per-request max_new (>= 1)
    arrivals: np.ndarray      # (R,) i32 arrival step, ascending
    # Absolute retire-by step per request; None = no deadlines
    # (every entry NO_DEADLINE).
    deadlines: Optional[np.ndarray] = None


# --------------------------------------------------------------------- #
# Shared enable predicates: every channel endpoint gated by the same
# expression of the same broadcast control token, so the matched-rates
# derivation proves the channels balanced (identical canonical jaxprs +
# feeder ports shown equal by tracing admission's fire).
# --------------------------------------------------------------------- #
def _on_active(tok: jax.Array) -> jax.Array:
    return (tok[0] > 0).astype(jnp.int32)


def _on_fin(tok: jax.Array) -> jax.Array:
    return (tok[1] > 0).astype(jnp.int32)


def _batch_axes(template_small, template_big) -> List[int]:
    """Per-leaf batch axis of a cache pytree, by shape comparison between
    two eval_shape templates that differ only in batch size."""
    ls, lb = jax.tree.leaves(template_small), jax.tree.leaves(template_big)
    axes: List[int] = []
    for s, b in zip(ls, lb):
        diff = [i for i, (x, y) in enumerate(zip(s.shape, b.shape)) if x != y]
        if len(diff) != 1:
            raise ValueError(
                "serving: cannot locate the batch axis of a cache leaf "
                f"(shape {s.shape} vs {b.shape}); per-slot cache merging "
                "needs exactly one batch-dependent axis per leaf")
        axes.append(diff[0])
    return axes


def _select_rows(mask: jax.Array, axes: List[int], new: PyTree,
                 old: PyTree) -> PyTree:
    """Per-row select over a cache pytree: rows where ``mask`` take
    ``new``, others keep ``old`` (batch axis varies per leaf)."""
    flat_new, treedef = jax.tree.flatten(new)
    flat_old = jax.tree.leaves(old)
    out = []
    for n, o, ax in zip(flat_new, flat_old, axes):
        shape = [1] * n.ndim
        shape[ax] = mask.shape[0]
        out.append(jnp.where(mask.reshape(shape), n, o))
    return jax.tree.unflatten(treedef, out)


def left_pad_prompts(prompts: List[np.ndarray], max_prompt: int
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Left-pad prompts into an (R, P) slab exactly as ``Engine._pad_batch``
    does (prompts end together), returning (slab, lens)."""
    R, P = len(prompts), max_prompt
    slab = np.zeros((R, P), np.int32)
    lens = np.zeros((R,), np.int32)
    for i, p in enumerate(prompts):
        p = np.asarray(p, np.int32)[-P:]
        slab[i, P - len(p):] = p
        lens[i] = len(p)
    return slab, lens


def poisson_trace(n: int, rate: float, seed: int = 0) -> np.ndarray:
    """Seeded open-loop Poisson arrival trace: ``n`` ascending integer
    arrival steps with exponential inter-arrival gaps of mean ``1/rate``."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n)
    return np.floor(np.cumsum(gaps)).astype(np.int32)


# --------------------------------------------------------------------- #
# Graph construction.
# --------------------------------------------------------------------- #
def build_serving_network(cfg: ArchConfig, params: PyTree,
                          workload: ServingWorkload, *,
                          batch_size: int, max_prompt: int, max_new: int,
                          eos_id: Optional[int] = None,
                          kernel_impl: str = "xla",
                          queue_depth: Optional[int] = None,
                          check_bounds: bool = True,
                          return_bounds: bool = False) -> Network:
    """Build the admission/gate/decode/merge/retire serving network with
    ``workload`` staged as the host-fed arrival queue.

    ``queue_depth`` bounds the waiting queue: arrived requests that would
    queue deeper than ``queue_depth`` behind this firing's admissions are
    shed (``STATUS_SHED``); ``None`` queues without bound.  Requests whose
    deadline passes — waiting or in flight — retire as ``STATUS_TIMEOUT``.
    Both are rate-0 outcomes of the same admission firing: shedding is
    backpressure expressed as a dynamic rate, not an error path.

    ``return_bounds=True`` returns ``(network, BoundsReport)`` so callers
    can pin the per-channel verdicts the build proved."""
    B, P, N = batch_size, max_prompt, max_new
    W = HEADER + P + N
    R = int(workload.prompts.shape[0])
    if R == 0:
        raise ValueError("serving: empty workload; stage >= 1 request")
    if workload.prompts.shape[1] != P:
        raise ValueError(
            f"serving: prompt slab width {workload.prompts.shape[1]} != "
            f"max_prompt {P}")
    if (workload.budgets < 1).any() or (workload.budgets > N).any():
        raise ValueError(
            f"serving: per-request budgets must be in 1..max_new={N}")
    if (np.diff(workload.arrivals) < 0).any():
        raise ValueError("serving: arrival trace must be ascending")
    if queue_depth is not None and queue_depth < 0:
        raise ValueError(f"serving: queue_depth={queue_depth} must be >= 0")
    deadlines_np = (np.full((R,), NO_DEADLINE, np.int32)
                    if workload.deadlines is None
                    else np.asarray(workload.deadlines, np.int32))
    if deadlines_np.shape != (R,):
        raise ValueError(
            f"serving: deadlines shape {deadlines_np.shape} != ({R},)")
    eos = jnp.int32(-1 if eos_id is None else eos_id)
    cache_len = P + N

    prompts = jnp.asarray(workload.prompts, jnp.int32)
    budgets = jnp.asarray(workload.budgets, jnp.int32)
    arrivals = jnp.asarray(workload.arrivals, jnp.int32)
    deadlines = jnp.asarray(deadlines_np, jnp.int32)
    qd = jnp.int32(B + R if queue_depth is None else queue_depth)

    # -- admission: static loop head -------------------------------------
    def admission_init():
        return {"taken": jnp.zeros((R,), jnp.int32), "t": jnp.int32(0),
                "retired": jnp.int32(0)}

    def admission_fire(st, ins, rates):
        del rates
        t = st["t"]
        idx = jnp.arange(R, dtype=jnp.int32)
        tbl = ins["fb"][0]
        # In-flight deadline expiry retires the slot exactly like an EOS:
        # FIN=1 with TIMEOUT status, freed and collected this firing.
        expired_slot = (tbl[:, C_ACTIVE] > 0) & (tbl[:, C_DEADLINE] < t)
        tbl = tbl.at[:, C_FIN].set(
            jnp.where(expired_slot, 1, tbl[:, C_FIN]))
        tbl = tbl.at[:, C_STATUS].set(
            jnp.where(expired_slot, STATUS_TIMEOUT, tbl[:, C_STATUS]))
        fin_mask = tbl[:, C_FIN] > 0
        n_fin = jnp.sum(fin_mask.astype(jnp.int32))
        # Completion latency: the finishing token was produced at step
        # t-1; the request waited since its (open-loop) arrival step.
        req = jnp.clip(tbl[:, C_REQ], 0, R - 1)
        lat = (t - 1) - arrivals[req]
        fin_rows = jnp.where(fin_mask[:, None],
                             tbl.at[:, C_LAT].set(lat), 0)
        tbl = jnp.where(fin_mask[:, None], 0, tbl)          # free the slots
        free = tbl[:, C_ACTIVE] == 0

        # The waiting queue is the arrived-but-unserved request set; the
        # ``taken`` vector (not a scalar pointer) lets sheds punch holes
        # in arrival order.
        waiting = (st["taken"] == 0) & (arrivals <= t)
        expired_wait = waiting & (deadlines < t)
        admissible = waiting & ~expired_wait
        adm_rank = jnp.cumsum(admissible.astype(jnp.int32)) - 1
        n_free = jnp.sum(free.astype(jnp.int32))
        k = jnp.minimum(jnp.sum(admissible.astype(jnp.int32)), n_free)
        admit_req = admissible & (adm_rank < k)
        # Queue overflow: admissible requests that would sit deeper than
        # queue_depth behind this firing's k admissions are shed.
        overflow = admissible & (adm_rank >= k + qd)

        # Shed/timeout records ride the free rows of the fin output —
        # at most B - n_fin per firing, the rest stay queued (graceful
        # backlog, never silent drops).
        to_shed = expired_wait | overflow
        shed_status = jnp.where(expired_wait, STATUS_TIMEOUT, STATUS_SHED)
        shed_rank = jnp.cumsum(to_shed.astype(jnp.int32)) - 1
        n_room = B - n_fin
        emit = to_shed & (shed_rank < n_room)
        n_shed = jnp.sum(emit.astype(jnp.int32))
        # Scatter-by-rank: j-th emitted shed lands in the j-th fin-free
        # row (out-of-range indices drop, so ranks >= B are inert).
        req_by_rank = jnp.zeros((B,), jnp.int32).at[
            jnp.where(emit, shed_rank, B)].set(idx, mode="drop")
        room = ~fin_mask
        room_rank = jnp.cumsum(room.astype(jnp.int32)) - 1
        take = room & (room_rank < n_shed)
        sreq = req_by_rank[jnp.clip(room_rank, 0, B - 1)]
        shed_header = jnp.stack([
            jnp.zeros((B,), jnp.int32),           # ACTIVE
            sreq,                                 # REQ
            jnp.zeros((B,), jnp.int32),           # POS
            jnp.zeros((B,), jnp.int32),           # PROD
            budgets[sreq],                        # BUDGET
            jnp.ones((B,), jnp.int32),            # FIN (collected by retire)
            jnp.zeros((B,), jnp.int32),           # LAST
            jnp.zeros((B,), jnp.int32),           # NEW
            t - arrivals[sreq],                   # LAT: age at shed
            shed_status[jnp.clip(sreq, 0, R - 1)],  # STATUS
            deadlines[sreq],                      # DEADLINE
            jnp.zeros((B,), jnp.int32),           # AGE
        ], axis=1)
        shed_rows = jnp.concatenate(
            [shed_header, jnp.zeros((B, P + N), jnp.int32)], axis=1)
        fin_rows = jnp.where(take[:, None], shed_rows, fin_rows)

        # j-th free slot takes the j-th admissible request (arrival
        # order; with no deadlines and unbounded queue this reduces to
        # the PR 7 contiguous-pointer admission bit-for-bit).
        free_rank = jnp.cumsum(free.astype(jnp.int32)) - 1
        admit = free & (free_rank < k)
        req_by_arank = jnp.zeros((B,), jnp.int32).at[
            jnp.where(admit_req, adm_rank, B)].set(idx, mode="drop")
        newreq = jnp.clip(req_by_arank[jnp.clip(free_rank, 0, B - 1)],
                          0, R - 1)
        header = jnp.stack([
            jnp.ones((B,), jnp.int32),            # ACTIVE
            newreq,                               # REQ
            jnp.full((B,), P - 1, jnp.int32),     # POS (P + produced - 1)
            jnp.zeros((B,), jnp.int32),           # PROD
            budgets[newreq],                      # BUDGET
            jnp.zeros((B,), jnp.int32),           # FIN
            jnp.zeros((B,), jnp.int32),           # LAST
            jnp.ones((B,), jnp.int32),            # NEW
            jnp.zeros((B,), jnp.int32),           # LAT
            jnp.full((B,), STATUS_OK, jnp.int32),  # STATUS
            deadlines[newreq],                    # DEADLINE
            jnp.zeros((B,), jnp.int32),           # AGE
        ], axis=1)
        new_rows = jnp.concatenate(
            [header, prompts[newreq], jnp.zeros((B, N), jnp.int32)], axis=1)
        tbl = jnp.where(admit[:, None], new_rows, tbl)
        n_active = jnp.sum((tbl[:, C_ACTIVE] > 0).astype(jnp.int32))
        n_out = n_fin + n_shed
        # ONE broadcast token: every control port gets the same traced
        # value, which is what lets the builder prove the feeder ports
        # equal and mark the xa/y/fina channels matched.
        ctl = jnp.stack([n_active, n_out, k])
        taken = jnp.where(admit_req | emit, 1, st["taken"])
        st = {"taken": taken, "t": t + 1,
              "retired": st["retired"] + n_out}
        return st, {"table": tbl, "x": tbl, "fin": fin_rows,
                    "c_gate": ctl, "c_dec": ctl, "c_merge": ctl,
                    "c_ret": ctl}

    admission = static_actor(
        "admission", ["fb"],
        ["table", "x", "fin", "c_gate", "c_dec", "c_merge", "c_ret"],
        admission_fire, init=admission_init,
        ready=lambda st: st["retired"] < R)

    # -- gate: rate-converts admission's static writes to dynamic reads --
    def gate_control(tok):
        return {"x": jnp.int32(1), "fin": jnp.int32(1),
                "xa": _on_active(tok), "fina": _on_fin(tok)}

    def gate_fire(st, ins, rates):
        del rates
        return st, {"xa": ins["x"][0], "fina": ins["fin"][0]}

    gate = dynamic_actor("gate", "c", gate_control, ["x", "fin"],
                         ["xa", "fina"], gate_fire)

    # -- decode: the model actor (KV caches as actor state) --------------
    zero_batch = {"tokens": jnp.zeros((B, P), jnp.int32)}

    def _prefill(batch):
        return lm_mod.prefill(params, cfg, batch, kernel_impl=kernel_impl,
                              max_cache_len=cache_len)

    _, cache_t = jax.eval_shape(_prefill, zero_batch)
    _, cache_t2 = jax.eval_shape(
        lambda b: lm_mod.prefill(params, cfg, b, kernel_impl=kernel_impl,
                                 max_cache_len=cache_len),
        {"tokens": jnp.zeros((B + 1, P), jnp.int32)})
    cache_axes = _batch_axes(cache_t, cache_t2)

    def decode_init():
        return jax.tree.map(lambda l: jnp.zeros(l.shape, l.dtype), cache_t)

    def decode_control(tok):
        on = _on_active(tok)
        return {"x": on, "y": on}

    def decode_fire(caches, ins, rates):
        del rates
        tbl = ins["x"][0]
        isnew = tbl[:, C_NEW] > 0
        last = tbl[:, C_LAST]
        pos = tbl[:, C_POS]
        prompt_rows = tbl[:, HEADER:HEADER + P]

        def do_prefill(_):
            lg, fresh = _prefill(
                {"tokens": jnp.where(isnew[:, None], prompt_rows, 0)})
            return jnp.argmax(lg, axis=-1).astype(jnp.int32), fresh

        def no_prefill(_):
            return jnp.zeros((B,), jnp.int32), caches

        tok0, fresh = jax.lax.cond(jnp.any(isnew), do_prefill, no_prefill,
                                   None)
        # decode_step runs on the PRE-merge caches: newly prefilled rows
        # must keep their fresh cache rows, not a decode write at a stale
        # position.  Rows are independent, so the continuing rows see
        # exactly the cache content the legacy engine would feed them.
        lg, dec = lm_mod.decode_step(params, cfg, last[:, None], pos,
                                     caches, kernel_impl=kernel_impl)
        tokd = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        new_caches = _select_rows(isnew, cache_axes, fresh, dec)
        return new_caches, {"y": jnp.where(isnew, tok0, tokd)}

    decode = dynamic_actor("decode", "c", decode_control, ["x"], ["y"],
                           decode_fire, init=decode_init,
                           cost_flops=2 * cfg.d_model * cfg.d_model
                           * max(cfg.n_layers, 1) * B)

    # -- merge: fold tokens into the table, detect EOS/budget ------------
    def merge_control(tok):
        return {"table": jnp.int32(1), "y": _on_active(tok),
                "fb": jnp.int32(1)}

    def merge_fire(st, ins, rates):
        # A rate-0 idle step never reaches this body (table/fb would be
        # the only enabled ports, and y's window is all that changes the
        # table) — but the executor still runs it when any port is
        # enabled, so the y window must be masked by the active flags.
        del rates
        tbl = ins["table"][0]
        y = ins["y"][0]
        active = tbl[:, C_ACTIVE] > 0
        produced = tbl[:, C_PROD]
        gen_cols = jnp.arange(N, dtype=jnp.int32)[None, :]
        gen = tbl[:, HEADER + P:]
        gen = jnp.where(active[:, None] & (gen_cols == produced[:, None]),
                        y[:, None], gen)
        produced = produced + active.astype(jnp.int32)
        fin = active & ((y == eos) | (produced >= tbl[:, C_BUDGET]))
        header = jnp.stack([
            (active & ~fin).astype(jnp.int32),                    # ACTIVE
            tbl[:, C_REQ],
            tbl[:, C_POS] + active.astype(jnp.int32),             # POS
            produced,
            tbl[:, C_BUDGET],
            fin.astype(jnp.int32),                                # FIN
            jnp.where(active, y, tbl[:, C_LAST]),                 # LAST
            jnp.zeros((B,), jnp.int32),                           # NEW
            tbl[:, C_LAT],
            tbl[:, C_STATUS],                       # STATUS (OK on EOS fin)
            tbl[:, C_DEADLINE],
            tbl[:, C_AGE] + active.astype(jnp.int32),             # AGE
        ], axis=1)
        fb = jnp.concatenate([header, tbl[:, HEADER:HEADER + P], gen],
                             axis=1)
        return st, {"fb": fb}

    merge = dynamic_actor("merge", "c", merge_control, ["table", "y"],
                          ["fb"], merge_fire)

    # -- retire: dynamic sink collecting finished sequences --------------
    def retire_init():
        return {"gen": jnp.zeros((R, N), jnp.int32),
                "lens": jnp.zeros((R,), jnp.int32),
                "lat": jnp.zeros((R,), jnp.int32),
                "status": jnp.zeros((R,), jnp.int32),
                "done": jnp.zeros((R,), jnp.int32)}

    def retire_control(tok):
        return {"fin": _on_fin(tok)}

    def retire_fire(st, ins, rates):
        del rates
        rows = ins["fin"][0]
        m = rows[:, C_FIN] > 0
        req = jnp.where(m, rows[:, C_REQ], R)     # out of range -> dropped
        gen = rows[:, HEADER + P:]
        return {
            "gen": st["gen"].at[req].set(gen, mode="drop"),
            "lens": st["lens"].at[req].set(rows[:, C_PROD], mode="drop"),
            "lat": st["lat"].at[req].set(rows[:, C_LAT], mode="drop"),
            "status": st["status"].at[req].set(rows[:, C_STATUS],
                                               mode="drop"),
            "done": st["done"].at[req].set(1, mode="drop"),
        }, {}

    retire = dynamic_actor("retire", "c", retire_control, ["fin"], [],
                           retire_fire, init=retire_init,
                           finish=lambda st: st)

    # -- wiring ----------------------------------------------------------
    b = NetworkBuilder()
    for spec in (admission, gate, decode, merge, retire):
        b.actor(spec)
    tbl_shape, tok_i32 = (B, W), jnp.int32
    # The delay-token feedback FIFO carrying the per-slot decode state;
    # its initial token is the empty slot table.  delay (1) >= rate (1),
    # so the loop-carry channel may legally cross a partition boundary —
    # grid cores or mesh devices (ExecutionPlan(devices=k), see
    # repro.core.shard) — and the whole serving graph shards without a
    # device_assign constraint.
    # Slot-table channels declare SLOT_DOMAIN + the request-id column:
    # guarded runs flag a poisoned row with the DOMAIN fault bit the
    # moment admission writes it, and row_id_col lets fault / feed
    # reports name the offending request, not just the channel.
    slot_kw = dict(token_shape=tbl_shape, dtype=tok_i32,
                   domain=SLOT_DOMAIN, row_id_col=C_REQ)
    b.connect("merge.fb", "admission.fb", delay=1,
              initial_token=jnp.zeros(tbl_shape, jnp.int32), name="fb",
              **slot_kw)
    b.connect("admission.table", "merge.table", name="table", **slot_kw)
    b.connect("admission.x", "gate.x", name="x", **slot_kw)
    b.connect("admission.fin", "gate.fin", name="fin", **slot_kw)
    b.connect("gate.xa", "decode.x", name="xa", **slot_kw)
    b.connect("decode.y", "merge.y", token_shape=(B,), dtype=tok_i32,
              domain=SLOT_DOMAIN, name="y")
    b.connect("gate.fina", "retire.fin", name="fina", **slot_kw)
    for ctl_port, actor in (("c_gate", "gate"), ("c_dec", "decode"),
                            ("c_merge", "merge"), ("c_ret", "retire")):
        b.connect(f"admission.{ctl_port}", f"{actor}.c", token_shape=(3,),
                  dtype=tok_i32, name=f"ctl_{actor}")
    # Declared accept/EOS rate bounds (PRUNE-style): admission can admit
    # 0..B requests per firing, a slot's decode/retire ports are enabled
    # in 0..all firings — the matched-rates derivation tightens these to
    # "balanced" per channel, but the declaration documents the intended
    # envelope and keeps check_bounds decidable if a wiring change ever
    # drops a matched proof.
    for ep in ("gate.xa", "decode.x", "decode.y", "merge.y",
               "gate.fina", "retire.fin"):
        b.rate_bounds(ep, 0.0, 1.0)
    net = b.build(check_bounds=check_bounds)
    if return_bounds:
        return net, (b.bounds_report if check_bounds else b.check_bounds())
    return net


# --------------------------------------------------------------------- #
# Fault -> request mapping (the quarantine half of the resilience layer).
# --------------------------------------------------------------------- #
def faulted_requests(network: Network, err: Exception,
                     workload: ServingWorkload) -> List[int]:
    """Map a guarded serving fault back to the offending request ids.

    Only ``DOMAIN`` faults are mappable — they mean a slot-table row held
    values outside ``SLOT_DOMAIN``, which (for the fault classes the
    serving layer models, see ``faultinject.poison_request``) can only
    have entered through the staged workload.  Two mapping passes:

    * **primary** — scan the staged slabs themselves.  The guarded
      executor runs to quiescence before raising, so the poisoned row may
      have transited (and left) several rings; the workload is the one
      place the culprit is guaranteed to still be visible.
    * **secondary** — if partial state survived (``err.result.state``),
      scan the resident windows of each DOMAIN-faulting channel that
      declared a ``row_id_col``: out-of-domain rows vote with their
      request-id column.  Catches corruption injected *after* staging
      (e.g. ``faultinject.poison_tokens`` on a live ring).

    Returns sorted unique request ids; empty when the fault carries no
    DOMAIN bit (non-request faults — overflow, stall — are not a
    request's fault and must not quarantine anyone).
    """
    diag = getattr(err, "diagnostics", None)
    faults = diag.faults if diag is not None else ()
    dom = [f for f in faults if "DOMAIN" in f.faults]
    if not dom:
        return []
    lo, hi = SLOT_DOMAIN
    R = int(workload.prompts.shape[0])
    culprits: set = set()

    prompts = np.asarray(workload.prompts)
    bad_rows = np.any((prompts < lo) | (prompts > hi), axis=1)
    culprits.update(int(i) for i in np.nonzero(bad_rows)[0])
    for slab in (workload.budgets, workload.arrivals):
        vals = np.asarray(slab)
        bad = (vals < lo) | (vals > hi)
        culprits.update(int(i) for i in np.nonzero(bad)[0])

    state = getattr(getattr(err, "result", None), "state", None)
    if state is not None:
        for f in dom:
            spec = network.fifos.get(f.fifo)
            if spec is None or spec.row_id_col is None:
                continue
            buf = np.asarray(state.fifo(f.fifo).buf)
            if buf.ndim < 2:
                continue
            rows = buf.reshape(-1, buf.shape[-1])
            bad = np.any((rows < lo) | (rows > hi), axis=1)
            for r in np.nonzero(bad)[0]:
                rid = int(rows[r, spec.row_id_col])
                if 0 <= rid < R:
                    culprits.add(rid)
    return sorted(culprits)
