"""The Motion Detection application — paper §4.1, Fig. 4.

Five actors: Source -> Gauss -> Thres -> Med -> Sink.  Gauss feeds Thres
through *two* channels, one of which carries an initial (delay) token: the
one-frame delay that enables consecutive-frame subtraction (the dotted
channel in Fig. 4).

Frame size 320x240, 8-bit grayscale: FIFO tokens are uint8 frames of
76 800 bytes exactly as in the paper (so Eq. 1 reproduces Table 1's buffer
memory); arithmetic inside actors runs in f32 and is rounded back to u8 at
every port — the 8-bit inter-actor contract of the original.  Token rate
r=1 for GPP-style execution, r=4 for the accelerated configuration
(paper §4.3).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Network, NetworkBuilder, static_actor
from repro.kernels.gauss5x5 import gauss5x5
from repro.kernels.motion_post import DEFAULT_THRESHOLD, med_ref, thres_ref

FRAME_H, FRAME_W = 240, 320


def build_motion_detection(n_frames: int, rate: int = 1,
                           frame_hw: Tuple[int, int] = (FRAME_H, FRAME_W),
                           threshold: float = DEFAULT_THRESHOLD,
                           video: Optional[jax.Array] = None,
                           gauss_impl: str = "xla") -> Network:
    """Build the 5-actor MD network for ``n_frames`` total frames.

    ``n_frames`` must be divisible by ``rate`` (windows of ``rate`` frames
    per firing).  ``video``: optional (n_frames, H, W) f32 array staged
    into the source actor; defaults to zeros (benchmarks stage real data
    via the source state).
    """
    H, W = frame_hw
    if n_frames % rate:
        raise ValueError(f"n_frames={n_frames} not divisible by rate={rate}")
    n_iter = n_frames // rate
    tok = (H, W)

    def to_u8(x):
        return jnp.clip(jnp.round(x), 0, 255).astype(jnp.uint8)

    def src_fire(state, inputs, rates):
        data, idx = state
        win = jax.lax.dynamic_slice_in_dim(data, idx * rate, rate, axis=0)
        return (data, idx + 1), {"out": win}

    def src_init():
        data = video if video is not None else jnp.zeros((n_frames, H, W), jnp.uint8)
        return (to_u8(jnp.asarray(data)), jnp.int32(0))

    source = static_actor("source", (), ("out",), src_fire, init=src_init,
                          ready=lambda st: st[1] < n_iter)

    def gauss_fire(state, inputs, rates):
        del rates
        out = jax.vmap(lambda f: gauss5x5(f, impl=gauss_impl))(
            inputs["in"].astype(jnp.float32))
        out = to_u8(out)
        # One filtered stream feeds two channels (direct + delayed).
        return state, {"out": out, "out_d": out}

    gauss = static_actor("gauss", ("in",), ("out", "out_d"), gauss_fire,
                         cost_flops=rate * H * W * 10 * 2)  # separable 5+5 MACs

    def thres_fire(state, inputs, rates):
        del rates
        out = jax.vmap(lambda c, p: thres_ref(c, p, threshold))(
            inputs["cur"].astype(jnp.float32), inputs["prev"].astype(jnp.float32))
        return state, {"out": to_u8(out)}

    thres = static_actor("thres", ("cur", "prev"), ("out",), thres_fire,
                         cost_flops=rate * H * W * 3)

    def med_fire(state, inputs, rates):
        del rates
        out = jax.vmap(med_ref)(inputs["in"].astype(jnp.float32))
        return state, {"out": to_u8(out)}

    med = static_actor("med", ("in",), ("out",), med_fire,
                       cost_flops=rate * H * W * 12)

    def sink_fire(state, inputs, rates):
        del rates
        data, idx = state
        data = jax.lax.dynamic_update_slice_in_dim(data, inputs["in"], idx * rate, axis=0)
        return (data, idx + 1), {}

    sink = static_actor("sink", ("in",), (), sink_fire,
                        init=lambda: (jnp.zeros((n_frames, H, W), jnp.uint8), jnp.int32(0)),
                        finish=lambda st: st[0])

    u8 = jnp.uint8
    b = NetworkBuilder()
    b.actors(source, gauss, thres, med, sink)
    b.connect("source.out", "gauss.in", rate=rate, token_shape=tok, dtype=u8,
              name="f_src_gauss")
    b.connect("gauss.out", "thres.cur", rate=rate, token_shape=tok, dtype=u8,
              name="f_gauss_thres")
    # The dotted Fig. 4 channel: one initial (delay) token -> Eq. 1 triple
    # buffer, enabling consecutive-frame subtraction.
    b.connect("gauss.out_d", "thres.prev", rate=rate, token_shape=tok,
              dtype=u8, delay=1, name="f_gauss_thres_d")
    b.connect("thres.out", "med.in", rate=rate, token_shape=tok, dtype=u8,
              name="f_thres_med")
    b.connect("med.out", "sink.in", rate=rate, token_shape=tok, dtype=u8,
              name="f_med_sink")
    return b.build()


def bench_workload(n_frames: int, rate: int = 4,
                   frame_hw: Tuple[int, int] = (FRAME_H, FRAME_W),
                   seed: int = 0, **build_kw) -> Network:
    """MD network staged with reproducible random frames.

    Shared by benchmarks/bench_executors.py and tests/test_perf_smoke.py.
    All channels here sit between static actors, so the specialized
    executor keeps them ring-buffered with trace-time phase offsets
    (period = LCM(2, 3) over the double buffers and the Fig. 2 delayed
    triple buffer); only the fps accounting lives here.
    """
    rng = np.random.default_rng(seed)
    video = jnp.asarray(
        rng.uniform(0, 255, (n_frames,) + tuple(frame_hw)).astype(np.float32))
    return build_motion_detection(n_frames, rate=rate, frame_hw=frame_hw,
                                  video=video, **build_kw)
