"""LM blocks as pipeline stages over a mesh axis — the paper's
heterogeneous actor-to-processor mapping applied to a transformer.

Each pipeline *stage* is a run of LM blocks (an actor in the paper's
sense); stage-to-stage activations are rate-r FIFO channels realized as
the double-buffered `ppermute` of ``repro.core.pipeline_spmd`` (Eq. 1's
2r capacity == the send/recv pair).  This is the third distribution mode
of the framework next to pjit DP/TP and the dataflow executors, and the
building block for PP × DP × TP meshes at >2 pods.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.pipeline import pipeline_reference, pipeline_spmd
from repro.models import lm as lm_mod
from repro.models.lm import _block_apply, layer_plan

PyTree = Any


def stack_stage_params(params: PyTree, cfg: ArchConfig, n_stages: int) -> PyTree:
    """Regroup the scan-stacked layer groups into ``n_stages`` pipeline
    stages: leaves (n_groups, ...) -> (n_stages, groups_per_stage, ...)."""
    cycle, n_groups, rest = layer_plan(cfg)
    if rest:
        raise ValueError("pipeline stages need rest-free layer plans")
    if n_groups % n_stages:
        raise ValueError(f"{n_groups} groups not divisible into {n_stages} stages")
    per = n_groups // n_stages
    return jax.tree.map(
        lambda l: l.reshape((n_stages, per) + l.shape[1:]), params["groups"])


def make_stage_fn(cfg: ArchConfig):
    """(stage_params, x) -> x: apply this stage's layer groups."""
    cycle, _, _ = layer_plan(cfg)

    def stage_fn(stage_params, x):
        per = jax.tree.leaves(stage_params)[0].shape[0]

        def group_body(x, gp):
            for i, kind in enumerate(cycle):
                x, _, _ = _block_apply(cfg, kind, gp[f"c{i}"], x[None],
                                       mode="train")
                x = x[0]
            return x, None

        x, _ = jax.lax.scan(group_body, x, stage_params)
        return x

    return stage_fn


def pipeline_forward(params: PyTree, cfg: ArchConfig, tokens: jax.Array,
                     mesh, n_stages: int, axis: str = "stage") -> jax.Array:
    """Full forward with the block stack distributed as pipeline stages.

    tokens: (n_micro, S) — one sequence per microbatch (the GPipe schedule
    streams them through the stages; B + S - 1 ticks).
    Embedding/unembedding run replicated outside the pipeline (they are
    the source/sink actors of the network).
    """
    from repro.models.layers import embed_lookup, rmsnorm, DTYPE
    x = embed_lookup(params["embed"]["w"], tokens).astype(DTYPE)
    if cfg.tie_embeddings:
        x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(DTYPE)
    stage_params = stack_stage_params(params, cfg, n_stages)
    y = pipeline_spmd(make_stage_fn(cfg), stage_params, x, mesh, axis=axis)
    y = rmsnorm(params["final_norm"], y, cfg.rms_eps)
    head = params["embed"]["w"] if cfg.tie_embeddings else params["lm_head"]["w"]
    return lm_mod._unembed_masked(y, head, cfg)


def pipeline_forward_reference(params: PyTree, cfg: ArchConfig,
                               tokens: jax.Array, n_stages: int) -> jax.Array:
    """Oracle: same computation, sequential stages, no mesh."""
    from repro.models.layers import embed_lookup, rmsnorm, DTYPE
    x = embed_lookup(params["embed"]["w"], tokens).astype(DTYPE)
    if cfg.tie_embeddings:
        x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(DTYPE)
    stage_params = stack_stage_params(params, cfg, n_stages)
    y = pipeline_reference(make_stage_fn(cfg), stage_params, x)
    y = rmsnorm(params["final_norm"], y, cfg.rms_eps)
    head = params["embed"]["w"] if cfg.tie_embeddings else params["lm_head"]["w"]
    return lm_mod._unembed_masked(y, head, cfg)
