"""LM blocks as pipeline stages over a mesh axis — the paper's
heterogeneous actor-to-processor mapping applied to a transformer.

Each pipeline *stage* is a run of LM blocks (an actor in the paper's
sense); stage-to-stage activations are rate-r FIFO channels realized as
the double-buffered `ppermute` of ``repro.core.pipeline_spmd`` (Eq. 1's
2r capacity == the send/recv pair).  This is the third distribution mode
of the framework next to pjit DP/TP and the dataflow executors, and the
building block for PP × DP × TP meshes at >2 pods.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import Network, NetworkBuilder, static_actor
from repro.core.pipeline import pipeline_reference, pipeline_spmd
from repro.models import lm as lm_mod
from repro.models.lm import _block_apply, layer_plan

PyTree = Any


def stack_stage_params(params: PyTree, cfg: ArchConfig, n_stages: int) -> PyTree:
    """Regroup the scan-stacked layer groups into ``n_stages`` pipeline
    stages: leaves (n_groups, ...) -> (n_stages, groups_per_stage, ...)."""
    cycle, n_groups, rest = layer_plan(cfg)
    if rest:
        raise ValueError("pipeline stages need rest-free layer plans")
    if n_groups % n_stages:
        raise ValueError(f"{n_groups} groups not divisible into {n_stages} stages")
    per = n_groups // n_stages
    return jax.tree.map(
        lambda l: l.reshape((n_stages, per) + l.shape[1:]), params["groups"])


def make_stage_fn(cfg: ArchConfig):
    """(stage_params, x) -> x: apply this stage's layer groups."""
    cycle, _, _ = layer_plan(cfg)

    def stage_fn(stage_params, x):
        per = jax.tree.leaves(stage_params)[0].shape[0]

        def group_body(x, gp):
            for i, kind in enumerate(cycle):
                x, _, _ = _block_apply(cfg, kind, gp[f"c{i}"], x[None],
                                       mode="train")
                x = x[0]
            return x, None

        x, _ = jax.lax.scan(group_body, x, stage_params)
        return x

    return stage_fn


def pipeline_forward(params: PyTree, cfg: ArchConfig, tokens: jax.Array,
                     mesh, n_stages: int, axis: str = "stage") -> jax.Array:
    """Full forward with the block stack distributed as pipeline stages.

    tokens: (n_micro, S) — one sequence per microbatch (the GPipe schedule
    streams them through the stages; B + S - 1 ticks).
    Embedding/unembedding run replicated outside the pipeline (they are
    the source/sink actors of the network).
    """
    from repro.models.layers import embed_lookup, rmsnorm, DTYPE
    x = embed_lookup(params["embed"]["w"], tokens).astype(DTYPE)
    if cfg.tie_embeddings:
        x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(DTYPE)
    stage_params = stack_stage_params(params, cfg, n_stages)
    y = pipeline_spmd(make_stage_fn(cfg), stage_params, x, mesh, axis=axis)
    y = rmsnorm(params["final_norm"], y, cfg.rms_eps)
    head = params["embed"]["w"] if cfg.tie_embeddings else params["lm_head"]["w"]
    return lm_mod._unembed_masked(y, head, cfg)


def build_lm_stage_network(params: PyTree, cfg: ArchConfig,
                           tokens: jax.Array, n_stages: int) -> Network:
    """The pipeline expressed literally as a paper-MoC actor network.

    One *stage* of LM blocks = one static actor; microbatch activations
    flow source -> stage_0 -> ... -> stage_{n-1} -> sink over rate-1
    channels whose tokens are whole ``(S, D)`` activation windows (the
    FIFO double buffer is Eq. 1's 2r capacity — exactly the send/recv
    pair ``pipeline_spmd`` realizes as a ``ppermute``).  ``tokens`` is
    ``(n_micro, S)``: one sequence per microbatch; embedding runs at
    build time (the host-side source), unembedding in the caller (see
    :func:`lm_stage_network_forward`).

    Unlike ``pipeline_spmd`` this network runs under any
    :class:`ExecutionPlan` — including ``accelerated=[stages...]`` with
    chunked :meth:`Program.stream` feeds — making the LM pipeline the
    fourth paper graph on the unified construction/execution surface.
    """
    from repro.models.layers import embed_lookup, DTYPE
    x = embed_lookup(params["embed"]["w"], tokens).astype(DTYPE)
    if cfg.tie_embeddings:
        x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(DTYPE)
    stage_params = stack_stage_params(params, cfg, n_stages)
    stage_fn = make_stage_fn(cfg)
    n_micro, S, D = x.shape

    def src_fire(state, inputs, rates):
        data, idx = state
        win = jax.lax.dynamic_index_in_dim(data, idx, axis=0, keepdims=False)
        return (data, idx + 1), {"out": win[None]}

    source = static_actor("source", (), ("out",), src_fire,
                          init=lambda: (x, jnp.int32(0)),
                          ready=lambda st: st[1] < n_micro)

    def sink_fire(state, inputs, rates):
        data, idx = state
        data = jax.lax.dynamic_update_index_in_dim(data, inputs["in"][0],
                                                   idx, axis=0)
        return (data, idx + 1), {}

    sink = static_actor("sink", ("in",), (), sink_fire,
                        init=lambda: (jnp.zeros((n_micro, S, D), x.dtype),
                                      jnp.int32(0)),
                        finish=lambda st: st[0])

    b = NetworkBuilder()
    b.actor(source)
    prev = "source.out"
    for s in range(n_stages):
        p_s = jax.tree.map(lambda l: l[s], stage_params)
        n_params = sum(int(l.size) for l in jax.tree.leaves(p_s))

        def fire(state, inputs, rates, p_s=p_s):
            return state, {"out": stage_fn(p_s, inputs["in"][0])[None]}

        b.actor(static_actor(f"stage{s}", ("in",), ("out",), fire,
                             cost_flops=2 * S * n_params))
        b.connect(prev, f"stage{s}.in", token_shape=(S, D), dtype=x.dtype,
                  name=f"f_s{s}")
        prev = f"stage{s}.out"
    b.actor(sink)
    b.connect(prev, "sink.in", token_shape=(S, D), dtype=x.dtype,
              name="f_out")
    return b.build()


def lm_stage_network_forward(params: PyTree, cfg: ArchConfig,
                             tokens: jax.Array, n_stages: int,
                             plan: Optional[Any] = None) -> jax.Array:
    """Forward pass through the stage actor network -> logits.

    Equivalent to :func:`pipeline_forward_reference` (tested in
    tests/test_graphs_paper.py) but executed by the dataflow runtime:
    builds the network, compiles it under ``plan`` (default: static
    schedule over the microbatches), collects the sink, applies final
    norm + unembedding.
    """
    from repro.models.layers import rmsnorm
    net = build_lm_stage_network(params, cfg, tokens, n_stages)
    n_micro = int(tokens.shape[0])
    if plan is None:
        prog = net.compile(mode="static", n_iterations=n_micro)
    else:
        if plan.accelerated is not None:
            # A heterogeneous plan replaces the staged source with a
            # zero-filled feed actor; run() would silently produce logits
            # of zero activations.  Streaming callers drive the network
            # through build_lm_stage_network + Program.stream directly.
            raise ValueError(
                "lm_stage_network_forward: plans with accelerated=[...] "
                "need explicit feeds; use build_lm_stage_network(...)"
                ".compile(plan).stream(...) instead")
        prog = net.compile(plan, n_iterations=n_micro)
    y = prog.collect("sink", prog.run().state)
    y = rmsnorm(params["final_norm"], y, cfg.rms_eps)
    head = params["embed"]["w"] if cfg.tie_embeddings else params["lm_head"]["w"]
    return lm_mod._unembed_masked(y, head, cfg)


def pipeline_forward_reference(params: PyTree, cfg: ArchConfig,
                               tokens: jax.Array, n_stages: int) -> jax.Array:
    """Oracle: same computation, sequential stages, no mesh."""
    from repro.models.layers import embed_lookup, rmsnorm, DTYPE
    x = embed_lookup(params["embed"]["w"], tokens).astype(DTYPE)
    if cfg.tie_embeddings:
        x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(DTYPE)
    stage_params = stack_stage_params(params, cfg, n_stages)
    y = pipeline_reference(make_stage_fn(cfg), stage_params, x)
    y = rmsnorm(params["final_norm"], y, cfg.rms_eps)
    head = params["embed"]["w"] if cfg.tie_embeddings else params["lm_head"]["w"]
    return lm_mod._unembed_masked(y, head, cfg)
