"""The MoE layer expressed literally as a dynamic-data-rate actor network.

This is the bridge between the paper's MoC and the LM substrate
(DESIGN.md §3): one *router* (control) actor and E *expert* (dynamic)
actors.  Per firing (= one token batch):

  router:   consumes the token window, emits (a) one control token per
            expert carrying that expert's per-firing token count
            (0..capacity — the paper's rate-{0, r} restriction realized as
            a masked fixed-capacity window), and (b) the dispatched token
            slabs on its data ports;
  expert_e: dynamic actor — control token disables the firing entirely
            when no tokens routed (lax.cond skips the FFN, the paper's 5x
            mechanism); otherwise consumes its (capacity, D) slab, applies
            its FFN, and emits the processed slab;
  combine:  consumes all expert slabs + the routing metadata and
            reconstitutes the (N, D) output with combine weights.

``moe_actor_network`` is semantically equivalent to
``repro.models.moe.moe_layer`` (tested in tests/test_moe_actors.py) —
the einsum/scatter implementation is the *fused accelerated* form of this
network, exactly like the paper's OpenCL kernels are the accelerated form
of its C actors.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import Network, NetworkBuilder, dynamic_actor, static_actor
from repro.core.actor import apply_rate_gate
from repro.models.layers import F32
from repro.models.moe import capacity_for


def build_moe_network(params: Dict[str, jax.Array], n_tokens: int, d_model: int,
                      top_k: int, capacity_factor: float,
                      n_firings: int, token_stream: jax.Array) -> Network:
    """Actor network for one MoE layer processing ``n_firings`` windows of
    ``n_tokens`` tokens each.  ``token_stream``: (n_firings*n_tokens, D)."""
    E = params["router"].shape[1]
    C = capacity_for(n_tokens, E, top_k, capacity_factor)
    N = n_tokens

    # ------------------------------------------------------------------ #
    def src_fire(state, inputs, rates):
        data, idx = state
        win = jax.lax.dynamic_slice_in_dim(data, idx * N, N, axis=0)
        return (data, idx + 1), {"out": win[None]}

    source = static_actor(
        "source", (), ("out",), src_fire,
        init=lambda: (jnp.asarray(token_stream), jnp.int32(0)),
        ready=lambda st: st[1] < n_firings)

    # ------------------------------------------------------------------ #
    # Router: control actor. Emits per-expert counts (control tokens),
    # dispatched slabs, and combine metadata.
    # ------------------------------------------------------------------ #
    def route(xt):
        logits = (xt @ params["router"]).astype(F32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_w, gate_e = jax.lax.top_k(probs, top_k)
        gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)
        onehot = jax.nn.one_hot(gate_e, E, dtype=jnp.int32)
        flat = onehot.reshape(N * top_k, E)
        ranks = (jnp.cumsum(flat, axis=0) - flat).reshape(N, top_k, E)
        rank = jnp.sum(ranks * onehot, axis=-1)
        keep = rank < C
        slot = jnp.where(keep, gate_e * C + rank, E * C)
        dispatch = jnp.zeros((E * C + 1, xt.shape[1]), xt.dtype)
        dispatch = dispatch.at[slot.reshape(-1)].add(
            jnp.repeat(xt, top_k, axis=0).reshape(N * top_k, -1))
        slabs = dispatch[:-1].reshape(E, C, -1)
        counts = jnp.sum(jax.nn.one_hot(gate_e, E, dtype=jnp.int32)
                         * keep[..., None], axis=(0, 1))
        w = (gate_w * keep.astype(F32))
        return slabs, counts, slot, w

    # Router out ports: per-expert slabs + control counts (one copy for the
    # expert, one for the packer feeding combine), routing metadata.
    rt_outs = (tuple(f"x{e}" for e in range(E))
               + tuple(f"c{e}" for e in range(E))
               + ("slot", "w")
               + tuple(f"c{e}_p" for e in range(E)))

    def router_fire(state, inputs, rates):
        xt = inputs["in"][0]
        slabs, counts, slot, w = route(xt)
        outs = {f"x{e}": slabs[e][None] for e in range(E)}
        outs.update({f"c{e}": counts[e].reshape(1, 1) for e in range(E)})
        outs.update({f"c{e}_p": counts[e].reshape(1, 1) for e in range(E)})
        outs["slot"] = slot[None].astype(jnp.int32)
        outs["w"] = w[None]
        return state, outs

    router = static_actor("router", ("in",), rt_outs, router_fire)

    # ------------------------------------------------------------------ #
    # Experts: dynamic actors — control token = routed count (rate 0 or r).
    # ------------------------------------------------------------------ #
    def make_expert(e: int):
        def control(tok):
            on = (tok[0] > 0).astype(jnp.int32)
            return {"in": on, "out": on}

        def fire(state, inputs, rates):
            slab = inputs["in"][0]                      # (C, D)
            g = jax.nn.silu((slab @ params["we_gate"][e]).astype(F32)).astype(slab.dtype)
            u = slab @ params["we_up"][e]
            y = (g * u) @ params["we_down"][e]
            return state, {"out": y[None]}

        return dynamic_actor(f"expert{e}", "c", control, ("in",), ("out",), fire)

    experts = [make_expert(e) for e in range(E)]

    # ------------------------------------------------------------------ #
    # Combine: rates of expert inputs mirror the expert enables, so the
    # combine actor is dynamic too (same mask derived from its own control
    # stream — the router broadcasts counts to it as a packed token).
    # ------------------------------------------------------------------ #
    def comb_control(tok):
        d = {f"y{e}": (tok[e] > 0).astype(jnp.int32) for e in range(E)}
        d.update({"slot": jnp.int32(1), "w": jnp.int32(1), "out": jnp.int32(1)})
        return d

    def comb_fire(state, inputs, rates):
        # Note the expert channels here are deliberately NOT matched_rates:
        # the router always writes x_e while an idle expert skips reading,
        # so occupancies drift and the channels are not transient — they
        # must stay ring-buffered under the specialized static executor.
        y_flat = jnp.zeros((E * C + 1, d_model), token_stream.dtype)
        for e in range(E):
            gated = apply_rate_gate(rates[f"y{e}"], inputs[f"y{e}"][0])
            if gated is None:
                continue
            y_flat = jax.lax.dynamic_update_slice_in_dim(y_flat, gated, e * C, axis=0)
        slot = inputs["slot"][0]
        w = inputs["w"][0]
        per_k = y_flat[slot.reshape(-1)].reshape(N, top_k, d_model)
        y = jnp.einsum("nkd,nk->nd", per_k, w.astype(token_stream.dtype))
        return state, {"out": y[None]}

    comb_ins = tuple(f"y{e}" for e in range(E)) + ("slot", "w")
    combine = dynamic_actor("combine", "cc", comb_control, comb_ins, ("out",),
                            comb_fire)

    # router emits per-expert counts; combine wants one packed control
    # token — a small static packer actor concatenates them (padded to the
    # (2E,) control-token shape).
    def pack_fire(state, inputs, rates):
        vec = jnp.concatenate([inputs[f"c{e}"][0] for e in range(E)] * 2)[:2 * E]
        return state, {"out": vec[None]}

    packer = static_actor("packer", tuple(f"c{e}" for e in range(E)), ("out",),
                          pack_fire)

    def sink_fire(state, inputs, rates):
        data, idx = state
        data = jax.lax.dynamic_update_slice_in_dim(
            data, inputs["in"][0], idx * N, axis=0)
        return (data, idx + 1), {}

    sink = static_actor(
        "sink", ("in",), (), sink_fire,
        init=lambda: (jnp.zeros((n_firings * N, d_model), token_stream.dtype),
                      jnp.int32(0)),
        finish=lambda st: st[0])

    # ------------------------------------------------------------------ #
    # Wiring.  Note the expert data channels are *not* matched-rate
    # transient (the builder derivation correctly leaves them buffered):
    # the router always writes x_e while an idle expert skips reading, so
    # occupancies drift and the channels must stay ring-buffered under the
    # specialized static executor; combine's y_e enables are keyed on the
    # packer's control stream, not the experts' — structurally unprovable.
    D = d_model
    b = NetworkBuilder()
    b.actors(source, router, packer, *experts, combine, sink)
    b.connect("source.out", "router.in", token_shape=(N, D), name="f_in")
    b.connect("combine.out", "sink.in", token_shape=(N, D), name="f_out")
    b.connect("router.slot", "combine.slot", token_shape=(N, top_k),
              dtype=jnp.int32, name="f_slot")
    b.connect("router.w", "combine.w", token_shape=(N, top_k),
              dtype=jnp.float32, name="f_w")
    # combine's control token packs all counts; shape (2E,) rather than
    # (E,) exercises is_control token-shape freedom (combine reads tok[e]).
    b.connect("packer.out", "combine.cc", token_shape=(2 * E,), name="f_cpack")
    for e in range(E):
        b.connect(f"router.x{e}", f"expert{e}.in", token_shape=(C, D),
                  name=f"f_x{e}")
        b.connect(f"expert{e}.out", f"combine.y{e}", token_shape=(C, D),
                  name=f"f_y{e}")
        b.connect(f"router.c{e}", f"expert{e}.c", name=f"f_ce{e}")
        b.connect(f"router.c{e}_p", f"packer.c{e}", token_shape=(1,),
                  dtype=jnp.int32, name=f"f_cp{e}")
    return b.build()
