from repro.data.pipeline import DataConfig, FileTokens, SyntheticLM, make_source

__all__ = ["DataConfig", "FileTokens", "SyntheticLM", "make_source"]
