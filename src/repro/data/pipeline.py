"""Deterministic, index-seeded token data pipeline.

Restart discipline (fault tolerance): the pipeline is **stateless** — batch
``i`` is a pure function of ``(seed, i)`` — so a trainer restored from a
step-``k`` checkpoint replays batch ``k`` exactly, with no iterator state
to checkpoint (DESIGN.md §5).  Sources:

  * ``SyntheticLM``   — a fixed-seed Markov-ish token stream (benchmarks,
    smoke tests, the 100M example run);
  * ``FileTokens``    — memory-mapped token file (one uint32 stream),
    sharded per host: host h of H reads only its slice (the multi-host
    ingestion path; in this container H == 1).

Each batch is {"tokens": (B, S) i32, "labels": (B, S) i32} with labels =
next token.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1
    path: Optional[str] = None    # None -> synthetic


class SyntheticLM:
    """Deterministic pseudo-text: tokens follow a power-law unigram with a
    position-mixed hash — structured enough that a model visibly learns."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # Fixed power-law unigram distribution.
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        self.probs = (1.0 / ranks) / np.sum(1.0 / ranks)
        self.mix = rng.integers(1, cfg.vocab, 8)

    def batch(self, index: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        per_host = cfg.global_batch // cfg.n_hosts
        rng = np.random.default_rng(
            (cfg.seed, index, cfg.host_id))          # pure fn of (seed, i, host)
        toks = rng.choice(cfg.vocab, size=(per_host, cfg.seq_len + 1),
                          p=self.probs).astype(np.int64)
        # Inject learnable bigram structure: every odd position repeats a
        # hash of its predecessor.
        h = (toks[:, :-1] * int(self.mix[0]) + int(self.mix[1])) % cfg.vocab
        odd = np.arange(1, cfg.seq_len + 1, 2)
        toks[:, odd] = h[:, odd - 1]
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        i = 0
        while True:
            yield self.batch(i)
            i += 1


class FileTokens:
    """Memory-mapped uint32 token stream, deterministic strided batching."""

    def __init__(self, cfg: DataConfig):
        if cfg.path is None:
            raise ValueError("FileTokens needs a path")
        self.cfg = cfg
        self.data = np.memmap(cfg.path, dtype=np.uint32, mode="r")
        self.n_windows = (len(self.data) - 1) // cfg.seq_len

    def batch(self, index: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        per_host = cfg.global_batch // cfg.n_hosts
        rng = np.random.default_rng((cfg.seed, index))
        win = rng.permutation(self.n_windows)[:cfg.global_batch]
        win = win[cfg.host_id * per_host:(cfg.host_id + 1) * per_host]
        toks = np.stack([
            self.data[w * cfg.seq_len:w * cfg.seq_len + cfg.seq_len + 1]
            for w in win]).astype(np.int64)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}


def make_source(cfg: DataConfig):
    return FileTokens(cfg) if cfg.path else SyntheticLM(cfg)
