"""repro — dynamic data rate actor networks on TPU pods.

Reproduction + extension of Boutellier & Hautala (2016); see README.md.
"""
__version__ = "1.0.0"
