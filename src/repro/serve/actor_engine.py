"""Continuous-batching serving engine on the dynamic-rate actor runtime.

Drop-in counterpart to :class:`repro.serve.Engine` that runs the
admission/decode/retire actor network of :mod:`repro.graphs.serving`
under any dynamic-capable :class:`ExecutionPlan` (host-dynamic by
default, megakernel via ``plan=ExecutionPlan(mode="megakernel")``, or
sharded across a device mesh via ``plan=ExecutionPlan(mode="dynamic",
devices=k)`` — the serving network's slot-table feedback channel
carries ``delay >= rate``, so it may legally cross devices and the
engine's greedy tokens stay identical at every device count; see
:mod:`repro.core.shard`).

Where the legacy engine groups requests into fixed batches and burns a
``decode_step`` on every slot until the *batch* finishes, the actor
engine admits requests into slots as they arrive and re-admits a slot
the moment its request retires (EOS or budget) — the dynamic-data-rate
win of the paper applied to serving.  Greedy tokens are identical
token-for-token to the legacy engine for dense model families (rows of
``prefill``/``decode_step`` are computed independently of their
batchmates at the same (B, P)/(B, 1) shapes).

``generate`` accepts an optional open-loop ``arrivals`` trace (one
arrival step per request, ascending — e.g. ``poisson_trace``); without
one every request is available at step 0 (the closed-loop batch case).

Resilience (the serving half of the PR 10 layer): per-request
``deadlines`` plus an engine-level ``queue_depth`` turn overload into
shed/timeout retirements instead of unbounded queueing, and
``generate(on_fault="quarantine")`` (guarded plans only) maps a
``NetworkFaultError`` back to the offending request ids via
``faulted_requests``, retires them with ``status="fault"``, and re-runs
the survivors from the pre-run checkpoint (the initial state — one
``generate`` is one run) with bounded retries.  Survivor tokens are
bit-identical to a fault-free run of the same survivor set: admission
timing cannot change a dense request's tokens (the module-level
bit-identity contract).
"""
from __future__ import annotations

from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ArchConfig
from repro.core import ExecutionPlan
from repro.core.health import NetworkFaultError
from repro.graphs.serving import (STATUS_FAULT, STATUS_OK, STATUS_SHED,
                                  STATUS_TIMEOUT, ServingWorkload,
                                  build_serving_network, faulted_requests,
                                  left_pad_prompts)
from repro.serve.engine import Request, Result, ServeConfig

PyTree = Any

_STATUS_STR = {STATUS_OK: "ok", STATUS_TIMEOUT: "timeout",
               STATUS_SHED: "shed", STATUS_FAULT: "fault"}


class ActorEngine:
    """Serving engine backed by the dynamic-rate actor network."""

    def __init__(self, cfg: ArchConfig, params: PyTree, scfg: ServeConfig,
                 plan: Optional[ExecutionPlan] = None,
                 queue_depth: Optional[int] = None):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self.queue_depth = queue_depth
        self.plan = plan if plan is not None else ExecutionPlan(
            mode="dynamic")
        if self.plan.mode not in ("dynamic", "megakernel"):
            raise ValueError(
                f"ActorEngine: plan mode {self.plan.mode!r} cannot run the "
                "serving feedback loop to data-dependent quiescence; use "
                "'dynamic' or 'megakernel'")
        #: Telemetry of the last generate() call.
        self.last_fire_counts: Optional[dict] = None
        self.last_sweeps: Optional[int] = None
        self.last_latency_steps: Optional[np.ndarray] = None
        self.last_program = None
        #: Per-request retirement status of the last generate() call
        #: ("ok" | "timeout" | "shed" | "fault"), aligned with the
        #: requests list.
        self.last_status: Optional[List[str]] = None
        #: Number of quarantine retries the last generate() call spent.
        self.last_retries: int = 0
        #: Decoded firing trace of the last generate() call (None unless
        #: the plan says trace=True).
        self.last_trace = None
        #: Sharding telemetry of the last generate() call (None unless
        #: the plan says devices > 1): bytes each sweep-barrier exchange
        #: moves across the mesh, from Program.stats().
        self.last_collective_bytes_per_sweep: Optional[int] = None

    # ------------------------------------------------------------------ #
    def _stage(self, requests: Sequence[Request],
               arrivals: Optional[np.ndarray],
               deadlines: Optional[np.ndarray]
               ) -> Tuple[ServingWorkload, Any]:
        scfg = self.scfg
        slab, lens = left_pad_prompts([r.prompt for r in requests],
                                      scfg.max_prompt)
        budgets = np.array([min(r.max_new, scfg.max_new) for r in requests],
                           np.int32)
        if arrivals is None:
            arrivals = np.zeros(len(requests), np.int32)
        arrivals = np.asarray(arrivals, np.int32)
        if arrivals.shape != (len(requests),):
            raise ValueError(
                f"ActorEngine: arrivals shape {arrivals.shape} != "
                f"({len(requests)},)")
        dl = None if deadlines is None else np.asarray(deadlines, np.int32)
        if dl is not None and dl.shape != (len(requests),):
            raise ValueError(
                f"ActorEngine: deadlines shape {dl.shape} != "
                f"({len(requests)},)")
        wl = ServingWorkload(prompts=slab, prompt_lens=lens,
                             budgets=budgets, arrivals=arrivals,
                             deadlines=dl)
        net = build_serving_network(
            self.cfg, self.params, wl, batch_size=scfg.batch_size,
            max_prompt=scfg.max_prompt, max_new=scfg.max_new,
            eos_id=scfg.eos_id, kernel_impl=scfg.kernel_impl,
            queue_depth=self.queue_depth)
        return wl, net

    def build_network(self, requests: Sequence[Request],
                      arrivals: Optional[np.ndarray] = None,
                      deadlines: Optional[np.ndarray] = None):
        """The serving network with these requests staged (exposed for
        tests/benchmarks that inspect the graph or pick their own plan)."""
        return self._stage(requests, arrivals, deadlines)[1]

    def generate(self, requests: List[Request],
                 arrivals: Optional[np.ndarray] = None,
                 deadlines: Optional[np.ndarray] = None,
                 on_fault: str = "raise",
                 max_retries: int = 2) -> List[Result]:
        if on_fault not in ("raise", "quarantine"):
            raise ValueError(
                f"ActorEngine: on_fault={on_fault!r}; pick 'raise' or "
                "'quarantine'")
        if on_fault == "quarantine" and not self.plan.guards:
            raise ValueError(
                "ActorEngine: on_fault='quarantine' needs a guarded plan "
                "(ExecutionPlan(guards=True)) — without fault flags there "
                "is no NetworkFaultError to map back to a request")
        live = [(i, r) for i, r in enumerate(requests) if r.max_new > 0]
        out: List[Optional[Result]] = [
            None if r.max_new > 0 else
            Result(tokens=np.zeros((0,), np.int32), prompt_len=len(r.prompt))
            for r in requests]
        self.last_retries = 0
        arr_all = (None if arrivals is None
                   else np.asarray(arrivals, np.int32))
        dl_all = (None if deadlines is None
                  else np.asarray(deadlines, np.int32))
        quarantined: List[int] = []      # original request indices
        if live:
            # Quarantine loop: every retry re-runs the survivor set from
            # the pre-run checkpoint (the initial network state) with the
            # culprits excluded; each round excludes >= 1 request, so the
            # loop is bounded by min(max_retries, len(live)).
            cur = list(live)
            while True:
                idxs = [i for i, _ in cur]
                arr = None if arr_all is None else arr_all[idxs]
                dl = None if dl_all is None else dl_all[idxs]
                wl, net = self._stage([r for _, r in cur], arr, dl)
                prog = net.compile(self.plan)
                try:
                    res = prog.run()
                    break
                except NetworkFaultError as err:
                    if on_fault != "quarantine":
                        raise
                    culprits = faulted_requests(net, err, wl)
                    if (not culprits
                            or self.last_retries >= max_retries
                            or len(culprits) >= len(cur)):
                        raise
                    self.last_retries += 1
                    quarantined.extend(cur[j][0] for j in culprits)
                    cur = [cr for j, cr in enumerate(cur)
                           if j not in set(culprits)]
            self.last_program = prog
            self.last_fire_counts = (
                {k: int(v) for k, v in res.fire_counts.items()}
                if res.fire_counts is not None else None)
            self.last_sweeps = (int(res.sweeps)
                                if res.sweeps is not None else None)
            self.last_trace = res.trace
            self.last_collective_bytes_per_sweep = (
                prog.stats().collective_bytes_per_sweep
                if self.plan.devices > 1 else None)
            sink = prog.collect("retire", res.state)
            done = np.asarray(sink["done"])
            if not done.all():
                raise RuntimeError(
                    f"ActorEngine: {int((1 - done).sum())} request(s) never "
                    "retired (network quiesced early); check max_sweeps")
            gen = np.asarray(sink["gen"])
            lens = np.asarray(sink["lens"])
            status = np.asarray(sink["status"])
            self.last_latency_steps = np.asarray(sink["lat"])
            for j, (i, r) in enumerate(cur):
                st = _STATUS_STR.get(int(status[j]), "ok")
                # Timeouts keep the tokens they produced before the
                # deadline (partial result); sheds never ran.
                n = int(lens[j]) if st in ("ok", "timeout") else 0
                out[i] = Result(tokens=gen[j, :n].astype(np.int32),
                                prompt_len=len(r.prompt), status=st)
        for i in quarantined:
            out[i] = Result(tokens=np.zeros((0,), np.int32),
                            prompt_len=len(requests[i].prompt),
                            status="fault")
        self.last_status = [r.status for r in out]  # type: ignore[union-attr]
        return out  # type: ignore[return-value]
