"""Continuous-batching serving engine on the dynamic-rate actor runtime.

Drop-in counterpart to :class:`repro.serve.Engine` that runs the
admission/decode/retire actor network of :mod:`repro.graphs.serving`
under any dynamic-capable :class:`ExecutionPlan` (host-dynamic by
default, megakernel via ``plan=ExecutionPlan(mode="megakernel")``, or
sharded across a device mesh via ``plan=ExecutionPlan(mode="dynamic",
devices=k)`` — the serving network's slot-table feedback channel
carries ``delay >= rate``, so it may legally cross devices and the
engine's greedy tokens stay identical at every device count; see
:mod:`repro.core.shard`).

Where the legacy engine groups requests into fixed batches and burns a
``decode_step`` on every slot until the *batch* finishes, the actor
engine admits requests into slots as they arrive and re-admits a slot
the moment its request retires (EOS or budget) — the dynamic-data-rate
win of the paper applied to serving.  Greedy tokens are identical
token-for-token to the legacy engine for dense model families (rows of
``prefill``/``decode_step`` are computed independently of their
batchmates at the same (B, P)/(B, 1) shapes).

``generate`` accepts an optional open-loop ``arrivals`` trace (one
arrival step per request, ascending — e.g. ``poisson_trace``); without
one every request is available at step 0 (the closed-loop batch case).
"""
from __future__ import annotations

from typing import Any, List, Optional, Sequence

import numpy as np

from repro.configs.base import ArchConfig
from repro.core import ExecutionPlan
from repro.graphs.serving import (ServingWorkload, build_serving_network,
                                  left_pad_prompts)
from repro.serve.engine import Request, Result, ServeConfig

PyTree = Any


class ActorEngine:
    """Serving engine backed by the dynamic-rate actor network."""

    def __init__(self, cfg: ArchConfig, params: PyTree, scfg: ServeConfig,
                 plan: Optional[ExecutionPlan] = None):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self.plan = plan if plan is not None else ExecutionPlan(
            mode="dynamic")
        if self.plan.mode not in ("dynamic", "megakernel"):
            raise ValueError(
                f"ActorEngine: plan mode {self.plan.mode!r} cannot run the "
                "serving feedback loop to data-dependent quiescence; use "
                "'dynamic' or 'megakernel'")
        #: Telemetry of the last generate() call.
        self.last_fire_counts: Optional[dict] = None
        self.last_sweeps: Optional[int] = None
        self.last_latency_steps: Optional[np.ndarray] = None
        self.last_program = None
        #: Decoded firing trace of the last generate() call (None unless
        #: the plan says trace=True).
        self.last_trace = None
        #: Sharding telemetry of the last generate() call (None unless
        #: the plan says devices > 1): bytes each sweep-barrier exchange
        #: moves across the mesh, from Program.stats().
        self.last_collective_bytes_per_sweep: Optional[int] = None

    # ------------------------------------------------------------------ #
    def build_network(self, requests: Sequence[Request],
                      arrivals: Optional[np.ndarray] = None):
        """The serving network with these requests staged (exposed for
        tests/benchmarks that inspect the graph or pick their own plan)."""
        scfg = self.scfg
        slab, lens = left_pad_prompts([r.prompt for r in requests],
                                      scfg.max_prompt)
        budgets = np.array([min(r.max_new, scfg.max_new) for r in requests],
                           np.int32)
        if arrivals is None:
            arrivals = np.zeros(len(requests), np.int32)
        arrivals = np.asarray(arrivals, np.int32)
        if arrivals.shape != (len(requests),):
            raise ValueError(
                f"ActorEngine: arrivals shape {arrivals.shape} != "
                f"({len(requests)},)")
        wl = ServingWorkload(prompts=slab, prompt_lens=lens,
                             budgets=budgets, arrivals=arrivals)
        return build_serving_network(
            self.cfg, self.params, wl, batch_size=scfg.batch_size,
            max_prompt=scfg.max_prompt, max_new=scfg.max_new,
            eos_id=scfg.eos_id, kernel_impl=scfg.kernel_impl)

    def generate(self, requests: List[Request],
                 arrivals: Optional[np.ndarray] = None) -> List[Result]:
        live = [(i, r) for i, r in enumerate(requests) if r.max_new > 0]
        out: List[Optional[Result]] = [
            None if r.max_new > 0 else
            Result(tokens=np.zeros((0,), np.int32), prompt_len=len(r.prompt))
            for r in requests]
        if live:
            idxs = [i for i, _ in live]
            arr = None if arrivals is None else np.asarray(
                arrivals, np.int32)[idxs]
            net = self.build_network([r for _, r in live], arrivals=arr)
            prog = net.compile(self.plan)
            res = prog.run()
            self.last_program = prog
            self.last_fire_counts = (
                {k: int(v) for k, v in res.fire_counts.items()}
                if res.fire_counts is not None else None)
            self.last_sweeps = (int(res.sweeps)
                                if res.sweeps is not None else None)
            self.last_trace = res.trace
            self.last_collective_bytes_per_sweep = (
                prog.stats().collective_bytes_per_sweep
                if self.plan.devices > 1 else None)
            sink = prog.collect("retire", res.state)
            done = np.asarray(sink["done"])
            if not done.all():
                raise RuntimeError(
                    f"ActorEngine: {int((1 - done).sum())} request(s) never "
                    "retired (network quiesced early); check max_sweeps")
            gen = np.asarray(sink["gen"])
            lens = np.asarray(sink["lens"])
            self.last_latency_steps = np.asarray(sink["lat"])
            for j, (i, r) in enumerate(live):
                out[i] = Result(tokens=gen[j, :lens[j]].astype(np.int32),
                                prompt_len=len(r.prompt))
        return out  # type: ignore[return-value]
