"""Batched serving engine: prefill + greedy decode over request batches.

The serving loop is the paper's dataflow pattern made explicit: the KV/SSM
caches are delay-token feedback FIFOs (state produced by firing t is
consumed by firing t+1), and each decode step is one network iteration
under the static schedule.  Requests are grouped into fixed-size batches
(the serve_step is compiled once per (batch, cache_len) shape).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import lm as lm_mod

PyTree = Any


@dataclasses.dataclass
class ServeConfig:
    batch_size: int = 8
    max_prompt: int = 64
    max_new: int = 32
    eos_id: Optional[int] = None
    kernel_impl: str = "xla"
    # Stop the decode loop as soon as every slot in the batch is done
    # (emitted EOS, or exhausted its per-request budget) instead of
    # always burning max_new - 1 steps.  Tokens past a slot's first EOS /
    # budget are discarded anyway, so the outputs are identical — only
    # the step count drops.  False keeps the historical fixed loop
    # (used by tests pinning the equivalence).
    early_stop: bool = True


@dataclasses.dataclass
class Request:
    prompt: np.ndarray          # (prompt_len,) int32
    max_new: int


@dataclasses.dataclass
class Result:
    tokens: np.ndarray          # generated ids
    prompt_len: int
    # Retirement status: "ok" | "timeout" | "shed" | "fault".  The legacy
    # fixed-batch engine always finishes its requests, so only the actor
    # engine's resilience layer (deadlines, shedding, quarantine) ever
    # sets a non-"ok" value.
    status: str = "ok"


class Engine:
    def __init__(self, cfg: ArchConfig, params: PyTree, scfg: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        #: decode_step calls of the last _generate_batch (idle-slot
        #: telemetry: with early_stop this drops below max_new - 1 when
        #: every slot finishes early, while tokens stay identical).
        self.last_decode_steps = 0
        cache_len = scfg.max_prompt + scfg.max_new

        def _prefill(params, batch):
            return lm_mod.prefill(params, cfg, batch,
                                  kernel_impl=scfg.kernel_impl,
                                  max_cache_len=cache_len)

        def _decode(params, tokens, pos, caches):
            return lm_mod.decode_step(params, cfg, tokens, pos, caches,
                                      kernel_impl=scfg.kernel_impl)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)

    # ------------------------------------------------------------------ #
    def _pad_batch(self, reqs: List[Request]) -> Dict[str, jax.Array]:
        B = self.scfg.batch_size
        P = self.scfg.max_prompt
        toks = np.zeros((B, P), np.int32)
        for i, r in enumerate(reqs):
            p = r.prompt[-P:]
            toks[i, P - len(p):] = p      # left-pad (prompts end together)
        return {"tokens": jnp.asarray(toks)}

    def generate(self, requests: List[Request]) -> List[Result]:
        out: List[Result] = []
        B = self.scfg.batch_size
        for lo in range(0, len(requests), B):
            group = requests[lo:lo + B]
            pad = group + [Request(np.zeros(1, np.int32), 0)] * (B - len(group))
            out.extend(self._generate_batch(pad)[:len(group)])
        return out

    def _generate_batch(self, reqs: List[Request]) -> List[Result]:
        scfg = self.scfg
        batch = self._pad_batch(reqs)
        logits, caches = self._prefill(self.params, batch)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        pos = jnp.full((scfg.batch_size,), scfg.max_prompt, jnp.int32)

        produced = [next_tok]
        # Host-side done tracking for the early stop: a slot is done once
        # it has emitted EOS or produced its per-request budget.  Tokens a
        # done slot would still produce are discarded by the truncation
        # below, so stopping early cannot change any result.
        budgets = np.array([min(max(r.max_new, 0), scfg.max_new)
                            for r in reqs], np.int64)
        seen_eos = np.zeros(scfg.batch_size, bool)
        if scfg.eos_id is not None:
            seen_eos |= np.asarray(next_tok)[:, 0] == scfg.eos_id
        self.last_decode_steps = 0
        for _ in range(scfg.max_new - 1):
            if scfg.early_stop and bool(
                    (seen_eos | (len(produced) >= budgets)).all()):
                break
            logits, caches = self._decode(self.params, next_tok, pos, caches)
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            pos = pos + 1
            produced.append(next_tok)
            self.last_decode_steps += 1
            if scfg.eos_id is not None:
                seen_eos |= np.asarray(next_tok)[:, 0] == scfg.eos_id
        gen = np.asarray(jnp.concatenate(produced, axis=1))
        if gen.shape[1] < scfg.max_new:   # early stop: pad the dead tail
            pad = np.zeros((scfg.batch_size, scfg.max_new - gen.shape[1]),
                           np.int32)
            gen = np.concatenate([gen, pad], axis=1)

        results = []
        for i, r in enumerate(reqs):
            toks = gen[i][:r.max_new]
            if scfg.eos_id is not None:
                stop = np.where(toks == scfg.eos_id)[0]
                if len(stop):
                    toks = toks[:stop[0] + 1]
            results.append(Result(tokens=toks, prompt_len=len(r.prompt)))
        return results
