from repro.serve.actor_engine import ActorEngine
from repro.serve.engine import Engine, Request, Result, ServeConfig

__all__ = ["ActorEngine", "Engine", "Request", "Result", "ServeConfig"]
