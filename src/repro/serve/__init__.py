from repro.serve.engine import Engine, Request, Result, ServeConfig

__all__ = ["Engine", "Request", "Result", "ServeConfig"]
