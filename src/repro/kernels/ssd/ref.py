"""Oracle for the SSD kernel: re-exports the naive recurrence from
repro.models.ssm (single source of truth for the math)."""
from repro.models.ssm import ssd_chunked, ssd_naive  # noqa: F401

ssd_ref = ssd_naive

__all__ = ["ssd_ref", "ssd_naive", "ssd_chunked"]
