from repro.kernels.ssd.ops import ssd
from repro.kernels.ssd.ref import ssd_chunked, ssd_naive, ssd_ref

__all__ = ["ssd", "ssd_ref", "ssd_naive", "ssd_chunked"]
