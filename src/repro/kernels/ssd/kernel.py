"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

TPU adaptation of the paper-pool's SSD algorithm (arXiv:2405.21060, GPU
Triton original): one program per (batch, head) walks the chunk axis
(innermost grid dim); the (P x N) state lives in a revisited f32 output
block in VMEM for the whole sequence — zero HBM state traffic between
chunks (the GPU version re-materializes through shared memory per block).
Intra-chunk work is two MXU matmuls ((c,c) score-like decay matrix and the
(c,N)x(N,P) contractions), so chunk length is chosen MXU-aligned (128/256).

Grid: (B, H, n_chunks), chunk innermost.  Inputs are pre-chunked
(B, nc, c, ...) by the ops.py wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_ref, *,
                chunk: int, nc: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, 0, :, 0, :].astype(jnp.float32)     # (c, P)
    dt = dt_ref[0, 0, :, 0].astype(jnp.float32)      # (c,)
    A = a_ref[0, 0]                                  # scalar (f32)
    B_ = b_ref[0, 0, :, :].astype(jnp.float32)       # (c, N)
    C_ = c_ref[0, 0, :, :].astype(jnp.float32)       # (c, N)
    h = h_ref[0, 0].astype(jnp.float32)              # (P, N)

    dA = dt * A                                      # (c,)
    cum = jnp.cumsum(dA)                             # (c,)
    # Segment decay matrix L[t, s] = exp(sum_{s<u<=t} dA_u), causal.
    seg = cum[:, None] - cum[None, :]
    t_ids = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_ids = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(s_ids <= t_ids, jnp.exp(seg), 0.0)

    # Intra-chunk: Y1 = (C B^T ⊙ L) @ (dt ⊙ x)
    G = (C_ @ B_.T) * L                              # (c, c)
    Y1 = G @ (dt[:, None] * x)                       # (c, P)

    # Inter-chunk: Y2[t] = exp(cum_t) * C_t @ h^T
    decay_in = jnp.exp(cum)                          # (c,)
    Y2 = decay_in[:, None] * (C_ @ h.T)              # (c, P)

    y_ref[0, 0, :, 0, :] = (Y1 + Y2).astype(y_ref.dtype)

    # State update: h' = exp(total) h + sum_s exp(total - cum_s) dt_s x_s B_s
    total = cum[-1]
    w = jnp.exp(total - cum) * dt                    # (c,)
    h_new = jnp.exp(total) * h + (w[:, None] * x).T @ B_   # (P, N)
    h_ref[0, 0] = h_new


def ssd_pallas(x, dt, A, B_, C_, *, chunk: int = 256, interpret: bool = False):
    """x: (B, L, H, P); dt: (B, L, H); A: (H,); B_/C_: (B, L, N).

    Returns (y (B, L, H, P), hT (B, H, P, N)).  L padded to chunk multiple
    with dt=0 (no-op steps), as in the jnp chunked path.
    """
    Bsz, L, H, P = x.shape
    N = B_.shape[-1]
    L0 = L
    if L % chunk:
        pad = chunk - L % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0)))
        L = L + pad
    nc = L // chunk
    xc = x.reshape(Bsz, nc, chunk, H, P)
    dtc = dt.reshape(Bsz, nc, chunk, H)
    Bc = B_.reshape(Bsz, nc, chunk, N)
    Cc = C_.reshape(Bsz, nc, chunk, N)
    A2 = jnp.broadcast_to(A.astype(jnp.float32)[None, :], (Bsz, H))

    kern = functools.partial(_ssd_kernel, chunk=chunk, nc=nc)
    y, hT = pl.pallas_call(
        kern,
        grid=(Bsz, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, 1, P), lambda b, h, j: (b, j, 0, h, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda b, h, j: (b, j, 0, h)),
            pl.BlockSpec((1, 1), lambda b, h, j: (b, h)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, j: (b, j, 0, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, j: (b, j, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, 1, P), lambda b, h, j: (b, j, 0, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, j: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, nc, chunk, H, P), x.dtype),
            jax.ShapeDtypeStruct((Bsz, H, P, N), jnp.float32),
        ],
        interpret=interpret,
    )(xc, dtc, A2, Bc, Cc)
    return y.reshape(Bsz, L, H, P)[:, :L0], hT
