"""Jitted entry point for the SSD kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.ssd.kernel import ssd_pallas
from repro.kernels.ssd.ref import ssd_chunked


@functools.partial(jax.jit, static_argnames=("chunk", "impl", "interpret"))
def ssd(x, dt, A, B_, C_, *, chunk: int = 256, impl: str = "pallas",
        interpret: bool = True):
    """Chunked SSD scan. Returns (y, final_state)."""
    if impl == "pallas":
        return ssd_pallas(x, dt, A, B_, C_, chunk=chunk, interpret=interpret)
    return ssd_chunked(x, dt, A, B_, C_, chunk=chunk)
