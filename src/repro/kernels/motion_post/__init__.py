from repro.kernels.motion_post.ops import motion_post
from repro.kernels.motion_post.ref import (DEFAULT_THRESHOLD, med_ref,
                                           median5, motion_post_ref, thres_ref)

__all__ = ["motion_post", "motion_post_ref", "thres_ref", "med_ref",
           "median5", "DEFAULT_THRESHOLD"]
