"""Jitted entry points for the fused Thres+Med kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.motion_post.kernel import motion_post_pallas
from repro.kernels.motion_post.ref import DEFAULT_THRESHOLD, motion_post_ref


@functools.partial(jax.jit, static_argnames=("impl", "threshold", "block_h", "interpret"))
def motion_post(cur: jax.Array, prev: jax.Array, *,
                threshold: float = DEFAULT_THRESHOLD, impl: str = "xla",
                block_h: int = 60, interpret: bool = True) -> jax.Array:
    cur = cur.astype(jnp.float32)
    prev = prev.astype(jnp.float32)
    if impl == "pallas":
        return motion_post_pallas(cur, prev, threshold=threshold,
                                  block_h=block_h, interpret=interpret)
    return motion_post_ref(cur, prev, threshold)
