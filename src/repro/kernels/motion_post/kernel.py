"""Pallas TPU kernel fusing the Thres and Med actors (motion detection).

Both actors are elementwise/stencil ops on the same frame pair, so on TPU
they fuse into a single VMEM pass: |cur - prev| > T, then a plus-shaped
5-point median via a min/max network (VPU-friendly — no data-dependent
branches).  This is the actor-merging optimization the paper applies on
the accelerated path ([22]) expressed as one kernel.

Tiling mirrors gauss5x5: whole padded frames resident in VMEM, grid walks
output row slabs with a 2-row halo (1 for the median + 1 safety margin is
not needed — exactly 1 row halo required; we keep the gauss slab walker
shape for uniformity).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.motion_post.ref import DEFAULT_THRESHOLD, median5


def _motion_post_kernel(cur_ref, prev_ref, o_ref, *, block_h: int, H: int,
                        threshold: float):
    i = pl.program_id(0)
    W = o_ref.shape[1]
    # Slabs of the 1-row edge-padded difference map: rows [i*bh, i*bh+bh+2).
    cur = cur_ref[pl.ds(i * block_h, block_h + 2), :]
    prev = prev_ref[pl.ds(i * block_h, block_h + 2), :]
    m = jnp.where(jnp.abs(cur - prev) > threshold, 255.0, 0.0)

    # Plus-shaped median on the slab; columns edge-padded locally.
    mp = jnp.concatenate([m[:, :1], m, m[:, -1:]], axis=1)
    c = mp[1:block_h + 1, 1:W + 1]
    u = mp[0:block_h, 1:W + 1]
    d = mp[2:block_h + 2, 1:W + 1]
    l = mp[1:block_h + 1, 0:W]
    r = mp[1:block_h + 1, 2:W + 2]
    o_ref[...] = median5(u, d, l, r, c)


def motion_post_pallas(cur: jax.Array, prev: jax.Array, *,
                       threshold: float = DEFAULT_THRESHOLD,
                       block_h: int = 60, interpret: bool = False) -> jax.Array:
    """cur/prev: (H, W) f32. Fused thres+median motion map."""
    H, W = cur.shape
    if H % block_h:
        raise ValueError(f"H={H} not divisible by block_h={block_h}")

    def pad1(x):
        return jnp.concatenate([x[:1], x, x[-1:]], axis=0).astype(jnp.float32)

    kern = functools.partial(_motion_post_kernel, block_h=block_h, H=H,
                             threshold=float(threshold))
    return pl.pallas_call(
        kern,
        grid=(H // block_h,),
        in_specs=[pl.BlockSpec((H + 2, W), lambda i: (0, 0)),
                  pl.BlockSpec((H + 2, W), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((block_h, W), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((H, W), jnp.float32),
        interpret=interpret,
    )(pad1(cur), pad1(prev))
