"""Oracle for the fused Thres+Med motion-detection tail (paper §4.1).

Thres: subtract consecutive frames, threshold against a fixed constant
(|cur - prev| > T -> 255 else 0).
Med:   5-point (plus-shaped) median filter on the binary motion map.

The paper implements these as two actors; its previous-work note ([22])
had them fused in one — we provide both: the actors stay separate in the
graph, and the *fused kernel* is the accelerated implementation of the
pair (actor merging on the accelerated path).  Edges are handled by
edge-padding before the median window.
"""
from __future__ import annotations

import jax.numpy as jnp

DEFAULT_THRESHOLD = 40.0


def thres_ref(cur: jnp.ndarray, prev: jnp.ndarray,
              threshold: float = DEFAULT_THRESHOLD) -> jnp.ndarray:
    return jnp.where(jnp.abs(cur - prev) > threshold, 255.0, 0.0)


def median5(a, b, c, d, e):
    """Median of 5 via min/max network:
    med5(a..e) = med3(e, max(min(a,b), min(c,d)), min(max(a,b), max(c,d)))."""
    mn, mx = jnp.minimum, jnp.maximum
    f = mx(mn(a, b), mn(c, d))
    g = mn(mx(a, b), mx(c, d))
    return mx(mn(f, g), mn(e, mx(f, g)))


def med_ref(m: jnp.ndarray) -> jnp.ndarray:
    """Plus-shaped 5-point median with edge padding."""
    H, W = m.shape
    p = jnp.pad(m, 1, mode="edge")
    c = p[1:H + 1, 1:W + 1]
    u = p[0:H, 1:W + 1]
    d = p[2:H + 2, 1:W + 1]
    l = p[1:H + 1, 0:W]
    r = p[1:H + 1, 2:W + 2]
    return median5(u, d, l, r, c)


def motion_post_ref(cur: jnp.ndarray, prev: jnp.ndarray,
                    threshold: float = DEFAULT_THRESHOLD) -> jnp.ndarray:
    return med_ref(thres_ref(cur, prev, threshold))
