"""Pallas TPU kernels (target: pl.pallas_call + BlockSpec VMEM tiling;
validated via interpret=True on CPU). Each subpackage: kernel.py (pallas),
ops.py (jitted dispatch), ref.py (pure-jnp oracle)."""
