"""Pallas TPU kernel for one DPD Poly branch (basis + 10-tap complex FIR).

TPU adaptation of the paper's OpenCL FIR actors: a 1-D sample stream maps
poorly onto the (8,128) VPU as a vector, so samples are blocked into
(rows, 128) lane tiles; the 10-tap convolution becomes 10 shifted
multiply-accumulates over a VMEM slab carrying a 9-sample history halo.
The basis power ``|x|^(2(k-1))`` is fused in front of the FIR so the slab
is read once (the paper's Poly actor == basis+FIR fused).

The *dynamic-rate* behaviour lives one level up: each Poly actor's firing
is predicated by the Configuration actor's control token (lax.cond), so a
disabled branch never launches this kernel at all — that is the paper's
5x, reproduced structurally.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.dyn_fir.ref import N_TAPS


def _branch_kernel(xr_ref, xi_ref, hr_ref, hi_ref, or_ref, oi_ref, *,
                   order: int, block: int):
    """Grid step over sample blocks: out samples [i*block, (i+1)*block)."""
    i = pl.program_id(0)
    # Slab with history halo: samples [i*block, i*block + block + T - 1).
    xr = xr_ref[0, pl.ds(i * block, block + N_TAPS - 1)]
    xi = xi_ref[0, pl.ds(i * block, block + N_TAPS - 1)]

    # Fused nonlinear basis phi_k(x) = x * |x|^(2(k-1)).
    mag2 = xr * xr + xi * xi
    scale = jnp.ones_like(mag2)
    for _ in range(order - 1):
        scale = scale * mag2
    br = xr * scale
    bi = xi * scale

    hr = hr_ref[0, :]
    hi = hi_ref[0, :]
    yr = jnp.zeros((block,), jnp.float32)
    yi = jnp.zeros((block,), jnp.float32)
    for t in range(N_TAPS):
        sr = br[N_TAPS - 1 - t: N_TAPS - 1 - t + block]
        si = bi[N_TAPS - 1 - t: N_TAPS - 1 - t + block]
        yr = yr + hr[t] * sr - hi[t] * si
        yi = yi + hr[t] * si + hi[t] * sr
    or_ref[0, :] = yr
    oi_ref[0, :] = yi


def dpd_branch_pallas(x_re: jax.Array, x_im: jax.Array,
                      h_re: jax.Array, h_im: jax.Array, *,
                      order: int, block: int = 1024,
                      interpret: bool = False):
    """x: (L + T - 1,) f32 stream with 9-sample history; h: (T,) f32.

    Returns (y_re, y_im): (L,) filtered samples. L % block == 0.
    """
    L = x_re.shape[0] - (N_TAPS - 1)
    if L % block:
        raise ValueError(f"L={L} not divisible by block={block}")
    kern = functools.partial(_branch_kernel, order=order, block=block)
    # Rank-2 (1, n) layouts — TPU VMEM wants >= 2-D tiles.
    out = pl.pallas_call(
        kern,
        grid=(L // block,),
        in_specs=[pl.BlockSpec((1, L + N_TAPS - 1), lambda i: (0, 0)),
                  pl.BlockSpec((1, L + N_TAPS - 1), lambda i: (0, 0)),
                  pl.BlockSpec((1, N_TAPS), lambda i: (0, 0)),
                  pl.BlockSpec((1, N_TAPS), lambda i: (0, 0))],
        out_specs=[pl.BlockSpec((1, block), lambda i: (0, i)),
                   pl.BlockSpec((1, block), lambda i: (0, i))],
        out_shape=[jax.ShapeDtypeStruct((1, L), jnp.float32),
                   jax.ShapeDtypeStruct((1, L), jnp.float32)],
        interpret=interpret,
    )(x_re[None], x_im[None], h_re[None], h_im[None])
    return out[0][0], out[1][0]
