from repro.kernels.dyn_fir.ops import dpd_branch
from repro.kernels.dyn_fir.ref import (N_BRANCHES, N_TAPS, basis_ref,
                                       branch_ref, dpd_bank_ref, fir_ref)

__all__ = ["dpd_branch", "branch_ref", "basis_ref", "fir_ref",
           "dpd_bank_ref", "N_TAPS", "N_BRANCHES"]
