"""Oracle for the Dynamic Predistortion FIR branches (paper §4.2).

A memory-polynomial DPD branch of order k computes the nonlinear basis
``phi_k(x) = x * |x|^(2(k-1))`` followed by a 10-tap complex FIR.  The
Adder sums the branches the Configuration actor enabled (2..10 active at
any time — the paper's dynamic data rates).

Complex samples are carried as (re, im) float32 pairs — the paper does the
same ("a pair of single precision floats"), doubling the FIFO channel
count inside the GPU box (46 channels total).
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

N_TAPS = 10
N_BRANCHES = 10


def basis_ref(x_re: jnp.ndarray, x_im: jnp.ndarray, order: int
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """phi_k(x) = x * |x|^(2(k-1)); order k >= 1."""
    mag2 = x_re * x_re + x_im * x_im
    scale = mag2 ** (order - 1)
    return x_re * scale, x_im * scale


def fir_ref(x_re: jnp.ndarray, x_im: jnp.ndarray,
            h_re: jnp.ndarray, h_im: jnp.ndarray
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Causal complex FIR. x: (..., L + N_TAPS - 1) with history prefix;
    h: (N_TAPS,). Returns (..., L): y[n] = sum_t h[t] * x[n + T-1 - t]."""
    L = x_re.shape[-1] - (N_TAPS - 1)
    y_re = jnp.zeros(x_re.shape[:-1] + (L,), jnp.float32)
    y_im = jnp.zeros_like(y_re)
    for t in range(N_TAPS):
        xr = x_re[..., N_TAPS - 1 - t: N_TAPS - 1 - t + L]
        xi = x_im[..., N_TAPS - 1 - t: N_TAPS - 1 - t + L]
        y_re = y_re + h_re[t] * xr - h_im[t] * xi
        y_im = y_im + h_re[t] * xi + h_im[t] * xr
    return y_re, y_im


def branch_ref(x_re, x_im, h_re, h_im, order: int):
    """One Poly actor: basis then FIR."""
    b_re, b_im = basis_ref(x_re, x_im, order)
    return fir_ref(b_re, b_im, h_re, h_im)


def dpd_bank_ref(x_re, x_im, taps_re, taps_im, active):
    """Full bank: sum over branches k of active[k] * branch_k(x).

    x: (..., L + T - 1); taps: (K, T); active: (K,) 0/1 float mask.
    This is the *static* (DAL-style) semantics: every branch computed, the
    mask only gates the sum — the baseline the dynamic runtime beats.
    """
    K = taps_re.shape[0]
    L = x_re.shape[-1] - (N_TAPS - 1)
    y_re = jnp.zeros(x_re.shape[:-1] + (L,), jnp.float32)
    y_im = jnp.zeros_like(y_re)
    for k in range(K):
        br, bi = branch_ref(x_re, x_im, taps_re[k], taps_im[k], k + 1)
        y_re = y_re + active[k] * br
        y_im = y_im + active[k] * bi
    return y_re, y_im
