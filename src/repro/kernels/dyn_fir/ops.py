"""Jitted entry points for the DPD branch kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.dyn_fir.kernel import dpd_branch_pallas
from repro.kernels.dyn_fir.ref import branch_ref


@functools.partial(jax.jit, static_argnames=("order", "impl", "block", "interpret"))
def dpd_branch(x_re: jax.Array, x_im: jax.Array, h_re: jax.Array,
               h_im: jax.Array, *, order: int, impl: str = "xla",
               block: int = 1024, interpret: bool = True):
    """One Poly actor's computation (basis + complex FIR)."""
    if impl == "pallas":
        return dpd_branch_pallas(x_re, x_im, h_re, h_im, order=order,
                                 block=block, interpret=interpret)
    return branch_ref(x_re, x_im, h_re, h_im, order)
