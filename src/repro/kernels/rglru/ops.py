"""Jitted entry point for the RG-LRU kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.rglru.kernel import rglru_pallas
from repro.kernels.rglru.ref import rglru_scan


@functools.partial(jax.jit, static_argnames=("chunk", "impl", "interpret"))
def rglru(log_a, gx, *, chunk: int = 128, impl: str = "pallas",
          interpret: bool = True):
    """RG-LRU recurrence. Returns (h_seq, hT)."""
    if impl == "pallas":
        return rglru_pallas(log_a, gx, chunk=chunk, interpret=interpret)
    return rglru_scan(log_a, gx)
