"""Pallas TPU kernel for the RG-LRU diagonal linear recurrence.

TPU adaptation: the GPU original (recurrentgemma) launches a scan kernel
with per-thread state in registers; on TPU the analogue is one program per
batch element walking sequence chunks (innermost grid dim) with the (W,)
state held in a revisited f32 VMEM block for the whole sequence.  Within a
chunk the recurrence h_t = a_t h_{t-1} + b_t is a short ``fori_loop`` over
rows of a VMEM-resident (c, W) slab — each step is one (W,)-wide VPU FMA,
and the state never touches HBM between steps (the XLA associative_scan
lowering round-trips log2(L) intermediates).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rglru_kernel(la_ref, gx_ref, h_seq_ref, h_ref, *, chunk: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    la = la_ref[0].astype(jnp.float32)     # (c, W) log decay
    gx = gx_ref[0].astype(jnp.float32)     # (c, W) gated input
    a = jnp.exp(la)

    def step(t, carry):
        h = carry
        h = a[t] * h + gx[t]
        h_seq_ref[0, t, :] = h.astype(h_seq_ref.dtype)
        return h

    h0 = h_ref[0].astype(jnp.float32)      # (W,)
    hT = jax.lax.fori_loop(0, chunk, step, h0)
    h_ref[0] = hT


def rglru_pallas(log_a, gx, *, chunk: int = 128, interpret: bool = False):
    """log_a, gx: (B, L, W) f32. Returns (h_seq (B, L, W), hT (B, W))."""
    B, L, W = gx.shape
    L0 = L
    if L % chunk:
        pad = chunk - L % chunk
        # log_a = 0 -> decay 1; gx = 0 -> state unchanged on padded steps.
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
        gx = jnp.pad(gx, ((0, 0), (0, pad), (0, 0)))
        L += pad
    nc = L // chunk

    kern = functools.partial(_rglru_kernel, chunk=chunk)
    h_seq, hT = pl.pallas_call(
        kern,
        grid=(B, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, W), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, chunk, W), lambda b, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, W), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, W), lambda b, j: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, L, W), jnp.float32),
            jax.ShapeDtypeStruct((B, W), jnp.float32),
        ],
        interpret=interpret,
    )(log_a, gx)
    return h_seq[:, :L0], hT
