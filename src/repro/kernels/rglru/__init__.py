from repro.kernels.rglru.ops import rglru
from repro.kernels.rglru.ref import rglru_naive, rglru_ref, rglru_scan

__all__ = ["rglru", "rglru_ref", "rglru_naive", "rglru_scan"]
