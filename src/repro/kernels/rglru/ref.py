"""Oracle for the RG-LRU kernel: the naive lax.scan recurrence."""
from repro.models.rglru import rglru_naive, rglru_scan  # noqa: F401

rglru_ref = rglru_naive

__all__ = ["rglru_ref", "rglru_naive", "rglru_scan"]
