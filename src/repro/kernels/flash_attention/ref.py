"""Pure-jnp oracle for blocked (flash) attention with GQA + causal + SWA."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        window: Optional[int] = None) -> jax.Array:
    """q: (B, S, H, hd); k/v: (B, S, Hkv, hd). Returns (B, S, H, hd)."""
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, S, Hkv, G, hd).astype(jnp.float32)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(jnp.float32(hd))
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= j <= i
    if window is not None:
        mask &= (i - j) < window
    scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bkgst,btkh->bskgh", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, hd).astype(q.dtype)
