"""Jitted entry point: dispatches flash attention to pallas or the oracle."""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "impl", "bq", "bk",
                                    "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, impl: str = "pallas",
                    bq: int = 128, bk: int = 128, interpret: bool = True):
    if impl == "pallas":
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      bq=bq, bk=bk, interpret=interpret)
    return flash_attention_ref(q, k, v, causal=causal, window=window)
