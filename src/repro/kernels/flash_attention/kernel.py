"""Pallas TPU flash-attention forward (GQA + causal + sliding window).

Classic blocked online-softmax: grid (B, H, nq, nk) with the K loop as the
innermost (fastest) grid dimension so the output block and the running
(m, l) statistics are *revisited* across K steps — they live in VMEM for
the whole row of K blocks, which is exactly the contiguous-accumulator
discipline the MXU wants (one (bq, hd) f32 accumulator resident while
(bq, bk) score tiles stream through).

GQA is handled in the BlockSpec index maps: the K/V block for query head
``h`` is head ``h // G`` — no materialized head repetition.

Block-level early exit: fully-masked (q-block, k-block) pairs (above the
causal diagonal, or beyond the SWA window) are skipped with ``pl.when``,
so SWA costs O(S * window) — the sub-quadratic property long_500k relies
on.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
                  bq: int, bk: int, scale: float, causal: bool,
                  window: Optional[int], nk: int):
    i = pl.program_id(2)   # q block
    j = pl.program_id(3)   # k block

    # ---- block-level masking predicate (static shapes, dynamic ids) ----
    q_lo = i * bq                      # first q row of this block
    q_hi = q_lo + bq - 1
    k_lo = j * bk
    k_hi = k_lo + bk - 1
    live = jnp.bool_(True)
    if causal:
        live &= k_lo <= q_hi           # some key not in the future
    if window is not None:
        live &= k_hi > q_lo - window   # some key inside the window

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(live)
    def _step():
        q = q_ref[0, :, 0, :].astype(jnp.float32)      # (bq, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)      # (bk, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)      # (bk, hd)
        s = (q @ k.T) * scale                          # (bq, bk)

        rows = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask &= cols <= rows
        if window is not None:
            mask &= (rows - cols) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[0, 0, 0, :]                     # (bq,)
        l_prev = l_ref[0, 0, 0, :]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        o_prev = o_ref[0, :, 0, :].astype(jnp.float32)
        o_new = o_prev * alpha[:, None] + p @ v
        m_ref[0, 0, 0, :] = m_new
        l_ref[0, 0, 0, :] = l_new
        o_ref[0, :, 0, :] = o_new.astype(o_ref.dtype)

    @pl.when(j == nk - 1)
    def _finalize():
        l = l_ref[0, 0, 0, :]
        o = o_ref[0, :, 0, :].astype(jnp.float32)
        o_ref[0, :, 0, :] = (o / jnp.maximum(l, 1e-20)[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal=True, window=None,
                           bq: int = 128, bk: int = 128,
                           interpret: bool = False):
    """q: (B, S, H, hd); k/v: (B, S, Hkv, hd) -> (B, S, H, hd)."""
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    bq = min(bq, S)
    bk = min(bk, S)
    if S % bq or S % bk:
        raise ValueError(f"S={S} must divide block sizes ({bq}, {bk})")
    nq, nk = S // bq, S // bk
    grid = (B, H, nq, nk)
    scale = float(1.0 / (hd ** 0.5))

    kern = functools.partial(_flash_kernel, bq=bq, bk=bk, scale=scale,
                             causal=causal, window=window, nk=nk)
    # f32 accumulation in the revisited output block; cast at the end.
    out, _, _ = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, hd), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b, h, i, j: (b, j, h // G, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b, h, i, j: (b, j, h // G, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, 1, hd), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, 1, 1, bq), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, 1, bq), lambda b, h, i, j: (b, h, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, H, nq, bq), jnp.float32),  # running max
            jax.ShapeDtypeStruct((B, H, nq, bq), jnp.float32),  # running sum
        ],
        interpret=interpret,
    )(q, k, v)
    return out.astype(q.dtype)
