"""Jitted public entry points for the Gauss 5x5 actor kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gauss5x5.kernel import gauss5x5_pallas
from repro.kernels.gauss5x5.ref import gauss5x5_ref


@functools.partial(jax.jit, static_argnames=("impl", "block_h", "interpret"))
def gauss5x5(frame: jax.Array, *, impl: str = "xla", block_h: int = 60,
             interpret: bool = True) -> jax.Array:
    """5x5 binomial Gaussian filter, border-skipping per the paper.

    impl="xla"    — pure-jnp reference path (used by dry-run / CPU).
    impl="pallas" — TPU Pallas kernel (interpret=True validates on CPU).
    """
    frame = frame.astype(jnp.float32)
    if impl == "pallas":
        return gauss5x5_pallas(frame, block_h=block_h, interpret=interpret)
    return gauss5x5_ref(frame)
