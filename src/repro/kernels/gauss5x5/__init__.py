from repro.kernels.gauss5x5.ops import gauss5x5
from repro.kernels.gauss5x5.ref import gauss5x5_ref

__all__ = ["gauss5x5", "gauss5x5_ref"]
