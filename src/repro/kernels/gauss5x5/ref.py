"""Pure-jnp oracle for the 5x5 Gaussian actor (paper §4.1).

Direct 25-tap convolution with the binomial kernel [1,4,6,4,1]^T[1,4,6,4,1]
/ 256.  Matching the paper's boundary rule: "the Gauss actor skips
filtering for two pixel rows in the frame top and frame bottom" — we skip
the 2-pixel border (rows *and* columns; the paper names rows only, columns
are unspecified — documented in DESIGN.md §8) and pass the original pixels
through.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

KERNEL_1D = np.array([1.0, 4.0, 6.0, 4.0, 1.0]) / 16.0
KERNEL_2D = np.outer(KERNEL_1D, KERNEL_1D)  # sums to 1


def gauss5x5_ref(frame: jnp.ndarray) -> jnp.ndarray:
    """frame: (H, W) float32 in [0, 255]. Returns filtered frame, borders kept."""
    H, W = frame.shape
    pad = jnp.pad(frame, 2, mode="edge")
    acc = jnp.zeros_like(frame)
    for dy in range(5):
        for dx in range(5):
            acc = acc + KERNEL_2D[dy, dx] * pad[dy:dy + H, dx:dx + W]
    border = jnp.zeros((H, W), bool)
    border = border.at[:2, :].set(True).at[-2:, :].set(True)
    border = border.at[:, :2].set(True).at[:, -2:].set(True)
    return jnp.where(border, frame, acc)
