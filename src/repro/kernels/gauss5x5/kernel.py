"""Pallas TPU kernel for the 5x5 Gaussian actor.

TPU adaptation of the paper's OpenCL Gauss kernel: instead of a work-item
per pixel, the frame is processed in VMEM-resident row slabs and the 2-D
binomial kernel is applied **separably** (vertical then horizontal 5-tap
passes: 10 multiplies/pixel instead of 25) — the VPU is an (8,128) vector
unit, so row-contiguous slabs are the natural tiling.

Tiling: the (edge-padded) frame is small enough to live in VMEM whole
(QVGA f32 = 300 KB, VGA = 1.2 MB « 16 MB), so the input BlockSpec maps the
full array and the grid walks output row slabs; each step slices its
haloed slab with ``pl.ds``.  This trades a little VMEM for zero re-DMA of
halo rows — the same contiguous-window reasoning as the paper's Eq. 1
buffers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.gauss5x5.ref import KERNEL_1D

_W1D = [float(w) for w in KERNEL_1D]


def _gauss_kernel(x_ref, o_ref, *, block_h: int, H: int):
    """One grid step: filter ``block_h`` output rows from the padded frame."""
    i = pl.program_id(0)
    W = o_ref.shape[1]
    # Haloed slab: padded rows [i*block_h, i*block_h + block_h + 4).
    x = x_ref[pl.ds(i * block_h, block_h + 4), :]

    # Vertical 5-tap pass -> (block_h, W).
    v = jnp.zeros((block_h, W), jnp.float32)
    for t in range(5):
        v = v + _W1D[t] * x[t:t + block_h, :]

    # Horizontal 5-tap pass on edge-padded columns.
    hpad = jnp.concatenate([v[:, :1], v[:, :1], v, v[:, -1:], v[:, -1:]], axis=1)
    h = jnp.zeros((block_h, W), jnp.float32)
    for t in range(5):
        h = h + _W1D[t] * hpad[:, t:t + W]

    # Border policy (paper §4.1): skip 2 rows top/bottom (+2 cols, see ref).
    centre = x[2:2 + block_h, :]  # the unfiltered pixels of this block
    row_ids = i * block_h + jax.lax.broadcasted_iota(jnp.int32, (block_h, W), 0)
    col_ids = jax.lax.broadcasted_iota(jnp.int32, (block_h, W), 1)
    border = (row_ids < 2) | (row_ids >= H - 2) | (col_ids < 2) | (col_ids >= W - 2)
    o_ref[...] = jnp.where(border, centre, h)


def gauss5x5_pallas(frame: jax.Array, *, block_h: int = 60,
                    interpret: bool = False) -> jax.Array:
    """frame: (H, W) f32 in [0,255]. H must be divisible by block_h."""
    H, W = frame.shape
    if H % block_h:
        raise ValueError(f"H={H} not divisible by block_h={block_h}")
    grid = (H // block_h,)

    # Edge-pad 2 rows each side so halo slicing needs no clamping.
    padded = jnp.concatenate([frame[:1], frame[:1], frame, frame[-1:], frame[-1:]],
                             axis=0).astype(jnp.float32)

    kern = functools.partial(_gauss_kernel, block_h=block_h, H=H)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((H + 4, W), lambda i: (0, 0))],  # whole padded frame
        out_specs=pl.BlockSpec((block_h, W), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((H, W), jnp.float32),
        interpret=interpret,
    )(padded)
