"""Production training launcher: ``--arch <id>`` on the production mesh.

On this CPU container it is exercised with ``--smoke`` (reduced config,
1-device mesh); on a pod the same script runs the full config — the mesh,
sharding rules, trainer, and checkpointing are identical code paths.

    PYTHONPATH=src python -m repro.launch.train --arch granite-8b --smoke \
        --steps 20
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, smoke_config
from repro.data import DataConfig, SyntheticLM
from repro.models import init_params
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.train import (Trainer, TrainerConfig, TrainOptions,
                         make_train_step)
from repro.train import sharding as shd


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + local mesh (CPU container)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--zero1", action="store_true")
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)

    n_dev = len(jax.devices())
    if args.smoke or n_dev < 256:
        shape = (n_dev, 1)
    else:
        shape = (16, 16)
    mesh = jax.make_mesh(shape, ("data", "model"))
    print(f"mesh: {dict(mesh.shape)}  arch: {cfg.name} "
          f"({cfg.param_count()/1e6:.1f}M params)")

    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    opt_state = init_opt_state(params)
    p_specs, dropped = shd.param_specs(params, mesh)
    for d in dropped:
        print(f"[sharding] {d}")
    o_specs = {"m": p_specs, "v": p_specs, "count": P()}
    if args.zero1:
        o_specs = {"m": shd.zero1_specs(p_specs, params, mesh),
                   "v": shd.zero1_specs(p_specs, params, mesh), "count": P()}

    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                  global_batch=args.batch))
    batch0 = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    b_specs = shd.batch_specs(batch0, mesh)

    opts = TrainOptions(microbatches=args.microbatches, zero1=args.zero1)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=args.steps)
    step = make_train_step(cfg, opt_cfg, opts)
    with mesh:
        jstep = jax.jit(step, in_shardings=jax.tree.map(
            lambda s: NamedSharding(mesh, s), (p_specs, o_specs, b_specs),
            is_leaf=lambda x: isinstance(x, P)))

        def init_state():
            p = init_params(key, cfg)
            return {"params": p, "opt": init_opt_state(p)}

        trainer = Trainer(
            TrainerConfig(total_steps=args.steps, checkpoint_every=25,
                          checkpoint_dir=args.ckpt_dir, log_every=10),
            jstep, data, init_state,
            to_device=lambda b: {k: jnp.asarray(v) for k, v in b.items()})
        trainer.run()
    h = trainer.metrics_history
    print(f"done: loss {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
