import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves, without hardware:
  * the sharding config is coherent (GSPMD partitions the whole step),
  * it fits memory (``compiled.memory_analysis()``),
  * and it yields the roofline terms.

Methodology notes (see EXPERIMENTS.md §Dry-run):

  * XLA's cost analysis counts a while/scan body ONCE, not x trip-count.
    Layer stacks are scanned, so per-cell FLOPs/bytes/collectives are
    derived from two cheap *depth probes* — the same step compiled with
    ``n_layers = cycle+rest`` and ``2*cycle+rest`` layers, **unrolled** —
    giving the exact per-cycle slope B and intercept G; the full-depth
    value is G + n_groups * B.  (Verified exact for everything outside
    inner per-layer scans.)
  * The blocked-attention inner scan is still counted once inside each
    probe layer; its true cost is added analytically (einsum flops are
    exact: 4*B*S*S_kv*H*hd per layer per forward pass) — the one
    documented analytic term, <=2% double-count.
  * Collective bytes are parsed from the compiled per-device HLO (result
    shapes of all-gather/all-reduce/reduce-scatter/all-to-all/
    collective-permute, including async -start forms).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh both --out results/dryrun.json
"""
import argparse
import dataclasses
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import REGISTRY, get_config, input_specs
from repro.configs.base import SHAPES, DECODE_SHAPES, ArchConfig
from repro.launch.mesh import make_production_mesh
from repro.models import lm as lm_mod
from repro.models.lm import layer_plan
from repro.optim.adamw import AdamWConfig, abstract_opt_state
from repro.train import sharding as shd
from repro.train.train_step import TrainOptions, make_train_step

# TPU v5e hardware constants (roofline).
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def parse_collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum result-shape bytes of every collective in the per-device HLO.

    Post-optimization HLO has untyped operands, so the result type is the
    reliable size source (== operand size for all-reduce / permute;
    gathered size for all-gather; scattered size for reduce-scatter — a
    consistent 'data surface' metric, noted in EXPERIMENTS.md).
    Async pairs are counted once (-start yes, -done no).
    """
    out = {c: 0.0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        eq = s.find("= ")
        if eq < 0:
            continue
        rhs = s[eq + 2:]
        for cname in _COLLECTIVES:
            m = re.search(r"\b" + re.escape(cname) + r"(-start)?\(", rhs)
            if not m or f"{cname}-done(" in rhs:
                continue
            result_part = rhs[:m.start()]
            for dt, dims in _SHAPE_RE.findall(result_part):
                if dt not in _DTYPE_BYTES:
                    continue
                n = 1
                for d in dims.split(","):
                    if d:
                        n *= int(d)
                out[cname] += n * _DTYPE_BYTES[dt]
            break
    out["total"] = sum(out.values())
    return out


# --------------------------------------------------------------------------- #
# Analytic attention correction (the flash-scan inner loop is a lax.scan —
# counted once by XLA cost analysis even in the unrolled probes).
# --------------------------------------------------------------------------- #
def attn_scan_flops(cfg: ArchConfig, shape_name: str) -> float:
    if shape_name in DECODE_SHAPES or cfg.family == "ssm":
        return 0.0
    seq, batch = SHAPES[shape_name]
    from repro.models.attention import FLASH_SCAN_THRESHOLD
    if seq <= FLASH_SCAN_THRESHOLD:
        return 0.0  # dense path, fully counted by the probes
    cycle, n_groups, rest = layer_plan(cfg)
    kinds = cycle * n_groups + rest
    bq = 512
    passes = 4.0 if shape_name == "train_4k" else 1.0  # fwd+remat+bwd(2x)
    total = 0.0
    for kind in kinds:
        if not kind.startswith("attn") and kind != "xdec":
            continue
        if kind == "attn_local" and cfg.swa_window is not None:
            s_kv = min(cfg.swa_window + bq, seq)
        else:
            s_kv = seq
        total += 4.0 * batch * seq * s_kv * cfg.n_heads * cfg.hd * passes
    return total


# --------------------------------------------------------------------------- #
# Step builders.
# --------------------------------------------------------------------------- #
def apply_variant(cfg: ArchConfig, variant: str) -> ArchConfig:
    """§Perf hillclimb variants (composable with '+'):
      moe_local16  — per-data-shard MoE dispatch (local_groups=16)
      kv_int8      — int8 ring KV caches
      cf1          — MoE capacity factor 1.0 (was 1.25)
    """
    for v in variant.split("+"):
        if v in ("", "base"):
            continue
        elif v == "moe_local16":
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, local_groups=16))
        elif v == "kv_int8":
            cfg = dataclasses.replace(cfg, kv_quant_int8=True)
        elif v == "actseq":
            cfg = dataclasses.replace(cfg, act_seq_shard=True)
        elif v == "cf1":
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=1.0))
        elif v in ("seqshard", "mb4", "mb8", "noremat", "f32grads"):
            pass  # handled in build_cell
        else:
            raise ValueError(f"unknown variant {v}")
    return cfg


def build_cell(cfg: ArchConfig, shape_name: str, mesh: Mesh,
               unroll: bool = False, opts: Optional[TrainOptions] = None,
               variant: str = "base"):
    """Returns (fn, args, in_shardings, dropped)."""
    cfg = apply_variant(cfg, variant)
    vset = set(variant.split("+"))
    seq, batch = SHAPES[shape_name]
    params_abs = lm_mod.abstract_params(cfg)
    p_specs, dropped = shd.param_specs(params_abs, mesh)
    data_specs = input_specs(cfg, shape_name)
    b_specs = shd.batch_specs(data_specs, mesh)

    if shape_name == "train_4k":
        opts = opts or TrainOptions(
            microbatches=4 if "mb4" in vset else 8 if "mb8" in vset else 1,
            remat="noremat" not in vset,
            grad_dtype="f32" if "f32grads" in vset else "bf16",
            zero1=True, unroll=unroll)
        opts = dataclasses.replace(opts, unroll=unroll)
        opt_cfg = AdamWConfig(total_steps=10000)
        step = make_train_step(cfg, opt_cfg, opts)
        opt_abs = abstract_opt_state(params_abs)
        p_train = shd.shard_over_data(p_specs, params_abs, mesh)
        o_specs = {"m": shd.shard_over_data(jax.tree.map(lambda s: s, p_specs),
                                            params_abs, mesh),
                   "v": shd.shard_over_data(jax.tree.map(lambda s: s, p_specs),
                                            params_abs, mesh),
                   "count": P()}
        args = (params_abs, opt_abs, data_specs)
        shardings = (p_train, o_specs, b_specs)
        return step, args, shardings, dropped

    if shape_name == "prefill_32k":
        def fn(params, batch):
            return lm_mod.prefill(params, cfg, batch, max_cache_len=seq,
                                  unroll=unroll)
        return fn, (params_abs, data_specs), (p_specs, b_specs), dropped

    caches_abs = lm_mod.serve_state(cfg, batch, seq, abstract=True)
    c_specs = shd.cache_specs(caches_abs, mesh,
                              seq_axes=("model",) if "seqshard" in vset else ())

    def fn(params, tokens, pos, caches):
        return lm_mod.decode_step(params, cfg, tokens, pos, caches,
                                  unroll=unroll)

    io_specs = shd.batch_specs(
        {"tokens": data_specs["tokens"], "pos": data_specs["pos"]}, mesh)
    args = (params_abs, data_specs["tokens"], data_specs["pos"], caches_abs)
    shardings = (p_specs, io_specs["tokens"], io_specs["pos"], c_specs)
    return fn, args, shardings, dropped


def _compile_and_measure(cfg, shape_name, mesh, unroll, variant="base"):
    fn, args, shardings, dropped = build_cell(cfg, shape_name, mesh, unroll,
                                              variant=variant)
    with mesh:
        in_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), shardings,
                             is_leaf=lambda x: isinstance(x, P))
        lowered = jax.jit(fn, in_shardings=in_sh).lower(*args)
        compiled = lowered.compile()
        cost = compiled.cost_analysis()
        coll = parse_collective_bytes(compiled.as_text())
        mem = compiled.memory_analysis()
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": coll,
        "mem": mem,
        "dropped": dropped,
    }


def _probe_cfg(cfg: ArchConfig, n_cycles: int) -> ArchConfig:
    cycle, n_groups, rest = layer_plan(cfg)
    return dataclasses.replace(
        cfg, n_layers=n_cycles * len(cycle) + len(rest))


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             probes: bool = True, variant: str = "base") -> Dict[str, Any]:
    cfg = get_config(arch)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "variant": variant,
    }
    if shape_name in cfg.skip_shapes:
        rec["status"] = "skipped"
        rec["reason"] = cfg.notes
        return rec
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    try:
        cycle, n_groups, rest = layer_plan(cfg)
        # 1. Full-depth compile (scan) — the runnability + memory artifact.
        full = _compile_and_measure(cfg, shape_name, mesh, unroll=False,
                                    variant=variant)
        mem = full["mem"]
        rec_mem = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
        # 2. Depth probes (unrolled) -> exact per-cycle slopes.
        if probes:
            a = _compile_and_measure(_probe_cfg(cfg, 1), shape_name, mesh, True,
                                     variant=variant)
            b = _compile_and_measure(_probe_cfg(cfg, 2), shape_name, mesh, True,
                                     variant=variant)
            slope_f = b["flops"] - a["flops"]
            slope_b = b["bytes"] - a["bytes"]
            flops_pd = a["flops"] + slope_f * (n_groups - 1)
            bytes_pd = a["bytes"] + slope_b * (n_groups - 1)
            coll = {}
            for k in list(a["coll"]):
                slope = b["coll"][k] - a["coll"][k]
                coll[k] = a["coll"][k] + slope * (n_groups - 1)
            rec["probe"] = {
                "cycle_len": len(cycle), "n_groups": n_groups,
                "rest": len(rest),
                "flops_1c": a["flops"], "flops_2c": b["flops"],
                "full_scan_flops": full["flops"],
            }
        else:
            flops_pd, bytes_pd, coll = full["flops"], full["bytes"], full["coll"]

        # 3. Analytic attention inner-scan correction (global -> per device).
        attn_corr = attn_scan_flops(cfg, shape_name) / n_chips
        flops_pd_corr = flops_pd + attn_corr

        seq, batch = SHAPES[shape_name]
        n_param = cfg.param_count()
        n_active = cfg.active_param_count()
        d_tokens = batch * (1 if shape_name in DECODE_SHAPES else seq)
        mult = 6 if shape_name == "train_4k" else 2
        model_flops = mult * n_active * d_tokens

        rec.update({
            "status": "ok",
            "n_chips": n_chips,
            "compile_s": round(time.time() - t0, 1),
            "dropped_shardings": full["dropped"],
            "memory": rec_mem,
            "flops_per_device": flops_pd_corr,
            "flops_per_device_hlo": flops_pd,
            "attn_scan_correction_pd": attn_corr,
            "hbm_bytes_per_device": bytes_pd,
            "collective_bytes_per_device": coll,
            "model_flops_global": model_flops,
            "params": n_param,
            "active_params": n_active,
            "roofline": {
                "compute_s": flops_pd_corr / PEAK_FLOPS,
                "memory_s": bytes_pd / HBM_BW,
                "collective_s": coll["total"] / ICI_BW,
            },
        })
        terms = rec["roofline"]
        rec["bottleneck"] = max(terms, key=terms.get)
        rec["useful_flops_frac"] = (model_flops / (flops_pd_corr * n_chips)
                                    if flops_pd_corr else None)
        rec["step_time_bound_s"] = max(terms.values())
    except Exception as e:  # noqa: BLE001
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--no-probes", action="store_true",
                    help="compile-only pass (multi-pod runnability check)")
    ap.add_argument("--out", default="results/dryrun.json")
    args = ap.parse_args()

    archs = list(REGISTRY) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    # Retry errored cells on resume; keep ok/skipped.
    results = [r for r in results if r["status"] != "error"]
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results}

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                key = (arch, shape, "2x16x16" if mp else "16x16")
                if key in done:
                    continue
                print(f"[dryrun] {key} ...", flush=True)
                # Roofline probes on the single-pod mesh only (the table is
                # single-pod; multi-pod proves the pod axis shards).
                rec = run_cell(arch, shape, mp,
                               probes=(not mp) and (not args.no_probes))
                results.append(rec)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
                status = rec["status"]
                extra = (f" bottleneck={rec.get('bottleneck')}"
                         f" compile={rec.get('compile_s')}s"
                         if status == "ok" else f" {rec.get('error', '')[:160]}")
                print(f"[dryrun] {key} -> {status}{extra}", flush=True)

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
