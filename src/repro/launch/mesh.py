"""Production mesh construction.

A function (NOT a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the `pod` axis is
    pure data-parallel (one cross-pod gradient all-reduce per step — the
    only DCN-crossing collective, by construction)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for subprocess-based distribution tests."""
    return jax.make_mesh(shape, axes)
