"""Serving launcher: batched generation with ``--arch <id>``.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --smoke
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.models import init_params
from repro.serve import Engine, Request, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-prompt", type=int, default=32)
    ap.add_argument("--batch-size", type=int, default=4)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    engine = Engine(cfg, params,
                    ServeConfig(batch_size=args.batch_size,
                                max_prompt=args.max_prompt,
                                max_new=args.max_new))
    rng = np.random.default_rng(0)
    reqs = [Request(rng.integers(0, cfg.vocab,
                                 rng.integers(3, args.max_prompt))
                    .astype(np.int32), args.max_new)
            for _ in range(args.requests)]
    t0 = time.perf_counter()
    results = engine.generate(reqs)
    dt = time.perf_counter() - t0
    n = sum(len(r.tokens) for r in results)
    print(f"{len(reqs)} requests -> {n} tokens in {dt:.2f}s")


if __name__ == "__main__":
    main()
