# NOTE: deliberately does NOT import submodules — dryrun must set XLA_FLAGS
# before anything touches jax device state.
