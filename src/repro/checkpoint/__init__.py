from repro.checkpoint.checkpointer import (Checkpointer,
                                           CheckpointIntegrityError,
                                           STREAM_CKPT_VERSION,
                                           load_stream_checkpoint,
                                           save_stream_checkpoint,
                                           stream_checkpoint_steps)

__all__ = ["Checkpointer", "CheckpointIntegrityError", "STREAM_CKPT_VERSION",
           "load_stream_checkpoint", "save_stream_checkpoint",
           "stream_checkpoint_steps"]
