"""Sharded, topology-independent checkpointing with async save.

Layout (orbax-lite, one directory per step):

    ckpt_dir/step_000123/
        manifest.json        # tree structure, global shapes/dtypes
        leaf_0000/shard_0_of_K.npy ...   # per-addressable-shard chunks
        leaf_0001/...

Design points for the 1000-node posture:
  * each host writes only its *addressable* shards (no gather);
  * the manifest is keyed by global shape + per-shard index maps, so a
    restore onto a DIFFERENT mesh (elastic downsize/upsize) reshapes via
    ``jax.make_array_from_callback`` — shard files are read per need;
  * saves run on a background thread (training continues; ``wait()``
    joins), and a ``step_XXXX.tmp`` -> rename commit makes saves atomic —
    a crash mid-save never corrupts the latest good checkpoint;
  * retention keeps the newest ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def _np_dtype(name: str) -> np.dtype:
    """Resolve dtype names incl. ml_dtypes extensions (bfloat16...)."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _save_arr(path: str, data: np.ndarray) -> None:
    """np.save with a lossless f32 detour for non-native dtypes (bf16):
    np.save stores ml_dtypes arrays as raw void records that np.load
    cannot cast back."""
    if data.dtype.kind == "V" or data.dtype.name not in np.sctypeDict:
        np.save(path, np.asarray(data, np.float32))
    else:
        np.save(path, data)


def _load_arr(path: str, dtype: np.dtype) -> np.ndarray:
    return np.load(path).astype(dtype)


def _leaf_dirname(i: int) -> str:
    return f"leaf_{i:04d}"


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------ #
    def save(self, step: int, tree: PyTree, blocking: bool = False) -> None:
        """Snapshot to host memory synchronously, write asynchronously."""
        self.wait()
        leaves, treedef = jax.tree.flatten(tree)
        # Materialize addressable shards NOW (cheap device->host copies) so
        # training can mutate buffers while the writer thread runs.
        snaps: List[Tuple[Dict, List[Tuple[Tuple, np.ndarray]]]] = []
        for leaf in leaves:
            arr = jax.device_put(leaf) if not hasattr(leaf, "addressable_shards") else leaf
            shards = []
            for sh in arr.addressable_shards:
                idx = tuple((s.start or 0, s.stop if s.stop is not None else dim)
                            for s, dim in zip(sh.index, arr.shape)) \
                    if arr.ndim else ()
                shards.append((idx, np.asarray(sh.data)))
            meta = {"shape": list(arr.shape), "dtype": str(np.dtype(arr.dtype))}
            snaps.append((meta, shards))
        manifest = {
            "step": step,
            "treedef": jax.tree_util.tree_structure(tree).serialize_using_proto().hex(),
            "n_leaves": len(leaves),
            "leaves": [m for m, _ in snaps],
        }

        def write():
            tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
            final = os.path.join(self.dir, f"step_{step:08d}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            for i, (_, shards) in enumerate(snaps):
                d = os.path.join(tmp, _leaf_dirname(i))
                os.makedirs(d)
                for j, (idx, data) in enumerate(shards):
                    _save_arr(os.path.join(d, f"shard_{j}.npy"), data)
                    with open(os.path.join(d, f"shard_{j}.idx.json"), "w") as f:
                        json.dump({"index": [list(t) for t in idx]}, f)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error:
            raise self._error

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------ #
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------------ #
    def restore(self, step: int, target: PyTree,
                shardings: Optional[PyTree] = None) -> PyTree:
        """Restore into the structure of ``target`` (arrays or
        ShapeDtypeStructs), placing shards per ``shardings`` (defaults to
        the target's own shardings / fully replicated).

        Elastic: the stored shard partition need not match the new mesh —
        each requested output shard is assembled from the covering stored
        chunks.
        """
        root = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(root, "manifest.json")) as f:
            manifest = json.load(f)
        leaves, treedef = jax.tree.flatten(target)
        if manifest["n_leaves"] != len(leaves):
            raise ValueError(
                f"checkpoint has {manifest['n_leaves']} leaves, target has "
                f"{len(leaves)} — structure mismatch")
        shard_list = jax.tree.leaves(shardings) if shardings is not None else \
            [getattr(l, "sharding", None) for l in leaves]

        out_leaves = []
        for i, (leaf, meta) in enumerate(zip(leaves, manifest["leaves"])):
            d = os.path.join(root, _leaf_dirname(i))
            shape = tuple(meta["shape"])
            dtype = _np_dtype(meta["dtype"])
            if tuple(leaf.shape) != shape:
                raise ValueError(f"leaf {i}: stored {shape} != target {leaf.shape}")
            # Load and assemble the global array from chunks.
            full = np.empty(shape, dtype)
            j = 0
            while os.path.exists(os.path.join(d, f"shard_{j}.npy")):
                data = _load_arr(os.path.join(d, f"shard_{j}.npy"), dtype)
                with open(os.path.join(d, f"shard_{j}.idx.json")) as f:
                    idx = json.load(f)["index"]
                sl = tuple(slice(a, b) for a, b in idx)
                full[sl] = data
                j += 1
            sharding = shard_list[i]
            if sharding is not None:
                arr = jax.make_array_from_callback(
                    shape, sharding, lambda sl, _full=full: _full[sl])
            else:
                arr = jax.device_put(full.astype(dtype))
            out_leaves.append(arr)
        return treedef.unflatten(out_leaves)
