"""Sharded, topology-independent checkpointing with async save.

Layout (orbax-lite, one directory per step):

    ckpt_dir/step_000123/
        manifest.json        # tree structure, global shapes/dtypes
        leaf_0000/shard_0_of_K.npy ...   # per-addressable-shard chunks
        leaf_0001/...

Design points for the 1000-node posture:
  * each host writes only its *addressable* shards (no gather);
  * the manifest is keyed by global shape + per-shard index maps, so a
    restore onto a DIFFERENT mesh (elastic downsize/upsize) reshapes via
    ``jax.make_array_from_callback`` — shard files are read per need;
  * saves run on a background thread (training continues; ``wait()``
    joins), and a ``step_XXXX.tmp`` -> rename commit makes saves atomic —
    a crash mid-save never corrupts the latest good checkpoint;
  * retention keeps the newest ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def _np_dtype(name: str) -> np.dtype:
    """Resolve dtype names incl. ml_dtypes extensions (bfloat16...)."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _save_arr(path: str, data: np.ndarray) -> None:
    """np.save with a lossless f32 detour for non-native dtypes (bf16):
    np.save stores ml_dtypes arrays as raw void records that np.load
    cannot cast back."""
    if data.dtype.kind == "V" or data.dtype.name not in np.sctypeDict:
        np.save(path, np.asarray(data, np.float32))
    else:
        np.save(path, data)


def _load_arr(path: str, dtype: np.dtype) -> np.ndarray:
    return np.load(path).astype(dtype)


def _leaf_dirname(i: int) -> str:
    return f"leaf_{i:04d}"


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------ #
    def save(self, step: int, tree: PyTree, blocking: bool = False) -> None:
        """Snapshot to host memory synchronously, write asynchronously."""
        self.wait()
        leaves, treedef = jax.tree.flatten(tree)
        # Materialize addressable shards NOW (cheap device->host copies) so
        # training can mutate buffers while the writer thread runs.
        snaps: List[Tuple[Dict, List[Tuple[Tuple, np.ndarray]]]] = []
        for leaf in leaves:
            arr = jax.device_put(leaf) if not hasattr(leaf, "addressable_shards") else leaf
            shards = []
            for sh in arr.addressable_shards:
                idx = tuple((s.start or 0, s.stop if s.stop is not None else dim)
                            for s, dim in zip(sh.index, arr.shape)) \
                    if arr.ndim else ()
                shards.append((idx, np.asarray(sh.data)))
            meta = {"shape": list(arr.shape), "dtype": str(np.dtype(arr.dtype))}
            snaps.append((meta, shards))
        manifest = {
            "step": step,
            "treedef": jax.tree_util.tree_structure(tree).serialize_using_proto().hex(),
            "n_leaves": len(leaves),
            "leaves": [m for m, _ in snaps],
        }

        def write():
            tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
            final = os.path.join(self.dir, f"step_{step:08d}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            for i, (_, shards) in enumerate(snaps):
                d = os.path.join(tmp, _leaf_dirname(i))
                os.makedirs(d)
                for j, (idx, data) in enumerate(shards):
                    _save_arr(os.path.join(d, f"shard_{j}.npy"), data)
                    with open(os.path.join(d, f"shard_{j}.idx.json"), "w") as f:
                        json.dump({"index": [list(t) for t in idx]}, f)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error:
            raise self._error

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------ #
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------------ #
    def restore(self, step: int, target: PyTree,
                shardings: Optional[PyTree] = None) -> PyTree:
        """Restore into the structure of ``target`` (arrays or
        ShapeDtypeStructs), placing shards per ``shardings`` (defaults to
        the target's own shardings / fully replicated).

        Elastic: the stored shard partition need not match the new mesh —
        each requested output shard is assembled from the covering stored
        chunks.
        """
        root = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(root, "manifest.json")) as f:
            manifest = json.load(f)
        leaves, treedef = jax.tree.flatten(target)
        if manifest["n_leaves"] != len(leaves):
            raise ValueError(
                f"checkpoint has {manifest['n_leaves']} leaves, target has "
                f"{len(leaves)} — structure mismatch")
        shard_list = jax.tree.leaves(shardings) if shardings is not None else \
            [getattr(l, "sharding", None) for l in leaves]

        out_leaves = []
        for i, (leaf, meta) in enumerate(zip(leaves, manifest["leaves"])):
            d = os.path.join(root, _leaf_dirname(i))
            shape = tuple(meta["shape"])
            dtype = _np_dtype(meta["dtype"])
            if tuple(leaf.shape) != shape:
                raise ValueError(f"leaf {i}: stored {shape} != target {leaf.shape}")
            # Load and assemble the global array from chunks.
            full = np.empty(shape, dtype)
            j = 0
            while os.path.exists(os.path.join(d, f"shard_{j}.npy")):
                data = _load_arr(os.path.join(d, f"shard_{j}.npy"), dtype)
                with open(os.path.join(d, f"shard_{j}.idx.json")) as f:
                    idx = json.load(f)["index"]
                sl = tuple(slice(a, b) for a, b in idx)
                full[sl] = data
                j += 1
            sharding = shard_list[i]
            if sharding is not None:
                arr = jax.make_array_from_callback(
                    shape, sharding, lambda sl, _full=full: _full[sl])
            else:
                arr = jax.device_put(full.astype(dtype))
            out_leaves.append(arr)
        return treedef.unflatten(out_leaves)


# ---------------------------------------------------------------------- #
# Durable stream checkpoints (PR 10): CRC'd, atomic, versioned snapshots
# of a whole run-in-progress — NetworkState rings + cursors, fire counts,
# stream cursors, trace ring — written at chunk boundaries by
# ``Program.stream`` / ``Program.run_checkpointed`` and read back by
# ``Program.resume_stream`` / ``Program.resume_run`` after a process
# kill.  Unlike ``Checkpointer`` (a params store restoring into a known
# target template), these snapshots describe their own structure: the
# payload is a JSON skeleton of plain containers whose array leaves live
# in per-leaf ``.npy`` files, each carrying a CRC32 in the manifest.
# A torn write can never be loaded (tmp-dir + ``os.replace`` commit);
# a bit-rotted one is detected by CRC and skipped in favor of the next
# older intact snapshot.
# ---------------------------------------------------------------------- #
STREAM_CKPT_VERSION = 1


class CheckpointIntegrityError(RuntimeError):
    """No intact stream checkpoint could be loaded from a directory."""


def _skeletonize(obj: Any, leaves: List[np.ndarray]) -> Any:
    """Split a plain-container payload into (JSON skeleton, array leaves)."""
    if isinstance(obj, (np.ndarray, jax.Array)):
        leaves.append(np.asarray(obj))
        return {"__leaf__": len(leaves) - 1}
    if isinstance(obj, dict):
        return {"__dict__": {str(k): _skeletonize(v, leaves)
                             for k, v in obj.items()}}
    if isinstance(obj, (list, tuple)):
        kind = "__tuple__" if isinstance(obj, tuple) else "__list__"
        return {kind: [_skeletonize(v, leaves) for v in obj]}
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return {"__val__": obj}
    if isinstance(obj, (np.integer, np.floating, np.bool_)):
        return {"__val__": obj.item()}
    raise TypeError(
        f"stream checkpoint payload holds a {type(obj).__name__}; only "
        "arrays, dicts, lists/tuples and JSON scalars are serializable")


def _unskeletonize(skel: Any, leaves: List[np.ndarray]) -> Any:
    if "__leaf__" in skel:
        return leaves[skel["__leaf__"]]
    if "__dict__" in skel:
        return {k: _unskeletonize(v, leaves)
                for k, v in skel["__dict__"].items()}
    if "__list__" in skel:
        return [_unskeletonize(v, leaves) for v in skel["__list__"]]
    if "__tuple__" in skel:
        return tuple(_unskeletonize(v, leaves) for v in skel["__tuple__"])
    return skel["__val__"]


def _step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"chunk_{step:08d}")


def save_stream_checkpoint(directory: str, step: int, payload: PyTree,
                           meta: Optional[Dict[str, Any]] = None,
                           keep: Optional[int] = 3) -> str:
    """Write one durable snapshot; returns its committed path.

    ``payload`` must be plain containers (dict/list/tuple) of arrays and
    JSON scalars — e.g. a ``NetworkState`` passed through
    ``state["fifos"]`` / ``state["actors"]`` dict views, never the raw
    registered pytree (its static metadata would not survive a process
    boundary).  ``keep`` bounds retention (None keeps everything; the
    default 3 leaves enough history for CRC fallback).
    """
    leaves: List[np.ndarray] = []
    skel = _skeletonize(payload, leaves)
    tmp = _step_dir(directory, step) + ".tmp"
    final = _step_dir(directory, step)
    os.makedirs(directory, exist_ok=True)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaf_meta = []
    for i, arr in enumerate(leaves):
        fname = f"leaf_{i:04d}.npy"
        _save_arr(os.path.join(tmp, fname), arr)
        with open(os.path.join(tmp, fname), "rb") as f:
            crc = zlib.crc32(f.read())
        leaf_meta.append({"file": fname, "crc32": crc,
                          "shape": list(arr.shape),
                          "dtype": str(arr.dtype)})
    manifest = {"format_version": STREAM_CKPT_VERSION, "step": step,
                "skeleton": skel, "leaves": leaf_meta,
                "meta": dict(meta or {})}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    if keep:
        for s in stream_checkpoint_steps(directory)[:-keep]:
            shutil.rmtree(_step_dir(directory, s), ignore_errors=True)
    return final


def stream_checkpoint_steps(directory: str) -> List[int]:
    """Committed (non-tmp) snapshot steps, ascending."""
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("chunk_") and not name.endswith(".tmp"):
            try:
                out.append(int(name.split("_")[1]))
            except ValueError:
                continue
    return sorted(out)


def _load_one(directory: str, step: int) -> Tuple[PyTree, Dict[str, Any]]:
    root = _step_dir(directory, step)
    with open(os.path.join(root, "manifest.json")) as f:
        manifest = json.load(f)
    ver = manifest.get("format_version")
    if ver != STREAM_CKPT_VERSION:
        raise CheckpointIntegrityError(
            f"{root}: format_version {ver} != supported "
            f"{STREAM_CKPT_VERSION}")
    leaves = []
    for m in manifest["leaves"]:
        path = os.path.join(root, m["file"])
        with open(path, "rb") as f:
            crc = zlib.crc32(f.read())
        if crc != m["crc32"]:
            raise CheckpointIntegrityError(
                f"{path}: CRC32 {crc:#010x} != manifest {m['crc32']:#010x} "
                "(bit rot or torn write)")
        leaves.append(_load_arr(path, _np_dtype(m["dtype"])))
    payload = _unskeletonize(manifest["skeleton"], leaves)
    return payload, manifest["meta"]


def load_stream_checkpoint(directory: str, step: Optional[int] = None
                           ) -> Tuple[PyTree, Dict[str, Any], int]:
    """Load the newest intact snapshot (or exactly ``step`` if given).

    Returns ``(payload, meta, step)``.  A snapshot failing its CRC or
    version check is skipped and the next older one is tried — so a
    crash *during* a save (already ruled out by the atomic rename) or
    later on-disk corruption degrades to losing one cadence interval,
    never the whole run.  Raises :class:`CheckpointIntegrityError` when
    nothing intact remains.
    """
    steps = ([step] if step is not None
             else list(reversed(stream_checkpoint_steps(directory))))
    if not steps:
        raise CheckpointIntegrityError(
            f"{directory}: no stream checkpoints found")
    errors = []
    for s in steps:
        try:
            payload, meta = _load_one(directory, s)
            return payload, meta, s
        except (CheckpointIntegrityError, OSError, KeyError,
                json.JSONDecodeError) as e:
            errors.append(f"chunk_{s:08d}: {e}")
    raise CheckpointIntegrityError(
        f"{directory}: every snapshot failed integrity checks:\n  "
        + "\n  ".join(errors))
