"""h2o-danube-3-4b [dense] — llama+mistral mix, sliding-window attention.
[arXiv:2401.16818; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab=32000,
    head_dim=120,                 # d_model / n_heads
    rope_theta=10000.0,
    swa_window=4096,
    attn_pattern=(0,),            # uniform SWA (mistral-style)
    notes="uniform SWA window 4096 -> sub-quadratic; long_500k runs",
)
