"""Architecture config system.

Every assigned architecture is a declarative :class:`ArchConfig`; the model
builders in ``repro.models`` consume it, the launcher selects one with
``--arch <id>``, and ``input_specs`` produces ShapeDtypeStruct stand-ins
for each of the four assigned input shapes (no allocation — dry-run safe).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------- #
# The four assigned LM shapes (seq_len, global_batch).
# ---------------------------------------------------------------------- #
SHAPES: Dict[str, Tuple[int, int]] = {
    "train_4k": (4096, 256),        # training
    "prefill_32k": (32768, 32),     # inference prefill
    "decode_32k": (32768, 128),     # one new token, 32k KV cache
    "long_500k": (524288, 1),       # long-context decode (sub-quadratic only)
}
DECODE_SHAPES = ("decode_32k", "long_500k")


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    local_groups: int = 0          # >0: per-data-shard dispatch (§Perf)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma recurrent block (RG-LRU + conv)."""
    lru_width: Optional[int] = None   # defaults to d_model
    conv_width: int = 4
    # layer pattern entry codes: 0 = recurrent block, 1 = local attention
    pattern: Tuple[int, ...] = (0, 0, 1)


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Audio/vision frontend stub: precomputed embeddings enter here."""
    n_layers: int
    n_ctx: int               # frames / patches
    d_model: int
    n_heads: int
    d_ff: int


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str              # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    rms_eps: float = 1e-6
    tie_embeddings: bool = False
    # Sliding-window attention: window size, and the cyclic layer pattern
    # (1 = global/full attention, 0 = local/SWA).  Uniform-SWA models use
    # pattern (0,); uniform-full models use (1,).
    swa_window: Optional[int] = None
    attn_pattern: Tuple[int, ...] = (1,)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    encoder: Optional[EncoderConfig] = None   # whisper / vlm frontend
    n_vision_tokens: int = 0                  # vlm: patch embeddings prepended
    kv_quant_int8: bool = False               # §Perf: int8 KV cache
    act_seq_shard: bool = False               # §Perf: Megatron-SP activations
    # Which assigned shapes run (long_500k only for sub-quadratic archs;
    # skips recorded in the dry-run table + DESIGN.md §6).
    skip_shapes: Tuple[str, ...] = ()
    notes: str = ""

    # ------------------------------------------------------------------ #
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to a multiple of 256 (Megatron-style padding) so
        the embedding/unembedding shard cleanly over the model axis — an
        unpadded 50280 vocab measured 13 GB/device of replicated f32 logits
        traffic in the dry-run (EXPERIMENTS.md §Dry-run)."""
        return -(-self.vocab // 256) * 256

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def param_count(self) -> int:
        """Total parameter count N (for 6·N·D roofline accounting)."""
        d, L = self.d_model, self.n_layers
        n = self.vocab * d  # embed
        if not self.tie_embeddings:
            n += self.vocab * d
        if self.family == "ssm":
            s = self.ssm
            di = s.d_inner(d)
            nh = s.n_heads(d)
            conv_dim = di + 2 * s.state_dim
            per = (d * (2 * di + 2 * s.state_dim + nh)   # in_proj
                   + conv_dim * s.conv_width              # conv1d
                   + nh                                   # dt bias
                   + nh + nh                              # A_log, D
                   + di                                   # gate norm
                   + di * d                               # out_proj
                   + d)                                   # pre-norm
            return n + L * per
        # attention sublayer
        attn = d * self.n_heads * self.hd + d * 2 * self.n_kv_heads * self.hd \
            + self.n_heads * self.hd * d
        if self.qkv_bias:
            attn += (self.n_heads + 2 * self.n_kv_heads) * self.hd
        # mlp sublayer (SwiGLU: 3 mats) or MoE experts
        if self.moe is not None:
            mlp = self.moe.n_experts * 3 * d * self.moe.d_ff_expert + d * self.moe.n_experts
        else:
            mlp = 3 * d * self.d_ff
        per = attn + mlp + 2 * d  # two norms
        total = n + L * per + d   # final norm
        if self.rglru is not None:
            # recurrent layers replace attention with RG-LRU machinery;
            # close enough for roofline purposes (exact count in DESIGN.md)
            pass
        if self.encoder is not None:
            e = self.encoder
            enc_per = (4 * e.d_model * e.d_model + 2 * e.d_model * e.d_ff + 2 * e.d_model)
            total += e.n_layers * enc_per + e.n_ctx * e.d_model
            if self.family == "audio":
                # decoder cross-attention adds 4 more projections per layer
                total += L * 4 * d * d
        return total

    def active_param_count(self) -> int:
        """N_active for MoE (6·N_active·D)."""
        if self.moe is None:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        full = self.param_count()
        all_experts = L * self.moe.n_experts * 3 * d * self.moe.d_ff_expert
        active = L * self.moe.top_k * 3 * d * self.moe.d_ff_expert
        return full - all_experts + active

    def shapes(self) -> Dict[str, Tuple[int, int]]:
        return {k: v for k, v in SHAPES.items() if k not in self.skip_shapes}


# ---------------------------------------------------------------------- #
# Input specs (ShapeDtypeStruct stand-ins) per (arch, shape, step kind).
# ---------------------------------------------------------------------- #
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def input_specs(cfg: ArchConfig, shape_name: str) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model *data* inputs for the given assigned shape.

    train/prefill shapes feed token ids (plus frontend-stub embeddings for
    audio/vlm); decode shapes feed one token per sequence (the KV/SSM cache
    is part of the serve state, built by ``repro.serve.state_specs``).
    """
    if shape_name in cfg.skip_shapes:
        raise ValueError(f"{cfg.name}: shape {shape_name} is skipped "
                         f"(see DESIGN.md §6): {cfg.notes}")
    seq, batch = SHAPES[shape_name]
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if shape_name in DECODE_SHAPES:
        specs["tokens"] = _sds((batch, 1), jnp.int32)
        specs["pos"] = _sds((batch,), jnp.int32)
    else:
        n_txt = seq
        if cfg.family == "vlm":
            n_txt = seq - cfg.n_vision_tokens
            specs["vision_embeds"] = _sds(
                (batch, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16)
        specs["tokens"] = _sds((batch, n_txt), jnp.int32)
        if shape_name == "train_4k":
            specs["labels"] = _sds((batch, n_txt), jnp.int32)
    if cfg.family == "audio":
        # Conv frontend stub: precomputed frame embeddings (paper-assigned
        # backbone only; see DESIGN.md).
        e = cfg.encoder
        specs["frames"] = _sds((batch, e.n_ctx, e.d_model), jnp.bfloat16)
    return specs
