"""whisper-small [audio] — enc-dec transformer backbone; conv frontend is a
STUB (input_specs provides precomputed frame embeddings).
[arXiv:2212.04356; unverified]"""
from repro.configs.base import ArchConfig, EncoderConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,                  # decoder layers
    d_model=768,
    n_heads=12,
    n_kv_heads=12,                # GQA kv=12 == MHA
    d_ff=3072,
    vocab=51865,
    head_dim=64,
    rope_theta=10000.0,           # backbone uses RoPE in this repro (learned
                                  # pos-emb in the original; DESIGN.md §8)
    attn_pattern=(1,),
    encoder=EncoderConfig(n_layers=12, n_ctx=1500, d_model=768, n_heads=12,
                          d_ff=3072),
    skip_shapes=("long_500k",),
    notes="enc-dec audio; decode_32k runs (it is enc-dec, not encoder-only); "
          "512k text decode out of domain -> long_500k skipped",
)
