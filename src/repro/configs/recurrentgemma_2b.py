"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1 attn : 2
recurrent pattern. [arXiv:2402.19427; hf]

The RG-LRU recurrence is the paper's delay-token feedback FIFO (IIR
example); the 2:1 layer cycle is a CSDF rate table (DESIGN.md §6)."""
from repro.configs.base import ArchConfig, RGLRUConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab=256000,
    head_dim=256,
    rope_theta=10000.0,
    tie_embeddings=True,
    swa_window=2048,
    # pattern entries: 0 = RG-LRU recurrent block, 1 = local attention
    rglru=RGLRUConfig(lru_width=2560, conv_width=4, pattern=(0, 0, 1)),
    attn_pattern=(0,),            # its attention layers are all local (SWA)
    notes="hybrid (recurrent + SWA) -> sub-quadratic; long_500k runs",
)
