"""internvl2-1b [vlm] — InternViT frontend (stub) + InternLM2 backbone.
[arXiv:2404.16821; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab=151655,
    head_dim=64,
    rope_theta=1_000_000.0,
    attn_pattern=(1,),
    n_vision_tokens=256,          # ViT patch-embedding stub, prepended
    skip_shapes=("long_500k",),
    notes="full-attention LM backbone -> long_500k skipped; vision frontend "
          "is a stub supplying precomputed patch embeddings",
)
