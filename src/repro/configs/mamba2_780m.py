"""mamba2-780m [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]

The paper's dynamic-rate mechanism is inapplicable to an attention-free
SSM (no routing, no variable consumption); it appears only as the
delay-token state-feedback FIFO of the recurrence (DESIGN.md §6)."""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,                    # attention-free
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4, chunk=256),
    notes="SSM -> sub-quadratic; long_500k runs (O(1) decode state)",
)
