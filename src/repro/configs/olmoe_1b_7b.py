"""olmoe-1b-7b [moe] — 64 experts top-8. [arXiv:2409.02060; hf]"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab=50304,
    head_dim=128,
    rope_theta=10000.0,
    attn_pattern=(1,),
    moe=MoEConfig(n_experts=64, top_k=8, d_ff_expert=1024),
    skip_shapes=("long_500k",),
    notes="full attention -> long_500k skipped; experts = dynamic actors",
)
