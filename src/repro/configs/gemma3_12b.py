"""gemma3-12b [dense] — 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    d_ff=15360,
    vocab=262144,
    head_dim=240,                  # d_model / n_heads
    rope_theta=1_000_000.0,
    tie_embeddings=True,           # gemma family ties embeddings
    swa_window=1024,
    attn_pattern=(0, 0, 0, 0, 0, 1),   # 5 local : 1 global
    # SWA-dominant -> sub-quadratic decode; long_500k runs.
    notes="5:1 local:global; long_500k served by SWA ring caches + sparse "
          "global layers (8 of 48 full)",
)
