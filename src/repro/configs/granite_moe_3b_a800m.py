"""granite-moe-3b-a800m [moe] — 40 experts top-8, d_ff_expert=512.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]

Dynamic-rate showcase: the router is the paper's control actor; every
expert is a dynamic actor with per-firing token rate 0..capacity
(DESIGN.md §3, graphs/moe_as_actors.py)."""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,                     # per-expert ff (assignment block)
    vocab=49155,
    head_dim=64,
    rope_theta=10000.0,
    attn_pattern=(1,),
    moe=MoEConfig(n_experts=40, top_k=8, d_ff_expert=512),
    skip_shapes=("long_500k",),
    notes="full attention -> long_500k skipped; experts = dynamic actors",
)
