"""Config registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

from typing import Dict

from repro.configs.base import (ArchConfig, EncoderConfig, MoEConfig,
                                RGLRUConfig, SSMConfig, SHAPES, DECODE_SHAPES,
                                input_specs)

from repro.configs.gemma3_12b import CONFIG as _gemma3
from repro.configs.h2o_danube_3_4b import CONFIG as _danube
from repro.configs.qwen2_72b import CONFIG as _qwen2
from repro.configs.granite_8b import CONFIG as _granite
from repro.configs.whisper_small import CONFIG as _whisper
from repro.configs.granite_moe_3b_a800m import CONFIG as _granite_moe
from repro.configs.olmoe_1b_7b import CONFIG as _olmoe
from repro.configs.recurrentgemma_2b import CONFIG as _rgemma
from repro.configs.internvl2_1b import CONFIG as _internvl
from repro.configs.mamba2_780m import CONFIG as _mamba2

REGISTRY: Dict[str, ArchConfig] = {c.name: c for c in [
    _gemma3, _danube, _qwen2, _granite, _whisper,
    _granite_moe, _olmoe, _rgemma, _internvl, _mamba2,
]}


def get_config(name: str) -> ArchConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(REGISTRY)}")
    return REGISTRY[name]


def smoke_config(name: str) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests (small layers/width,
    few experts, tiny vocab — per the assignment block)."""
    import dataclasses
    cfg = get_config(name)
    kw = dict(
        n_layers=min(cfg.n_layers, 4) if cfg.rglru is None else 6,
        d_model=64,
        n_heads=max(cfg.n_heads // 4, 2) if cfg.n_heads else 0,
        n_kv_heads=max(min(cfg.n_kv_heads, cfg.n_heads // 4 or 1), 1) if cfg.n_heads else 0,
        d_ff=128,
        vocab=503,
        head_dim=16 if cfg.n_heads else None,
        swa_window=16 if cfg.swa_window else None,
        n_vision_tokens=8 if cfg.n_vision_tokens else 0,
    )
    if cfg.moe is not None:
        kw["moe"] = MoEConfig(n_experts=8, top_k=2, d_ff_expert=32,
                              capacity_factor=8.0)  # drop-free for smoke consistency
        kw["d_ff"] = 32
    if cfg.ssm is not None:
        kw["ssm"] = SSMConfig(state_dim=16, head_dim=16, expand=2, conv_width=4, chunk=8)
        kw["n_heads"] = 0
        kw["n_kv_heads"] = 0
        kw["head_dim"] = None
        kw["d_ff"] = 0
    if cfg.rglru is not None:
        kw["rglru"] = RGLRUConfig(lru_width=64, conv_width=4, pattern=cfg.rglru.pattern)
    if cfg.encoder is not None:
        kw["encoder"] = EncoderConfig(n_layers=2, n_ctx=24, d_model=64,
                                      n_heads=2, d_ff=128)
    if cfg.n_kv_heads and cfg.n_heads and kw["n_heads"] % kw["n_kv_heads"]:
        kw["n_kv_heads"] = 1
    return dataclasses.replace(cfg, **kw)


__all__ = ["ArchConfig", "MoEConfig", "SSMConfig", "RGLRUConfig",
           "EncoderConfig", "REGISTRY", "get_config", "smoke_config",
           "SHAPES", "DECODE_SHAPES", "input_specs"]
