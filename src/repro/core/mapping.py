"""Actor-to-device mapping — paper §3.3 adapted to the mesh world.

The paper maps each actor either to a GPP core (fixed or OS-chosen "free"
mapping) or to the OpenCL/GPU device.  On a TPU pod the analogue is:

  * ``heterogeneous_split``  — partition a network into a host-resident
    part (sources/sinks doing I/O, kept interpreted) and an
    accelerator-resident part compiled into a single XLA program.  Boundary
    FIFO channels become explicit array arguments/results of the compiled
    step, preserving Eq. 1 window semantics (contiguous windows in, out).

  * ``Placement``           — pins an actor to a named mesh axis slice; the
    compiled executors turn placements into sharding constraints so GSPMD
    materializes cross-placement FIFO traffic as collectives.  ``None``
    placement is the paper's *free mapping* (GSPMD decides).
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.actor import ActorSpec, static_actor
from repro.core.fifo import FifoSpec
from repro.core.network import Edge, Network, NetworkState, name_index_map


@dataclasses.dataclass(frozen=True)
class Placement:
    """Actor placement: mesh axis name + index range (or None = free)."""

    axis: Optional[str] = None
    index: Optional[int] = None


def partition_actors(network: Network, accelerated: List[str]) -> Tuple[List[str], List[str]]:
    """Split actor names into (host, accelerated) sets, validating coverage."""
    accel = set(accelerated)
    unknown = accel - set(network.actors)
    if unknown:
        raise ValueError(f"unknown actors in accelerated set: {sorted(unknown)}")
    host = [n for n in network.actors if n not in accel]
    return host, list(accelerated)


def boundary_fifos(network: Network, accelerated: List[str]) -> Tuple[List[str], List[str]]:
    """FIFOs crossing the host/accelerator boundary.

    Returns (into_accel, out_of_accel) fifo-name lists — the channels whose
    windows become the compiled step's inputs/outputs (the paper's
    host<->GPU transfer buffers; on a pod, the DMA'd feed/fetch arrays).
    """
    accel = set(accelerated)
    into, out = [], []
    for e in network.edges:
        src_in = e.src_actor in accel
        dst_in = e.dst_actor in accel
        if not src_in and dst_in:
            into.append(e.fifo)
        elif src_in and not dst_in:
            out.append(e.fifo)
    return into, out


def heterogeneous_split(network: Network, accelerated: List[str],
                        n_iterations: int) -> Tuple[Network, List[str], List[str]]:
    """Build the accelerator subnetwork with boundary source/sink actors.

    Each inbound boundary FIFO gets a *feed* source actor that serves
    pre-staged windows ``(n_iterations, r, *token_shape)`` from its state;
    each outbound FIFO gets a *fetch* sink collecting windows likewise.
    The result is a plain Network, so all executors/verifiers apply.
    """
    accel = set(accelerated)
    into, out = boundary_fifos(network, accelerated)

    actors: List[ActorSpec] = [network.actors[n] for n in accelerated]
    fifos: List[FifoSpec] = []
    edges: List[Edge] = []
    initial = {}
    for e in network.edges:
        spec = network.fifos[e.fifo]
        if e.src_actor in accel and e.dst_actor in accel:
            fifos.append(spec)
            edges.append(e)
            if e.fifo in network.initial_tokens:
                initial[e.fifo] = network.initial_tokens[e.fifo]

    def make_feed(fifo_name: str) -> Tuple[ActorSpec, FifoSpec, Edge]:
        spec = network.fifos[fifo_name]
        e = network.edge_of(fifo_name)

        def fire(state, inputs, rates):
            del inputs, rates
            data, idx = state
            win = jax.lax.dynamic_index_in_dim(data, idx, axis=0, keepdims=False)
            return (data, idx + 1), {"out": win}

        def init():
            data = jnp.zeros((n_iterations, spec.rate) + tuple(spec.token_shape), spec.dtype)
            return (data, jnp.int32(0))

        feed = static_actor(f"__feed_{fifo_name}", (), ("out",), fire, init=init,
                            ready=lambda st: st[1] < n_iterations)
        return feed, spec, Edge(fifo_name, feed.name, "out", e.dst_actor, e.dst_port)

    def make_fetch(fifo_name: str) -> Tuple[ActorSpec, FifoSpec, Edge]:
        spec = network.fifos[fifo_name]
        e = network.edge_of(fifo_name)

        def fire(state, inputs, rates):
            del rates
            data, idx = state
            data = jax.lax.dynamic_update_index_in_dim(data, inputs["in"], idx, axis=0)
            return (data, idx + 1), {}

        def init():
            data = jnp.zeros((n_iterations, spec.rate) + tuple(spec.token_shape), spec.dtype)
            return (data, jnp.int32(0))

        fetch = static_actor(f"__fetch_{fifo_name}", ("in",), (), fire, init=init,
                             finish=lambda st: st[0])
        return fetch, spec, Edge(fifo_name, e.src_actor, e.src_port, fetch.name, "in")

    feed_names, fetch_names = [], []
    for f in into:
        a, spec, edge = make_feed(f)
        actors.append(a)
        fifos.append(spec)
        edges.append(edge)
        if f in network.initial_tokens:
            initial[f] = network.initial_tokens[f]
        feed_names.append(a.name)
    for f in out:
        a, spec, edge = make_fetch(f)
        actors.append(a)
        fifos.append(spec)
        edges.append(edge)
        fetch_names.append(a.name)

    sub = Network(actors, fifos, edges, initial_tokens=initial)
    return sub, feed_names, fetch_names


def stage_feed(state: Any, feed_actor: str, data: jax.Array) -> Any:
    """Install pre-staged windows into a feed actor's state.

    Boundary feeds operate on the flat :class:`NetworkState` pytree — the
    staged windows replace the feed actor's zero-filled slab in place of
    its tuple slot, keeping the treedef (and thus donation signatures) of
    the compiled step unchanged.  Legacy ``{"fifos": ..., "actors": ...}``
    dict states are staged in kind (the executors convert them on entry).
    """
    if not isinstance(state, NetworkState):
        st = dict(state)
        actors = dict(st["actors"])
        _, cursor = actors[feed_actor]
        actors[feed_actor] = (jnp.asarray(data), cursor)
        st["actors"] = actors
        return st
    idx = name_index_map(state.actor_names)[feed_actor]
    _, cursor = state.actors[idx]
    return state.replace_actor(idx, (jnp.asarray(data), cursor))
