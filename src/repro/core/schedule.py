"""Scheduling utilities for the MoC.

The MoC gives every channel a single rate shared by both ports, so the SDF
repetition vector is all-ones (see ``repro.core.network.repetition_vector``)
and a valid static schedule is a topological order with delay edges broken.

This module adds the *cycle-static* (CSDF-flavored) utilities used by the
LM-side integrations: layer stacks whose behaviour varies in a fixed cycle
(gemma3's 5 local : 1 global attention pattern, recurrentgemma's 2 RG-LRU :
1 local-attention pattern) are exactly cyclic rate tables — data-independent
rate variation the paper's §2.1 attributes to CSDF, sitting between the
static and the fully dynamic scheduler.
"""
from __future__ import annotations

import math
from typing import Iterable, List, Sequence, Tuple

import numpy as np


def phase_unroll_period(phase_counts: Iterable[int], bound: int = 6) -> int:
    """Unroll period for trace-time FIFO phase specialization, bounded.

    ``compile_static`` specializes FIFO cursors to trace-time phases by
    unrolling one *super-iteration* of ``period`` network iterations.  A
    channel is offset-specialized iff its ``n_write_phases`` (2 for double
    buffers, 3 for Fig. 2 delay triple buffers) divides ``period``, so the
    ideal period is the LCM of all cycle lengths — one of {1, 2, 3, 6}
    under the current MoC, never exceeding the default ``bound`` of 6.

    When the LCM exceeds ``bound`` (a tighter caller bound, or a future
    channel scheme), we pick the period <= bound that covers the most
    channels instead of giving up entirely; ties go to the smaller unroll
    (smaller compiled body).
    """
    counts = list(phase_counts)
    period = 1
    for c in counts:
        if c < 1:
            raise ValueError(f"phase count must be >= 1, got {c}")
        period = period * c // math.gcd(period, c)
    if period <= bound:
        return period
    best, best_cover = 1, -1
    for p in range(1, bound + 1):
        cover = sum(1 for c in counts if p % c == 0)
        if cover > best_cover:
            best, best_cover = p, cover
    return best


def cyclic_rate_table(pattern: Sequence[int], length: int) -> np.ndarray:
    """Unroll a cyclic per-firing pattern to ``length`` firings.

    ``cyclic_rate_table([0,0,1], 26)`` -> the recurrentgemma layer kinds
    (0 = RG-LRU, 1 = local attention) for 26 layers.
    """
    pattern = list(pattern)
    reps = -(-length // len(pattern))
    return np.asarray((pattern * reps)[:length], dtype=np.int32)


def layer_pattern_groups(pattern: Sequence[int], n_layers: int) -> Tuple[int, int]:
    """(n_full_cycles, n_remainder_layers) of a cyclic layer pattern.

    Used to build scan-over-groups layer stacks: full cycles are scanned
    (one compiled body per cycle position), remainder layers are unrolled.
    Keeping the scanned body small is what keeps 80-layer configs cheap to
    lower for the 512-device dry-run.
    """
    cycle = len(pattern)
    return n_layers // cycle, n_layers % cycle


def validate_single_appearance(order: List[str], names: Sequence[str]) -> None:
    if sorted(order) != sorted(names):
        raise ValueError(
            f"schedule must contain every actor exactly once; got {order} for {list(names)}"
        )
