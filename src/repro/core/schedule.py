"""Scheduling utilities for the MoC.

The MoC gives every channel a single rate shared by both ports, so the SDF
repetition vector is all-ones (see ``repro.core.network.repetition_vector``)
and a valid static schedule is a topological order with delay edges broken.

This module adds the *cycle-static* (CSDF-flavored) utilities used by the
LM-side integrations: layer stacks whose behaviour varies in a fixed cycle
(gemma3's 5 local : 1 global attention pattern, recurrentgemma's 2 RG-LRU :
1 local-attention pattern) are exactly cyclic rate tables — data-independent
rate variation the paper's §2.1 attributes to CSDF, sitting between the
static and the fully dynamic scheduler.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np


def cyclic_rate_table(pattern: Sequence[int], length: int) -> np.ndarray:
    """Unroll a cyclic per-firing pattern to ``length`` firings.

    ``cyclic_rate_table([0,0,1], 26)`` -> the recurrentgemma layer kinds
    (0 = RG-LRU, 1 = local attention) for 26 layers.
    """
    pattern = list(pattern)
    reps = -(-length // len(pattern))
    return np.asarray((pattern * reps)[:length], dtype=np.int32)


def layer_pattern_groups(pattern: Sequence[int], n_layers: int) -> Tuple[int, int]:
    """(n_full_cycles, n_remainder_layers) of a cyclic layer pattern.

    Used to build scan-over-groups layer stacks: full cycles are scanned
    (one compiled body per cycle position), remainder layers are unrolled.
    Keeping the scanned body small is what keeps 80-layer configs cheap to
    lower for the 512-device dry-run.
    """
    cycle = len(pattern)
    return n_layers // cycle, n_layers % cycle


def validate_single_appearance(order: List[str], names: Sequence[str]) -> None:
    if sorted(order) != sorted(names):
        raise ValueError(
            f"schedule must contain every actor exactly once; got {order} for {list(names)}"
        )
