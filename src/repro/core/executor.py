"""Network executors — paper §3.3 adapted to XLA.

The paper runs every actor on its own OS thread and lets the OS schedule
firings by data availability (blocking FIFOs).  Inside one XLA program
there are no threads, so we provide three execution strategies whose
*observable* FIFO semantics are identical.  The public entrypoint is
``Network.compile(ExecutionPlan(mode=...)) -> Program``
(repro.core.program); the strategy names below survive as deprecated
shims at the bottom of this module:

  1. ``compile_static``   — the whole network compiles to one jitted
     ``lax.scan``; one scan step = one *iteration* = one (predicated)
     firing of every actor in a topological order.  This is the analogue of
     the paper's accelerator-mapped subnetwork: maximum fusion, contiguous
     Eq. 1 buffer windows, dynamic actors predicated with ``lax.cond`` so
     rate-0 firings genuinely skip compute (the source of the paper's 5x).
     With ``specialize=True`` (default) transient channels
     (``Network.register_fifos``) are register-allocated — windows flow
     producer->consumer as traced values, no ring-buffer traffic — and the
     remaining buffered channels get their phase cycle unrolled (LCM of
     ``n_write_phases``, <= 6 — see ``phase_unroll_period``) so
     cursor-driven ``dynamic_slice`` arithmetic on statically-scheduled
     ports folds to compile-time slice offsets (EXPERIMENTS.md §Executor
     perf: DPD 1.95x).

  2. ``compile_dynamic``  — a token-driven scheduler compiled as
     ``lax.while_loop``: every sweep attempts each actor, firing it iff its
     blocking predicates hold (control token peeked to evaluate rates
     first).  This handles networks whose occupancies are data dependent —
     the general dynamic-dataflow case.  With ``multi_firing=True``
     (default) an actor is fired up to its occupancy-derived bound —
     ``min(occ // r, room // r)`` for static actors, control-channel
     occupancy for dynamic ones — per sweep via ``lax.fori_loop`` instead
     of once, reaching quiescence in strictly fewer sweeps (PRUNE,
     arXiv:1802.06625, motivates the decidable bound; CAF's OpenCL actors,
     arXiv:1709.07781, motivate batching firings per dispatch).

  3. ``run_interpreted``  — an eager Python loop (one jitted fire per
     actor), standing in for the paper's GPP-threaded execution and used as
     the measurement baseline (DAL-multicore analogue) in the benchmarks.

All executors thread a flat :class:`repro.core.network.NetworkState`
pytree (built once per network) and accept ``donate=True`` to let XLA
update FIFO buffers in place across calls.

``RuntimeMode.STATIC_DAL`` reproduces the *reference* framework's
restriction: dynamic-rate actors are rejected on the accelerated path
(DAL's OpenCL extension is limited to SDF — paper §2.3), forcing the
all-branches-active execution that the proposed framework beats.
"""
from __future__ import annotations

import dataclasses
import enum
import functools
import os
import warnings
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.fifo import FifoSpec, FifoState
from repro.core.health import HealthState, init_health
from repro.core.network import Network, NetworkState
from repro.core.trace import init_trace
from repro.core.schedule import phase_unroll_period

# Legacy dict states are accepted everywhere and converted on entry.
State = Union[NetworkState, Dict[str, Any]]

# Worst-case firings of one actor per multi-firing visit.  Eq. 1 caps any
# channel at 2 (double buffer) or 3 (delay triple buffer) windows, so no
# connected actor can ever have more than 3 pending firings; 8 leaves slack
# for port-free corner cases without risking runaway loops.
_MAX_FIRINGS_PER_VISIT = 8


class RuntimeMode(enum.Enum):
    PROPOSED = "proposed"        # this paper: dynamic rates allowed everywhere
    STATIC_DAL = "static_dal"    # reference framework: SDF only on the accelerator


def assert_mode_allows(network: Network, mode: RuntimeMode,
                       accelerated: Optional[List[str]] = None) -> None:
    """DAL's OpenCL path rejects dynamic actors (paper §2.3 / §4.3)."""
    if mode is not RuntimeMode.STATIC_DAL:
        return
    accel = set(accelerated if accelerated is not None else network.actors)
    bad = [n for n in accel if network.actors[n].is_dynamic]
    if bad:
        raise ValueError(
            f"STATIC_DAL mode: dynamic-rate actors {bad} cannot be mapped to "
            "the accelerator (SDF-only reference framework); rewrite them "
            "statically or run them interpreted"
        )


def _is_concrete(x: Any) -> bool:
    """True when ``x`` is a compile-time constant (not a traced value)."""
    return not isinstance(x, jax.core.Tracer)


# --------------------------------------------------------------------------- #
# Single predicated firing (shared by all executors).
# --------------------------------------------------------------------------- #
def _register_read(spec: FifoSpec, st: FifoState, window: jax.Array,
                   enabled: jax.Array) -> Tuple[jax.Array, FifoState]:
    """Consume from a register-allocated channel: the forwarded ``window``
    replaces the buffer read; cursor arithmetic matches ``read_masked``."""
    e = (enabled > 0).astype(jnp.int32)
    return window, FifoState(buf=st.buf, rd=st.rd + e, wr=st.wr,
                             occ=st.occ - e * spec.rate)


def _register_write(spec: FifoSpec, st: FifoState,
                    enabled: jax.Array) -> FifoState:
    """Produce to a register-allocated channel: the buffer is untouched
    (the window is forwarded via the regs dict); cursor arithmetic matches
    ``write_masked``."""
    e = (enabled > 0).astype(jnp.int32)
    return FifoState(buf=st.buf, rd=st.rd, wr=st.wr + e,
                     occ=st.occ + e * spec.rate)


def fire_actor(network: Network, name: str, state: State,
               phase: Optional[int] = None,
               regs: Optional[Dict[int, jax.Array]] = None,
               period: Optional[int] = None,
               health: Optional["HealthState"] = None):
    """Fire actor ``name`` once, updating FIFO and actor state.

    Implements the firing protocol of paper §2.2:
      1. dynamic actors first consume one control token;
      2. the control token pins every regular port to rate 0 or r;
      3. tokens are consumed from enabled inputs, the body computes,
         tokens are produced to enabled outputs.

    Rate-0 ports freeze their FIFO cursors; a firing whose every regular
    port is disabled skips the body entirely via ``lax.cond``.
    Callers guarantee blocking preconditions (the static scheduler proves
    them at build time; the dynamic scheduler checks them per sweep).

    ``phase`` (a Python int) enables trace-time cursor specialization for
    the static schedule, on two levels:

      * channels in ``network.register_fifos`` (transient: delay-free with
        provably-matched enables) are register-allocated — the produced
        window is forwarded to the consumer through ``regs`` (a per-
        iteration dict keyed by fifo index) as a traced value, and the ring
        buffer is never touched (only the cursor/occupancy scalars advance,
        exactly as the masked path would);
      * buffered channels whose port enable is a compile-time constant use
        static slice offsets ``(phase % n_write_phases) * r`` instead of
        cursor-driven ``dynamic_slice``.

    Valid only when the state descends from ``Network.init_state`` through
    whole phase cycles (the ``compile_static`` contract).  ``period`` is
    the unroll period ``phase`` cycles through: a buffered channel is
    offset-specialized only when its own phase cycle divides ``period``
    (``period=None`` asserts the caller's phase covers every channel).
    Genuinely data-dependent ports of buffered channels keep the masked
    dynamic-cursor path.  Observable results (actor states, cursors,
    occupancies, live tokens) are bit-identical to ``phase=None``; only
    the dead slots of register-allocated buffers differ (their content is
    unspecified by the MoC).

    ``health`` (a :class:`repro.core.health.HealthState`) arms the channel
    guards: every read/write additionally evaluates its fault-bit word
    (overflow / underflow / cursor consistency / non-finite tokens) from
    the pre-op cursors and ORs it into the per-channel fault vector.  The
    return value becomes ``(state, health)``.  Guards ride the dynamic
    masked path only — the phase-specialized static schedule proves its
    blocking preconditions at build time (``check_schedule_feasible``), so
    combining ``health`` with ``phase``/``regs`` is rejected.
    """
    if health is not None and (phase is not None or regs is not None):
        raise ValueError(
            "fire_actor: health guards apply to the dynamic (masked-cursor) "
            "path; the phase-specialized static schedule proves blocking "
            "bounds at build time — run with ExecutionPlan(mode='dynamic', "
            "guards=True) instead")
    if not isinstance(state, NetworkState):
        state = network.state_from_dict(state)
    a = network.actors[name]
    fifos = list(state.fifos)
    reg_mode = phase is not None and regs is not None

    def is_reg(spec: FifoSpec) -> bool:
        return reg_mode and spec.name in network.register_fifos

    def phase_covers(spec: FifoSpec) -> bool:
        return phase is not None and (period is None
                                      or period % spec.n_write_phases == 0)

    def forwarded(spec: FifoSpec, fi: int) -> jax.Array:
        if fi not in regs:
            raise ValueError(
                f"fifo {spec.name}: consumer {name} fired before its "
                "producer in the specialized schedule — pass a topological "
                "order (or specialize=False) to compile_static")
        return regs[fi]

    # 1. Control token (always rate 1, consumed unconditionally).
    ctrl_tok = None
    ctl = network.control_specs[name]
    if ctl is not None:
        cspec, ci = ctl
        if is_reg(cspec):
            ctok, fifos[ci] = _register_read(cspec, fifos[ci],
                                             forwarded(cspec, ci),
                                             jnp.int32(1))
        elif phase_covers(cspec):
            ctok, fifos[ci] = cspec.read_static(fifos[ci], phase)
        elif health is not None:
            ctok, fifos[ci], bits = cspec.read_guarded(fifos[ci])
            health = health.record(ci, bits)
        else:
            ctok, fifos[ci] = cspec.read(fifos[ci])
        ctrl_tok = ctok[0]  # rate-1 window -> single token

    # 2. Per-port 0/1 enables for this firing.
    rates = a.rates_for(ctrl_tok)

    # 3. Consume enabled inputs (static windows, masked cursor advance).
    windows: Dict[str, jax.Array] = {}
    for p, spec, fi in network.in_port_specs[name]:
        en = rates[p]
        if is_reg(spec):
            windows[p], fifos[fi] = _register_read(spec, fifos[fi],
                                                   forwarded(spec, fi), en)
        elif phase_covers(spec) and _is_concrete(en):
            if int(en) > 0:
                windows[p], fifos[fi] = spec.read_static(fifos[fi], phase)
            else:
                # Constant-disabled port: its cursor never moved off 0, so
                # the (unspecified-by-the-MoC) window is the slot-0 slice.
                windows[p] = jax.lax.slice_in_dim(fifos[fi].buf, 0, spec.rate,
                                                  axis=0)
        elif health is not None:
            windows[p], fifos[fi], bits = spec.read_masked_guarded(
                fifos[fi], en > 0)
            health = health.record(fi, bits)
        else:
            windows[p], fifos[fi] = spec.read_masked(fifos[fi], en > 0)

    # 4. Body, predicated on any port being enabled.
    enabled_list = [rates[p] for p in (*a.in_ports, *a.out_ports)]
    concrete_on = any(_is_concrete(e) and int(e) > 0 for e in enabled_list)
    if enabled_list:
        any_enabled = functools.reduce(jnp.logical_or, [e > 0 for e in enabled_list])
    else:
        any_enabled = jnp.bool_(True)  # pure source/sink with no regular ports

    out_specs = {p: spec for p, spec, _ in network.out_port_specs[name]}

    def run_body(operand):
        st, wins = operand
        new_st, outs = a.fire(st, wins, rates)
        missing = set(a.out_ports) - set(outs)
        if missing:
            raise ValueError(f"actor {name}: fire() missing outputs {sorted(missing)}")
        outs = {
            p: jnp.asarray(outs[p], out_specs[p].dtype).reshape(
                (out_specs[p].rate,) + tuple(out_specs[p].token_shape))
            for p in a.out_ports
        }
        return new_st, outs

    def skip_body(operand):
        st, _ = operand
        zeros = {
            p: jnp.zeros((s.rate,) + tuple(s.token_shape), s.dtype)
            for p, s in out_specs.items()
        }
        return st, zeros

    aidx = network.actor_index[name]
    if a.is_dynamic and not concrete_on:
        new_actor_state, outputs = jax.lax.cond(
            any_enabled, run_body, skip_body, (state.actors[aidx], windows))
    else:
        # Static actor, or a dynamic one with a constant-enabled port: the
        # body runs on every firing, so the cond would always take the true
        # branch — eliding it produces identical values without forcing XLA
        # to materialize both arms' buffer copies.
        new_actor_state, outputs = run_body((state.actors[aidx], windows))

    # 5. Produce to enabled outputs.
    for p, spec, fi in network.out_port_specs[name]:
        en = rates[p]
        if is_reg(spec):
            regs[fi] = outputs[p]
            fifos[fi] = _register_write(spec, fifos[fi], en)
        elif phase_covers(spec) and _is_concrete(en):
            if int(en) > 0:
                fifos[fi] = spec.write_static(fifos[fi], outputs[p], phase)
            # Constant-disabled port: cursor frozen, buffer untouched.
        elif health is not None:
            fifos[fi], bits, occ_after = spec.write_masked_guarded(
                fifos[fi], outputs[p], en > 0)
            health = health.record(fi, bits).mark_high_water(fi, occ_after)
        else:
            fifos[fi] = spec.write_masked(fifos[fi], outputs[p], en > 0)

    actors = list(state.actors)
    actors[aidx] = new_actor_state
    new_state = dataclasses.replace(state, fifos=tuple(fifos),
                                    actors=tuple(actors))
    if health is not None:
        return new_state, health
    return new_state


# --------------------------------------------------------------------------- #
# 1. Static single-appearance schedule  ->  jitted lax.scan.
# --------------------------------------------------------------------------- #
def make_iteration_step(network: Network,
                        order: Optional[List[str]] = None,
                        phase: Optional[int] = None) -> Callable[[State], NetworkState]:
    """One network iteration: every actor fires once, topologically ordered.

    Build-time checks prove that under Eq. 1 capacities the schedule never
    violates blocking semantics (see ``Network.check_schedule_feasible``).
    With a trace-time ``phase`` the iteration runs cursor-specialized:
    transient channels forward their windows through a fresh per-iteration
    register dict, buffered static ports use compile-time slice offsets.
    """
    order = list(order) if order is not None else network.topological_order()
    network.check_schedule_feasible()

    def step(state: State) -> NetworkState:
        regs: Dict[int, jax.Array] = {}
        for nm in order:
            state = fire_actor(network, nm, state, phase=phase, regs=regs)
        return state

    return step


def _phase_aligned_fifos(network: Network,
                         period: int) -> List[Tuple[str, bool, bool]]:
    """(fifo, read_side_static, write_side_static) deducible at build time,
    for buffered (non-register-allocated) channels whose phase cycle the
    unroll ``period`` covers — the only ones offset-specialization touches.

    A side is statically scheduled when its port consumes/produces
    unconditionally under ``compile_static``: every port of a static actor,
    and the control port of a dynamic actor.  (Constant-enable ports of
    dynamic actors also specialize, but only trace-time concreteness can
    prove that — they advance in lockstep with the build-time-static set,
    so checking this set suffices for the phase-alignment guard.)
    """
    out = []
    for e in network.edges:
        if e.fifo in network.register_fifos:
            continue
        if period % network.fifos[e.fifo].n_write_phases:
            continue
        src = network.actors[e.src_actor]
        dst = network.actors[e.dst_actor]
        read_static = (e.dst_port == dst.control_port) or not dst.is_dynamic
        write_static = not src.is_dynamic
        out.append((e.fifo, read_static, write_static))
    return out


def _compile_static(network: Network, n_iterations: int,
                    mode: RuntimeMode = RuntimeMode.PROPOSED,
                    order: Optional[List[str]] = None,
                    donate: bool = False,
                    specialize: bool = True,
                    unroll_bound: int = 6) -> Callable[[State], NetworkState]:
    """Compile ``n_iterations`` of the network into a single XLA program.

    ``specialize=True`` applies trace-time cursor specialization:

      * transient channels (``network.register_fifos``: delay-free, enables
        provably matched) are register-allocated — windows flow
        producer->consumer as traced values and their ring buffers are
        never read or written;
      * remaining (buffered) channels get the phase cycle unrolled inside
        the scan body (period = LCM of their ``n_write_phases``, <= 6) so
        statically-scheduled ports use compile-time slice offsets instead
        of cursor-driven ``dynamic_slice``.

    The input state must be phase-aligned and transient-drained: fresh from
    ``Network.init_state``, or the result of a prior run whose iteration
    count was a multiple of the period (checked eagerly when cursors are
    concrete).  Final actor states, cursors, occupancies and live tokens
    are bit-identical to ``specialize=False``; dead slots of
    register-allocated buffers keep their initial zeros.

    ``donate=True`` donates the input state so XLA can reuse its buffers
    in place — the caller's state object is consumed by the call.  Beware
    that a state from ``Network.init_state`` may *share* arrays with the
    graph definition (e.g. a signal staged at build time is aliased, not
    copied): donating it consumes those arrays for every future
    ``init_state`` too.  ``jax.tree.map(jnp.copy, state)`` first when the
    network outlives the call (see benchmarks/bench_executors.py).
    """
    assert_mode_allows(network, mode)
    order = list(order) if order is not None else network.topological_order()
    network.check_schedule_feasible()

    period = (phase_unroll_period(
        [spec.n_write_phases for name, spec in network.fifos.items()
         if name not in network.register_fifos],
        bound=unroll_bound) if specialize else 1)
    n_super, rem = divmod(n_iterations, period)

    def step(state: NetworkState, p: Optional[int]) -> NetworkState:
        regs: Dict[int, jax.Array] = {}
        for nm in order:
            state = fire_actor(network, nm, state, phase=p, regs=regs,
                               period=period)
        return state

    def run(state: State) -> NetworkState:
        if not isinstance(state, NetworkState):
            state = network.state_from_dict(state)

        def body(s, _):
            if specialize:
                for p in range(period):
                    s = step(s, p)
            else:
                s = step(s, None)
            return s, None

        if n_super:
            state, _ = jax.lax.scan(body, state, None, length=n_super)
        for p in range(rem):
            state = step(state, p if specialize else None)
        return state

    jitted = jax.jit(run, donate_argnums=(0,) if donate else ())
    if not specialize:
        return jitted

    aligned = _phase_aligned_fifos(network, period)

    def checked(state: State) -> NetworkState:
        st = state if isinstance(state, NetworkState) else network.state_from_dict(state)
        for fname, read_static, write_static in aligned:
            fs = st.fifos[network.fifo_index[fname]]
            spec = network.fifos[fname]
            for cursor, is_static in ((fs.rd, read_static), (fs.wr, write_static)):
                if is_static and _is_concrete(cursor) and int(cursor) % spec.n_write_phases:
                    raise ValueError(
                        f"compile_static(specialize=True): fifo {fname} cursor "
                        f"{int(cursor)} is not phase-aligned (cycle "
                        f"{spec.n_write_phases}); start from Network.init_state "
                        "or run a multiple of the unroll period, or pass "
                        "specialize=False")
        for fname in network.register_fifos:
            occ = st.fifos[network.fifo_index[fname]].occ
            if _is_concrete(occ) and int(occ):
                raise ValueError(
                    f"compile_static(specialize=True): transient fifo {fname} "
                    f"enters with occupancy {int(occ)}; register-allocated "
                    "channels must be drained (start from Network.init_state "
                    "or pass specialize=False)")
        return jitted(state)

    return checked


# --------------------------------------------------------------------------- #
# 2. Token-driven dynamic scheduler  ->  jitted lax.while_loop.
# --------------------------------------------------------------------------- #
def _can_fire(network: Network, name: str, state: NetworkState) -> jax.Array:
    """Blocking predicate of paper §2.2, evaluated without side effects.

    For dynamic actors the control token is *peeked* (not consumed) so the
    control function can be evaluated first — our shared-memory analogue of
    the paper's blocking control-port read.  All port->spec resolution uses
    the tables precomputed at network build time.
    """
    a = network.actors[name]
    fifos = state.fifos
    ok = jnp.bool_(True)
    if a.ready is not None:
        ok = jnp.logical_and(ok, a.ready(state.actors[network.actor_index[name]]))
    ctl = network.control_specs[name]
    if ctl is not None:
        cspec, ci = ctl
        ok = jnp.logical_and(ok, cspec.can_peek(fifos[ci]))
        # Rates given the (peeked) control token; garbage if !can_peek, but
        # then `ok` is already False and the and-tree short-circuits in value.
        rates = a.rates_for(cspec.peek(fifos[ci]))
    else:
        rates = a.rates_for(None)
    for p, spec, fi in network.in_port_specs[name]:
        have = spec.can_read(fifos[fi])
        ok = jnp.logical_and(ok, jnp.logical_or(rates[p] == 0, have))
    for p, spec, fi in network.out_port_specs[name]:
        room = spec.can_write(fifos[fi])
        ok = jnp.logical_and(ok, jnp.logical_or(rates[p] == 0, room))
    return ok


def _max_fireable(network: Network, name: str, state: NetworkState) -> jax.Array:
    """Upper bound on this actor's fireable count, from occupancies alone.

    The PRUNE-style decidable bound (arXiv:1802.06625):

      * dynamic actors consume exactly one control token per firing, so the
        control channel's occupancy is a hard bound that holds whatever the
        (data-dependent) regular-port rates turn out to be — crucially it
        does not under-count rate-0 firings, which need no data tokens;
      * static actors fire at full rate r on every port, so
        ``min(occ // r over inputs, room // r over outputs)`` is exact.

    The bound never misses a fireable actor (``_can_fire`` implies bound
    >= 1: peeking needs control occ >= 1; static reads/writes need a full
    window of tokens/room), and every firing inside the bound is still
    guarded by a per-firing ``_can_fire`` — so the multi-firing sweep
    performs exactly the firings the one-per-sweep baseline would,
    compressed into fewer sweeps.
    """
    ctl = network.control_specs[name]
    if ctl is not None:
        _, ci = ctl
        return jnp.minimum(jnp.int32(_MAX_FIRINGS_PER_VISIT),
                           state.fifos[ci].occ)
    k = jnp.int32(_MAX_FIRINGS_PER_VISIT)
    for _, spec, fi in network.in_port_specs[name]:
        k = jnp.minimum(k, state.fifos[fi].occ // spec.rate)
    for _, spec, fi in network.out_port_specs[name]:
        room = spec.writable_occupancy_bound - state.fifos[fi].occ
        k = jnp.minimum(k, room // spec.rate)
    return k


def _make_visit_body(network: Network, names: List[str],
                     multi_firing: bool) -> Callable:
    """One in-order visit of ``names``: the token-driven sweep body.

    Shared by the single-device dynamic executor (``names`` = every
    actor) and the per-device sub-sweeps of the sharded executor
    (:mod:`repro.core.shard`, ``names`` = one device's partition of the
    firing table) — both backends fire the identical per-actor logic, so
    sharded quiescence states stay bit-identical to the single-device
    run by Kahn determinism.

    Returns ``visit_all(state, counts, hlth, trc, sweeps) -> (state,
    counts, hlth, trc, fired_any)``: each named actor is attempted (up
    to its occupancy bound under ``multi_firing``), guarded per firing
    by ``_can_fire``, with the optional health/trace slots following
    the empty-pytree-when-off contract of ``_compile_dynamic``.
    """
    n_fifos = len(network.fifos)

    def fire_once(nm: str, state, counts, hlth, trc, sweeps):
        ready = _can_fire(network, nm, state)

        def do_fire(operand):
            st, c, h = operand
            if h is None:
                st = fire_actor(network, nm, st)
            else:
                st, h = fire_actor(network, nm, st, health=h)
            c = dict(c)
            c[nm] = c[nm] + 1
            return st, c, h

        state, counts, hlth = jax.lax.cond(ready, do_fire, lambda o: o,
                                           (state, counts, hlth))
        if trc is not None:
            # One event per attempt — fired or skipped — with post-attempt
            # occupancies, recorded unconditionally so tracing never
            # perturbs the schedule's control flow.
            occs = jnp.stack([state.fifos[i].occ for i in range(n_fifos)])
            trc = trc.record(network.actor_index[nm], sweeps, ready, occs)
        return state, counts, hlth, trc, ready

    def visit_all(state, counts, hlth, trc, sweeps):
        fired_any = jnp.bool_(False)
        for nm in names:
            if multi_firing:
                k = _max_fireable(network, nm, state)

                def body(_, c, nm=nm):
                    st, cnt, h, t, fired = c
                    st, cnt, h, t, ready = fire_once(nm, st, cnt, h, t,
                                                     sweeps)
                    return st, cnt, h, t, jnp.logical_or(fired, ready)

                state, counts, hlth, trc, fired = jax.lax.fori_loop(
                    0, k, body, (state, counts, hlth, trc,
                                 jnp.bool_(False)))
            else:
                state, counts, hlth, trc, fired = fire_once(
                    nm, state, counts, hlth, trc, sweeps)
            fired_any = jnp.logical_or(fired_any, fired)
        return state, counts, hlth, trc, fired_any

    return visit_all


def _compile_dynamic(network: Network, max_sweeps: int = 1_000_000,
                     mode: RuntimeMode = RuntimeMode.PROPOSED,
                     multi_firing: bool = True,
                     donate: bool = False,
                     return_sweeps: bool = False,
                     guards: bool = False,
                     trace_capacity: Optional[int] = None
                     ) -> Callable[..., Tuple]:
    """Token-driven executor: sweeps until quiescence (no actor can fire).

    Returns ``(final_state, fire_counts)`` where ``fire_counts[actor]`` is
    the number of firings — used by the benchmarks for throughput
    accounting (frames / samples per second).  With ``return_sweeps=True``
    the executor returns the full health-aware record ``(final_state,
    fire_counts, n_sweeps, stalled, health)``: ``stalled`` is True when
    the loop exited via the ``max_sweeps`` bound with work remaining
    (previously indistinguishable from quiescence), and ``health`` is the
    :class:`repro.core.health.HealthState` fault/high-water record when
    ``guards=True``, else None.

    ``guards=True`` arms the per-channel fault guards (overflow /
    underflow / cursor consistency / non-finite tokens) on every firing's
    reads and writes.  The health vectors thread the sweep carry as extra
    loop state; with ``guards=False`` that slot is the empty pytree
    ``None``, so the guards-off loop lowers to the identical HLO as before
    the health layer existed, and a guarded clean run's states / cursors /
    fire counts / sweeps are bit-identical to an unguarded one (guards
    observe channel operations, they never change them).

    ``trace_capacity=N`` arms firing-level event tracing: every firing
    *attempt* appends ``[actor, sweep, fired, occ...]`` to a ring-
    buffered :class:`repro.core.trace.TraceState` riding the sweep carry
    next to ``health`` — and following the same contract: the off slot
    is the empty pytree ``None``, so an untraced loop lowers to the
    identical HLO and a traced run's states / cursors / fire counts /
    sweeps stay bit-identical (the trace observes, it never schedules).
    With ``return_sweeps=True`` the record grows to ``(final_state,
    fire_counts, n_sweeps, stalled, health, trace)``.

    ``multi_firing=True`` fires each visited actor up to its
    occupancy-derived bound (``_max_fireable``) via ``lax.fori_loop``
    before moving to the next actor, instead of once per sweep: the same
    set of firings happens in strictly fewer sweeps, collapsing the
    O(sweeps x actors) predicate/cond overhead of the baseline.  Dataflow
    (Kahn) determinism makes the final state bit-identical either way.
    """
    assert_mode_allows(network, mode)
    names = list(network.actors)
    visit_all = _make_visit_body(network, names, multi_firing)

    def sweep(carry):
        state, counts, hlth, trc, _, sweeps = carry
        state, counts, hlth, trc, fired_any = visit_all(
            state, counts, hlth, trc, sweeps)
        return state, counts, hlth, trc, fired_any, sweeps + 1

    def cond(carry):
        _, _, _, _, fired_any, sweeps = carry
        return jnp.logical_and(fired_any, sweeps < max_sweeps)

    def run(state: State):
        if not isinstance(state, NetworkState):
            state = network.state_from_dict(state)
        counts = {nm: jnp.int32(0) for nm in names}
        hlth = init_health(len(network.fifos)) if guards else None
        trc = (init_trace(len(network.fifos), trace_capacity)
               if trace_capacity else None)
        carry = (state, counts, hlth, trc, jnp.bool_(True), jnp.int32(0))
        state, counts, hlth, trc, fired_any, sweeps = jax.lax.while_loop(
            cond, sweep, carry)
        if return_sweeps:
            # fired_any still True at exit means the loop left through the
            # sweep budget, not quiescence — the stall the health layer
            # surfaces instead of returning partial state silently.
            stalled = jnp.logical_and(fired_any, sweeps >= max_sweeps)
            return state, counts, sweeps, stalled, hlth, trc
        return state, counts

    return jax.jit(run, donate_argnums=(0,) if donate else ())


# --------------------------------------------------------------------------- #
# 3. Interpreted executor (GPP-thread / DAL-multicore analogue).
# --------------------------------------------------------------------------- #
def _run_interpreted(network: Network, state: State, n_iterations: int,
                     order: Optional[List[str]] = None,
                     donate: bool = False) -> NetworkState:
    """Eagerly fire the static schedule actor-by-actor (no cross-actor fusion).

    Each actor's firing is independently jitted — the analogue of the
    paper's per-thread GPP execution where no cross-actor optimization can
    happen.  Used as the multicore baseline in the Table 3/4 benchmarks.

    ``donate=True`` donates each intermediate state to the next firing so
    XLA updates FIFO buffers in place; the caller's input state is copied
    once up front so it survives the run.
    """
    order = list(order) if order is not None else network.topological_order()
    network.check_schedule_feasible()
    if not isinstance(state, NetworkState):
        state = network.state_from_dict(state)
    if donate:
        state = jax.tree.map(jnp.copy, state)
    fns = {nm: jax.jit(functools.partial(fire_actor, network, nm),
                       donate_argnums=(0,) if donate else ())
           for nm in order}
    for _ in range(n_iterations):
        for nm in order:
            state = fns[nm](state)
    return state


def collect_sink(network: Network, state: State, actor: str) -> Any:
    """Run an actor's ``finish`` hook on its final state (paper §3.1)."""
    a = network.actors[actor]
    if not isinstance(state, NetworkState):
        state = network.state_from_dict(state)
    st = state.actors[network.actor_index[actor]]
    return a.finish(st) if a.finish is not None else st


# --------------------------------------------------------------------------- #
# Legacy entrypoints — thin deprecated shims over Network.compile / Program.
#
# Deprecation policy (EXPERIMENTS.md §API): the shims delegate to the exact
# same runners Program uses, so results stay bit-identical for at least two
# further PRs; new code should construct an ExecutionPlan instead, where
# every executor policy (mode, specialization, multi-firing, donation,
# heterogeneous placement) is a plan field.
# --------------------------------------------------------------------------- #
# Warned entrypoints, module-level so each shim warns once per process:
# per-call warnings flooded benchmark loops that call a shim-built runner
# factory repeatedly (thousands of identical lines per bench section).
_DEPRECATION_WARNED: set = set()


def reset_deprecation_warnings() -> None:
    """Re-arm the once-per-process shim warnings (testing hook)."""
    _DEPRECATION_WARNED.clear()


def _warn_deprecated(old: str, new: str) -> None:
    msg = (f"{old} is deprecated; use {new} (see ExecutionPlan and "
           "ExecutionPlan.validate in repro.core.program for the plan "
           "fields and the cross-field rules they must satisfy)")
    if os.environ.get("REPRO_STRICT_DEPRECATION") == "1":
        # CI's retirement gate: legacy entrypoints become hard errors so
        # no new call site can land while the shims still exist.
        raise DeprecationWarning(msg)
    if old in _DEPRECATION_WARNED:
        return
    _DEPRECATION_WARNED.add(old)
    warnings.warn(msg, DeprecationWarning, stacklevel=3)


def compile_static(network: Network, n_iterations: int,
                   mode: RuntimeMode = RuntimeMode.PROPOSED,
                   order: Optional[List[str]] = None,
                   donate: bool = False,
                   specialize: bool = True,
                   unroll_bound: int = 6) -> Callable[[State], NetworkState]:
    """Deprecated: ``network.compile(mode="static", n_iterations=...)``."""
    _warn_deprecated("compile_static(net, n, ...)",
                     'net.compile(mode="static", n_iterations=n, ...).run')
    prog = network.compile(
        mode="static", n_iterations=n_iterations, runtime_mode=mode,
        order=tuple(order) if order is not None else None, donate=donate,
        specialize=specialize, unroll_bound=unroll_bound)
    return lambda state=None: prog.run(state).state


def compile_dynamic(network: Network, max_sweeps: int = 1_000_000,
                    mode: RuntimeMode = RuntimeMode.PROPOSED,
                    multi_firing: bool = True,
                    donate: bool = False,
                    return_sweeps: bool = False) -> Callable[..., Tuple]:
    """Deprecated: ``network.compile(mode="dynamic", ...)``."""
    _warn_deprecated("compile_dynamic(net, ...)",
                     'net.compile(mode="dynamic", ...).run')
    prog = network.compile(
        mode="dynamic", runtime_mode=mode, multi_firing=multi_firing,
        donate=donate, max_sweeps=max_sweeps)

    def run(state=None):
        r = prog.run(state)
        if return_sweeps:
            return r.state, r.fire_counts, r.sweeps
        return r.state, r.fire_counts

    return run


def run_interpreted(network: Network, state: State, n_iterations: int,
                    order: Optional[List[str]] = None,
                    donate: bool = False) -> NetworkState:
    """Deprecated: ``network.compile(mode="interpreted", ...).run(state)``."""
    _warn_deprecated("run_interpreted(net, state, n, ...)",
                     'net.compile(mode="interpreted", n_iterations=n, ...)'
                     ".run(state)")
    prog = network.compile(
        mode="interpreted", n_iterations=n_iterations,
        order=tuple(order) if order is not None else None, donate=donate)
    return prog.run(state).state
