"""Network executors — paper §3.3 adapted to XLA.

The paper runs every actor on its own OS thread and lets the OS schedule
firings by data availability (blocking FIFOs).  Inside one XLA program
there are no threads, so we provide three execution strategies whose
*observable* FIFO semantics are identical:

  1. ``compile_static``   — the whole network compiles to one jitted
     ``lax.scan``; one scan step = one *iteration* = one (predicated)
     firing of every actor in a topological order.  This is the analogue of
     the paper's accelerator-mapped subnetwork: maximum fusion, contiguous
     Eq. 1 buffer windows, dynamic actors predicated with ``lax.cond`` so
     rate-0 firings genuinely skip compute (the source of the paper's 5x).

  2. ``compile_dynamic``  — a token-driven scheduler compiled as
     ``lax.while_loop``: every sweep attempts each actor, firing it iff its
     blocking predicates hold (control token peeked to evaluate rates
     first).  This handles networks whose occupancies are data dependent —
     the general dynamic-dataflow case.

  3. ``run_interpreted``  — an eager Python loop (one jitted fire per
     actor), standing in for the paper's GPP-threaded execution and used as
     the measurement baseline (DAL-multicore analogue) in the benchmarks.

``RuntimeMode.STATIC_DAL`` reproduces the *reference* framework's
restriction: dynamic-rate actors are rejected on the accelerated path
(DAL's OpenCL extension is limited to SDF — paper §2.3), forcing the
all-branches-active execution that the proposed framework beats.
"""
from __future__ import annotations

import enum
import functools
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.actor import ActorSpec
from repro.core.fifo import FifoSpec, FifoState
from repro.core.network import Network

State = Dict[str, Any]


class RuntimeMode(enum.Enum):
    PROPOSED = "proposed"        # this paper: dynamic rates allowed everywhere
    STATIC_DAL = "static_dal"    # reference framework: SDF only on the accelerator


def assert_mode_allows(network: Network, mode: RuntimeMode,
                       accelerated: Optional[List[str]] = None) -> None:
    """DAL's OpenCL path rejects dynamic actors (paper §2.3 / §4.3)."""
    if mode is not RuntimeMode.STATIC_DAL:
        return
    accel = set(accelerated if accelerated is not None else network.actors)
    bad = [n for n in accel if network.actors[n].is_dynamic]
    if bad:
        raise ValueError(
            f"STATIC_DAL mode: dynamic-rate actors {bad} cannot be mapped to "
            "the accelerator (SDF-only reference framework); rewrite them "
            "statically or run them interpreted"
        )


# --------------------------------------------------------------------------- #
# Single predicated firing (shared by all executors).
# --------------------------------------------------------------------------- #
def fire_actor(network: Network, name: str, state: State) -> State:
    """Fire actor ``name`` once, updating FIFO and actor state.

    Implements the firing protocol of paper §2.2:
      1. dynamic actors first consume one control token;
      2. the control token pins every regular port to rate 0 or r;
      3. tokens are consumed from enabled inputs, the body computes,
         tokens are produced to enabled outputs.

    Rate-0 ports freeze their FIFO cursors; a firing whose every regular
    port is disabled skips the body entirely via ``lax.cond``.
    Callers guarantee blocking preconditions (the static scheduler proves
    them at build time; the dynamic scheduler checks them per sweep).
    """
    a = network.actors[name]
    fifos = dict(state["fifos"])
    actor_states = dict(state["actors"])

    # 1. Control token (always rate 1).
    ctrl_tok = None
    if a.is_dynamic:
        cspec = network.fifo_for_in_port(name, a.control_port)
        ctok, fifos[cspec.name] = cspec.read(fifos[cspec.name])
        ctrl_tok = ctok[0]  # rate-1 window -> single token

    # 2. Per-port 0/1 enables for this firing.
    rates = a.rates_for(ctrl_tok)

    # 3. Consume enabled inputs (static windows, masked cursor advance).
    windows: Dict[str, jax.Array] = {}
    for p in a.in_ports:
        spec = network.fifo_for_in_port(name, p)
        win, fifos[spec.name] = spec.read_masked(fifos[spec.name], rates[p] > 0)
        windows[p] = win

    # 4. Body, predicated on any port being enabled.
    enabled_list = [rates[p] for p in (*a.in_ports, *a.out_ports)]
    if enabled_list:
        any_enabled = functools.reduce(jnp.logical_or, [e > 0 for e in enabled_list])
    else:
        any_enabled = jnp.bool_(True)  # pure source/sink with no regular ports

    out_specs = {p: network.fifo_for_out_port(name, p) for p in a.out_ports}

    def run_body(operand):
        st, wins = operand
        new_st, outs = a.fire(st, wins, rates)
        missing = set(a.out_ports) - set(outs)
        if missing:
            raise ValueError(f"actor {name}: fire() missing outputs {sorted(missing)}")
        outs = {
            p: jnp.asarray(outs[p], out_specs[p].dtype).reshape(
                (out_specs[p].rate,) + tuple(out_specs[p].token_shape))
            for p in a.out_ports
        }
        return new_st, outs

    def skip_body(operand):
        st, _ = operand
        zeros = {
            p: jnp.zeros((s.rate,) + tuple(s.token_shape), s.dtype)
            for p, s in out_specs.items()
        }
        return st, zeros

    if a.is_dynamic:
        new_actor_state, outputs = jax.lax.cond(
            any_enabled, run_body, skip_body, (actor_states[name], windows))
    else:
        new_actor_state, outputs = run_body((actor_states[name], windows))
    actor_states[name] = new_actor_state

    # 5. Produce to enabled outputs.
    for p in a.out_ports:
        spec = out_specs[p]
        fifos[spec.name] = spec.write_masked(fifos[spec.name], outputs[p], rates[p] > 0)

    return {"fifos": fifos, "actors": actor_states}


# --------------------------------------------------------------------------- #
# 1. Static single-appearance schedule  ->  jitted lax.scan.
# --------------------------------------------------------------------------- #
def make_iteration_step(network: Network,
                        order: Optional[List[str]] = None) -> Callable[[State], State]:
    """One network iteration: every actor fires once, topologically ordered.

    Build-time checks prove that under Eq. 1 capacities the schedule never
    violates blocking semantics (see ``Network.check_schedule_feasible``).
    """
    order = list(order) if order is not None else network.topological_order()
    network.check_schedule_feasible()

    def step(state: State) -> State:
        for nm in order:
            state = fire_actor(network, nm, state)
        return state

    return step


def compile_static(network: Network, n_iterations: int,
                   mode: RuntimeMode = RuntimeMode.PROPOSED,
                   order: Optional[List[str]] = None,
                   donate: bool = False) -> Callable[[State], State]:
    """Compile ``n_iterations`` of the network into a single XLA program."""
    assert_mode_allows(network, mode)
    step = make_iteration_step(network, order)

    def run(state: State) -> State:
        def body(s, _):
            return step(s), None

        final, _ = jax.lax.scan(body, state, None, length=n_iterations)
        return final

    return jax.jit(run, donate_argnums=(0,) if donate else ())


# --------------------------------------------------------------------------- #
# 2. Token-driven dynamic scheduler  ->  jitted lax.while_loop.
# --------------------------------------------------------------------------- #
def _can_fire(network: Network, name: str, state: State) -> jax.Array:
    """Blocking predicate of paper §2.2, evaluated without side effects.

    For dynamic actors the control token is *peeked* (not consumed) so the
    control function can be evaluated first — our shared-memory analogue of
    the paper's blocking control-port read.
    """
    a = network.actors[name]
    fifos = state["fifos"]
    ok = jnp.bool_(True)
    if a.ready is not None:
        ok = jnp.logical_and(ok, a.ready(state["actors"][name]))
    if a.is_dynamic:
        cspec = network.fifo_for_in_port(name, a.control_port)
        cst = fifos[cspec.name]
        ok = jnp.logical_and(ok, cspec.can_peek(cst))
        # Rates given the (peeked) control token; garbage if !can_peek, but
        # then `ok` is already False and the and-tree short-circuits in value.
        rates = a.rates_for(cspec.peek(cst))
    else:
        rates = a.rates_for(None)
    for p in a.in_ports:
        spec = network.fifo_for_in_port(name, p)
        have = spec.can_read(fifos[spec.name])
        ok = jnp.logical_and(ok, jnp.logical_or(rates[p] == 0, have))
    for p in a.out_ports:
        spec = network.fifo_for_out_port(name, p)
        room = spec.can_write(fifos[spec.name])
        ok = jnp.logical_and(ok, jnp.logical_or(rates[p] == 0, room))
    return ok


def compile_dynamic(network: Network, max_sweeps: int = 1_000_000,
                    mode: RuntimeMode = RuntimeMode.PROPOSED) -> Callable[[State], Tuple[State, Dict[str, jax.Array]]]:
    """Token-driven executor: sweeps until quiescence (no actor can fire).

    Returns ``(final_state, fire_counts)`` where ``fire_counts[actor]`` is
    the number of firings — used by the benchmarks for throughput
    accounting (frames / samples per second).
    """
    assert_mode_allows(network, mode)
    names = list(network.actors)

    def sweep(carry):
        state, counts, _, sweeps = carry
        fired_any = jnp.bool_(False)
        for nm in names:
            ready = _can_fire(network, nm, state)

            def do_fire(operand):
                st, c = operand
                st = fire_actor(network, nm, st)
                c = dict(c)
                c[nm] = c[nm] + 1
                return st, c

            state, counts = jax.lax.cond(ready, do_fire, lambda o: o, (state, counts))
            fired_any = jnp.logical_or(fired_any, ready)
        return state, counts, fired_any, sweeps + 1

    def cond(carry):
        _, _, fired_any, sweeps = carry
        return jnp.logical_and(fired_any, sweeps < max_sweeps)

    def run(state: State):
        counts = {nm: jnp.int32(0) for nm in names}
        carry = (state, counts, jnp.bool_(True), jnp.int32(0))
        state, counts, _, _ = jax.lax.while_loop(cond, sweep, carry)
        return state, counts

    return jax.jit(run)


# --------------------------------------------------------------------------- #
# 3. Interpreted executor (GPP-thread / DAL-multicore analogue).
# --------------------------------------------------------------------------- #
def run_interpreted(network: Network, state: State, n_iterations: int,
                    order: Optional[List[str]] = None) -> State:
    """Eagerly fire the static schedule actor-by-actor (no cross-actor fusion).

    Each actor's firing is independently jitted — the analogue of the
    paper's per-thread GPP execution where no cross-actor optimization can
    happen.  Used as the multicore baseline in the Table 3/4 benchmarks.
    """
    order = list(order) if order is not None else network.topological_order()
    network.check_schedule_feasible()
    fns = {nm: jax.jit(functools.partial(fire_actor, network, nm)) for nm in order}
    for _ in range(n_iterations):
        for nm in order:
            state = fns[nm](state)
    return state


def collect_sink(network: Network, state: State, actor: str) -> Any:
    """Run an actor's ``finish`` hook on its final state (paper §3.1)."""
    a = network.actors[actor]
    st = state["actors"][actor]
    return a.finish(st) if a.finish is not None else st
