"""Pipeline-parallel execution of actor chains — shard_map + ppermute.

The paper's heterogeneous runtime streams tokens between processors through
Eq. 1 double buffers.  On a TPU mesh the same structure is the classic
double-buffered microbatch pipeline: each mesh slice owns one *stage*
(a fused run of actors / LM layers), stage-to-stage FIFO channels become
``lax.ppermute`` transfers, and the Eq. 1 ``2r`` capacity is exactly the
send/recv double buffer that lets transfer i+1 overlap compute i.

``pipeline_spmd`` implements the GPipe-style schedule with B microbatches
over S stages in B + S - 1 ticks.  It is expressed with ``shard_map`` so
the ppermute is explicit (not GSPMD-inferred) and composes with the data/
model axes of the production mesh.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
try:
    from jax import shard_map
except ImportError:  # older jax
    from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_spmd(stage_fn: Callable[[Any, jax.Array], jax.Array],
                  stage_params: Any,
                  microbatches: jax.Array,
                  mesh: Mesh,
                  axis: str = "stage") -> jax.Array:
    """Run ``microbatches`` through a pipeline of identical-signature stages.

    Args:
      stage_fn: ``(params_for_stage, x) -> y`` with ``y.shape == x.shape``
        (LM blocks satisfy this; heterogeneous IO needs a wrapper pair).
      stage_params: pytree whose leaves have a leading ``n_stages`` axis,
        sharded along ``axis``.
      microbatches: ``(n_micro, *x_shape)`` array, replicated along ``axis``.
      mesh: mesh containing ``axis`` (size = n_stages).
      axis: mesh axis name carrying the pipeline.

    Returns ``(n_micro, *x_shape)`` outputs of the final stage (valid on
    every shard — gathered via the closing ppermute ring).
    """
    n_stages = mesh.shape[axis]
    n_micro = microbatches.shape[0]
    n_ticks = n_micro + n_stages - 1

    def per_shard(params, mb):
        # params: leaves (1, ...) — this stage's slice;  mb: (n_micro, *x).
        params = jax.tree.map(lambda p: p[0], params)
        stage = jax.lax.axis_index(axis)
        x_shape = mb.shape[1:]

        def tick(carry, t):
            recv, outs = carry
            # Stage 0 ingests microbatch t (when in range); others use recv.
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            fed = jax.lax.dynamic_index_in_dim(mb, mb_idx, 0, keepdims=False)
            x = jnp.where(stage == 0, fed, recv)
            active = jnp.logical_and(t - stage >= 0, t - stage < n_micro)
            y = jax.lax.cond(active, lambda v: stage_fn(params, v), lambda v: v, x)
            # Collect at the last stage: microbatch (t - (S-1)) completes at t.
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            do_collect = jnp.logical_and(stage == n_stages - 1,
                                         t - (n_stages - 1) >= 0)
            outs = jax.lax.cond(
                do_collect,
                lambda o: jax.lax.dynamic_update_index_in_dim(o, y, out_idx, 0),
                lambda o: o,
                outs)
            # Double-buffered shift to the next stage (Eq. 1 2r analogue:
            # ppermute's send buffer + next tick's recv).
            nxt = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (nxt, outs), None

        outs0 = jnp.zeros((n_micro,) + x_shape, microbatches.dtype)
        (_, outs), _ = jax.lax.scan(tick, (jnp.zeros(x_shape, mb.dtype), outs0),
                                    jnp.arange(n_ticks))
        # Broadcast final-stage results so every shard returns the same
        # (replicated-out) value: only the last stage contributes to the
        # psum (a one-hop broadcast in disguise).
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    spec_params = jax.tree.map(lambda _: P(axis), stage_params)
    import inspect
    kw = ("check_vma" if "check_vma" in
          inspect.signature(shard_map).parameters else "check_rep")
    fn = shard_map(per_shard, mesh=mesh,
                   in_specs=(spec_params, P()), out_specs=P(),
                   **{kw: False})
    return fn(stage_params, microbatches)


def pipeline_reference(stage_fn: Callable[[Any, jax.Array], jax.Array],
                       stage_params: Any,
                       microbatches: jax.Array) -> jax.Array:
    """Oracle: run stages sequentially (no mesh) — for pipeline tests."""
    n_stages = jax.tree.leaves(stage_params)[0].shape[0]

    def run_one(x):
        for s in range(n_stages):
            p = jax.tree.map(lambda l: l[s], stage_params)
            x = stage_fn(p, x)
        return x

    return jax.vmap(run_one)(microbatches)
