"""Dataflow actors — paper §2.2 and §3.1.

An actor consists of the mandatory ``fire`` function and optional ``init``,
``control`` and ``finish`` functions (paper §3.1 — the same formulation as
DAL, plus the ``control`` function that is this paper's addition).

*Static* actors consume/produce exactly the FIFO rate ``r`` on every port
on every firing.  *Dynamic* actors have one **control port** (token rate 1)
whose consumed token value pins every regular port to rate 0 or r for the
duration of that firing.

TPU adaptation: an actor is a pure JAX function; the executor threads a
state pytree through firings.  The ``control`` function maps the (traced)
control token to a dict of 0/1 enables; rate-0 ports freeze their FIFO
cursor and the actor body can be skipped entirely via ``lax.cond`` — this
is how the paper's "5x from running only the active filters" materializes
inside a single compiled XLA program.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

# fire(state, inputs: {port: (r, *tok_shape)}, rates: {port: 0/1 i32}) ->
#     (new_state, outputs: {port: (r, *tok_shape)})
FireFn = Callable[[Any, Mapping[str, jax.Array], Mapping[str, jax.Array]],
                  Tuple[Any, Dict[str, jax.Array]]]
# control(token) -> {port: 0/1 enable} for every regular port.
ControlFn = Callable[[jax.Array], Dict[str, jax.Array]]
InitFn = Callable[[], Any]


@dataclasses.dataclass(frozen=True)
class ActorSpec:
    """Static description of one actor.

    Attributes:
      name:          unique actor name.
      in_ports:      regular input port names, P_a^- (excludes control port).
      out_ports:     regular output port names, P_a^+.
      fire:          the firing function (mandatory, paper §3.1).
      control_port:  name of the control input port; None for static actors.
      control:       maps one control token -> per-port 0/1 enables.  Must
                     cover every regular port; required iff ``control_port``.
      init:          optional state constructor, run once at app init.
      finish:        optional, run once at termination (host-side; used by
                     sinks to hand results back).
      placement:     optional device/mesh tag — the actor-to-core mapping of
                     paper §3.3. ``None`` = "free mapping" (let the compiler
                     place it).
      ready:         optional readiness predicate ``state -> jax.Array``
                     (scalar bool), matching the annotation below.  The
                     token-driven scheduler consumes it as a *traced*
                     predicate: it is evaluated inside the compiled
                     ``lax.while_loop`` sweep and combined with the FIFO
                     blocking predicates via ``jnp.logical_and``, so it
                     must be a pure JAX function returning a scalar boolean
                     array — never a Python ``bool`` (a Python bool would
                     bake one branch in at trace time).  Sources use it to
                     signal input exhaustion — the analogue of the paper's
                     ``finish``-driven teardown.
      cost_flops:    optional static per-firing FLOP estimate (roofline).
    """

    name: str
    in_ports: Tuple[str, ...]
    out_ports: Tuple[str, ...]
    fire: FireFn
    control_port: Optional[str] = None
    control: Optional[ControlFn] = None
    init: Optional[InitFn] = None
    finish: Optional[Callable[[Any], Any]] = None
    placement: Optional[str] = None
    ready: Optional[Callable[[Any], jax.Array]] = None
    cost_flops: int = 0

    def __post_init__(self) -> None:
        if self.control_port is not None and self.control is None:
            raise ValueError(f"actor {self.name}: dynamic actor needs a control function")
        if self.control_port is None and self.control is not None:
            raise ValueError(f"actor {self.name}: control function without control port")
        if self.control_port in self.in_ports:
            raise ValueError(
                f"actor {self.name}: control port {self.control_port!r} must not "
                f"be listed among regular in_ports"
            )
        names = list(self.in_ports) + list(self.out_ports)
        if len(set(names)) != len(names):
            raise ValueError(f"actor {self.name}: duplicate port names {names}")

    # ------------------------------------------------------------------ #
    @property
    def is_dynamic(self) -> bool:
        return self.control_port is not None

    @property
    def is_source(self) -> bool:
        """Zero input ports (paper §2.2). Control port does not count."""
        return not self.in_ports and self.control_port is None

    @property
    def is_sink(self) -> bool:
        return not self.out_ports

    def all_in_ports(self) -> Tuple[str, ...]:
        if self.control_port is not None:
            return (self.control_port,) + tuple(self.in_ports)
        return tuple(self.in_ports)

    def rates_for(self, ctrl_token: Optional[jax.Array]) -> Dict[str, jax.Array]:
        """Evaluate the control function -> {port: 0/1 enable} (i32).

        Static actors enable every port unconditionally.
        """
        one = jnp.int32(1)
        if not self.is_dynamic:
            return {p: one for p in (*self.in_ports, *self.out_ports)}
        assert ctrl_token is not None
        rates = {k: jnp.asarray(v, jnp.int32) for k, v in self.control(ctrl_token).items()}
        missing = (set(self.in_ports) | set(self.out_ports)) - set(rates)
        if missing:
            raise ValueError(
                f"actor {self.name}: control() must set a rate for every regular "
                f"port; missing {sorted(missing)}"
            )
        return rates

    def init_state(self) -> Any:
        return self.init() if self.init is not None else ()


def static_actor(name: str, in_ports, out_ports, fire: FireFn, **kw) -> ActorSpec:
    """Convenience constructor for static-rate actors."""
    return ActorSpec(name=name, in_ports=tuple(in_ports), out_ports=tuple(out_ports),
                     fire=fire, **kw)


def dynamic_actor(name: str, control_port: str, control: ControlFn,
                  in_ports, out_ports, fire: FireFn, **kw) -> ActorSpec:
    """Convenience constructor for dynamic-rate actors (paper's contribution)."""
    return ActorSpec(name=name, in_ports=tuple(in_ports), out_ports=tuple(out_ports),
                     fire=fire, control_port=control_port, control=control, **kw)


def apply_rate_gate(rate: jax.Array, window: jax.Array) -> Optional[jax.Array]:
    """Gate a window by its 0/1 rate enable, folding constants at trace time.

    Actor bodies that sum over maskable inputs multiply each window by its
    rate flag (disabled windows hold MoC-unspecified data).  When the
    enable is a compile-time constant — every firing of a static-rewrite
    graph — the multiply is pure overhead: returns the window unchanged for
    a constant 1 and ``None`` (drop the term) for a constant 0, keeping the
    traced multiply only for genuinely data-dependent enables.
    """
    if not isinstance(rate, jax.core.Tracer):
        return window if int(rate) else None
    return rate.astype(window.dtype) * window


def map_fire(fn: Callable[[jax.Array], jax.Array], in_port: str, out_port: str) -> FireFn:
    """Lift a per-window function into a FireFn for 1-in/1-out actors."""

    def fire(state, inputs, rates):
        del rates
        return state, {out_port: fn(inputs[in_port])}

    return fire
