"""Declarative network construction — the paper's §3.1/§3.4 host API.

The paper's host program is a handful of uniform calls: declare actors,
declare channels, launch.  Hand-assembling parallel ``actors`` / ``fifos``
/ ``edges`` lists (the pre-builder style) scatters one logical connection
across three places and pushes every MoC rule violation to the monolithic
``Network.__init__`` validator, whose errors point at lists, not at the
line that made the mistake.  :class:`NetworkBuilder` is the declarative
replacement::

    b = NetworkBuilder()
    b.actor(source)
    b.actor(amp)
    b.actor(sink)
    b.connect("source.out", "amp.in", rate=2, token_shape=(4,))
    b.connect("ctl.out", "amp.c")            # control: inferred from port
    net = b.build()                          # -> plain repro.core.Network

One ``connect`` call replaces a ``FifoSpec`` + an ``Edge``; channel names
are auto-derived (override with ``name=``), ``is_control`` is inferred
from the destination port, and ``matched_rates`` — the transient-channel
declaration that unlocks register allocation in the specialized static
executor — is *derived* from the two endpoint actors' control functions
when the match is provable (see :func:`derive_matched_rates`).  Violations
of the MoC's structural rules (unknown actor/port, double connection,
control-rate, …) are reported at the offending ``connect`` call with the
exact fix, not at build time.

``build()`` emits today's :class:`repro.core.network.Network` unchanged —
builder-constructed and hand-assembled networks are indistinguishable
(same actor/fifo ordering rules: registration / connection order), so all
executors, verifiers and the :class:`repro.core.program.Program` runtime
apply as-is.
"""
from __future__ import annotations

import dataclasses
import difflib
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.actor import ActorSpec
from repro.core.fifo import FifoSpec
from repro.core.network import Edge, Network


def _suggest(name: str, known: Sequence[str]) -> str:
    close = difflib.get_close_matches(name, list(known), n=2)
    hint = f"; did you mean {' or '.join(map(repr, close))}?" if close else ""
    return f"known: {sorted(known)}{hint}"


@dataclasses.dataclass(frozen=True)
class _Connection:
    """One declared channel, pre-Network: spec + endpoint binding."""

    spec: FifoSpec
    edge: Edge
    matched_override: Optional[bool]   # None = derive at build()
    initial_token: Optional[Any]


# --------------------------------------------------------------------------- #
# matched_rates derivation: prove the two ports are always enabled together.
# --------------------------------------------------------------------------- #
def _canonical_enable_str(closed) -> str:
    """Canonical string of the sub-jaxpr computing one enable output.

    Control functions compute the *whole* per-port dict, so the raw jaxpr
    of one port carries dead equations for every other port (and their
    count varies per actor).  Backward-slice from the output, rename vars
    in first-use order, and inline captured const values — two ports
    canonicalize equally iff they run the same live computation on the
    token (the basis of the matched-rates proof).
    """
    import numpy as np
    jaxpr = closed.jaxpr
    needed = {id(v) for v in jaxpr.outvars
              if not isinstance(v, jax.core.Literal)}
    kept = []
    for eqn in reversed(jaxpr.eqns):
        if any(id(v) in needed for v in eqn.outvars):
            kept.append(eqn)
            needed |= {id(v) for v in eqn.invars
                       if not isinstance(v, jax.core.Literal)}
    kept.reverse()
    consts = {id(v): c for v, c in zip(jaxpr.constvars, closed.consts)}
    names: Dict[int, str] = {}

    def nm(v) -> str:
        if isinstance(v, jax.core.Literal):
            return f"lit({v.val!r}:{getattr(v.aval, 'dtype', None)})"
        if id(v) not in names:
            if id(v) in consts:
                c = np.asarray(consts[id(v)])
                names[id(v)] = f"const({c.dtype}:{c.tolist()!r})"
            else:
                names[id(v)] = f"v{len(names)}"
        return names[id(v)]

    for v in jaxpr.invars:
        nm(v)
    lines = []
    for eqn in kept:
        params = sorted((k, repr(p)) for k, p in eqn.params.items())
        lines.append(f"{[nm(v) for v in eqn.outvars]} = "
                     f"{eqn.primitive.name}{params} "
                     f"{[nm(v) for v in eqn.invars]}")
    lines.append("out " + repr([nm(v) for v in jaxpr.outvars]))
    return "\n".join(lines)


def _enable_expr(actor: ActorSpec, port: str,
                 ctl_token_spec: Optional[FifoSpec],
                 ctl_feed: Optional[Tuple[str, str]]):
    """Classify a port's enable as ``("const", v)`` or ``("expr", s, feed)``.

    * static actor -> every regular port is unconditionally enabled:
      ``("const", 1)``;
    * dynamic actor -> trace ``control(token)[port]`` to a jaxpr.  If the
      output provably does not depend on the token (no dataflow path from
      the input var), evaluate it once: ``("const", v)``.  Otherwise the
      canonical jaxpr string plus the identity of the channel feeding the
      control port — ``("expr", jaxpr_str, (feeder_actor, feeder_port))`` —
      is the symbolic enable.

    Returns ``None`` when the enable cannot be classified (control channel
    not yet known, tracing failure) — callers treat that as unprovable.
    """
    if not actor.is_dynamic:
        return ("const", 1)
    if ctl_token_spec is None or ctl_feed is None:
        return None
    try:
        tok0 = jnp.zeros((1,) + tuple(ctl_token_spec.token_shape),
                         ctl_token_spec.dtype)[0]

        def enable(tok):
            return jnp.asarray(actor.control(tok)[port], jnp.int32)

        closed = jax.make_jaxpr(enable)(tok0)
    except Exception:
        return None
    jaxpr = closed.jaxpr
    # Dataflow reachability: does any outvar depend on the token invar?
    reached = {id(v) for v in jaxpr.invars}
    for eqn in jaxpr.eqns:
        if any(not isinstance(v, jax.core.Literal) and id(v) in reached
               for v in eqn.invars):
            reached |= {id(v) for v in eqn.outvars}
    depends = any(not isinstance(v, jax.core.Literal) and id(v) in reached
                  for v in jaxpr.outvars)
    if not depends:
        try:
            return ("const", int(enable(tok0)))
        except Exception:
            return None
    return ("expr", _canonical_enable_str(closed), ctl_feed)


def _ports_provably_equal(actor: ActorSpec, p1: str, p2: str,
                          in_specs: Dict[str, FifoSpec]) -> bool:
    """True when ``actor`` provably emits the same value on ``p1``/``p2``.

    Proof by tracing one firing of ``fire`` with example-shaped inputs and
    checking that the two output ports flatten to the *same jaxpr
    variable* — the single-assignment form can only reuse a var for both
    outputs when they are literally the same traced value (e.g. DPD's
    configuration actor broadcasting one token to all control ports).
    Conservative: any trace failure, and any pair that merely computes
    equal-but-distinct values, is "not provable".
    """
    if p1 == p2:
        return True
    try:
        state0 = actor.init_state()
        ones = {p: jnp.int32(1)
                for p in (*actor.in_ports, *actor.out_ports)}
        ins = {}
        for p in actor.in_ports:
            spec = in_specs.get(p)
            if spec is None:
                return False
            ins[p] = jnp.zeros((spec.rate,) + tuple(spec.token_shape),
                               spec.dtype)

        def f(st, windows):
            _, outs = actor.fire(st, windows, ones)
            return outs[p1], outs[p2]

        closed = jax.make_jaxpr(f)(state0, ins)
        o1, o2 = closed.jaxpr.outvars
        return (not isinstance(o1, jax.core.Literal)
                and not isinstance(o2, jax.core.Literal)
                and o1 is o2)
    except Exception:
        return False


def derive_matched_rates(src: ActorSpec, src_port: str,
                         dst: ActorSpec, dst_port: str,
                         src_env, dst_env,
                         feeder_equal) -> bool:
    """Decide whether a delay-free data channel's ports are provably
    enabled together (the ``FifoSpec.matched_rates`` invariant).

    ``src_env`` / ``dst_env`` are :func:`_enable_expr` classifications for
    the producing and consuming port; ``feeder_equal(actor, pa, pb)``
    proves two output ports of a shared control-feeder actor carry the
    same value.  The provable cases:

      * both enables constant and equal (covers a dynamic port whose
        control function pins it unconditionally on, e.g. DPD fork's
        ``in`` port against a static source);
      * both enables are the *same expression* of control tokens that
        *provably carry the same value* — identical jaxprs, control
        channels fed by the same actor on ports shown equal by tracing
        that actor's ``fire`` (DPD's configuration fan-out).

    Channels between two static actors are deliberately **not** marked:
    both enables are constant, but registerizing static-static bulk
    channels fuses producer stencils into every consumer tap (the XLA CPU
    mega-fusion pathology, EXPERIMENTS.md §Executor perf) — the buffered
    static-offset path is the measured optimum there, and
    ``Network.register_fifos`` already handles the profitable
    static-producer *control* channels separately.
    """
    if not (src.is_dynamic or dst.is_dynamic):
        return False
    if src_env is None or dst_env is None:
        return False
    if src_env[0] == "const" and dst_env[0] == "const":
        return src_env[1] == dst_env[1]
    if src_env[0] == "expr" and dst_env[0] == "expr":
        _, s_expr, (s_feed_actor, s_feed_port) = src_env
        _, d_expr, (d_feed_actor, d_feed_port) = dst_env
        if s_expr != d_expr or s_feed_actor != d_feed_actor:
            return False
        return feeder_equal(s_feed_actor, s_feed_port, d_feed_port)
    return False  # const vs token-dependent: enables can diverge


# --------------------------------------------------------------------------- #
# PRUNE-style buffer-bound analysis (arXiv:1802.06625): decide per channel,
# from declared or derived enable-fraction bounds, whether the Eq. 1
# capacity provably suffices — overflow/starvation becomes a *build* error
# for decidable graphs and stays a runtime guard flag only for the rest.
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class ChannelBounds:
    """One channel's enable-fraction bounds and the verdict they prove.

    ``src_bounds`` / ``dst_bounds`` are ``(lo, hi)`` fractions of firings
    in which the producing / consuming port is enabled (1.0 = every
    firing, the static case).  Verdicts:

      * ``"balanced"``   — production provably equals consumption
        (matched-rates derivation, or equal constant bounds): the Eq. 1
        capacity is exact, the channel cannot overflow or starve.
      * ``"unbounded"``  — the producer's floor exceeds the consumer's
        ceiling: backlog grows every iteration, and under blocking
        semantics the producer eventually blocks for good (the bounded-
        buffer image of PRUNE's unbounded-growth verdict).
      * ``"starved"``    — the consumer's floor exceeds the producer's
        ceiling: the consumer is guaranteed to stall waiting on tokens
        that provably never arrive often enough.
      * ``"undecided"``  — token-dependent enables with no declared
        bounds: not provable either way at build time; the runtime
        guards (``ExecutionPlan(guards=True)``) own this channel.
    """

    fifo: str
    src: str
    dst: str
    src_bounds: Tuple[float, float]
    dst_bounds: Tuple[float, float]
    verdict: str

    def describe(self) -> str:
        return (f"channel {self.fifo!r} ({self.src} -> {self.dst}): "
                f"{self.verdict} [producer enabled "
                f"{self.src_bounds[0]:g}..{self.src_bounds[1]:g} of "
                f"firings, consumer {self.dst_bounds[0]:g}.."
                f"{self.dst_bounds[1]:g}]")


@dataclasses.dataclass(frozen=True)
class BoundsReport:
    """Per-channel verdicts of :meth:`NetworkBuilder.check_bounds`."""

    channels: Tuple[ChannelBounds, ...]

    def violations(self) -> Tuple[ChannelBounds, ...]:
        return tuple(c for c in self.channels
                     if c.verdict in ("unbounded", "starved"))

    def undecided(self) -> Tuple[ChannelBounds, ...]:
        return tuple(c for c in self.channels if c.verdict == "undecided")

    def describe(self) -> str:
        return "\n".join(c.describe() for c in self.channels)


# --------------------------------------------------------------------------- #
# The builder.
# --------------------------------------------------------------------------- #
class NetworkBuilder:
    """Incremental, validating construction surface for actor networks."""

    def __init__(self) -> None:
        self._actors: Dict[str, ActorSpec] = {}
        self._connections: List[_Connection] = []
        self._fifo_names: set = set()
        self._used_out: Dict[Tuple[str, str], str] = {}
        self._used_in: Dict[Tuple[str, str], str] = {}
        self._rate_bounds: Dict[Tuple[str, str], Tuple[float, float]] = {}
        #: Last :meth:`check_bounds` result (also set by
        #: ``build(check_bounds=True)``).
        self.bounds_report: Optional[BoundsReport] = None

    # -- actors --------------------------------------------------------- #
    def actor(self, spec: ActorSpec) -> ActorSpec:
        """Register an actor.  Registration order is the network's actor
        order (and thus the state layout).  Returns ``spec`` for chaining."""
        if not isinstance(spec, ActorSpec):
            raise TypeError(
                f"NetworkBuilder.actor() takes an ActorSpec, got "
                f"{type(spec).__name__}; build one with static_actor(...) "
                "or dynamic_actor(...)")
        if spec.name in self._actors:
            raise ValueError(
                f"actor {spec.name!r} already registered; actor names must "
                "be unique within a network")
        self._actors[spec.name] = spec
        return spec

    def actors(self, *specs: ActorSpec) -> "NetworkBuilder":
        for s in specs:
            self.actor(s)
        return self

    # -- endpoint parsing ------------------------------------------------ #
    def _parse(self, endpoint: str, kind: str) -> Tuple[str, str]:
        if not isinstance(endpoint, str) or endpoint.count(".") != 1:
            raise ValueError(
                f"{kind} endpoint {endpoint!r} must be an 'actor.port' "
                "string (exactly one dot)")
        actor, port = endpoint.split(".")
        if actor not in self._actors:
            raise ValueError(
                f"{kind} endpoint {endpoint!r}: unknown actor {actor!r} — "
                f"register it with b.actor(...) first; "
                f"{_suggest(actor, self._actors)}")
        return actor, port

    # -- channels -------------------------------------------------------- #
    def connect(self, src: str, dst: str, *,
                rate: int = 1,
                token_shape: Optional[Tuple[int, ...]] = None,
                dtype: Any = None,
                capacity: Optional[int] = None,
                delay: int = 0,
                control: Optional[bool] = None,
                name: Optional[str] = None,
                matched_rates: Optional[bool] = None,
                initial_token: Optional[Any] = None,
                domain: Optional[Tuple[float, float]] = None,
                row_id_col: Optional[int] = None) -> str:
        """Declare one channel ``src("actor.port") -> dst("actor.port")``.

        * ``name`` defaults to ``"src.port->dst.port"``;
        * ``domain=(lo, hi)`` declares the valid value range of every
          token element: guarded runs flag out-of-range enabled windows
          with the ``DOMAIN`` fault bit (:mod:`repro.core.health`) and
          ``Program.stream`` validates staged feeds against it host-side;
        * ``row_id_col`` names the record-id column of record-row tokens
          (>= 2-D token shapes) so fault and feed-validation reports can
          name the offending record, not just the channel;
        * ``control`` (whether this is a rate-1 control channel) is
          inferred from the destination port being the consuming actor's
          control port — pass it only to assert your expectation;
        * control channels default to ``token_shape=(1,)``/``int32`` (the
          scalar-token convention of every paper graph);
        * ``capacity`` is **derived** from the Eq. 1 law (``2r`` / ``3r+1``)
          — pass it to assert the expected value, mismatches raise;
        * ``matched_rates=None`` defers to :func:`derive_matched_rates` at
          ``build()`` time; ``True``/``False`` overrides the derivation;
        * ``delay=1`` with ``rate > 1`` additionally glues the two
          endpoint actors to one core under grid-partitioned megakernel
          plans (``ExecutionPlan(cores=k)``): the Fig. 2 copy-back
          cannot cross a partition boundary unless the initial tokens
          cover a whole read window
          (``Network.validate_partition`` / ``delay_partition_constraints``).

        Returns the channel name.
        """
        src_actor, src_port = self._parse(src, "source")
        dst_actor, dst_port = self._parse(dst, "destination")
        sa, da = self._actors[src_actor], self._actors[dst_actor]

        if src_port not in sa.out_ports:
            raise ValueError(
                f"connect({src!r}, {dst!r}): actor {src_actor!r} has no "
                f"output port {src_port!r}; {_suggest(src_port, sa.out_ports)}")
        if dst_port not in da.all_in_ports():
            raise ValueError(
                f"connect({src!r}, {dst!r}): actor {dst_actor!r} has no "
                f"input port {dst_port!r}; "
                f"{_suggest(dst_port, da.all_in_ports())}")

        if (src_actor, src_port) in self._used_out:
            raise ValueError(
                f"connect({src!r}, {dst!r}): output port {src!r} is already "
                f"connected by channel "
                f"{self._used_out[(src_actor, src_port)]!r}; the MoC allows "
                "exactly one reader per channel — add a fork actor to fan "
                "out")
        if (dst_actor, dst_port) in self._used_in:
            raise ValueError(
                f"connect({src!r}, {dst!r}): input port {dst!r} is already "
                f"connected by channel "
                f"{self._used_in[(dst_actor, dst_port)]!r}; the MoC allows "
                "exactly one writer per channel — add a merge actor to fan "
                "in")

        is_control = dst_port == da.control_port
        if control is not None and bool(control) != is_control:
            if control:
                raise ValueError(
                    f"connect({src!r}, {dst!r}): control=True but "
                    f"{dst_port!r} is not the control port of "
                    f"{dst_actor!r} (control_port={da.control_port!r})")
            raise ValueError(
                f"connect({src!r}, {dst!r}): control=False but "
                f"{dst_port!r} IS the control port of {dst_actor!r}; "
                "control channels are inferred from the destination port")
        if is_control:
            if rate != 1:
                raise ValueError(
                    f"connect({src!r}, {dst!r}): control channels must have "
                    f"token rate 1 (paper §2.2), got rate={rate}")
            if delay:
                raise ValueError(
                    f"connect({src!r}, {dst!r}): control channels cannot "
                    "carry delay tokens")
            token_shape = (1,) if token_shape is None else token_shape
            dtype = jnp.int32 if dtype is None else dtype
        else:
            if token_shape is None:
                raise ValueError(
                    f"connect({src!r}, {dst!r}): data channels need an "
                    "explicit token_shape=")
            dtype = jnp.float32 if dtype is None else dtype

        if name is None:
            name = f"{src}->{dst}"
        if name in self._fifo_names:
            raise ValueError(
                f"connect({src!r}, {dst!r}): channel name {name!r} already "
                "used; pass a unique name=")

        spec = FifoSpec(name, rate, tuple(token_shape), dtype, delay=delay,
                        is_control=is_control,
                        matched_rates=bool(matched_rates),
                        domain=domain, row_id_col=row_id_col)
        if capacity is not None and capacity != spec.capacity_tokens:
            raise ValueError(
                f"connect({src!r}, {dst!r}): capacity={capacity} contradicts "
                f"the Eq. 1 law — rate {rate} with delay {delay} allocates "
                f"{spec.capacity_tokens} tokens "
                f"({'3r+1' if delay else '2r'}); capacities are derived, not "
                "chosen (drop capacity= or fix rate/delay)")
        if initial_token is not None and not delay:
            raise ValueError(
                f"connect({src!r}, {dst!r}): initial_token needs delay=1 "
                "(initial tokens live on delay channels, paper §2.2)")

        edge = Edge(name, src_actor, src_port, dst_actor, dst_port)
        self._connections.append(_Connection(spec, edge, matched_rates,
                                             initial_token))
        self._fifo_names.add(name)
        self._used_out[(src_actor, src_port)] = name
        self._used_in[(dst_actor, dst_port)] = name
        return name

    # -- dangling-port accounting ---------------------------------------- #
    def dangling_ports(self) -> List[str]:
        """Every declared-but-unconnected port, as ``actor.port`` strings."""
        out = []
        for a in self._actors.values():
            for p in a.all_in_ports():
                if (a.name, p) not in self._used_in:
                    out.append(f"{a.name}.{p}")
            for p in a.out_ports:
                if (a.name, p) not in self._used_out:
                    out.append(f"{a.name}.{p}")
        return out

    # -- matched-rates derivation ---------------------------------------- #
    def _control_feed(self, actor: ActorSpec):
        """(feeder (actor, port), control FifoSpec) for a dynamic actor."""
        for c in self._connections:
            e = c.edge
            if e.dst_actor == actor.name and e.dst_port == actor.control_port:
                return (e.src_actor, e.src_port), c.spec
        return None, None

    def _derive_matched(self) -> Dict[str, bool]:
        in_specs: Dict[str, Dict[str, FifoSpec]] = {n: {} for n in self._actors}
        for c in self._connections:
            in_specs[c.edge.dst_actor][c.edge.dst_port] = c.spec

        env_cache: Dict[Tuple[str, str], Any] = {}

        def env(actor_name: str, port: str):
            key = (actor_name, port)
            if key not in env_cache:
                a = self._actors[actor_name]
                feed, cspec = self._control_feed(a)
                env_cache[key] = _enable_expr(a, port, cspec, feed)
            return env_cache[key]

        feeder_cache: Dict[Tuple[str, str, str], bool] = {}

        def feeder_equal(actor_name: str, pa: str, pb: str) -> bool:
            key = (actor_name, *sorted((pa, pb)))
            if key not in feeder_cache:
                feeder_cache[key] = _ports_provably_equal(
                    self._actors[actor_name], pa, pb,
                    in_specs[actor_name])
            return feeder_cache[key]

        out: Dict[str, bool] = {}
        for c in self._connections:
            if c.matched_override is not None:
                out[c.spec.name] = c.matched_override
                continue
            if c.spec.is_control or c.spec.delay:
                out[c.spec.name] = False
                continue
            e = c.edge
            out[c.spec.name] = derive_matched_rates(
                self._actors[e.src_actor], e.src_port,
                self._actors[e.dst_actor], e.dst_port,
                env(e.src_actor, e.src_port), env(e.dst_actor, e.dst_port),
                feeder_equal)
        return out

    # -- PRUNE-style bound proofs ----------------------------------------- #
    def rate_bounds(self, endpoint: str, lo: float,
                    hi: float) -> "NetworkBuilder":
        """Declare worst/best-case enable bounds for a dynamic port.

        ``lo`` / ``hi`` bound the *fraction of firings* in which
        ``endpoint`` ("actor.port") is enabled by its control token —
        the declared-rate input PRUNE's analysis needs where the enable
        is data-dependent and not derivable from the control jaxpr.
        ``rate_bounds("fork.active", 0.0, 1.0)`` is the (vacuous)
        default; ``(1.0, 1.0)`` pins the port always-on; ``(0.5, 0.5)``
        declares exact half-rate decimation.  Returns ``self``.
        """
        actor, port = self._parse(endpoint, "rate_bounds")
        a = self._actors[actor]
        if port not in a.all_in_ports() and port not in a.out_ports:
            raise ValueError(
                f"rate_bounds({endpoint!r}): actor {actor!r} has no port "
                f"{port!r}; "
                f"{_suggest(port, (*a.all_in_ports(), *a.out_ports))}")
        if not (0.0 <= lo <= hi <= 1.0):
            raise ValueError(
                f"rate_bounds({endpoint!r}): bounds must satisfy "
                f"0 <= lo <= hi <= 1 (fractions of firings), got "
                f"lo={lo}, hi={hi}")
        self._rate_bounds[(actor, port)] = (float(lo), float(hi))
        return self

    def _port_bounds(self, actor_name: str, port: str,
                     env) -> Tuple[float, float]:
        """Enable-fraction bounds of one port, most-precise source first:
        declared ``rate_bounds`` > control port (consumes every firing) >
        provably-constant enable > static actor > dynamic unknown."""
        a = self._actors[actor_name]
        declared = self._rate_bounds.get((actor_name, port))
        if declared is not None:
            return declared
        if port == a.control_port:
            return (1.0, 1.0)
        if not a.is_dynamic:
            return (1.0, 1.0)
        e = env(actor_name, port)
        if e is not None and e[0] == "const":
            v = 1.0 if e[1] > 0 else 0.0
            return (v, v)
        return (0.0, 1.0)

    def check_bounds(self) -> BoundsReport:
        """Run the PRUNE-style per-channel bound analysis (no build).

        Combines the matched-rates derivation (provably co-enabled ports
        -> ``"balanced"``), constant-enable proofs from the control
        jaxprs, and any declared :meth:`rate_bounds` into a per-channel
        verdict; see :class:`ChannelBounds` for the taxonomy.  The report
        is also stored as ``self.bounds_report``.
        """
        matched = self._derive_matched()
        env_cache: Dict[Tuple[str, str], Any] = {}

        def env(actor_name: str, port: str):
            key = (actor_name, port)
            if key not in env_cache:
                a = self._actors[actor_name]
                feed, cspec = self._control_feed(a)
                env_cache[key] = _enable_expr(a, port, cspec, feed)
            return env_cache[key]

        channels = []
        for c in self._connections:
            e = c.edge
            src_b = self._port_bounds(e.src_actor, e.src_port, env)
            dst_b = self._port_bounds(e.dst_actor, e.dst_port, env)
            if matched.get(c.spec.name):
                verdict = "balanced"
            elif src_b[0] > dst_b[1]:
                verdict = "unbounded"
            elif dst_b[0] > src_b[1]:
                verdict = "starved"
            elif src_b == dst_b and src_b[0] == src_b[1]:
                verdict = "balanced"
            else:
                verdict = "undecided"
            channels.append(ChannelBounds(
                fifo=c.spec.name,
                src=f"{e.src_actor}.{e.src_port}",
                dst=f"{e.dst_actor}.{e.dst_port}",
                src_bounds=src_b, dst_bounds=dst_b, verdict=verdict))
        report = BoundsReport(channels=tuple(channels))
        self.bounds_report = report
        return report

    # -- emission --------------------------------------------------------- #
    def build(self, derive_matched: bool = True,
              check_bounds: bool = False) -> Network:
        """Validate and emit the :class:`Network`.

        Dangling ports are reported here with the exact ``connect`` calls
        still missing; everything else was validated incrementally.  With
        ``derive_matched=True`` (default) channels left with
        ``matched_rates=None`` get the provable-transiency derivation.
        ``check_bounds=True`` additionally runs the PRUNE-style buffer
        bound analysis (:meth:`check_bounds`) and rejects graphs with a
        provably unbounded or starved channel — overflow becomes a build
        error where decidable, a runtime guard flag only for the rest.
        """
        dangling = self.dangling_ports()
        if dangling:
            raise ValueError(
                "network has dangling ports (every port connects to exactly "
                f"one channel, paper §3.2): {sorted(dangling)} — add a "
                "b.connect(...) for each")
        if check_bounds:
            bad = self.check_bounds().violations()
            if bad:
                raise ValueError(
                    "NetworkBuilder.build(check_bounds=True): the declared/"
                    "derived rate bounds prove these channels violate their "
                    "Eq. 1 buffers:\n  "
                    + "\n  ".join(c.describe() for c in bad)
                    + "\n(fix the graph, adjust rate_bounds(...), or build "
                    "with check_bounds=False and rely on runtime guards)")
        matched = (self._derive_matched() if derive_matched
                   else {c.spec.name: bool(c.matched_override)
                         for c in self._connections})
        fifos = [dataclasses.replace(c.spec, matched_rates=matched[c.spec.name])
                 if matched[c.spec.name] != c.spec.matched_rates else c.spec
                 for c in self._connections]
        initial = {c.spec.name: c.initial_token for c in self._connections
                   if c.initial_token is not None}
        return Network(list(self._actors.values()), fifos,
                       [c.edge for c in self._connections],
                       initial_tokens=initial or None)
