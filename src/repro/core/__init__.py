"""repro.core — the paper's dynamic-data-rate dataflow MoC, in JAX.

Public API surface (mirrors the paper's minimal C API of §3.1/§3.4: actor
description, channel law, network composition, one compile entrypoint).

The construction surface is :class:`NetworkBuilder` (declare actors,
connect ports, build); the execution surface is ``Network.compile(plan)``
returning a :class:`Program`.  ``compile_static`` / ``compile_dynamic`` /
``run_interpreted`` remain as deprecated shims."""
from repro.core.actor import (ActorSpec, apply_rate_gate, dynamic_actor,
                              map_fire, static_actor)
from repro.core.fifo import FifoSpec, FifoState, total_buffer_bytes
from repro.core.network import (Edge, Network, NetworkState,
                                iteration_token_flops, name_index_map,
                                repetition_vector)
from repro.core.builder import (BoundsReport, ChannelBounds, NetworkBuilder,
                                derive_matched_rates)
from repro.core.health import (CURSOR_INVALID, DOMAIN, NONFINITE, OVERFLOW,
                               STALL, UNDERFLOW, ChannelFault, Diagnostics,
                               HealthState, NetworkFaultError, StallReport,
                               decode_health, diagnose_stall, fault_names,
                               init_health)
from repro.core.faultinject import (corrupt_cursor, expire_deadline,
                                    inject_overflow, inject_underflow,
                                    poison_request, poison_tokens,
                                    truncate_feed)
from repro.core.executor import (
    RuntimeMode,
    assert_mode_allows,
    collect_sink,
    compile_dynamic,
    compile_static,
    fire_actor,
    make_iteration_step,
    run_interpreted,
)
from repro.core.program import (MEGAKERNEL, ExecutionPlan, Mode, Program,
                                ProgramStats, RunResult)
from repro.core.trace import (TRACE_CAPACITY_DEFAULT, Profile, Trace,
                              TraceState, decode_trace, init_trace,
                              merge_device_traces, merge_traces,
                              validate_chrome_trace)

# Megakernel names resolve lazily (module __getattr__ below): the backend
# imports jax.experimental.pallas(+tpu), ~1 s of import cost every
# non-megakernel consumer of repro.core should not pay.
_MEGAKERNEL_EXPORTS = ("GridPartition", "MegakernelLayout",
                       "compile_megakernel", "default_assignment",
                       "lower_network", "partition_layout",
                       "state_hbm_bytes")

# Sharding names resolve lazily too: repro.core.shard reuses the
# megakernel partition pass, so importing it pulls the same pallas
# dependency chain.
_SHARD_EXPORTS = ("build_device_partition", "collective_bytes_per_sweep",
                  "compile_sharded", "decode_device_trace")


def __getattr__(name: str):
    if name in _MEGAKERNEL_EXPORTS:
        from repro.core import megakernel
        return getattr(megakernel, name)
    if name in _SHARD_EXPORTS:
        from repro.core import shard
        return getattr(shard, name)
    raise AttributeError(f"module 'repro.core' has no attribute {name!r}")
from repro.core.mapping import (
    Placement,
    boundary_fifos,
    heterogeneous_split,
    partition_actors,
    stage_feed,
)
from repro.core.pipeline import pipeline_reference, pipeline_spmd
from repro.core.schedule import (cyclic_rate_table, layer_pattern_groups,
                                 phase_unroll_period)

__all__ = [
    "ActorSpec", "apply_rate_gate", "dynamic_actor", "map_fire", "static_actor",
    "FifoSpec", "FifoState", "total_buffer_bytes",
    "Edge", "Network", "NetworkState", "iteration_token_flops",
    "name_index_map", "repetition_vector",
    "NetworkBuilder", "derive_matched_rates", "BoundsReport", "ChannelBounds",
    "OVERFLOW", "UNDERFLOW", "CURSOR_INVALID", "NONFINITE", "STALL",
    "DOMAIN",
    "ChannelFault", "Diagnostics", "HealthState", "NetworkFaultError",
    "StallReport", "decode_health", "diagnose_stall", "fault_names",
    "init_health",
    "corrupt_cursor", "inject_overflow", "inject_underflow", "poison_tokens",
    "poison_request", "expire_deadline", "truncate_feed",
    "ExecutionPlan", "MEGAKERNEL", "Mode", "Program", "ProgramStats",
    "RunResult",
    "TRACE_CAPACITY_DEFAULT", "Profile", "Trace", "TraceState",
    "decode_trace", "init_trace", "merge_traces", "merge_device_traces",
    "validate_chrome_trace",
    "GridPartition", "MegakernelLayout", "compile_megakernel",
    "default_assignment", "lower_network", "partition_layout",
    "state_hbm_bytes",
    "build_device_partition", "collective_bytes_per_sweep",
    "compile_sharded", "decode_device_trace",
    "RuntimeMode", "assert_mode_allows", "collect_sink", "compile_dynamic",
    "compile_static", "fire_actor", "make_iteration_step", "run_interpreted",
    "Placement", "boundary_fifos", "heterogeneous_split", "partition_actors",
    "stage_feed", "pipeline_reference", "pipeline_spmd",
    "cyclic_rate_table", "layer_pattern_groups", "phase_unroll_period",
]
