"""Firing-level event tracing and occupancy profiling.

The runtime's aggregate telemetry (``fire_counts``, ``sweeps``, fault
high-water marks) says *what* happened but not *when*: which actors fired
in which sweep, which channels saturated, where a grid core idled waiting
on a crossing FIFO.  This module adds the missing timeline layer, shared
by both dynamic backends:

  * the **host dynamic executor** records one event per firing *attempt*
    (actor index, sweep number, fired-or-skipped, per-channel occupancy
    sampled after the attempt) into a loop-carried :class:`TraceState`;
  * the **megakernel** writes the same rows into a fixed-capacity
    device-side trace ring — an extra output ref threaded through the
    sweep loop exactly like the PR 6 fault refs, so ``trace=False``
    contributes an empty pytree and bit-identical HLO.

Capacity is fixed at compile time (``ExecutionPlan(trace_capacity=...)``,
default :data:`TRACE_CAPACITY_DEFAULT`); when the run outgrows it the
ring wraps and the *oldest* events are dropped, with the drop count
surfaced on the decoded :class:`Trace`.

On host, :func:`decode_trace` unwraps the ring into a :class:`Trace`:

  * ``trace.to_perfetto(path)`` exports Chrome trace-event JSON — one
    thread track per actor (grouped per core under grid partitioning),
    an occupancy counter track per channel — viewable in Perfetto
    (https://ui.perfetto.dev) or chrome://tracing;
  * ``trace.profile()`` derives a :class:`Profile`: per-actor mean
    firing cost (host wall-clock attributed over firings on the dynamic
    executor; firing-count x flops weighted in-kernel, where no
    per-firing clock exists) and per-channel occupancy churn;
  * ``Profile.as_cut_weights()`` feeds
    ``ExecutionPlan(cut_objective="profile", profile=...)`` so the grid
    partition cut uses *measured* churn instead of static capacity
    bytes — the measurement half of the ROADMAP autotuner.

Event rows are int32 vectors ``[actor_index, sweep, fired,
occ_0..occ_{F-1}]`` (width ``3 + n_fifos``); the column offsets are the
module constants ``COL_ACTOR`` / ``COL_SWEEP`` / ``COL_FIRED`` /
``COL_OCC``.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

#: Default trace-ring capacity in events (one event per firing attempt).
TRACE_CAPACITY_DEFAULT = 4096

# Event-row column layout (int32): [actor, sweep, fired, occ_0..occ_{F-1}].
COL_ACTOR, COL_SWEEP, COL_FIRED, COL_OCC = 0, 1, 2, 3


# --------------------------------------------------------------------------- #
# Device-side state (loop-carried on the dynamic executor, an output ref
# pair on the megakernel).
# --------------------------------------------------------------------------- #
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TraceState:
    """Fixed-capacity event ring + monotonic attempt counter.

    ``ring`` is ``(capacity, 3 + n_fifos)`` int32; ``count`` is the total
    number of events ever recorded (so ``count > capacity`` means the
    ring wrapped and the oldest ``count - capacity`` events are gone).
    """

    ring: jax.Array
    count: jax.Array

    def record(self, actor_index, sweep, fired, occs) -> "TraceState":
        """Append one event row (functional; wraps when full)."""
        row = jnp.concatenate([
            jnp.stack([jnp.asarray(actor_index, jnp.int32),
                       jnp.asarray(sweep, jnp.int32),
                       jnp.asarray(fired, jnp.int32)]),
            jnp.asarray(occs, jnp.int32),
        ])
        slot = self.count % self.ring.shape[0]
        return TraceState(ring=self.ring.at[slot].set(row),
                          count=self.count + 1)


def init_trace(n_fifos: int, capacity: int = TRACE_CAPACITY_DEFAULT
               ) -> TraceState:
    """Empty trace ring for a network with ``n_fifos`` channels."""
    return TraceState(
        ring=jnp.zeros((int(capacity), COL_OCC + int(n_fifos)), jnp.int32),
        count=jnp.int32(0))


# --------------------------------------------------------------------------- #
# Host-side decoded trace.
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class Trace:
    """Chronologically ordered firing events, decoded on host."""

    actor_names: Tuple[str, ...]
    fifo_names: Tuple[str, ...]
    #: ``(n_events, 3 + n_fifos)`` int32 — see the COL_* constants.
    events: np.ndarray
    capacity: int
    dropped: int = 0
    wall_time_s: Optional[float] = None
    #: Static per-actor cost estimates (flops), for wall-clock attribution.
    actor_flops: Tuple[int, ...] = ()
    #: Per-channel token sizes (bytes), for churn-in-bytes profiles.
    fifo_token_bytes: Tuple[int, ...] = ()
    #: Core index per actor under grid partitioning (None = single core).
    actor_cores: Optional[Tuple[int, ...]] = None

    @property
    def n_events(self) -> int:
        return int(self.events.shape[0])

    def firing_counts(self) -> Dict[str, int]:
        """Events with ``fired == 1`` per actor (drops excluded)."""
        fired = self.events[self.events[:, COL_FIRED] == 1, COL_ACTOR]
        return {nm: int((fired == i).sum())
                for i, nm in enumerate(self.actor_names)}

    def attempt_counts(self) -> Dict[str, int]:
        """All recorded attempts (fired + skipped) per actor."""
        return {nm: int((self.events[:, COL_ACTOR] == i).sum())
                for i, nm in enumerate(self.actor_names)}

    def occupancy(self, fifo: str) -> np.ndarray:
        """The sampled occupancy series of one channel, in event order."""
        return self.events[:, COL_OCC + self.fifo_names.index(fifo)]

    def extend(self, other: "Trace") -> "Trace":
        """Concatenate a later chunk's trace onto this one (stream use):
        the other trace's sweep numbers are offset past this trace's
        last sweep so the merged timeline stays monotonic."""
        if (other.actor_names != self.actor_names
                or other.fifo_names != self.fifo_names):
            raise ValueError("Trace.extend: traces come from different "
                             "networks")
        offset = (int(self.events[:, COL_SWEEP].max()) + 1
                  if self.n_events else 0)
        ev = other.events.copy()
        ev[:, COL_SWEEP] += offset
        wall = None
        if self.wall_time_s is not None or other.wall_time_s is not None:
            wall = (self.wall_time_s or 0.0) + (other.wall_time_s or 0.0)
        return dataclasses.replace(
            self, events=np.concatenate([self.events, ev], axis=0),
            dropped=self.dropped + other.dropped, wall_time_s=wall)

    # ------------------------------------------------------------------ #
    def profile(self) -> "Profile":
        """Derive measured per-actor costs and per-channel churn."""
        firings = self.firing_counts()
        # Wall-clock attribution: total run wall time split over actors
        # proportionally to firings x static flops (the dynamic executor
        # measures one wall clock around the whole jitted run — there is
        # no per-firing host clock inside a lax.while_loop, and none at
        # all inside the kernel).  Where no wall time was measured the
        # cost stays None and as_cut_weights falls back to the same
        # firings x flops weights.
        flops = {nm: max(1, int(f)) for nm, f in
                 zip(self.actor_names, self.actor_flops or
                     (1,) * len(self.actor_names))}
        weight = {nm: firings.get(nm, 0) * flops[nm]
                  for nm in self.actor_names}
        total_w = sum(weight.values())
        cost_s: Optional[Dict[str, float]] = None
        if self.wall_time_s is not None and total_w > 0:
            cost_s = {}
            for nm in self.actor_names:
                n = firings.get(nm, 0)
                cost_s[nm] = (self.wall_time_s * weight[nm] / total_w / n
                              if n else 0.0)
        # Occupancy churn: total |delta occ| between consecutive samples,
        # scaled to bytes by the channel token size — a measured stand-in
        # for "traffic through this channel" that a crossing cut wants to
        # keep inside one core.
        tok_bytes = (self.fifo_token_bytes or
                     (1,) * len(self.fifo_names))
        churn: Dict[str, int] = {}
        for i, nm in enumerate(self.fifo_names):
            occ = self.events[:, COL_OCC + i].astype(np.int64)
            delta = int(np.abs(np.diff(occ)).sum()) if len(occ) > 1 else 0
            churn[nm] = delta * max(1, int(tok_bytes[i]))
        return Profile(actor_names=self.actor_names,
                       firing_counts=firings, actor_flops=flops,
                       actor_cost_s=cost_s, channel_churn_bytes=churn,
                       wall_time_s=self.wall_time_s, dropped=self.dropped)

    # ------------------------------------------------------------------ #
    def to_perfetto(self, path: Optional[str] = None) -> dict:
        """Chrome trace-event JSON (Perfetto / chrome://tracing).

        One thread track per actor — named ``actor [core k]`` under grid
        partitioning — plus one counter track per channel (``occ:name``,
        emitted on change).  Fired attempts are complete ("X") slices,
        skipped attempts thread-scoped instants ("i").  Event timestamps
        are event-ordinal microseconds scaled so the timeline spans the
        measured wall time when one exists.
        """
        n = self.n_events
        scale = (self.wall_time_s * 1e6 / n
                 if self.wall_time_s and n else 1.0)
        ev: List[dict] = [{"name": "process_name", "ph": "M", "pid": 0,
                           "tid": 0, "args": {"name": "actor network"}}]
        for i, nm in enumerate(self.actor_names):
            label = nm
            if self.actor_cores is not None:
                label = f"{nm} [core {self.actor_cores[i]}]"
            ev.append({"name": "thread_name", "ph": "M", "pid": 0,
                       "tid": i + 1, "args": {"name": label}})
        prev_occ: Dict[str, int] = {}
        for k in range(n):
            row = self.events[k]
            ts = k * scale
            a = int(row[COL_ACTOR])
            base = {"cat": "firing", "pid": 0, "tid": a + 1, "ts": ts,
                    "args": {"sweep": int(row[COL_SWEEP])}}
            if int(row[COL_FIRED]):
                ev.append({"name": self.actor_names[a], "ph": "X",
                           "dur": scale, **base})
            else:
                ev.append({"name": f"{self.actor_names[a]} (skipped)",
                           "ph": "i", "s": "t", **base})
            for i, fnm in enumerate(self.fifo_names):
                occ = int(row[COL_OCC + i])
                if prev_occ.get(fnm) != occ:
                    prev_occ[fnm] = occ
                    ev.append({"name": f"occ:{fnm}", "ph": "C", "pid": 0,
                               "ts": ts, "args": {"tokens": occ}})
        doc = {"traceEvents": ev, "displayTimeUnit": "ms",
               "otherData": {"dropped_events": self.dropped,
                             "capacity": self.capacity,
                             "wall_time_s": self.wall_time_s}}
        if path is not None:
            with open(path, "w") as f:
                json.dump(doc, f)
        return doc


def merge_traces(traces: Sequence[Trace]) -> Optional[Trace]:
    """Fold per-chunk traces into one stream-long trace (sweep offsets
    applied chunk by chunk); None when the sequence is empty."""
    traces = [t for t in traces if t is not None]
    if not traces:
        return None
    out = traces[0]
    for t in traces[1:]:
        out = out.extend(t)
    return out


def merge_device_traces(traces: Sequence[Trace]) -> Optional[Trace]:
    """Interleave the per-device traces of ONE sharded run
    (``ExecutionPlan(devices=k)``) into a single timeline.

    Unlike :func:`merge_traces`, sweep numbers are NOT offset — the
    devices ran the same barrier rounds concurrently, so round ``r``
    means the same instant everywhere; events are stable-sorted by
    round (device order breaks ties) and drop counts summed.  Each
    device records only its own actors' attempts, so the merged
    ``firing_counts`` are exact; occupancy samples keep each recording
    device's local (conservative-between-barriers) view.
    """
    traces = [t for t in traces if t is not None]
    if not traces:
        return None
    first = traces[0]
    for t in traces[1:]:
        if (t.actor_names != first.actor_names
                or t.fifo_names != first.fifo_names):
            raise ValueError("merge_device_traces: traces come from "
                             "different networks")
    events = np.concatenate([t.events for t in traces], axis=0)
    order = np.argsort(events[:, COL_SWEEP], kind="stable")
    return dataclasses.replace(
        first, events=events[order],
        dropped=sum(t.dropped for t in traces))


# --------------------------------------------------------------------------- #
# Derived profile -> partition weights.
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class Profile:
    """Measured per-actor cost and per-channel occupancy churn."""

    actor_names: Tuple[str, ...]
    firing_counts: Dict[str, int]
    actor_flops: Dict[str, int]
    #: Mean seconds per firing (None when no wall clock was measured —
    #: the in-kernel case; weights then fall back to firings x flops).
    actor_cost_s: Optional[Dict[str, float]]
    channel_churn_bytes: Dict[str, int]
    wall_time_s: Optional[float] = None
    dropped: int = 0

    def as_cut_weights(self) -> Dict[str, Dict[str, int]]:
        """Integer weights for ``cut_objective="profile"``: per-actor
        load (firings x flops, floor 1 so unfired actors keep a seat)
        and per-channel measured churn in bytes."""
        actors = {nm: max(1, self.firing_counts.get(nm, 0)
                          * self.actor_flops.get(nm, 1))
                  for nm in self.actor_names}
        return {"actors": actors,
                "channels": dict(self.channel_churn_bytes)}


# --------------------------------------------------------------------------- #
# Decode (device pytree -> host Trace).
# --------------------------------------------------------------------------- #
def decode_trace(network, trace: Optional[TraceState],
                 wall_time_s: Optional[float] = None,
                 actor_cores: Optional[Mapping[str, int]] = None
                 ) -> Optional[Trace]:
    """Unwrap a device trace ring into a chronological :class:`Trace`."""
    if trace is None:
        return None
    ring = np.asarray(trace.ring)
    total = int(trace.count)
    cap = int(ring.shape[0])
    if total <= cap:
        events = ring[:total].copy()
    else:
        s = total % cap
        events = np.concatenate([ring[s:], ring[:s]], axis=0)
    actor_names = tuple(network.actors)
    fifo_names = tuple(network.fifos)
    flops = tuple(max(1, int(getattr(a, "cost_flops", 1) or 1))
                  for a in network.actors.values())
    tok_bytes = tuple(int(spec.token_size_bytes)
                      for spec in network.fifos.values())
    cores = None
    if actor_cores is not None:
        cores = tuple(int(actor_cores.get(nm, 0)) for nm in actor_names)
    return Trace(actor_names=actor_names, fifo_names=fifo_names,
                 events=events, capacity=cap,
                 dropped=max(0, total - cap), wall_time_s=wall_time_s,
                 actor_flops=flops, fifo_token_bytes=tok_bytes,
                 actor_cores=cores)


# --------------------------------------------------------------------------- #
# Chrome trace-event schema validation (used by the CI trace job).
# --------------------------------------------------------------------------- #
_REQUIRED_KEYS = {
    "M": ("name", "ph", "pid", "args"),
    "X": ("name", "ph", "pid", "tid", "ts", "dur"),
    "i": ("name", "ph", "pid", "tid", "ts", "s"),
    "C": ("name", "ph", "pid", "ts", "args"),
}


def validate_chrome_trace(doc: Any) -> List[str]:
    """Validate a Chrome trace-event document; returns a list of problem
    strings (empty == valid).  Checks the JSON object format, per-phase
    required keys, and non-decreasing timestamps per track (thread
    tracks keyed by (pid, tid); counter tracks by (pid, name))."""
    problems: List[str] = []
    if isinstance(doc, dict):
        events = doc.get("traceEvents")
        if not isinstance(events, list):
            return ["JSON-object format: 'traceEvents' missing or not a "
                    "list"]
    elif isinstance(doc, list):
        events = doc
    else:
        return [f"document is {type(doc).__name__}, expected dict or list"]
    last_ts: Dict[tuple, float] = {}
    for i, e in enumerate(events):
        if not isinstance(e, dict):
            problems.append(f"event {i}: not an object")
            continue
        ph = e.get("ph")
        if ph is None:
            problems.append(f"event {i}: missing 'ph'")
            continue
        required = _REQUIRED_KEYS.get(ph)
        if required is None:
            # Other phases (B/E/b/e/s/f/...) are legal Chrome events;
            # the exporter here only emits M/X/i/C, so just sanity-check.
            required = ("name", "ph")
        missing = [k for k in required if k not in e]
        if missing:
            problems.append(f"event {i} (ph={ph!r}): missing keys "
                            f"{missing}")
            continue
        if ph == "M":
            continue
        if "ts" in e:
            key = ((e["pid"], "C", e["name"]) if ph == "C"
                   else (e["pid"], e.get("tid")))
            ts = float(e["ts"])
            if ts < last_ts.get(key, float("-inf")):
                problems.append(
                    f"event {i} (ph={ph!r}, track {key}): ts {ts} goes "
                    f"backwards (prev {last_ts[key]})")
            last_ts[key] = ts
    return problems
