"""Fault injection: corrupt a live NetworkState the way real bugs would.

The health layer's guards (:mod:`repro.core.health`) claim to catch
overflow, underflow, cursor corruption and non-finite tokens on every
backend — a claim that is only worth anything if something *proves* each
guard fires.  This module is the chaos half of that proof: each injector
takes a valid :class:`~repro.core.network.NetworkState` and returns one
corrupted exactly like a specific bug class would corrupt it, so the
chaos suite (``tests/test_faults.py``) can assert the resulting run
raises a :class:`~repro.core.health.NetworkFaultError` naming the right
channel on the dynamic executor, the megakernel, and every grid core
count.

The injectors model the *mechanism*, not just the symptom:

  * :func:`inject_overflow` lowers a channel's occupancy counter — the
    scheduler now believes there is room, lets the producer fire past the
    Eq. 1 writable bound, and the write guard sees the true (cursor-
    derived) occupancy exceed it.  A dynamic rate spiking past its
    declared capacity corrupts state through exactly this path.
  * :func:`inject_underflow` raises the counter — the consumer fires on
    tokens that do not exist.
  * :func:`corrupt_cursor` offsets rd/wr/occ directly (the stuck-bit /
    torn-update model); any inconsistency trips ``CURSOR_INVALID`` on the
    channel's next visit, fired or not.
  * :func:`poison_tokens` appends a NaN/Inf window *with consistent
    cursors* — the only flag that run can raise is ``NONFINITE``, so the
    test discriminates the data-health guard from the cursor guards.
  * :func:`truncate_feed` drops trailing windows from a host stream —
    the feed-validation satellite's error path in ``Program.stream``.
  * :func:`poison_request` / :func:`expire_deadline` model the two
    *serving-level* fault classes (PR 10): a request whose staged prompt
    row carries out-of-domain garbage (tripping the slot-table channels'
    ``DOMAIN`` write guard the moment admission writes it), and a request
    whose deadline has already passed at arrival (shed as a
    ``STATUS_TIMEOUT`` rate-0 admission firing).  They corrupt the
    :class:`~repro.graphs.serving.ServingWorkload` rather than a ring —
    the serving faults are *input* faults, which is what makes them
    quarantinable per request.

Injectors never touch the network definition, only a state (or staged
workload); they are pure (input unmodified) and jit-free, so tests can
inject between runs at will.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Mapping, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.fifo import FifoState
from repro.core.network import Network, NetworkState


def _fifo_index(network: Network, fifo: str) -> int:
    if fifo not in network.fifo_index:
        raise ValueError(
            f"unknown channel {fifo!r}; known: {sorted(network.fifos)}")
    return network.fifo_index[fifo]


def _replace_fifo(state: NetworkState, fi: int,
                  fs: FifoState) -> NetworkState:
    fifos = state.fifos[:fi] + (fs,) + state.fifos[fi + 1:]
    return dataclasses.replace(state, fifos=fifos)


def _as_network_state(network: Network, state: Any) -> NetworkState:
    if not isinstance(state, NetworkState):
        state = network.state_from_dict(state)
    return state


def inject_overflow(network: Network, state: Any, fifo: str,
                    by: Optional[int] = None) -> NetworkState:
    """Make the scheduler believe ``fifo`` has room it does not have.

    Lowers the occupancy counter by ``by`` tokens (default: one window =
    ``rate``), leaving rd/wr untouched.  The next time the producer's
    occupancy check passes spuriously it writes past the true Eq. 1
    bound: the write guard raises ``OVERFLOW`` (true occupancy exceeds
    the writable bound) and ``CURSOR_INVALID`` (the counter disagrees
    with the cursors) on the channel's next visit.
    """
    fi = _fifo_index(network, fifo)
    spec = network.fifos[fifo]
    by = spec.rate if by is None else int(by)
    state = _as_network_state(network, state)
    fs = state.fifos[fi]
    return _replace_fifo(state, fi, FifoState(
        buf=fs.buf, rd=fs.rd, wr=fs.wr, occ=fs.occ - jnp.int32(by)))


def inject_underflow(network: Network, state: Any, fifo: str,
                     by: Optional[int] = None) -> NetworkState:
    """Make the scheduler believe ``fifo`` holds tokens it does not hold.

    Raises the occupancy counter by ``by`` tokens (default one window);
    the consumer then fires on a channel whose true (cursor-derived)
    occupancy cannot cover its rate — ``UNDERFLOW`` plus
    ``CURSOR_INVALID`` on the next visit.
    """
    fi = _fifo_index(network, fifo)
    spec = network.fifos[fifo]
    by = spec.rate if by is None else int(by)
    state = _as_network_state(network, state)
    fs = state.fifos[fi]
    return _replace_fifo(state, fi, FifoState(
        buf=fs.buf, rd=fs.rd, wr=fs.wr, occ=fs.occ + jnp.int32(by)))


def corrupt_cursor(network: Network, state: Any, fifo: str,
                   rd: int = 0, wr: int = 0, occ: int = 0) -> NetworkState:
    """Offset ``fifo``'s cursors additively (stuck-bit / torn-update
    model).  Any combination that breaks ``occ == delay + (wr-rd)*rate``
    trips ``CURSOR_INVALID`` on the channel's next read or write visit,
    whether or not that visit fires."""
    fi = _fifo_index(network, fifo)
    state = _as_network_state(network, state)
    fs = state.fifos[fi]
    return _replace_fifo(state, fi, FifoState(
        buf=fs.buf, rd=fs.rd + jnp.int32(rd), wr=fs.wr + jnp.int32(wr),
        occ=fs.occ + jnp.int32(occ)))


def poison_tokens(network: Network, state: Any, fifo: str,
                  value: float = float("nan")) -> NetworkState:
    """Append one window of ``value`` (NaN by default) to ``fifo`` with
    *consistent* cursor advance — a producer emitting garbage data, not a
    scheduling bug.  The run's only possible flag is ``NONFINITE``, on
    the consumer's read of the poisoned window.

    Requires a float channel (integer channels cannot carry NaN/Inf) with
    room for one window.
    """
    fi = _fifo_index(network, fifo)
    spec = network.fifos[fifo]
    if not jnp.issubdtype(jnp.dtype(spec.dtype), jnp.inexact):
        raise ValueError(
            f"poison_tokens: channel {fifo!r} carries {jnp.dtype(spec.dtype)}"
            " tokens; non-finite poison needs a float channel")
    state = _as_network_state(network, state)
    fs = state.fifos[fi]
    if int(fs.occ) + spec.rate > spec.writable_occupancy_bound:
        raise ValueError(
            f"poison_tokens: channel {fifo!r} has no room for a poison "
            f"window (occupancy {int(fs.occ)} / bound "
            f"{spec.writable_occupancy_bound}); drain it first")
    window = jnp.full((spec.rate,) + tuple(spec.token_shape), value,
                      spec.dtype)
    return _replace_fifo(state, fi, spec.write(fs, window))


def truncate_feed(feeds: Mapping[str, Any], fifo: str,
                  drop: int = 1) -> Dict[str, Any]:
    """Drop the last ``drop`` windows from one channel's host stream.

    Models a truncated capture / short read on the host side of a
    heterogeneous plan; ``Program.stream`` must reject the resulting
    unequal feed lengths *by name* before any chunk runs.
    """
    if fifo not in feeds:
        raise ValueError(
            f"truncate_feed: no feed named {fifo!r}; feeds: "
            f"{sorted(feeds)}")
    out = {k: v for k, v in feeds.items()}
    arr = np.asarray(out[fifo])
    if drop < 0 or drop > arr.shape[0]:
        raise ValueError(
            f"truncate_feed: cannot drop {drop} of {arr.shape[0]} windows")
    out[fifo] = arr[:arr.shape[0] - drop]
    return out


# --------------------------------------------------------------------- #
# Serving-level injectors: corrupt the staged workload, not a ring.
# --------------------------------------------------------------------- #
POISON_VALUE = -(2 ** 20)


def _check_slot(workload, slot: int) -> int:
    n = int(np.asarray(workload.prompts).shape[0])
    if not (0 <= slot < n):
        raise ValueError(
            f"request slot {slot} out of range for a workload of {n} "
            "requests")
    return slot


def poison_request(workload, slot: int,
                   value: int = POISON_VALUE):
    """Poison one staged request's prompt row with an out-of-domain value.

    Models a corrupted/adversarial input request: every slot-table
    channel declares ``SLOT_DOMAIN`` (non-negative i32), so the moment
    admission writes the poisoned row a guarded run flags ``DOMAIN`` on
    the write — the integer-channel analogue of ``poison_tokens``'s
    NaN.  ``faulted_requests`` maps the fault back to exactly this slot,
    which is what the ``ActorEngine`` quarantine path retires with
    ``status="fault"``.
    """
    from repro.graphs.serving import SLOT_DOMAIN
    _check_slot(workload, slot)
    lo, hi = SLOT_DOMAIN
    if lo <= value <= hi:
        raise ValueError(
            f"poison_request: value {value} is inside SLOT_DOMAIN "
            f"{SLOT_DOMAIN}; an in-domain value is not a poison")
    prompts = np.array(workload.prompts, np.int32, copy=True)
    prompts[slot, :] = value
    return dataclasses.replace(workload, prompts=prompts)


def expire_deadline(workload, slot: int, at: int = 0):
    """Give one staged request a deadline already in the past.

    ``at`` is the absolute step the deadline is set *before* (default 0:
    expired before the network's first firing).  Admission sheds the
    request as a ``STATUS_TIMEOUT`` rate-0 firing the first step it is
    both arrived and expired — no fault is raised; deadline expiry is a
    *policy* outcome, not a health event.
    """
    _check_slot(workload, slot)
    deadlines = (np.array(workload.deadlines, np.int32, copy=True)
                 if workload.deadlines is not None
                 else np.full((np.asarray(workload.prompts).shape[0],),
                              2 ** 30 - 1, np.int32))
    deadlines[slot] = at - 1
    return dataclasses.replace(workload, deadlines=deadlines)
