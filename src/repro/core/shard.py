"""Multi-device sharded actor networks — the paper's multi-processor
platform mapped onto a JAX device mesh.

The paper splits one actor network across heterogeneous command queues
(GPP + GPU, §3.3); the JAX-native equivalent of "another processor" is
another device of a 1-D ``Mesh``.  ``ExecutionPlan(devices=k)`` reuses
the megakernel grid's partition machinery (``partition_layout`` with
``cores`` = devices: contiguous crossing-bytes cut, delay-channel
endpoints glued, partition-crossing channels classified ``SHARED``) and
replaces its *same-address-space* synchronization — polled cursor
semaphore rows in shared scratch — with *collective* synchronization:

  * every device holds a full replica of the :class:`NetworkState`
    pytree but sweeps ONLY its own partition of the firing table
    (``lax.switch`` on ``axis_index``, one traced sub-sweep per device,
    each reusing the exact per-actor visit body of the single-device
    dynamic executor — ``repro.core.executor._make_visit_body``);
  * at each sweep barrier every SHARED crossing channel exchanges its
    ring buffer + write cursor producer -> consumer and its read cursor
    consumer -> producer via ``jax.lax.ppermute``, then every replica
    recomputes occupancy from the channel invariant
    ``occ = delay + (wr - rd) * rate`` — the collective analogue of the
    packed cursor semaphore rows;
  * global quiescence is an all-reduce (``psum``) of the per-device
    fired-this-round flags, replacing the single scheduler's
    ``fired_any`` loop carry.

Correctness leans on two properties.  *Conservative staleness*: between
barriers a producer sees a stale (low) read cursor, so its occupancy
view is >= the truth and it can never overflow; a consumer sees a stale
(low) write cursor, so its view is <= the truth and it can never
underflow — exactly the monotonic-cursor argument that makes the grid's
polled semaphores safe (EXPERIMENTS.md §Megakernel), transplanted to a
message-passing platform.  *Kahn determinism*: blocking reads + single
writer per channel make the quiescent state independent of firing
order, so final states / ring bytes / cursors / fire counts are
bit-identical to the single-device dynamic executor for every device
count (the sharded run takes more *rounds* — barrier rounds are not
sweeps, and sweep counts are deliberately outside the contract).

Delay channels keep the grid rule: ``delay < rate`` channels may not
cross devices (``Network.validate_partition``, same Fig. 2 copy-back
race) — the copy-back executes on the producer, whose ring replica is
authoritative and is what the barrier ships.

The exit-merge is also what makes the PR 10 durability layer free at
``devices=k``: the runner takes a *host* replicated NetworkState and
returns one, so ``Program.run_checkpointed`` can cut a sharded run at
any sweep boundary, snapshot the merged state, and resume on a fresh
process/mesh — the restored state re-enters through the same
``in_specs=(P(),)`` replication, and Kahn determinism makes the resumed
run bit-identical to the uninterrupted one.

Everything here is testable on a CPU host via
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (see
``tests/test_shard.py``); no TPU is needed to pin the semantics.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.executor import (RuntimeMode, _make_visit_body,
                                 assert_mode_allows)
from repro.core.fifo import FifoState
from repro.core.health import HealthState, init_health
from repro.core.megakernel.lower import (GridPartition, MegakernelLayout,
                                         SHARED, _CURSOR_ITEMSIZE,
                                         lower_network, partition_layout)
from repro.core.network import Network, NetworkState
from repro.core.trace import (Trace, TraceState, decode_trace, init_trace,
                              merge_device_traces)

#: The mesh axis name of the 1-D device partition.
AXIS = "dev"


def build_device_partition(network: Network, devices: int,
                           device_assign: Optional[Mapping[str, int]] = None,
                           cut_objective: str = "crossing",
                           profile: Optional[Mapping[str, Any]] = None
                           ) -> Tuple[MegakernelLayout, GridPartition]:
    """Partition the firing table across ``devices`` mesh devices.

    Pure build-time metadata: the megakernel's ``lower_network`` +
    ``partition_layout`` run with ``cores`` = devices, so the cut
    heuristics (crossing bytes / flops balance / measured profile) and
    the delay-channel glue are shared verbatim with the grid backend.
    ``forward_transients`` stays off — transient forwarding is a
    megakernel *lowering*, while each device here runs the host dynamic
    executor over real ring state.
    """
    layout = lower_network(network)
    part = partition_layout(network, layout, cores=devices,
                            assign=(dict(device_assign)
                                    if device_assign is not None else None),
                            objective=cut_objective,
                            forward_transients=False,
                            profile=profile)
    return layout, part


def collective_bytes_per_sweep(layout: MegakernelLayout,
                               partition: GridPartition) -> int:
    """Bytes each sweep-barrier exchange moves across the mesh.

    Per crossing channel: its full Eq. 1 ring (producer -> consumer)
    plus the rd/wr cursor pair (one int each way); plus the 4-byte
    quiescence flag every round all-reduces.  The collective counterpart
    of the grid's ``shared_scratch_bytes`` polling surface — the two
    are compared side by side in EXPERIMENTS.md §Sharding.
    """
    total = _CURSOR_ITEMSIZE    # psum'd per-device progress flag
    for fi in partition.shared_fifos:
        total += (layout.fifo_specs[fi].capacity_bytes
                  + 2 * _CURSOR_ITEMSIZE)
    return total


def _crossing_edges(network: Network, layout: MegakernelLayout,
                    partition: GridPartition) -> List[Tuple[int, int, int]]:
    """``(fifo_index, producer_device, consumer_device)`` per SHARED
    channel, in layout order."""
    names = list(network.actors)
    out = []
    for fi in partition.shared_fifos:
        e = network.edge_of(layout.fifo_names[fi])
        src = partition.assignment[names.index(e.src_actor)]
        dst = partition.assignment[names.index(e.dst_actor)]
        out.append((fi, src, dst))
    return out


def compile_sharded(network: Network, layout: MegakernelLayout,
                    partition: GridPartition, max_sweeps: int = 1_000_000,
                    mode: RuntimeMode = RuntimeMode.PROPOSED,
                    multi_firing: bool = True,
                    guards: bool = False,
                    trace_capacity: Optional[int] = None) -> Callable:
    """The sharded dynamic executor: one sub-sweep per device under
    ``shard_map``, crossing channels exchanged at sweep barriers.

    Returns a runner with the single-device dynamic executor's record
    shape — ``runner(state) -> (state, counts, sweeps, stalled, health,
    trace)`` — where ``sweeps`` counts *barrier rounds* (one progress
    all-reduce each), ``health`` is the bitwise-OR / high-water merge
    across devices, and ``trace`` is the all-gathered per-device ring
    pair ``(rings (k, cap, 3+F), counts (k,))`` for
    :func:`decode_device_trace`.

    Observability caveats, by design: a traced event's occupancy sample
    is the recording device's *local view* (conservative between
    barriers), and a guarded run's ``high_water`` marks may legitimately
    exceed the single-device run's (the producer's occupancy view is an
    upper bound at write time) — both observe, neither schedules, so
    the state/counts bit-identity contract is untouched.
    """
    assert_mode_allows(network, mode)
    k = partition.n_cores
    if jax.device_count() < k:
        raise RuntimeError(
            f"compile_sharded: partition spans {k} devices but only "
            f"{jax.device_count()} are visible; on a CPU host set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={k} before "
            "jax initializes")
    # Explicit sub-mesh over the first k devices: jax.make_mesh insists
    # on covering every visible device, while a plan's device count is a
    # property of the network cut, not the host.
    mesh = Mesh(np.array(jax.devices()[:k]), (AXIS,))
    names = list(network.actors)
    n_fifos = len(network.fifos)
    crossing = _crossing_edges(network, layout, partition)
    # One traced sub-sweep per device, over that device's firing-table
    # slice in visit order — the exact per-actor body of the
    # single-device executor.
    bodies = [_make_visit_body(network, [names[i] for i in rows],
                               multi_firing)
              for rows in partition.core_rows]
    # Static merge owners: the device whose replica is authoritative for
    # each leaf at quiescence.  Actors: their partition device.  Private
    # channels: their owning device.  Crossing channels: the PRODUCER —
    # the barrier runs after every round (including the final no-fire
    # round), so its rd is synchronized at exit, its wr/ring are the
    # single writer's truth, and the consumer never writes ring bytes.
    fifo_owner = list(partition.fifo_cores)
    for fi, src, _dst in crossing:
        fifo_owner[fi] = src

    def exchange(state: NetworkState, dev: jax.Array) -> NetworkState:
        """The sweep-barrier collective: ship each crossing channel's
        ring + wr producer -> consumer and rd consumer -> producer, then
        restore every replica's occupancy from the channel invariant
        ``occ = delay + (wr - rd) * rate`` (exact on the endpoints;
        non-endpoint replicas keep their untouched init-state view)."""
        fifos = list(state.fifos)
        for fi, src, dst in crossing:
            spec = layout.fifo_specs[fi]
            fs = fifos[fi]
            fwd = [(src, dst)]
            bwd = [(dst, src)]
            buf = jax.lax.ppermute(fs.buf, AXIS, fwd)
            wr = jax.lax.ppermute(fs.wr, AXIS, fwd)
            rd = jax.lax.ppermute(fs.rd, AXIS, bwd)
            is_dst = dev == dst
            is_src = dev == src
            new_buf = jnp.where(is_dst, buf, fs.buf)
            new_wr = jnp.where(is_dst, wr, fs.wr)
            new_rd = jnp.where(is_src, rd, fs.rd)
            new_occ = (jnp.int32(spec.delay)
                       + (new_wr - new_rd) * jnp.int32(spec.rate))
            fifos[fi] = FifoState(buf=new_buf, rd=new_rd, wr=new_wr,
                                  occ=new_occ)
        return dataclasses.replace(state, fifos=tuple(fifos))

    def sharded_run(state: NetworkState):
        dev = jax.lax.axis_index(AXIS)
        counts0 = {nm: jnp.int32(0) for nm in names}
        hlth0 = init_health(n_fifos) if guards else None
        trc0 = init_trace(n_fifos, trace_capacity) if trace_capacity else None

        def branch(i):
            def run_branch(operand):
                st, cnt, h, t, sweeps = operand
                return bodies[i](st, cnt, h, t, sweeps)
            return run_branch

        branches = [branch(i) for i in range(k)]

        def sweep(carry):
            st, cnt, h, t, _, sweeps = carry
            # No collectives inside the switch: every device must issue
            # the identical exchange sequence, so the barrier sits
            # outside, once per round, unconditionally.
            st, cnt, h, t, fired = jax.lax.switch(
                dev, branches, (st, cnt, h, t, sweeps))
            st = exchange(st, dev)
            fired_any = jax.lax.psum(fired.astype(jnp.int32), AXIS) > 0
            return st, cnt, h, t, fired_any, sweeps + 1

        def cond(carry):
            _, _, _, _, fired_any, sweeps = carry
            return jnp.logical_and(fired_any, sweeps < max_sweeps)

        carry = (state, counts0, hlth0, trc0, jnp.bool_(True), jnp.int32(0))
        state, counts, hlth, trc, fired_any, sweeps = jax.lax.while_loop(
            cond, sweep, carry)
        stalled = jnp.logical_and(fired_any, sweeps >= max_sweeps)

        # ---- merge to one replicated result ---------------------------- #
        # Each leaf is taken whole from its static owner (all_gather +
        # constant index): exact for every dtype — no float re-derivation,
        # no one-hot arithmetic.
        def take(x, owner):
            return jax.lax.all_gather(x, AXIS)[owner]

        fifos = tuple(
            FifoState(buf=take(fs.buf, o), rd=take(fs.rd, o),
                      wr=take(fs.wr, o), occ=take(fs.occ, o))
            for fs, o in zip(state.fifos, fifo_owner))
        actors = tuple(
            jax.tree.map(functools.partial(take, owner=o), a)
            for a, o in zip(state.actors, partition.assignment))
        state = dataclasses.replace(state, fifos=fifos, actors=actors)
        # Fire counts: each actor is counted only on its owner (0
        # elsewhere), so an integer psum is the exact total.
        counts = {nm: jax.lax.psum(counts[nm], AXIS) for nm in names}
        if hlth is not None:
            # Fault words are bitmasks: OR across devices (a psum would
            # double-count a bit two endpoints both recorded).
            gathered = jax.lax.all_gather(hlth.fault, AXIS)
            fault = functools.reduce(jnp.bitwise_or,
                                     [gathered[d] for d in range(k)])
            hlth = HealthState(fault=fault,
                               high_water=jax.lax.pmax(hlth.high_water,
                                                       AXIS))
        if trc is not None:
            trc = (jax.lax.all_gather(trc.ring, AXIS),
                   jax.lax.all_gather(trc.count, AXIS))
        return state, counts, sweeps, stalled, hlth, trc

    sharded = jax.jit(shard_map(sharded_run, mesh=mesh, in_specs=(P(),),
                                out_specs=P(), check_rep=False))

    def run(state):
        if not isinstance(state, NetworkState):
            state = network.state_from_dict(state)
        return sharded(state)

    return run


def decode_device_trace(network: Network, trc: Optional[Tuple],
                        partition: GridPartition,
                        wall_time_s: Optional[float] = None
                        ) -> Optional[Trace]:
    """Decode the all-gathered ``(rings (k, cap, 3+F), counts (k,))``
    pair of a sharded run into ONE :class:`repro.core.trace.Trace`:
    per-device rings are decoded independently, then interleaved by
    barrier round (stable by device), with ``actor_cores`` recording the
    mesh device of each actor — Perfetto tracks read ``actor [core d]``
    with d the device."""
    if trc is None:
        return None
    rings, counts = trc
    names = tuple(network.actors)
    devmap = {names[i]: d for d, rows in enumerate(partition.core_rows)
              for i in rows}
    per_dev = [
        decode_trace(network,
                     TraceState(ring=jnp.asarray(rings[d]),
                                count=jnp.asarray(counts[d])),
                     wall_time_s=wall_time_s if d == 0 else None,
                     actor_cores=devmap)
        for d in range(partition.n_cores)
    ]
    return merge_device_traces(per_dev)
