"""Program: the single compile/run/stream entrypoint — paper §3.4.

The paper's runtime exposes one operation: *launch the network*.  Our
reproduction had grown three parallel entrypoints (``compile_static``,
``compile_dynamic``, ``run_interpreted``) plus a separate
``heterogeneous_split`` + ``stage_feed`` code path for host/accelerator
placement.  :class:`Program` folds them behind one object::

    plan = ExecutionPlan(mode="static", n_iterations=8)
    prog = net.compile(plan)           # Network.compile -> Program
    result = prog.run()                # RunResult(state, counts, sweeps)

Every execution policy is a field of :class:`ExecutionPlan` — the mode
(static scan / token-driven dynamic / interpreted / persistent-Pallas
megakernel, see :mod:`repro.core.megakernel`), trace-time
specialization, multi-firing sweeps, buffer donation, and *heterogeneous
placement*: ``accelerated=[...]`` splits the network at construction so
boundary channels become feed/fetch actors, and :meth:`Program.stream`
drives chunked host-feed/fetch through the compiled accelerator step (the
paper's host<->device transfer loop).  Future policies (sharding across a
mesh axis, async dispatch, alternate backends — ROADMAP) land as new plan
fields, not new entrypoints.

The legacy trio lives on in ``repro.core.executor`` as thin deprecated
shims delegating here; results are bit-identical (pinned by
``tests/test_program_api.py``).
"""
from __future__ import annotations

import dataclasses
import enum
import functools
import time
import warnings
from typing import Any, Dict, List, Mapping, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (CheckpointIntegrityError,
                              load_stream_checkpoint, save_stream_checkpoint)
from repro.core.executor import (RuntimeMode, _compile_dynamic,
                                 _compile_static, _run_interpreted,
                                 collect_sink)
from repro.core.fifo import FifoState
from repro.core.health import (Diagnostics, NetworkFaultError, decode_health)
from repro.core.mapping import heterogeneous_split
from repro.core.network import (Network, NetworkState, iteration_token_flops)
from repro.core.schedule import phase_unroll_period
from repro.core.trace import (TRACE_CAPACITY_DEFAULT, Trace, decode_trace,
                              merge_traces)


class Mode(str, enum.Enum):
    """Execution backends of :class:`ExecutionPlan`.

    A ``str`` enum so plans written with bare strings (``mode="static"``)
    and with the enum (``mode=Mode.MEGAKERNEL``) are interchangeable.
    """

    STATIC = "static"
    DYNAMIC = "dynamic"
    INTERPRETED = "interpreted"
    # Device-resident scheduling: the whole network as ONE persistent
    # Pallas kernel — Eq. 1 rings in scratch memory, the token-driven
    # sweep loop inside the kernel (repro.core.megakernel).
    MEGAKERNEL = "megakernel"


#: Convenience alias so call sites can write ``ExecutionPlan(mode=MEGAKERNEL)``.
MEGAKERNEL = Mode.MEGAKERNEL

_MODES = tuple(m.value for m in Mode)

# donate="auto" default threshold: donation is only profitable when the
# state the call consumes is dominated by register-allocatable traffic;
# once the *buffered* (ring-resident) channel bytes grow past this, the
# in-place aliasing constraint costs more than the elided copies
# (measured on MD: 707 -> 415 tok/s donated, EXPERIMENTS.md §Executor
# perf — negative result; DPD, whose bulk channels registerize, gains
# 1.2x).  1 MiB was measured on this container's CPU backend; real-TPU
# HBM economics differ, so ``ExecutionPlan(donate_threshold_bytes=...)``
# overrides it per plan (the resolved value is reported by
# ``Program.stats().resolved_donate_threshold``).
_DONATE_AUTO_BUFFERED_BYTES_MAX = 1 << 20

#: Partition-cut objectives of the megakernel grid backend (mirrors
#: ``repro.core.megakernel.lower.CUT_OBJECTIVES``, duplicated here so a
#: plan can validate without importing the Pallas-backed package).
_CUT_OBJECTIVES = ("crossing", "flops", "profile")


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """Declarative execution policy — every executor knob in one record.

    Fields:
      mode:          ``"static"`` (whole network -> one jitted scan),
                     ``"dynamic"`` (token-driven ``while_loop`` scheduler,
                     runs to quiescence), ``"interpreted"`` (eager
                     per-actor firing, the GPP-thread analogue), or
                     ``"megakernel"`` / :data:`Mode.MEGAKERNEL` (one
                     persistent Pallas kernel: Eq. 1 rings in scratch,
                     the token-driven sweep loop device-resident; runs to
                     quiescence like dynamic mode and is bit-identical to
                     it).
      n_iterations:  iteration count for static/interpreted schedules (and
                     the chunk length of :meth:`Program.stream`); dynamic
                     and megakernel modes run to quiescence and ignore it
                     unless ``accelerated`` needs it for feed slab sizing.
      specialize:    static mode: trace-time cursor specialization +
                     transient-channel register allocation.  Megakernel
                     mode: in-kernel transient forwarding — core-private
                     ``register_fifos`` channels lower to loop-carried
                     token windows instead of scratch rings (dead-slot
                     carve-out: their stale ring bytes leave the
                     bit-identity contract, and they must enter drained;
                     ``specialize=False`` keeps every ring in scratch).
      multi_firing:  dynamic/megakernel modes: fire each actor up to its
                     occupancy bound per sweep.
      donate:        donate the input state so XLA reuses its buffers.
                     Default ``"auto"``: donation is applied only to
                     ``run()`` calls where the program owns the state
                     (``state=None`` — a private copy is donated, so
                     caller-held arrays are never invalidated), and only
                     when the buffered (non-register-allocated) channel
                     bytes are small enough that copy elision wins — the
                     measured heuristic behind the MD donate regression
                     (EXPERIMENTS.md §Executor perf).  ``donate=True``
                     keeps the legacy semantics: every call donates,
                     including states the caller passed in (which are
                     consumed).  Megakernel mode resolves donation to
                     False regardless — buffers are staged through
                     kernel scratch, there is nothing to donate.
      donate_threshold_bytes:
                     buffered-bytes ceiling of the ``donate="auto"``
                     heuristic; ``None`` uses the 1 MiB default measured
                     on this container's CPU backend (re-measure on real
                     HBM — ROADMAP).  The resolved value is reported as
                     ``Program.stats().resolved_donate_threshold``.
      runtime_mode:  ``RuntimeMode.PROPOSED`` (this paper) or
                     ``STATIC_DAL`` (reference framework: SDF-only
                     accelerator, dynamic actors rejected).
      order:         optional static firing order (defaults topological).
      max_sweeps:    dynamic/megakernel sweep bound.
      unroll_bound:  static mode phase-unroll period cap.
      interpret:     megakernel mode: force Pallas interpret mode on
                     (True) or off (False); ``None`` auto-selects
                     interpret off-TPU (the tier-1 CPU fallback).
      cores:         megakernel mode: number of grid partitions for the
                     multi-core sweep (paper §3.3 actor-to-core
                     mapping).  Each core runs its own occupancy-bounded
                     firing loop over its slice of the firing table;
                     partition-crossing channels are guarded by shared
                     cursor semaphores and quiescence is global.  Final
                     states / ring bytes / cursors / fire counts are
                     bit-identical for every core count.
      assign:        optional explicit actor -> core map (must cover
                     every actor; validated by
                     ``Network.validate_partition``).  Default is a
                     contiguous cut of the visit order with
                     delay-channel endpoints glued, per
                     ``cut_objective``.
      cut_objective: megakernel mode: the default partition cut's
                     criterion.  ``"crossing"`` (default) minimizes
                     partition-crossing ring bytes (the shared-scratch /
                     semaphore surface) among contiguous cuts whose
                     ``cost_flops`` bottleneck stays within the balance
                     slack; ``"flops"`` is the legacy pure load-balance
                     cut; ``"profile"`` runs the crossing cut over
                     *measured* weights from a traced run (requires
                     ``profile=``).  Ignored under an explicit
                     ``assign``.
      accelerated:   optional actor subset mapped to the accelerator: the
                     network is split (``heterogeneous_split``) and the
                     plan executes the accelerator subnetwork, with
                     boundary channels exposed as feed/fetch actors and
                     :meth:`Program.stream` as the host transfer loop.
      guards:        dynamic/megakernel modes: arm the runtime health
                     layer's per-channel fault guards (overflow /
                     underflow / cursor consistency / non-finite tokens —
                     :mod:`repro.core.health`).  Faulting runs raise
                     :class:`repro.core.health.NetworkFaultError` naming
                     the offending channel and actors, and every
                     ``RunResult.diagnostics`` carries the decoded fault
                     and high-water record.  Off by default: guards-off
                     kernels are bit-identical to the pre-health runtime,
                     and clean guarded runs stay bit-identical too (the
                     guards observe channel ops, they never change them).
      trace:         dynamic/megakernel modes: record one firing-level
                     event per attempt (actor, sweep, fired-or-skipped,
                     per-channel occupancy) into a fixed-capacity ring —
                     loop-carried on the host dynamic executor, a
                     device-side output ref inside the megakernel's sweep
                     loop.  Decoded onto ``RunResult.trace`` as a
                     :class:`repro.core.trace.Trace` (Perfetto export,
                     derived :class:`repro.core.trace.Profile`).  Same
                     off-path contract as ``guards``: ``trace=False``
                     lowers to bit-identical HLO and traced runs never
                     perturb states / cursors / fire counts / sweeps.
      trace_capacity:
                     event capacity of the trace ring (requires
                     ``trace=True``); ``None`` uses
                     :data:`repro.core.trace.TRACE_CAPACITY_DEFAULT`.
                     Overflowing runs keep the newest events and report
                     the drop count on ``Trace.dropped``.
      profile:       megakernel mode: the measured weights the
                     ``cut_objective="profile"`` partition cut uses — a
                     :class:`repro.core.trace.Profile`, its
                     ``as_cut_weights()`` dict (``{"actors": {...},
                     "channels": {...}}``), or the frozen tuple form a
                     previous plan normalized it to.  Required iff
                     ``cut_objective="profile"``.
      devices:       dynamic mode: shard the network across ``devices``
                     devices of a 1-D mesh (:mod:`repro.core.shard`) —
                     the JAX-native analogue of the paper's GPP+GPU
                     command queues.  The firing table is partitioned by
                     the same crossing-bytes cut as the megakernel grid
                     (``cores`` = devices); each device sweeps only its
                     own actors, partition-crossing channels exchange
                     ring tokens + cursor rows via collective permutes
                     at sweep barriers (instead of same-address-space
                     cursor polling), and quiescence is an all-reduce of
                     per-device progress flags.  Final states / ring
                     bytes / cursors / fire counts are bit-identical to
                     the single-device dynamic executor for every device
                     count (Kahn determinism); sweep counts are not part
                     of that contract (barrier rounds replace sweeps).
                     ``devices=1`` is exactly the plain dynamic path.
      device_assign: optional explicit actor -> device map for
                     ``devices > 1`` (must cover every actor; validated
                     by ``Network.validate_partition`` with the same
                     delay-channel crossing rule as the grid).  Default
                     is the ``cut_objective`` contiguous cut.
    """

    mode: Union[str, Mode] = "static"
    n_iterations: Optional[int] = None
    specialize: bool = True
    multi_firing: bool = True
    donate: Union[bool, str] = "auto"
    donate_threshold_bytes: Optional[int] = None
    runtime_mode: RuntimeMode = RuntimeMode.PROPOSED
    order: Optional[Tuple[str, ...]] = None
    max_sweeps: int = 1_000_000
    unroll_bound: int = 6
    interpret: Optional[bool] = None
    cores: int = 1
    assign: Optional[Mapping[str, int]] = None
    cut_objective: str = "crossing"
    accelerated: Optional[Tuple[str, ...]] = None
    guards: bool = False
    trace: bool = False
    trace_capacity: Optional[int] = None
    profile: Optional[Any] = None
    devices: int = 1
    device_assign: Optional[Mapping[str, int]] = None

    def __post_init__(self) -> None:
        """Field-local normalization and value checks only.

        Everything that relates two plan fields (or a plan field to the
        network) lives in :meth:`validate`, which ``Network.compile`` /
        :class:`Program` call before building anything — so a plan
        record can always be *constructed* field by field (e.g. by an
        autotuner enumerating the space) and is judged as a whole at
        compile time.
        """
        if isinstance(self.mode, Mode):
            object.__setattr__(self, "mode", self.mode.value)
        if self.mode not in _MODES:
            raise ValueError(
                f"ExecutionPlan.mode must be one of {_MODES}, got "
                f"{self.mode!r}")
        if not isinstance(self.cores, int) or self.cores < 1:
            raise ValueError(
                f"ExecutionPlan.cores must be an int >= 1, got "
                f"{self.cores!r}")
        if not isinstance(self.devices, int) \
                or isinstance(self.devices, bool) or self.devices < 1:
            raise ValueError(
                f"ExecutionPlan.devices must be an int >= 1, got "
                f"{self.devices!r}")
        if self.assign is not None:
            # Freeze to a sorted pair tuple so the frozen plan stays
            # immutable (callers may pass any mapping).
            object.__setattr__(
                self, "assign",
                tuple(sorted((str(k), int(v))
                             for k, v in dict(self.assign).items())))
        if self.device_assign is not None:
            object.__setattr__(
                self, "device_assign",
                tuple(sorted((str(k), int(v))
                             for k, v in dict(self.device_assign).items())))
        if self.cut_objective not in _CUT_OBJECTIVES:
            raise ValueError(
                f"ExecutionPlan.cut_objective must be one of "
                f"{_CUT_OBJECTIVES}, got {self.cut_objective!r}")
        if self.trace_capacity is not None and (
                not isinstance(self.trace_capacity, int)
                or isinstance(self.trace_capacity, bool)
                or self.trace_capacity < 1):
            raise ValueError(
                f"ExecutionPlan.trace_capacity must be None or an int "
                f">= 1, got {self.trace_capacity!r}")
        if self.profile is not None:
            # Accept a Profile, its as_cut_weights() mapping, or the
            # frozen tuple form a prior plan normalized to (so
            # dataclasses.replace round-trips); freeze to sorted pair
            # tuples like `assign`.
            prof = self.profile
            if hasattr(prof, "as_cut_weights"):
                prof = prof.as_cut_weights()
            if isinstance(prof, tuple):
                prof = {k: dict(v) for k, v in prof}
            if (not isinstance(prof, Mapping) or "actors" not in prof
                    or set(prof) - {"actors", "channels"}):
                raise ValueError(
                    "ExecutionPlan.profile must be a "
                    "repro.core.trace.Profile or a mapping with 'actors' "
                    f"(and optional 'channels') weights, got {prof!r}")
            object.__setattr__(self, "profile", (
                ("actors", tuple(sorted(
                    (str(k), int(v))
                    for k, v in dict(prof["actors"]).items()))),
                ("channels", tuple(sorted(
                    (str(k), int(v))
                    for k, v in dict(prof.get("channels", {})).items()))),
            ))
        if not (isinstance(self.donate, bool) or self.donate == "auto"):
            raise ValueError(
                f"ExecutionPlan.donate must be True, False or 'auto', got "
                f"{self.donate!r}")
        if self.donate_threshold_bytes is not None and (
                not isinstance(self.donate_threshold_bytes, int)
                or isinstance(self.donate_threshold_bytes, bool)
                or self.donate_threshold_bytes < 0):
            raise ValueError(
                f"ExecutionPlan.donate_threshold_bytes must be None or an "
                f"int >= 0, got {self.donate_threshold_bytes!r}")
        if self.order is not None:
            object.__setattr__(self, "order", tuple(self.order))
        if self.accelerated is not None:
            object.__setattr__(self, "accelerated", tuple(self.accelerated))
        if self.n_iterations is not None and self.n_iterations < 0:
            raise ValueError(
                f"ExecutionPlan: n_iterations must be >= 0, got "
                f"{self.n_iterations}")

    def validate(self, network: "Network", *,
                 stream_persistent: Optional[bool] = None,
                 stream_on_fault: Optional[str] = None,
                 stream_checkpoint_dir: Optional[str] = None
                 ) -> "ExecutionPlan":
        """Judge the plan as a whole against ``network`` — THE cross-field
        rule book, called by ``Network.compile`` (via ``Program``) before
        anything is built and by ``Program.stream`` before a stream runs.

        Each rule raises a single-sentence ``ValueError`` naming the
        offending field pair.  ``__post_init__`` only checks fields in
        isolation, so a plan object can always be constructed; it becomes
        a *valid* plan only relative to a network.  The stream-only rules
        engage when ``stream_persistent`` / ``stream_on_fault`` are
        passed (``Program.stream`` forwards its arguments); plain
        compiles leave them None.  Returns ``self`` so call sites can
        chain ``plan.validate(net)``.
        """
        if (self.cores != 1 or self.assign is not None
                or self.cut_objective != "crossing") \
                and self.mode != "megakernel":
            raise ValueError(
                f"ExecutionPlan(mode={self.mode!r}): cores=/assign=/"
                "cut_objective= are grid-partition knobs of the megakernel "
                "backend; the host executors have no core axis (use "
                "mode=Mode.MEGAKERNEL, or accelerated=[...] for "
                "host/accelerator placement)")
        if self.guards and self.mode not in ("dynamic", "megakernel"):
            raise ValueError(
                f"ExecutionPlan(mode={self.mode!r}): guards=True is a "
                "sweep-loop health knob of the dynamic and megakernel "
                "backends; the static specializer register-allocates its "
                "channels away and the interpreter fires eagerly, so "
                "neither has the per-channel cursor state the guards "
                "watch")
        if self.trace and self.mode not in ("dynamic", "megakernel"):
            raise ValueError(
                f"ExecutionPlan(mode={self.mode!r}): trace=True is a "
                "sweep-loop observability knob of the dynamic and "
                "megakernel backends; the static/interpreted schedules "
                "have no firing attempts to record (every actor fires by "
                "construction)")
        if self.trace_capacity is not None and not self.trace:
            raise ValueError(
                "ExecutionPlan.trace_capacity requires trace=True")
        if self.cut_objective == "profile" and self.profile is None:
            raise ValueError(
                "ExecutionPlan(cut_objective='profile') needs measured "
                "weights: run once with ExecutionPlan(trace=True), then "
                "pass profile=RunResult.trace.profile() (or its "
                ".as_cut_weights() dict)")
        if self.profile is not None and self.cut_objective != "profile":
            raise ValueError(
                f"ExecutionPlan.profile is only consumed by "
                f"cut_objective='profile', but the plan says "
                f"{self.cut_objective!r}")
        if self.devices > 1 and self.cores != 1:
            raise ValueError(
                f"ExecutionPlan(devices={self.devices}, cores="
                f"{self.cores}): devices= (the mesh axis) and cores= (the "
                "megakernel grid axis) are exclusive — pick one partition "
                "axis per plan")
        if self.device_assign is not None and self.devices == 1:
            raise ValueError(
                "ExecutionPlan(device_assign=..., devices=1): "
                "device_assign places actors on mesh devices, so it "
                "requires devices > 1 (use assign= for the megakernel "
                "grid's core map)")
        if self.devices > 1 and self.mode != "dynamic":
            raise ValueError(
                f"ExecutionPlan(mode={self.mode!r}, devices="
                f"{self.devices}): multi-device sharding runs the "
                "token-driven dynamic executor per device; use "
                "mode='dynamic' (one megakernel per device is a ROADMAP "
                "item, not a plan knob yet)")
        if self.devices > 1 and self.accelerated is not None:
            raise ValueError(
                f"ExecutionPlan(devices={self.devices}, "
                "accelerated=[...]): sharding and heterogeneous "
                "host/accelerator placement are exclusive — the mesh IS "
                "the accelerator set under devices=, so drop "
                "accelerated= (or stream with devices=1)")
        needs_iters = (self.mode in ("static", "interpreted")
                       or self.accelerated is not None)
        if needs_iters and self.n_iterations is None:
            raise ValueError(
                f"ExecutionPlan(mode={self.mode!r}"
                + (", accelerated=[...]" if self.accelerated is not None else "")
                + "): pass n_iterations= — static/interpreted schedules "
                "compile a fixed iteration count, and heterogeneous plans "
                "size their boundary feed/fetch slabs with it (dynamic "
                "mode alone runs to quiescence without one)")
        if self.accelerated is not None:
            unknown = set(self.accelerated) - set(network.actors)
            if unknown:
                raise ValueError(
                    f"ExecutionPlan.accelerated names unknown actors "
                    f"{sorted(unknown)}; known: {sorted(network.actors)}")
        if self.assign is not None and self.accelerated is None:
            # Explicit core maps must cover the executed network; under
            # accelerated= the executed network is the split subnetwork,
            # whose partition_layout re-validates against the right
            # actor set.
            network.validate_partition(dict(self.assign), self.cores)
        if self.device_assign is not None:
            network.validate_partition(dict(self.device_assign),
                                       self.devices, unit="device")
        if (stream_persistent is not None or stream_on_fault is not None
                or stream_checkpoint_dir is not None):
            if self.accelerated is None:
                raise ValueError(
                    "Program.stream: this plan has no heterogeneous "
                    "placement; pass ExecutionPlan(accelerated=[...], "
                    "n_iterations=chunk) so boundary channels become "
                    "host feed/fetch actors")
            # persistent x on_fault="resume"/"skip" is legal since PR 10:
            # a faulting persistent entry falls back to the chunked loop,
            # whose per-chunk checkpoints make the policy meaningful.
            # What persistent mode still cannot do is DURABLE cadence —
            # one entry has no chunk boundaries to snapshot at.
            if stream_persistent and stream_checkpoint_dir is not None:
                raise ValueError(
                    "Program.stream: persistent=True runs the whole "
                    "stream as one kernel entry with no chunk boundaries "
                    "to snapshot at, so checkpoint_dir= has no cadence; "
                    "use the chunked loop (persistent=False) for durable "
                    "checkpoints")
        return self


@dataclasses.dataclass(frozen=True)
class RunResult:
    """One execution's outcome.

    ``state`` is the final :class:`NetworkState` (bit-identical to the
    legacy entrypoints' output for the same plan).  ``fire_counts`` /
    ``sweeps`` are populated by dynamic mode only.  ``diagnostics`` is
    the decoded :class:`repro.core.health.Diagnostics` of dynamic /
    megakernel runs — with guards off it still carries the ``stalled``
    flag (the sweep loop left through its budget, not quiescence); with
    ``ExecutionPlan(guards=True)`` it adds per-channel fault words and
    high-water occupancy marks.  ``trace`` is the decoded
    :class:`repro.core.trace.Trace` of a ``plan.trace=True`` run
    (firing-level events plus occupancy samples; None otherwise).
    """

    state: NetworkState
    fire_counts: Optional[Dict[str, jax.Array]] = None
    sweeps: Optional[jax.Array] = None
    diagnostics: Optional[Diagnostics] = None
    trace: Optional[Trace] = None


@dataclasses.dataclass(frozen=True)
class ProgramStats:
    """Static + last-run telemetry for a compiled program.

    ``actor_flops`` is the per-firing FLOP annotation (``cost_flops``);
    ``actor_window_bytes`` the bytes moved through that actor's ports per
    firing (Eq. 1 windows); ``actor_intensity`` their ratio — the
    operational-intensity coordinate of a roofline plot.

    Megakernel programs additionally report the device-residency split:
    ``scratch_bytes`` (buffered Eq. 1 rings + cursor block held in kernel
    scratch for the whole run — forwarded channels contribute nothing),
    ``transient_scratch_bytes`` (ring bytes of the transient channels,
    the forwarding upper bound), ``forwarded_fifos`` / ``reclaimed_
    scratch_bytes`` (the channels actually lowered to loop-carried
    windows under this plan's partition, and the ring bytes that
    reclaimed) and ``hbm_state_bytes`` (the kernel's HBM operands — ring
    copies, actor states, hoisted closure arrays — measured from the
    last run's state).  ``resolved_donate`` is the per-graph outcome of
    ``donate="auto"`` and ``resolved_donate_threshold`` the buffered-
    bytes ceiling it used (``plan.donate_threshold_bytes`` or the
    measured 1 MiB default).

    The ``last_stream_*`` fields describe the last :meth:`Program.stream`
    call: chunk count, whether persistent-feed mode ran, and the staging
    traffic — ``last_stream_staged_bytes_per_chunk`` is what crosses the
    host boundary at each chunk (chunked mode re-stages the megakernel's
    ring/cursor scratch every entry on top of the feed/fetch slabs;
    persistent mode stages rings once and pays only the slab share), and
    ``last_stream_total_staged_bytes`` the whole stream's staging bill.

    Grid-partitioned megakernel programs (``plan.cores``) add the
    per-partition telemetry: ``grid_cores``, ``partition_actors`` (actor
    names per core, visit order), ``core_scratch_bytes`` (each core's
    private ring block, forwarding excluded), ``shared_scratch_bytes``
    (partition-crossing rings plus their semaphore cursor rows),
    ``shared_fifos`` (the crossing channels), ``core_cursor_rows`` (the
    per-core private cursor-block split — the shared semaphore block
    holds the remaining ``len(shared_fifos)`` rows), ``cut_objective``
    (the partition cut's criterion) and ``partition_fire_counts``
    (firings per core in the last run — the occupancy telemetry of each
    core's bounded firing loop).

    Sharded programs (``plan.devices > 1`` — :mod:`repro.core.shard`)
    report ``devices`` (always present; 1 when unsharded),
    ``device_partition_actors`` (actor names per mesh device, visit
    order), ``collective_bytes_per_sweep`` (bytes every sweep-barrier
    exchange moves: each crossing channel's ring + rd/wr cursor pair,
    plus the quiescence flag — the collective analogue of the grid's
    ``shared_scratch_bytes`` polling surface) and
    ``quiescence_allreduces`` (barrier rounds of the last run, one
    progress all-reduce each).
    """

    mode: str
    n_actors: int
    n_fifos: int
    buffer_bytes: int
    register_fifos: Tuple[str, ...]
    iteration_flops: int
    actor_flops: Dict[str, int]
    actor_window_bytes: Dict[str, int]
    actor_intensity: Dict[str, float]
    last_sweeps: Optional[int] = None
    last_fire_counts: Optional[Dict[str, int]] = None
    resolved_donate: Optional[bool] = None
    resolved_donate_threshold: Optional[int] = None
    scratch_bytes: Optional[int] = None
    transient_scratch_bytes: Optional[int] = None
    forwarded_fifos: Optional[Tuple[str, ...]] = None
    reclaimed_scratch_bytes: Optional[int] = None
    hbm_state_bytes: Optional[int] = None
    grid_cores: Optional[int] = None
    partition_actors: Optional[Tuple[Tuple[str, ...], ...]] = None
    core_scratch_bytes: Optional[Tuple[int, ...]] = None
    shared_scratch_bytes: Optional[int] = None
    shared_fifos: Optional[Tuple[str, ...]] = None
    core_cursor_rows: Optional[Tuple[int, ...]] = None
    cut_objective: Optional[str] = None
    partition_fire_counts: Optional[Tuple[int, ...]] = None
    last_stream_chunks: Optional[int] = None
    last_stream_persistent: Optional[bool] = None
    last_stream_staged_bytes_per_chunk: Optional[int] = None
    last_stream_total_staged_bytes: Optional[int] = None
    devices: int = 1
    device_partition_actors: Optional[Tuple[Tuple[str, ...], ...]] = None
    collective_bytes_per_sweep: Optional[int] = None
    quiescence_allreduces: Optional[int] = None

    #: Version of the :meth:`to_json` schema.  Bump ONLY when a field is
    #: renamed/removed or its meaning changes; adding optional fields is
    #: backward-compatible and keeps the version.  v2 (multi-device
    #: sharding): added ``devices`` (now always present, 1 when
    #: unsharded — the semantic change behind the bump) plus the
    #: sharding telemetry ``device_partition_actors`` /
    #: ``collective_bytes_per_sweep`` / ``quiescence_allreduces``;
    #: every v1 field survives unchanged, so v1 consumers keep parsing.
    SCHEMA_VERSION = 2

    def to_json(self) -> Dict[str, Any]:
        """The stats as a ``json.dump``-able dict (committed schema).

        Every dataclass field appears under its own name with tuples
        lowered to lists; ``schema_version`` pins the layout so external
        dashboards can parse dumps across repo versions.
        """
        def lower(v):
            if isinstance(v, tuple):
                return [lower(x) for x in v]
            if isinstance(v, dict):
                return {k: lower(x) for k, x in v.items()}
            return v

        doc: Dict[str, Any] = {"schema_version": self.SCHEMA_VERSION}
        for f in dataclasses.fields(self):
            doc[f.name] = lower(getattr(self, f.name))
        return doc


# ---------------------------------------------------------------------- #
# Durable-checkpoint payload helpers (PR 10): Trace and NetworkState go
# through plain containers so repro.checkpoint's skeleton serializer can
# round-trip them across a process boundary.
# ---------------------------------------------------------------------- #
def _trace_to_payload(t: Trace) -> Dict[str, Any]:
    return {"actor_names": list(t.actor_names),
            "fifo_names": list(t.fifo_names),
            "events": np.asarray(t.events, np.int32),
            "capacity": int(t.capacity),
            "dropped": int(t.dropped),
            "wall_time_s": (None if t.wall_time_s is None
                            else float(t.wall_time_s)),
            "actor_flops": [int(x) for x in t.actor_flops],
            "fifo_token_bytes": [int(x) for x in t.fifo_token_bytes],
            "actor_cores": (None if t.actor_cores is None
                            else [int(x) for x in t.actor_cores])}


def _trace_from_payload(d: Mapping[str, Any]) -> Trace:
    return Trace(actor_names=tuple(d["actor_names"]),
                 fifo_names=tuple(d["fifo_names"]),
                 events=np.asarray(d["events"], np.int32),
                 capacity=int(d["capacity"]),
                 dropped=int(d["dropped"]),
                 wall_time_s=d["wall_time_s"],
                 actor_flops=tuple(int(x) for x in d["actor_flops"]),
                 fifo_token_bytes=tuple(int(x)
                                        for x in d["fifo_token_bytes"]),
                 actor_cores=(None if d["actor_cores"] is None
                              else tuple(int(x) for x in d["actor_cores"])))


class Program:
    """A network compiled under a plan; run with :meth:`run` or
    :meth:`stream`.  Built via :meth:`repro.core.network.Network.compile`.
    """

    def __init__(self, network: Network, plan: ExecutionPlan):
        self.plan = plan
        self.source_network = network
        self._last: Optional[RunResult] = None
        self._last_is_stream_chunk = False
        #: Per-chunk fault/recovery log of the last :meth:`stream` call
        #: (entries only for chunks that needed the on_fault policy).
        self.last_stream_report: List[Dict[str, Any]] = []
        #: Telemetry of the last :meth:`stream` call (chunks / persistent /
        #: staged bytes), surfaced through :meth:`stats`.
        self._last_stream: Optional[Dict[str, Any]] = None
        #: Merged :class:`repro.core.trace.Trace` across the last
        #: :meth:`stream` call's chunks (None unless ``plan.trace``).
        self.last_stream_trace: Optional[Trace] = None
        #: Accumulated per-actor fire counts across the last
        #: :meth:`stream` / :meth:`resume_stream` call's chunks (None for
        #: modes without counts); a resumed stream's totals equal the
        #: uninterrupted run's — the counts ride the durable checkpoint.
        self.last_stream_fire_counts: Optional[Dict[str, int]] = None
        #: Accumulated sweeps across the last stream call's chunks.
        self.last_stream_sweeps: Optional[int] = None
        #: Full-length programs built lazily by persistent-feed streams,
        #: keyed by total window count (reused across stream() calls).
        self._persistent_progs: Dict[int, "Program"] = {}
        #: Bounded-sweep twin programs built lazily by
        #: :meth:`run_checkpointed`, keyed by segment sweep budget.
        self._segment_progs: Dict[int, "Program"] = {}
        self._feed_by_fifo: Dict[str, str] = {}
        self._fetch_by_fifo: Dict[str, str] = {}
        # THE cross-field rule book: every plan x network combination is
        # judged here (and only here) before anything is built.
        plan.validate(network)
        if plan.accelerated is not None:
            sub, feeds, fetches = heterogeneous_split(
                network, list(plan.accelerated), plan.n_iterations)
            self.network = sub
            self._feed_by_fifo = {f[len("__feed_"):]: f for f in feeds}
            self._fetch_by_fifo = {f[len("__fetch_"):]: f for f in fetches}
        else:
            self.network = network
        self.donate = self._resolve_donate(plan, self.network)
        self._layout = None
        self._partition = None
        self._shard_layout = None
        self._shard_partition = None
        if plan.mode == "megakernel":
            from repro.core.megakernel import lower_network, partition_layout
            self._layout = lower_network(self.network)
            self._partition = partition_layout(
                self.network, self._layout, plan.cores,
                dict(plan.assign) if plan.assign is not None else None,
                objective=plan.cut_objective,
                forward_transients=plan.specialize,
                profile=({k: dict(v) for k, v in plan.profile}
                         if plan.profile is not None else None))
        if plan.devices > 1:
            if jax.device_count() < plan.devices:
                raise RuntimeError(
                    f"ExecutionPlan(devices={plan.devices}): only "
                    f"{jax.device_count()} JAX device(s) visible; on a CPU "
                    "host force a bigger mesh with XLA_FLAGS="
                    f"--xla_force_host_platform_device_count={plan.devices} "
                    "(set before jax initializes)")
            from repro.core.shard import build_device_partition
            self._shard_layout, self._shard_partition = \
                build_device_partition(
                    self.network, plan.devices,
                    device_assign=(dict(plan.device_assign)
                                   if plan.device_assign is not None
                                   else None),
                    cut_objective=plan.cut_objective)
        # donate="auto" must never consume a state the *caller* passed in
        # (donated inputs are invalidated; callers legitimately reuse
        # states across runs), so auto donation applies only to run(None),
        # where the program donates its own private copy.  Two runners are
        # built for that case; jit tracing is lazy, so an unused variant
        # costs nothing.
        if plan.mode == "megakernel" or plan.devices > 1:
            # Donation is meaningless here (megakernel buffers are staged
            # through kernel scratch; sharded state is replicated across
            # the mesh): one runner serves both donate paths and no
            # private copy is ever made (_resolve_donate -> False).
            runner = self._make_runner(False)
            self._runners = {False: runner, True: runner}
        elif isinstance(plan.donate, bool):
            self._runners = {plan.donate: self._make_runner(plan.donate)}
        else:
            self._runners = {False: self._make_runner(False)}
            if self.donate:
                self._runners[True] = self._make_runner(True)

    def _make_runner(self, donate: bool):
        plan = self.plan
        order = list(plan.order) if plan.order is not None else None
        trace_cap = ((plan.trace_capacity or TRACE_CAPACITY_DEFAULT)
                     if plan.trace else None)
        if plan.mode == "static":
            return _compile_static(
                self.network, plan.n_iterations, mode=plan.runtime_mode,
                order=order, donate=donate, specialize=plan.specialize,
                unroll_bound=plan.unroll_bound)
        if plan.mode == "dynamic":
            if plan.devices > 1:
                from repro.core.shard import compile_sharded
                return compile_sharded(
                    self.network, self._shard_layout, self._shard_partition,
                    plan.max_sweeps, mode=plan.runtime_mode,
                    multi_firing=plan.multi_firing, guards=plan.guards,
                    trace_capacity=trace_cap)
            return _compile_dynamic(
                self.network, plan.max_sweeps, mode=plan.runtime_mode,
                multi_firing=plan.multi_firing, donate=donate,
                return_sweeps=True, guards=plan.guards,
                trace_capacity=trace_cap)
        if plan.mode == "megakernel":
            from repro.core.megakernel import compile_megakernel
            return compile_megakernel(
                self.network, max_sweeps=plan.max_sweeps,
                mode=plan.runtime_mode, multi_firing=plan.multi_firing,
                interpret=plan.interpret, layout=self._layout,
                partition=self._partition, guards=plan.guards,
                trace_capacity=trace_cap)
        return functools.partial(
            _run_interpreted, self.network,
            n_iterations=plan.n_iterations, order=order, donate=donate)

    @staticmethod
    def _resolve_donate(plan: ExecutionPlan, network: Network) -> bool:
        """Resolve ``donate="auto"`` per graph.

        Donation helps only while the ring-buffered state stays small:
        once the buffered (non-register-allocated) channel bytes dominate,
        the aliasing constraint regresses throughput (MD: 707 -> 415
        tok/s; DPD, whose bulk channels registerize, gains 1.2x —
        EXPERIMENTS.md §Executor perf).  The megakernel stages buffers
        through kernel scratch itself, so donation buys nothing there.
        """
        if plan.mode == "megakernel":
            return False    # even explicit donate=True: nothing to donate
        if plan.devices > 1:
            # The sharded runner keeps the state replicated across the
            # mesh and merges a fresh copy out — nothing to alias.
            return False
        if isinstance(plan.donate, bool):
            return plan.donate
        # register_fifos leave their ring buffers untouched ONLY under the
        # specialized static executor; every other mode keeps those rings
        # live, so their bytes count as buffered there.
        registerized = (network.register_fifos
                        if plan.mode == "static" and plan.specialize
                        else frozenset())
        buffered = sum(
            spec.capacity_bytes for name, spec in network.fifos.items()
            if name not in registerized)
        return buffered <= Program._donate_threshold(plan)

    @staticmethod
    def _donate_threshold(plan: ExecutionPlan) -> int:
        """The buffered-bytes ceiling of the ``donate="auto"`` heuristic."""
        if plan.donate_threshold_bytes is not None:
            return plan.donate_threshold_bytes
        return _DONATE_AUTO_BUFFERED_BYTES_MAX

    # ------------------------------------------------------------------ #
    def init_state(self) -> NetworkState:
        """Fresh state of the executed network (the accelerator subnetwork
        under a heterogeneous plan)."""
        return self.network.init_state()

    def run(self, state: Optional[Any] = None) -> RunResult:
        """Execute once from ``state`` (fresh :meth:`init_state` if None).

        Legacy ``{"fifos": ..., "actors": ...}`` dict states are accepted.
        With an explicit ``plan.donate=True`` a passed-in state's buffers
        are consumed; under the default ``"auto"`` only runs that create
        their own state donate (a private copy), so caller-held arrays
        stay valid.
        """
        st = self.init_state() if state is None else state
        if state is None:
            donate_now = self.donate
            if donate_now:
                # init_state() may alias arrays staged in the graph closure
                # (e.g. a source's signal slab); donating those would
                # poison every later init_state() of the network.  When
                # run() creates the state itself, donate a private copy.
                st = jax.tree.map(jnp.copy, st)
        else:
            # A caller-passed state is consumed only under an *explicit*
            # donate=True plan; the "auto" heuristic never invalidates
            # arrays the caller may still hold.
            donate_now = self.plan.donate is True
        runner = self._runners[donate_now]
        if self.plan.mode in ("dynamic", "megakernel"):
            t0 = time.perf_counter() if self.plan.trace else None
            if self.plan.mode == "dynamic":
                final, counts, sweeps, stalled, health, trc = runner(st)
            else:
                res = runner(st)     # _MegaResult: 3-tuple + attributes
                final, counts, sweeps = res
                stalled, health = res.stalled, res.health
                trc = res.trace
            # One scalar host sync; a stalled exit then pays the eager
            # per-actor forensics, the path where latency is moot.
            stalled_b = bool(stalled)
            trace = None
            if trc is not None:
                # The bool() sync above blocked until the computation
                # finished, so this wall-clock covers the whole run —
                # the per-firing cost attribution is proportional, not a
                # per-event clock (none exists inside one jitted sweep
                # loop).
                dt = time.perf_counter() - t0
                if self.plan.devices > 1:
                    # Sharded runs return one per-device trace ring each
                    # (all-gathered); decode and merge, with actor_cores
                    # recording the mesh device instead of a grid core.
                    from repro.core.shard import decode_device_trace
                    trace = decode_device_trace(
                        self.network, trc, self._shard_partition,
                        wall_time_s=dt)
                else:
                    cores = None
                    part = self._partition
                    if part is not None and part.n_cores > 1:
                        names = tuple(self.network.actors)
                        cores = {names[i]: c
                                 for c, rows in enumerate(part.core_rows)
                                 for i in rows}
                    trace = decode_trace(self.network, trc, wall_time_s=dt,
                                         actor_cores=cores)
            diag = decode_health(self.network, health, stalled_b,
                                 final if stalled_b else None)
            result = RunResult(final, fire_counts=counts, sweeps=sweeps,
                               diagnostics=diag, trace=trace)
            self._last = result
            self._last_is_stream_chunk = False
            if not diag.ok:
                if self.plan.guards:
                    err = NetworkFaultError(diag)
                    err.result = result
                    raise err
                if stalled_b:
                    # Guards off: surface the exhaustion (satellite fix for
                    # the silent max_sweeps return) without changing the
                    # no-raise contract of unguarded plans.
                    warnings.warn(
                        f"Program.run: sweep budget "
                        f"(max_sweeps={self.plan.max_sweeps}) exhausted "
                        f"with work remaining — partial state returned; "
                        f"{diag.summary()}", RuntimeWarning, stacklevel=2)
            return result
        result = RunResult(runner(st))  # static/interpreted: bare state
        self._last = result
        self._last_is_stream_chunk = False
        return result

    def collect(self, actor: str, state: Optional[NetworkState] = None) -> Any:
        """Run ``actor``'s ``finish`` hook on its state (paper §3.1);
        defaults to the last :meth:`run`'s final state."""
        if state is None:
            if self._last is None:
                raise ValueError("Program.collect: no run yet; pass a state "
                                 "or call run() first")
            if self._last_is_stream_chunk:
                raise ValueError(
                    "Program.collect: the last execution was stream(), whose "
                    "implicit final state covers only the LAST chunk; use "
                    "the dict stream() returned for the full output, or "
                    "pass a state explicitly")
            state = self._last.state
        return collect_sink(self.network, state, actor)

    # ------------------------------------------------------------------ #
    # Chunked host-feed / fetch loop (heterogeneous plans).                #
    # ------------------------------------------------------------------ #
    def _set_actor(self, state: NetworkState, actor: str, value: Any) -> NetworkState:
        return state.replace_actor(self.network.actor_index[actor], value)

    def _normalize_feed(self, fifo: str, feed_actor: str, spec: Any,
                        raw: Any, where: str = ""):
        """Validate + window-normalize one feed array; returns
        ``(raw_dtype, (n, r, *token_shape) array)``.  ``where`` labels
        the chunk in errors when the feed arrived as a per-chunk list."""
        raw = jnp.asarray(raw)
        # Real-to-real casts (int windows into a float channel, float
        # probes into a uint8 frame channel) are long-standing host
        # conveniences; complex data into a real channel silently
        # drops the imaginary half, which is always a wrong feed wired
        # to the right name — reject that one here with the actor
        # named instead of staging garbage.
        if (jnp.issubdtype(raw.dtype, jnp.complexfloating)
                and not jnp.issubdtype(jnp.dtype(spec.dtype),
                                       jnp.complexfloating)):
            raise ValueError(
                f"Program.stream: feed {fifo!r}{where} (staged into actor "
                f"{feed_actor!r}) carries dtype {raw.dtype}, but the "
                f"channel expects {jnp.dtype(spec.dtype)}; cast the "
                "stream explicitly if the conversion is intended")
        arr = raw.astype(spec.dtype)
        window = (spec.rate,) + tuple(spec.token_shape)
        if arr.shape[1:] != window:
            if arr.ndim >= 1 and arr.shape[0] % spec.rate == 0 \
                    and arr.shape[1:] == tuple(spec.token_shape):
                arr = arr.reshape((-1,) + window)
            else:
                raise ValueError(
                    f"Program.stream: feed {fifo!r}{where} (staged into "
                    f"actor {feed_actor!r}) has shape {arr.shape}; expected "
                    f"(n, {spec.rate}, *{tuple(spec.token_shape)}) "
                    "windows or the flattened token stream")
        return raw.dtype, arr

    def stream(self, feeds: Mapping[str, Any], on_fault: str = "raise",
               max_retries: int = 2,
               persistent: bool = False,
               checkpoint_dir: Optional[str] = None,
               checkpoint_every: int = 1) -> Dict[str, jax.Array]:
        """Stream host data through the accelerated subnetwork in chunks.

        ``feeds`` maps each *inbound boundary channel* name to its full
        token stream — ``(total_windows, r, *token_shape)``, the
        flattened ``(total_windows * r, *token_shape)``, or a
        list/tuple of per-chunk arrays (one element per
        ``plan.n_iterations``-window chunk, each in either layout; every
        element must keep the dtype and shape of chunk 0 — a mismatch is
        rejected naming the chunk index and channel, it is never staged).
        The stream is cut into chunks of ``plan.n_iterations`` windows;
        each chunk is staged into the feed actors, executed under the
        plan, and the fetch actors' slabs collected.  Actor and
        internal-FIFO state (e.g. filter histories, delay tokens)
        carries across chunks — streaming N chunks equals one long run
        over the concatenation.

        ``persistent=True`` is the persistent-feed mode: instead of
        re-entering the compiled chunk-length program once per chunk —
        which re-stages every buffered ring HBM -> kernel scratch on
        each megakernel entry — the stream compiles ONE full-length
        program (same network, ``n_iterations=total``), stages the feed
        slabs once, and runs to completion in a single entry; rings stay
        resident across what used to be chunk boundaries.  Outputs are
        bit-identical to the chunked loop (the concatenation invariant
        above).  With ``on_fault="resume"``/``"skip"`` a faulting
        persistent entry *falls back to the chunked loop* — the only
        place per-chunk checkpoints exist to restore — logged in
        ``last_stream_report`` as ``action="fallback-chunked"``; the
        healthy path keeps the single-entry staging savings, reported by
        :meth:`stats` (``last_stream_staged_bytes_per_chunk`` /
        ``last_stream_total_staged_bytes``).  Durable snapshots are the
        one thing persistent mode cannot do (no chunk boundaries), so
        ``checkpoint_dir=`` requires ``persistent=False``.

        ``checkpoint_dir=`` makes the chunked loop *durable*: every
        ``checkpoint_every`` chunks (and at the final chunk) the full
        progress — NetworkState rings + cursors, fetched output slabs,
        accumulated fire counts/sweeps, per-chunk trace rings — is
        written as a CRC'd, atomically-committed, versioned snapshot
        (:mod:`repro.checkpoint`).  After a process kill,
        :meth:`resume_stream` on a freshly compiled program continues
        from the newest intact snapshot bit-identically to the
        uninterrupted run.

        The loop checkpoints the :class:`NetworkState` before each chunk;
        ``on_fault`` decides what a :class:`NetworkFaultError` from a
        guarded run (``ExecutionPlan(guards=True)``) does:

          * ``"raise"`` (default): re-raise, augmented with the chunk
            index — the stream dies but the error names chunk, channel
            and actors.
          * ``"resume"``: re-stage the chunk from the checkpoint and
            retry up to ``max_retries`` times, then raise.  Retries are
            meaningful for *nondeterministic* faults (flaky hardware, a
            poisoned transient the caller repairs out of band) — a
            deterministic fault fails identically each attempt.
          * ``"skip"``: restore the checkpoint, emit all-zero windows for
            the chunk's fetch slabs, and continue with the next chunk —
            the degraded-service mode of a serving loop.

        Chunks needing the policy are logged in ``last_stream_report``
        (dicts of chunk / attempts / action / fault).  Unguarded plans
        never raise ``NetworkFaultError``, so the policy only engages
        under ``guards=True``.

        Returns ``{outbound_channel: (total_windows, r, *token_shape)}``.
        """
        arrays, total, chunk, n_chunks, slab_bytes, ring_bytes = \
            self._prepare_stream(feeds, on_fault, max_retries, persistent,
                                 checkpoint_dir, checkpoint_every)
        report: List[Dict[str, Any]] = []
        self.last_stream_report = report
        if persistent:
            try:
                return self._stream_persistent(arrays, total, chunk,
                                               n_chunks, slab_bytes,
                                               ring_bytes)
            except NetworkFaultError as err:
                if on_fault == "raise":
                    raise
                # The checkpointed chunk loop is the only surface with
                # something to restore; re-run the stream there (its
                # outputs are bit-identical by the concatenation
                # invariant, so the fallback changes recovery, not data).
                report.append({"chunk": None, "attempts": 1,
                               "action": "fallback-chunked",
                               "fault": str(err)})
        return self._stream_chunked(arrays, total, chunk, n_chunks, on_fault,
                                    max_retries, slab_bytes, ring_bytes,
                                    report, checkpoint_dir, checkpoint_every)

    def _prepare_stream(self, feeds: Mapping[str, Any], on_fault: str,
                        max_retries: int, persistent: bool,
                        checkpoint_dir: Optional[str],
                        checkpoint_every: int):
        """Shared stream validation + feed normalization (stream and
        resume_stream enter the chunk loop through the same checks)."""
        if on_fault not in ("raise", "resume", "skip"):
            raise ValueError(
                f"Program.stream: on_fault must be 'raise', 'resume' or "
                f"'skip', got {on_fault!r}")
        # Stream-context cross-field rules (heterogeneous placement,
        # persistent x checkpoint_dir) live in the one plan rule book.
        self.plan.validate(self.source_network, stream_persistent=persistent,
                           stream_on_fault=on_fault,
                           stream_checkpoint_dir=checkpoint_dir)
        if not isinstance(max_retries, int) or isinstance(max_retries, bool) \
                or max_retries < 0:
            raise ValueError(
                f"Program.stream: max_retries must be an int >= 0, got "
                f"{max_retries!r}")
        if not isinstance(checkpoint_every, int) \
                or isinstance(checkpoint_every, bool) or checkpoint_every < 1:
            raise ValueError(
                f"Program.stream: checkpoint_every must be an int >= 1, "
                f"got {checkpoint_every!r}")
        chunk = self.plan.n_iterations
        if self.plan.mode == "static" and self.plan.specialize:
            # The specialized static executor requires phase-aligned input
            # cursors; chunk 2+ resumes from chunk 1's final state, so the
            # chunk size must cover whole phase-unroll periods.  Check here,
            # before any chunk runs, instead of failing mid-stream with a
            # resumption error that blames the state rather than the plan.
            period = phase_unroll_period(
                [spec.n_write_phases
                 for name, spec in self.network.fifos.items()
                 if name not in self.network.register_fifos],
                bound=self.plan.unroll_bound)
            if chunk % period:
                raise ValueError(
                    f"Program.stream: n_iterations={chunk} is not a "
                    f"multiple of the phase-unroll period {period} of the "
                    "accelerated subnetwork, so chunks after the first "
                    "would resume from non-phase-aligned cursors; use a "
                    "multiple (delay channels cycle 3, double buffers 2) "
                    "or plan specialize=False")
        unknown = set(feeds) - set(self._feed_by_fifo)
        if unknown:
            raise ValueError(
                f"Program.stream: unknown feed channels {sorted(unknown)}; "
                f"inbound boundary channels: {sorted(self._feed_by_fifo)}")
        missing = set(self._feed_by_fifo) - set(feeds)
        if missing:
            raise ValueError(
                f"Program.stream: missing feeds for inbound boundary "
                f"channels {sorted(missing)}")
        arrays: Dict[str, jax.Array] = {}
        total = None
        for fifo, arr in feeds.items():
            spec = self.source_network.fifos[fifo]
            feed_actor = self._feed_by_fifo[fifo]
            if isinstance(arr, (list, tuple)):
                # Per-chunk feed: one element per chunk.  Each element is
                # normalized on its own, then pinned to chunk 0's dtype
                # and window layout — chunk 2+ of a drifting stream must
                # fail HERE naming the chunk, not stage a silently cast /
                # misaligned slab (the cross-chunk validation gap).
                if len(arr) == 0:
                    raise ValueError(
                        f"Program.stream: feed {fifo!r} is an empty "
                        "per-chunk list; pass one array per chunk")
                dt0 = a0 = None
                parts = []
                for i, piece in enumerate(arr):
                    dt, a = self._normalize_feed(fifo, feed_actor, spec,
                                                 piece, where=f" chunk {i}")
                    if i == 0:
                        dt0, a0 = dt, a
                        if a.shape[0] != chunk:
                            raise ValueError(
                                f"Program.stream: per-chunk feed {fifo!r} "
                                f"chunk 0 covers {a.shape[0]} windows, but "
                                f"chunks are n_iterations={chunk} windows "
                                "each; pass whole chunks (or one "
                                "concatenated array)")
                    else:
                        if dt != dt0:
                            raise ValueError(
                                f"Program.stream: feed {fifo!r} chunk {i} "
                                f"carries dtype {dt}, but chunk 0 staged "
                                f"{dt0}; per-chunk feeds must keep one "
                                "dtype across the stream (cast explicitly "
                                "if the drift is intended)")
                        if a.shape != a0.shape:
                            raise ValueError(
                                f"Program.stream: feed {fifo!r} chunk {i} "
                                f"has window shape {tuple(a.shape)}, but "
                                f"chunk 0 staged {tuple(a0.shape)}; "
                                "per-chunk feeds must keep a consistent "
                                "window count and token shape across "
                                "chunks")
                    parts.append(a)
                arr = jnp.concatenate(parts, axis=0)
            else:
                _, arr = self._normalize_feed(fifo, feed_actor, spec, arr)
            if total is None:
                total = arr.shape[0]
            elif arr.shape[0] != total:
                raise ValueError(
                    f"Program.stream: feed {fifo!r} carries {arr.shape[0]} "
                    f"windows but other feeds carry {total}; all feeds "
                    "must cover the same number of iterations")
            arrays[fifo] = arr
        if total is None:
            raise ValueError("Program.stream: no feeds given")
        if total % chunk:
            raise ValueError(
                f"Program.stream: {total} windows do not divide into "
                f"chunks of n_iterations={chunk}; pad the stream or pick "
                "a dividing chunk size")
        n_chunks = total // chunk
        # Staging-traffic accounting (stats().last_stream_*): the boundary
        # feed/fetch slab share every chunk pays in either mode, plus the
        # megakernel's ring + cursor scratch footprint — which the chunked
        # loop re-stages HBM -> scratch on every kernel entry and the
        # persistent run stages exactly once.
        slab_bytes = 0
        for f in list(arrays) + list(self._fetch_by_fifo):
            spec = self.source_network.fifos[f]
            slab_bytes += chunk * spec.rate * spec.token_size_bytes
        if self._layout is not None:
            from repro.core.megakernel import entry_staging_bytes
            ring_bytes = entry_staging_bytes(self._layout, self._partition)
        else:
            ring_bytes = 0
        self._check_feed_domains(arrays, chunk)
        return arrays, total, chunk, n_chunks, slab_bytes, ring_bytes

    def _check_feed_domains(self, arrays: Mapping[str, jax.Array],
                            chunk: int) -> None:
        """Reject out-of-domain feed windows before any chunk runs.

        A staged value outside a channel's declared ``domain`` would trip
        the DOMAIN write guard mid-stream, blaming the run instead of the
        input.  The error names the chunk index the bad window lands in
        and — when the channel declares ``row_id_col`` — the request id
        carried by the offending row, so serving callers can quarantine
        the request without replaying the stream.
        """
        for fifo, arr in arrays.items():
            spec = self.source_network.fifos[fifo]
            if spec.domain is None:
                continue
            lo, hi = spec.domain
            a = np.asarray(arr)
            bad = (a < lo) | (a > hi) | ~np.isfinite(a.astype(np.float64))
            if not bad.any():
                continue
            idx = tuple(int(x) for x in np.argwhere(bad)[0])
            w = idx[0]
            detail = ""
            if spec.row_id_col is not None and len(idx) >= 2:
                rid = int(a[idx[:-1] + (int(spec.row_id_col),)])
                detail = f", request id {rid}"
            raise ValueError(
                f"Program.stream: feed {fifo!r} window {w} (chunk "
                f"{w // chunk}) carries value {a[idx]!r} outside the "
                f"channel domain [{lo}, {hi}]{detail}; drop or repair the "
                "request before streaming")

    def _stream_persistent(self, arrays: Mapping[str, jax.Array], total: int,
                           chunk: int, n_chunks: int, slab_bytes: int,
                           ring_bytes: int) -> Dict[str, jax.Array]:
        # One full-length program over the SAME source network: by the
        # concatenation invariant its single run is bit-identical to
        # the chunked loop, and the feed slabs (sized total instead of
        # chunk) are staged exactly once.
        prog = self._persistent_progs.get(total)
        if prog is None:
            prog = Program(
                self.source_network,
                dataclasses.replace(self.plan, n_iterations=total))
            self._persistent_progs[total] = prog
        base = prog.init_state()
        for fifo, arr in arrays.items():
            base = prog._set_actor(base, prog._feed_by_fifo[fifo],
                                   (arr, jnp.int32(0)))
        result = prog.run(base)
        # collect() stays guarded: the implicit state belongs to the
        # full-length twin program, not this chunk-length one.
        self._last = result
        self._last_is_stream_chunk = True
        self.last_stream_trace = result.trace
        self.last_stream_fire_counts = (
            {k: int(v) for k, v in result.fire_counts.items()}
            if result.fire_counts is not None else None)
        self.last_stream_sweeps = (int(result.sweeps)
                                   if result.sweeps is not None else None)
        self._last_stream = {
            "chunks": n_chunks, "persistent": True,
            "staged_bytes_per_chunk": slab_bytes,
            "total_staged_bytes": ring_bytes + n_chunks * slab_bytes,
        }
        return {f: result.state.actor(prog._fetch_by_fifo[f])[0]
                for f in self._fetch_by_fifo}

    def _stream_chunked(self, arrays: Mapping[str, jax.Array], total: int,
                        chunk: int, n_chunks: int, on_fault: str,
                        max_retries: int, slab_bytes: int, ring_bytes: int,
                        report: List[Dict[str, Any]],
                        checkpoint_dir: Optional[str],
                        checkpoint_every: int,
                        start_chunk: int = 0,
                        state: Optional[NetworkState] = None,
                        outs: Optional[Dict[str, list]] = None,
                        traces: Optional[List[Trace]] = None,
                        counts: Optional[Dict[str, int]] = None,
                        sweeps: int = 0) -> Dict[str, jax.Array]:
        """The chunked stream loop, resumable at any chunk boundary.

        ``stream`` enters it at chunk 0 with fresh accumulators;
        ``resume_stream`` enters it at the first chunk after the newest
        intact snapshot, with every accumulator restored — the loop body
        cannot tell the difference, which is the bit-identity argument.
        """
        if state is None:
            state = self.init_state()
        if outs is None:
            outs = {f: [] for f in self._fetch_by_fifo}
        chunk_traces: List[Trace] = [] if traces is None else traces
        acc_counts = counts
        acc_sweeps = int(sweeps)
        self.last_stream_trace = None
        retrying = on_fault in ("resume", "skip")
        for c in range(start_chunk, n_chunks):
            # The per-chunk checkpoint: the last good NetworkState, before
            # this chunk's feeds are staged.  Restoring it re-runs (or
            # skips) the chunk with actor/FIFO history intact.
            checkpoint = state
            attempts = 0
            while True:
                base = checkpoint
                if retrying and self.plan.donate is True:
                    # An explicit-donate run consumes its input buffers —
                    # which the staged state shares with the checkpoint —
                    # so every retryable attempt donates a private copy.
                    base = jax.tree.map(jnp.copy, checkpoint)
                for fifo, arr in arrays.items():
                    base = self._set_actor(base, self._feed_by_fifo[fifo],
                                           (arr[c * chunk:(c + 1) * chunk],
                                            jnp.int32(0)))
                for fifo, fetch in self._fetch_by_fifo.items():
                    slab, _ = base.actor(fetch)
                    base = self._set_actor(base, fetch,
                                           (jnp.zeros_like(slab),
                                            jnp.int32(0)))
                attempts += 1
                try:
                    chunk_res = self.run(base)
                    state = chunk_res.state
                    if chunk_res.fire_counts is not None:
                        if acc_counts is None:
                            acc_counts = {}
                        for k, v in chunk_res.fire_counts.items():
                            acc_counts[k] = acc_counts.get(k, 0) + int(v)
                    if chunk_res.sweeps is not None:
                        acc_sweeps += int(chunk_res.sweeps)
                    if chunk_res.trace is not None:
                        chunk_traces.append(chunk_res.trace)
                    # Guard collect() immediately (not after the loop): the
                    # implicit last state holds only this chunk's fetch
                    # slabs, not the whole stream — and must stay guarded
                    # if a later chunk raises.
                    self._last_is_stream_chunk = True
                    if attempts > 1:
                        report.append({"chunk": c, "attempts": attempts,
                                       "action": "recovered", "fault": None})
                    for fifo, fetch in self._fetch_by_fifo.items():
                        outs[fifo].append(state.actor(fetch)[0])
                    break
                except NetworkFaultError as err:
                    self._last_is_stream_chunk = True
                    if on_fault == "resume" and attempts <= max_retries:
                        continue
                    if on_fault == "skip":
                        report.append({"chunk": c, "attempts": attempts,
                                       "action": "skip", "fault": str(err)})
                        state = checkpoint
                        for fifo, fetch in self._fetch_by_fifo.items():
                            outs[fifo].append(
                                jnp.zeros_like(state.actor(fetch)[0]))
                        break
                    report.append({"chunk": c, "attempts": attempts,
                                   "action": "raise", "fault": str(err)})
                    err.args = (f"Program.stream: chunk {c} of {n_chunks} "
                                f"failed after {attempts} attempt(s): "
                                f"{err.args[0]}",)
                    raise
            if checkpoint_dir is not None and (
                    (c + 1) % checkpoint_every == 0 or c + 1 == n_chunks):
                # Snapshot AFTER the chunk commits: the payload is the
                # full progress (state, fetched windows, fire counts,
                # sweeps, trace ring) and the manifest's step is the count
                # of chunks durably done.  A kill between snapshots loses
                # at most checkpoint_every chunks of work, never data
                # integrity (the writer commits by atomic rename).
                self._save_stream_snapshot(
                    checkpoint_dir, c + 1, n_chunks, chunk, total, state,
                    outs, acc_counts, acc_sweeps, chunk_traces)
        self.last_stream_fire_counts = (dict(acc_counts)
                                        if acc_counts is not None else None)
        self.last_stream_sweeps = acc_sweeps
        self._last_stream = {
            "chunks": n_chunks, "persistent": False,
            "staged_bytes_per_chunk": ring_bytes + slab_bytes,
            "total_staged_bytes": n_chunks * (ring_bytes + slab_bytes),
        }
        # One Trace across the whole stream: later chunks' sweep numbers
        # are offset past the earlier chunks', so per-actor firing counts
        # and occupancy series read as a single run.
        self.last_stream_trace = merge_traces(chunk_traces)
        return {f: jnp.concatenate(ws, axis=0) for f, ws in outs.items()}

    # ------------------------------------------------------------------ #
    # Durable snapshots: payload <-> plain-container serialization.
    # ------------------------------------------------------------------ #
    def _save_stream_snapshot(self, directory: str, done_chunks: int,
                              n_chunks: int, chunk: int, total: int,
                              state: NetworkState, outs: Dict[str, list],
                              counts: Optional[Dict[str, int]], sweeps: int,
                              traces: List[Trace]) -> None:
        payload = {
            "state": self._state_payload(state),
            "outs": {f: [np.asarray(w) for w in ws]
                     for f, ws in outs.items()},
            "fire_counts": dict(counts) if counts is not None else None,
            "sweeps": int(sweeps),
            "traces": [_trace_to_payload(t) for t in traces],
        }
        meta = {
            "kind": "stream", "chunk": int(done_chunks),
            "n_chunks": int(n_chunks), "chunk_windows": int(chunk),
            "total_windows": int(total), "mode": self.plan.mode,
            "devices": int(self.plan.devices),
            "feed_fifos": sorted(self._feed_by_fifo),
            "fetch_fifos": sorted(self._fetch_by_fifo),
        }
        save_stream_checkpoint(directory, int(done_chunks), payload, meta)

    def _state_payload(self, state: NetworkState) -> Dict[str, Any]:
        """NetworkState -> plain containers of arrays (name-keyed, so the
        snapshot survives pytree-registration details of actor states)."""
        fifos = {name: {"buf": np.asarray(fs.buf), "rd": np.asarray(fs.rd),
                        "wr": np.asarray(fs.wr), "occ": np.asarray(fs.occ)}
                 for name, fs in zip(state.fifo_names, state.fifos)}
        actors = {name: [np.asarray(leaf) for leaf in jax.tree.leaves(a)]
                  for name, a in zip(state.actor_names, state.actors)}
        return {"fifos": fifos, "actors": actors}

    def _state_from_payload(self, payload: Mapping[str, Any]) -> NetworkState:
        """Rebuild a NetworkState on this program's network from a
        snapshot payload, validating names/shapes and restoring each
        actor's pytree structure from the template init state."""
        template = self.network.init_state()
        fifos = []
        for name, fs in zip(template.fifo_names, template.fifos):
            if name not in payload["fifos"]:
                raise CheckpointIntegrityError(
                    f"snapshot has no channel {name!r}; it was taken on a "
                    "different network")
            d = payload["fifos"][name]
            buf = jnp.asarray(np.asarray(d["buf"]), fs.buf.dtype)
            if buf.shape != fs.buf.shape:
                raise CheckpointIntegrityError(
                    f"snapshot channel {name!r} ring has shape "
                    f"{tuple(np.asarray(d['buf']).shape)}, this network "
                    f"allocates {tuple(fs.buf.shape)}; capacities (Eq. 1) "
                    "or token shapes differ")
            fifos.append(FifoState(
                buf=buf, rd=jnp.asarray(np.asarray(d["rd"]), jnp.int32),
                wr=jnp.asarray(np.asarray(d["wr"]), jnp.int32),
                occ=jnp.asarray(np.asarray(d["occ"]), jnp.int32)))
        actors = []
        for name, a in zip(template.actor_names, template.actors):
            if name not in payload["actors"]:
                raise CheckpointIntegrityError(
                    f"snapshot has no actor {name!r}; it was taken on a "
                    "different network")
            tmpl_leaves, treedef = jax.tree.flatten(a)
            saved = payload["actors"][name]
            if len(saved) != len(tmpl_leaves):
                raise CheckpointIntegrityError(
                    f"snapshot actor {name!r} carries {len(saved)} state "
                    f"leaves, this network expects {len(tmpl_leaves)}")
            leaves = []
            for tl, sl in zip(tmpl_leaves, saved):
                arr = jnp.asarray(np.asarray(sl), jnp.asarray(tl).dtype)
                if arr.shape != jnp.asarray(tl).shape:
                    raise CheckpointIntegrityError(
                        f"snapshot actor {name!r} leaf has shape "
                        f"{tuple(arr.shape)}, expected "
                        f"{tuple(jnp.asarray(tl).shape)}")
                leaves.append(arr)
            actors.append(jax.tree.unflatten(treedef, leaves))
        return dataclasses.replace(template, fifos=tuple(fifos),
                                   actors=tuple(actors))

    def resume_stream(self, checkpoint_dir: str, feeds: Mapping[str, Any],
                      on_fault: str = "raise", max_retries: int = 2,
                      checkpoint_every: int = 1) -> Dict[str, jax.Array]:
        """Continue an interrupted ``stream(checkpoint_dir=...)`` run.

        Call it on a freshly compiled program over the same network with
        the SAME feeds: the newest intact snapshot under
        ``checkpoint_dir`` restores the network state, fetched windows,
        fire counts, sweep total and trace ring, and the chunk loop
        continues at the first unfinished chunk.  The returned windows —
        and every piece of telemetry — are bit-identical to the
        uninterrupted run (Kahn determinism: each chunk is a pure
        function of the restored state and its feed slice).  Snapshots
        that fail their CRC (torn by the kill) are skipped in favor of
        the next-newest intact one.
        """
        arrays, total, chunk, n_chunks, slab_bytes, ring_bytes = \
            self._prepare_stream(feeds, on_fault, max_retries, False,
                                 checkpoint_dir, checkpoint_every)
        payload, meta, step = load_stream_checkpoint(checkpoint_dir)
        if meta.get("kind") != "stream":
            raise ValueError(
                f"resume_stream: {checkpoint_dir!r} holds a "
                f"{meta.get('kind')!r} checkpoint; those resume via "
                "Program.resume_run")
        if (int(meta["chunk_windows"]) != chunk
                or int(meta["total_windows"]) != total):
            raise ValueError(
                f"resume_stream: snapshot covers chunks of "
                f"{meta['chunk_windows']} windows over a "
                f"{meta['total_windows']}-window stream, but this program "
                f"streams {chunk}-window chunks over {total} windows; "
                "resume with the original plan and feeds")
        state = self._state_from_payload(payload["state"])
        outs: Dict[str, list] = {
            f: [jnp.asarray(w) for w in payload["outs"].get(f, [])]
            for f in self._fetch_by_fifo}
        counts = (dict(payload["fire_counts"])
                  if payload.get("fire_counts") is not None else None)
        traces = [_trace_from_payload(d) for d in payload.get("traces", [])]
        report: List[Dict[str, Any]] = []
        self.last_stream_report = report
        return self._stream_chunked(
            arrays, total, chunk, n_chunks, on_fault, max_retries,
            slab_bytes, ring_bytes, report, checkpoint_dir, checkpoint_every,
            start_chunk=int(meta["chunk"]), state=state, outs=outs,
            traces=traces, counts=counts, sweeps=int(payload.get("sweeps", 0)))

    # ------------------------------------------------------------------ #
    # Durable segmented runs: run() for quiescence graphs, checkpointed
    # every N sweeps so a killed process resumes bit-identically.
    # ------------------------------------------------------------------ #
    def _segment_program(self, every_sweeps: int) -> "Program":
        seg = self._segment_progs.get(every_sweeps)
        if seg is None:
            seg = Program(self.source_network,
                          dataclasses.replace(self.plan,
                                              max_sweeps=every_sweeps))
            self._segment_progs[every_sweeps] = seg
        return seg

    def _run_one_segment(self, seg_prog: "Program",
                         state: Any) -> Tuple[RunResult, bool]:
        """Run one bounded segment; returns (result, stalled).

        A segment that exhausts its sweep budget without quiescing is the
        NORMAL case mid-run, so the stall diagnostics a plain ``run()``
        would raise/warn about are re-read as "segment boundary" — but a
        segment that stalls with real fault flags set still raises.
        """
        try:
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", RuntimeWarning)
                res = seg_prog.run(state)
        except NetworkFaultError as err:
            diag = err.diagnostics
            if (diag is not None and diag.stalled and not diag.faults
                    and getattr(err, "result", None) is not None):
                return err.result, True
            raise
        return res, bool(res.diagnostics.stalled
                         if res.diagnostics is not None else False)

    def run_checkpointed(self, checkpoint_dir: str, every_sweeps: int,
                         state: Optional[Any] = None,
                         keep: int = 3) -> RunResult:
        """``run()`` with durable progress snapshots every ``every_sweeps``.

        The run is split into bounded segments (a twin program with
        ``max_sweeps=every_sweeps``); after each segment the full
        :class:`NetworkState`, accumulated fire counts, sweep total and
        trace ring are committed to ``checkpoint_dir`` as a CRC'd,
        atomically-renamed snapshot.  After a process kill,
        :meth:`resume_run` continues from the newest intact snapshot and
        the final :class:`RunResult` is bit-identical to the
        uninterrupted run — each sweep is a deterministic function of the
        state, so cutting the run at sweep boundaries changes nothing but
        wall time.  Works at any ``devices=`` count (the sharded runner
        takes and returns host exit-merged states).

        Only modes that run to data-dependent quiescence segment
        meaningfully (``dynamic``, ``megakernel``); heterogeneous plans
        checkpoint through ``stream(checkpoint_dir=...)`` instead.
        """
        if self.plan.mode not in ("dynamic", "megakernel"):
            raise ValueError(
                f"Program.run_checkpointed: mode {self.plan.mode!r} runs a "
                "fixed iteration count, not to quiescence; checkpoint "
                "streams via stream(checkpoint_dir=...) instead")
        if self.plan.accelerated is not None:
            raise ValueError(
                "Program.run_checkpointed: heterogeneous plans execute via "
                "stream(); use stream(checkpoint_dir=...) for durability")
        if not isinstance(every_sweeps, int) or isinstance(every_sweeps, bool) \
                or every_sweeps < 1:
            raise ValueError(
                f"Program.run_checkpointed: every_sweeps must be an int "
                f">= 1, got {every_sweeps!r}")
        st = self.init_state() if state is None else state
        return self._run_segments(self._segment_program(every_sweeps), st,
                                  counts=None, sweeps_total=0, traces=[],
                                  segment=0, checkpoint_dir=checkpoint_dir,
                                  every_sweeps=every_sweeps, keep=keep)

    def _run_segments(self, seg_prog: "Program", st: Any,
                      counts: Optional[Dict[str, int]], sweeps_total: int,
                      traces: List[Trace], segment: int, checkpoint_dir: str,
                      every_sweeps: int, keep: int) -> RunResult:
        while True:
            res, stalled = self._run_one_segment(seg_prog, st)
            st = res.state
            if res.fire_counts is not None:
                if counts is None:
                    counts = {}
                for k, v in res.fire_counts.items():
                    counts[k] = counts.get(k, 0) + int(v)
            if res.sweeps is not None:
                sweeps_total += int(res.sweeps)
            if res.trace is not None:
                traces.append(res.trace)
            segment += 1
            done = not stalled
            over_budget = stalled and sweeps_total >= self.plan.max_sweeps
            payload = {
                "state": self._state_payload(
                    st if isinstance(st, NetworkState)
                    else self.network.state_from_dict(st)),
                "outs": {},
                "fire_counts": dict(counts) if counts is not None else None,
                "sweeps": int(sweeps_total),
                "traces": [_trace_to_payload(t) for t in traces],
            }
            meta = {"kind": "run", "segment": int(segment),
                    "every_sweeps": int(every_sweeps),
                    "done": bool(done or over_budget),
                    "mode": self.plan.mode,
                    "devices": int(self.plan.devices)}
            save_stream_checkpoint(checkpoint_dir, segment, payload, meta,
                                   keep=keep)
            if over_budget:
                # Mirror run()'s budget-exhausted contract on the FULL
                # budget (the segment budget is an implementation detail).
                if self.plan.guards and res.diagnostics is not None:
                    err = NetworkFaultError(res.diagnostics)
                    err.result = self._final_run_result(
                        st, counts, sweeps_total, traces, res)
                    raise err
                warnings.warn(
                    f"Program.run_checkpointed: stalled after "
                    f"{sweeps_total} sweeps (max_sweeps="
                    f"{self.plan.max_sweeps}) without quiescing",
                    RuntimeWarning, stacklevel=2)
                done = True
            if done:
                final = self._final_run_result(st, counts, sweeps_total,
                                               traces, res)
                self._last = final
                self._last_is_stream_chunk = False
                return final

    def _final_run_result(self, st: Any, counts: Optional[Dict[str, int]],
                          sweeps_total: int, traces: List[Trace],
                          res: RunResult) -> RunResult:
        return RunResult(
            state=st,
            fire_counts=dict(counts) if counts is not None else None,
            sweeps=sweeps_total if res.sweeps is not None else None,
            diagnostics=res.diagnostics,
            trace=merge_traces(traces) if traces else None)

    def resume_run(self, checkpoint_dir: str, keep: int = 3) -> RunResult:
        """Continue (or recover the result of) a ``run_checkpointed``.

        Loads the newest intact snapshot under ``checkpoint_dir``: if the
        run had already quiesced (``done``), the final
        :class:`RunResult` is reconstructed from the snapshot; otherwise
        the segment loop continues until quiescence.  Either way the
        result is bit-identical to the uninterrupted run.
        """
        payload, meta, step = load_stream_checkpoint(checkpoint_dir)
        if meta.get("kind") != "run":
            raise ValueError(
                f"resume_run: {checkpoint_dir!r} holds a "
                f"{meta.get('kind')!r} checkpoint; those resume via "
                "Program.resume_stream")
        st = self._state_from_payload(payload["state"])
        counts = (dict(payload["fire_counts"])
                  if payload.get("fire_counts") is not None else None)
        sweeps_total = int(payload.get("sweeps", 0))
        traces = [_trace_from_payload(d) for d in payload.get("traces", [])]
        if meta.get("done"):
            final = RunResult(
                state=st,
                fire_counts=dict(counts) if counts is not None else None,
                sweeps=sweeps_total if sweeps_total else None,
                diagnostics=None,
                trace=merge_traces(traces) if traces else None)
            self._last = final
            self._last_is_stream_chunk = False
            return final
        return self._run_segments(
            self._segment_program(int(meta["every_sweeps"])), st,
            counts=counts, sweeps_total=sweeps_total, traces=traces,
            segment=int(meta["segment"]), checkpoint_dir=checkpoint_dir,
            every_sweeps=int(meta["every_sweeps"]), keep=keep)

    # ------------------------------------------------------------------ #
    def stats(self) -> ProgramStats:
        """Sweep counts, buffer bytes and the per-actor FLOP roofline."""
        net = self.network
        flops: Dict[str, int] = {}
        byts: Dict[str, int] = {}
        for name, a in net.actors.items():
            flops[name] = int(a.cost_flops)
            moved = sum(spec.rate * spec.token_size_bytes
                        for _, spec, _ in net.in_port_specs[name])
            moved += sum(spec.rate * spec.token_size_bytes
                         for _, spec, _ in net.out_port_specs[name])
            ctl = net.control_specs[name]
            if ctl is not None:
                moved += ctl[0].token_size_bytes
            byts[name] = int(moved)
        intensity = {n: (flops[n] / byts[n] if byts[n] else 0.0)
                     for n in net.actors}
        last = self._last
        scratch = transient = hbm = None
        forwarded = reclaimed = None
        grid_cores = part_actors = core_bytes = None
        shared_bytes = shared_names = part_counts = None
        cursor_split = cut_obj = None
        if self._layout is not None:
            from repro.core.megakernel import state_hbm_bytes
            scratch = self._layout.scratch_bytes
            transient = self._layout.transient_scratch_bytes
            if last is not None:
                # State pytree (rings, cursors, actor states) plus the
                # hoisted closure arrays — every HBM operand the kernel
                # touches.
                hbm = (state_hbm_bytes(last.state)
                       + getattr(self._runners[False],
                                 "hoisted_const_bytes", 0))
            part = self._partition
            if part is not None:
                names = tuple(net.actors)
                # Effective scratch under this partition's forwarding set:
                # the layout's no-forwarding footprint minus the rings
                # transient forwarding turned into loop-carried windows.
                scratch = part.scratch_bytes(self._layout)
                forwarded = tuple(self._layout.fifo_names[i]
                                  for i in part.forwarded_fifos)
                reclaimed = part.reclaimed_ring_bytes(self._layout)
                grid_cores = part.n_cores
                part_actors = tuple(
                    tuple(names[i] for i in rows) for rows in part.core_rows)
                core_bytes = part.private_ring_bytes(self._layout)
                shared_bytes = (part.shared_ring_bytes(self._layout)
                                + part.semaphore_bytes())
                shared_names = tuple(self._layout.fifo_names[i]
                                     for i in part.shared_fifos)
                cursor_split = part.core_cursor_rows
                cut_obj = part.objective
                if last is not None and last.fire_counts is not None:
                    part_counts = tuple(
                        sum(int(last.fire_counts[names[i]]) for i in rows)
                        for rows in part.core_rows)
        dev_actors = coll_bytes = allreduces = None
        if self._shard_partition is not None:
            from repro.core.shard import collective_bytes_per_sweep
            names = tuple(net.actors)
            dev_actors = tuple(
                tuple(names[i] for i in rows)
                for rows in self._shard_partition.core_rows)
            coll_bytes = collective_bytes_per_sweep(
                self._shard_layout, self._shard_partition)
            if last is not None and last.sweeps is not None:
                allreduces = int(last.sweeps)
        return ProgramStats(
            mode=self.plan.mode,
            n_actors=len(net.actors),
            n_fifos=len(net.fifos),
            buffer_bytes=net.buffer_bytes(),
            register_fifos=tuple(sorted(net.register_fifos)),
            iteration_flops=iteration_token_flops(net),
            actor_flops=flops,
            actor_window_bytes=byts,
            actor_intensity=intensity,
            last_sweeps=(int(last.sweeps) if last is not None
                         and last.sweeps is not None else None),
            last_fire_counts=({k: int(v) for k, v in last.fire_counts.items()}
                              if last is not None
                              and last.fire_counts is not None else None),
            resolved_donate=self.donate,
            resolved_donate_threshold=self._donate_threshold(self.plan),
            scratch_bytes=scratch,
            transient_scratch_bytes=transient,
            forwarded_fifos=forwarded,
            reclaimed_scratch_bytes=reclaimed,
            hbm_state_bytes=hbm,
            grid_cores=grid_cores,
            partition_actors=part_actors,
            core_scratch_bytes=core_bytes,
            shared_scratch_bytes=shared_bytes,
            shared_fifos=shared_names,
            core_cursor_rows=cursor_split,
            cut_objective=cut_obj,
            partition_fire_counts=part_counts,
            last_stream_chunks=(self._last_stream["chunks"]
                                if self._last_stream else None),
            last_stream_persistent=(self._last_stream["persistent"]
                                    if self._last_stream else None),
            last_stream_staged_bytes_per_chunk=(
                self._last_stream["staged_bytes_per_chunk"]
                if self._last_stream else None),
            last_stream_total_staged_bytes=(
                self._last_stream["total_staged_bytes"]
                if self._last_stream else None),
            devices=self.plan.devices,
            device_partition_actors=dev_actors,
            collective_bytes_per_sweep=coll_bytes,
            quiescence_allreduces=allreduces,
        )
