"""The persistent Pallas megakernel: device-resident dynamic scheduling.

One ``pl.pallas_call`` executes the whole network to quiescence:

  * every **buffered** Eq. 1 ring is staged into a **scratch** allocation
    (``pltpu.VMEM`` shapes from :meth:`MegakernelLayout.scratch_shape`)
    at kernel entry and copied back to the HBM outputs at exit — between
    those two copies no channel traffic leaves the device's fast memory;
  * **forwarded** channels (``GridPartition.forwarded_fifos``: the
    core-private subset of the ``register_fifos`` transient analysis) get
    no scratch ring and no HBM input operand at all — their Eq. 1
    capacity lives as a **loop-carried token window** of the sweep loop,
    written and read with the same masked offset arithmetic as the ring
    path, initialized to the dead-slot zeros of ``init_state``;
  * FIFO cursors (rd / wr / occ per channel) and actor states are
    **loop-carried values** of the in-kernel sweep ``lax.while_loop`` —
    the register-resident analogue of ``FifoState``'s scalars.  The
    cursor block is **split per core** (``GridPartition.cursor_rows``):
    each core's private channels pack into that core's own block and only
    partition-crossing channels share the semaphore block, so the
    coherence surface a parallel grid mapping must fence is exactly the
    shared block;
  * the sweep loop itself is the paper's §3.3 device-resident scheduler:
    each sweep visits every actor in declaration order, peeks its control
    token straight out of channel storage, and predicates up to
    ``_max_fireable``-many firings on ring occupancy via ``lax.cond`` —
    the exact blocking semantics of the host-side token-driven executor,
    with no host round trip per dispatch decision.

**Closure hoisting.**  Actor functions close over arrays staged at graph
build time (DPD's reconfiguration schedule, the MoE layer weights).
``pallas_call`` requires every array a kernel touches to be an explicit
operand, so :func:`_hoist_consts` traces each actor's ``fire`` /
``control`` / ``ready`` once at compile time, lifts the captured arrays
out of the jaxpr, and the runner passes them as extra kernel inputs —
weights enter the megakernel the same way they would enter any other
accelerator kernel.

**Bit-identity contract.**  The channel helpers (``_chan_read_masked``,
``_chan_write_masked``, ``_chan_peek``) mirror ``FifoSpec.read_masked`` /
``write_masked`` / ``peek`` operation for operation — same offsets, same
masked-window rewrite (disabled writes rewrite the current bytes, no
``lax.cond`` identity arm), same predicated slot-0 delay copy-back — and
``_fire`` / ``_can_fire`` / ``_max_fireable`` mirror their
``repro.core.executor`` namesakes.  Final states, fire counts and sweep
counts are therefore bit-identical to ``compile_dynamic`` (pinned by
``tests/test_megakernel.py``; the ring helpers alone are pinned against
the queue oracle in ``tests/test_megakernel_ring.py``) — with ONE
carve-out, mirroring the static specializer's dead-slot rule: a
*forwarded* channel's loop-carried window starts from ``init_state``'s
zeros instead of the incoming HBM buffer, so its **stale** ring bytes
are no longer part of the contract (from a fresh ``init_state`` even
those coincide — the masked updates evolve identical bytes from an
identical zero start).  Live tokens, cursors, actor states, fire counts
and sweeps remain exact; like the static specializer, forwarded
channels must enter **drained** (occupancy 0 — checked per run when
cursors are concrete), else compile with ``specialize=False`` to keep
every ring in scratch.

**Interpret fallback.**  ``interpret=None`` auto-selects Pallas interpret
mode off-TPU so tier-1 runs the kernel on CPU; the Mosaic (non-interpret)
TPU path is a ROADMAP open item — actor bodies may use ops Mosaic cannot
lower yet (MoE's top_k/scatter), so on TPU pass ``interpret=True`` to
fall back deliberately.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.executor import (_MAX_FIRINGS_PER_VISIT, RuntimeMode,
                                 _is_concrete, assert_mode_allows)
from repro.core.fifo import FifoSpec, FifoState
from repro.core.health import (HealthState, init_health, read_guard_bits,
                               true_occupancy, write_guard_bits)
from repro.core.megakernel.lower import (CURSOR_FIELDS, FiringRow,
                                         GridPartition, MegakernelLayout,
                                         lower_network, partition_layout)
from repro.core.network import Network, NetworkState
from repro.core.trace import TraceState

# Cursor row layout inside each packed (rows, 3) cursor block.
_RD, _WR, _OCC = 0, 1, 2


# --------------------------------------------------------------------------- #
# Channel storage — scratch ring refs for buffered channels, loop-carried
# token windows for forwarded ones, and the per-core cursor-block split.
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class _ChannelStore:
    """Trace-time view of the kernel's channel storage.

    ``rings`` holds the scratch refs of buffered channels (indexed via
    ``ring_pos``); forwarded channels live in the ``wins`` tuple threaded
    through the sweep carry (indexed via ``fwd_pos``).  ``cursor_slot``
    maps a flat channel index to its ``(block, row)`` in the split
    cursor-block tuple (``GridPartition.cursor_rows``: one private block
    per core, then the shared semaphore block).
    """

    specs: Tuple[FifoSpec, ...]
    rings: Tuple[Any, ...]
    ring_pos: Dict[int, int]
    fwd_pos: Dict[int, int]
    cursor_slot: Tuple[Tuple[int, int], ...]


def _cur(curs: Tuple[jax.Array, ...], slot: Tuple[int, int],
         field: int) -> jax.Array:
    block, row = slot
    return curs[block][row, field]


def _cur_advance(curs: Tuple[jax.Array, ...], slot: Tuple[int, int],
                 rd=None, wr=None, occ=None) -> Tuple[jax.Array, ...]:
    block, row = slot
    blk = curs[block]
    if rd is not None:
        blk = blk.at[row, _RD].add(rd)
    if wr is not None:
        blk = blk.at[row, _WR].add(wr)
    if occ is not None:
        blk = blk.at[row, _OCC].add(occ)
    return curs[:block] + (blk,) + curs[block + 1:]


# --------------------------------------------------------------------------- #
# Channel ops — FifoSpec's masked API, re-expressed on the channel store.
# Each mirrors its fifo.py namesake bit for bit; the phase-offset
# arithmetic is *shared* with FifoSpec (_read_offset / _write_offset) so a
# future phase-scheme change cannot diverge silently.  The forwarded path
# runs the same offsets and the same masked-window rewrite on the carried
# window value, so from identical initial bytes every byte evolves
# identically to a ring.
# --------------------------------------------------------------------------- #
def _window_slice(store: _ChannelStore, wins: Tuple[jax.Array, ...],
                  fi: int, off: jax.Array, size: int) -> jax.Array:
    p = store.fwd_pos.get(fi)
    if p is not None:
        return jax.lax.dynamic_slice_in_dim(wins[p], off, size, axis=0)
    return store.rings[store.ring_pos[fi]][pl.ds(off, size)]


def _chan_peek(store: _ChannelStore, wins, curs, fi: int) -> jax.Array:
    """``FifoSpec.peek``: next single token, cursor untouched."""
    spec = store.specs[fi]
    off = spec._read_offset(_cur(curs, store.cursor_slot[fi], _RD))
    return _window_slice(store, wins, fi, off, 1)[0]


def _chan_read(store: _ChannelStore, wins, curs,
               fi: int) -> Tuple[jax.Array, Tuple[jax.Array, ...]]:
    """``FifoSpec.read``: unconditional window consume (control ports)."""
    spec = store.specs[fi]
    slot = store.cursor_slot[fi]
    off = spec._read_offset(_cur(curs, slot, _RD))
    window = _window_slice(store, wins, fi, off, spec.rate)
    curs = _cur_advance(curs, slot, rd=1, occ=-spec.rate)
    return window, curs


def _chan_read_masked(store: _ChannelStore, wins, curs, fi: int,
                      enabled: jax.Array
                      ) -> Tuple[jax.Array, Tuple[jax.Array, ...]]:
    """``FifoSpec.read_masked``: static-shaped window, masked cursor
    advance; disabled reads return the current (stale) slots exactly as
    the functional API does, so gated consumers see identical bytes."""
    spec = store.specs[fi]
    slot = store.cursor_slot[fi]
    off = spec._read_offset(_cur(curs, slot, _RD))
    window = _window_slice(store, wins, fi, off, spec.rate)
    e = enabled.astype(jnp.int32)
    curs = _cur_advance(curs, slot, rd=e, occ=-e * spec.rate)
    return window, curs


def _chan_write_masked(store: _ChannelStore, wins, curs, fi: int,
                       tokens: jax.Array, enabled: jax.Array
                       ) -> Tuple[Tuple[jax.Array, ...],
                                  Tuple[jax.Array, ...]]:
    """``FifoSpec.write_masked``: the window slot is rewritten
    unconditionally with either the new tokens or its current content
    (no cond identity arm), and delay channels fold the Fig. 2 copy-back
    into a predicated single-token rewrite of slot 0 (forwarded channels
    are delay-free by construction, so only the ring path carries it)."""
    spec = store.specs[fi]
    slot = store.cursor_slot[fi]
    e = enabled.astype(jnp.int32)
    off = spec._write_offset(_cur(curs, slot, _WR))
    p = store.fwd_pos.get(fi)
    if p is not None:
        cur = jax.lax.dynamic_slice_in_dim(wins[p], off, spec.rate, axis=0)
        eff = jnp.where(enabled, jnp.asarray(tokens, spec.dtype), cur)
        w = jax.lax.dynamic_update_slice_in_dim(wins[p], eff, off, axis=0)
        wins = wins[:p] + (w,) + wins[p + 1:]
    else:
        ring = store.rings[store.ring_pos[fi]]
        cur = ring[pl.ds(off, spec.rate)]
        eff = jnp.where(enabled, jnp.asarray(tokens, spec.dtype), cur)
        ring[pl.ds(off, spec.rate)] = eff
        if spec.delay:
            do_copy = jnp.logical_and(
                enabled, (_cur(curs, slot, _WR) % spec.n_write_phases) == 2)
            slot0 = jnp.where(do_copy, ring[3 * spec.rate], ring[0])
            ring[pl.ds(0, 1)] = slot0[None]
    curs = _cur_advance(curs, slot, wr=e, occ=e * spec.rate)
    return wins, curs


# --------------------------------------------------------------------------- #
# Guarded channel ops — the health layer's in-kernel fault flags.  Each
# wrapper snapshots the pre-op cursors, runs the UNCHANGED unguarded op,
# and ORs the fault bits into the loop-carried HealthState: guards observe
# channel traffic, they never alter it, so a guarded clean run's ring
# bytes / cursors / states stay bit-identical to the unguarded kernel.
# The `_chan_*` ops above keep their signatures (pinned against the queue
# oracle by tests/test_megakernel_ring.py).
# --------------------------------------------------------------------------- #
def _chan_read_guarded(store: _ChannelStore, wins, curs, fi: int,
                       hlth: HealthState):
    spec = store.specs[fi]
    slot = store.cursor_slot[fi]
    rd, wr, occ = (_cur(curs, slot, _RD), _cur(curs, slot, _WR),
                   _cur(curs, slot, _OCC))
    window, curs = _chan_read(store, wins, curs, fi)
    bits = read_guard_bits(spec, rd, wr, occ, jnp.bool_(True), window)
    return window, curs, hlth.record(fi, bits)


def _chan_read_masked_guarded(store: _ChannelStore, wins, curs, fi: int,
                              enabled: jax.Array, hlth: HealthState):
    spec = store.specs[fi]
    slot = store.cursor_slot[fi]
    rd, wr, occ = (_cur(curs, slot, _RD), _cur(curs, slot, _WR),
                   _cur(curs, slot, _OCC))
    window, curs = _chan_read_masked(store, wins, curs, fi, enabled)
    bits = read_guard_bits(spec, rd, wr, occ, enabled, window)
    return window, curs, hlth.record(fi, bits)


def _chan_write_masked_guarded(store: _ChannelStore, wins, curs, fi: int,
                               tokens: jax.Array, enabled: jax.Array,
                               hlth: HealthState):
    spec = store.specs[fi]
    slot = store.cursor_slot[fi]
    rd, wr, occ = (_cur(curs, slot, _RD), _cur(curs, slot, _WR),
                   _cur(curs, slot, _OCC))
    wins, curs = _chan_write_masked(store, wins, curs, fi, tokens, enabled)
    bits = write_guard_bits(spec, rd, wr, occ, enabled, tokens)
    e = enabled.astype(jnp.int32)
    occ_after = true_occupancy(spec, rd, wr) + e * spec.rate
    return wins, curs, hlth.record(fi, bits).mark_high_water(fi, occ_after)


# --------------------------------------------------------------------------- #
# Closure hoisting: actor fns -> (jaxpr-eval callable, captured arrays).
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class _HoistedFn:
    """One actor function with its closure arrays lifted out.

    ``call(args, const_values)`` evaluates the traced jaxpr with the
    hoisted arrays substituted back in as inputs; ``const_ids`` index into
    the layout-wide deduplicated const table.  When ``const_ids`` is empty
    the original Python callable is used directly (preserving trace-time
    constant folding on concrete rates, exactly like the host executors).
    """

    call: Callable
    const_ids: Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class _ActorFns:
    fire: _HoistedFn
    control: Optional[_HoistedFn]
    ready: Optional[_HoistedFn]


def _hoist_fn(fn: Callable, example_args: Tuple[Any, ...],
              register: Callable[[List[Any]], Tuple[int, ...]]) -> _HoistedFn:
    """Trace ``fn`` once against abstract example args; lift the jaxpr's
    captured concrete arrays into the shared const table."""
    closed, out_shape = jax.make_jaxpr(fn, return_shape=True)(*example_args)
    if not closed.consts:
        return _HoistedFn(call=lambda args, _consts: fn(*args),
                          const_ids=())
    in_tree = jax.tree.structure(example_args)
    out_tree = jax.tree.structure(out_shape)
    const_ids = register(list(closed.consts))
    jaxpr = closed.jaxpr

    def call(args: Tuple[Any, ...], const_values: List[jax.Array]) -> Any:
        flat, tree = jax.tree.flatten(args)
        if tree != in_tree:
            raise ValueError(
                f"megakernel hoisted call: argument structure {tree} does "
                f"not match the traced structure {in_tree}")
        outs = jax.core.eval_jaxpr(jaxpr, const_values, *flat)
        return jax.tree.unflatten(out_tree, outs)

    return _HoistedFn(call=call, const_ids=const_ids)


def _hoist_consts(network: Network, layout: MegakernelLayout
                  ) -> Tuple[Dict[str, _ActorFns], List[jax.Array]]:
    """Build per-actor hoisted fire/control/ready callables plus the
    deduplicated table of every array any actor closure captures."""
    example = jax.eval_shape(network.init_state)
    consts: List[jax.Array] = []
    seen: Dict[int, int] = {}
    # The dedup key is id(original); jnp.asarray may *copy* (numpy
    # consts), so the original must be kept alive for as long as `seen`
    # is consulted or a recycled id could alias a later actor's const to
    # the wrong operand.
    keepalive: List[Any] = []

    def register(arrs: List[Any]) -> Tuple[int, ...]:
        ids = []
        for arr in arrs:
            key = id(arr)
            if key not in seen:
                seen[key] = len(consts)
                consts.append(jnp.asarray(arr))
                keepalive.append(arr)
            ids.append(seen[key])
        return tuple(ids)

    fns: Dict[str, _ActorFns] = {}
    for row in layout.firing_table:
        a = network.actors[row.name]
        st_ex = example.actors[row.index]
        wins_ex = {
            pb.port: jax.ShapeDtypeStruct(
                (layout.fifo_specs[pb.fifo].rate,)
                + tuple(layout.fifo_specs[pb.fifo].token_shape),
                layout.fifo_specs[pb.fifo].dtype)
            for pb in row.inputs
        }
        control = None
        if row.control is not None:
            cspec = layout.fifo_specs[row.control]
            tok_ex = jax.ShapeDtypeStruct(tuple(cspec.token_shape),
                                          cspec.dtype)
            rate_keys = list(jax.eval_shape(a.control, tok_ex))
            missing = (set(a.in_ports) | set(a.out_ports)) - set(rate_keys)
            if missing:
                raise ValueError(
                    f"actor {row.name}: control() must set a rate for every "
                    f"regular port; missing {sorted(missing)}")
            control = _hoist_fn(a.control, (tok_ex,), register)
        else:
            rate_keys = list(a.in_ports) + list(a.out_ports)
        rates_ex = {k: jax.ShapeDtypeStruct((), jnp.int32)
                    for k in rate_keys}
        fire = _hoist_fn(a.fire, (st_ex, wins_ex, rates_ex), register)
        ready = (_hoist_fn(a.ready, (st_ex,), register)
                 if row.has_ready else None)
        fns[row.name] = _ActorFns(fire=fire, control=control, ready=ready)
    return fns, consts


# --------------------------------------------------------------------------- #
# In-kernel firing protocol — mirrors executor.fire_actor's masked path.
# --------------------------------------------------------------------------- #
def _rates_for(a, fns: _ActorFns, consts: List[jax.Array],
               ctrl_tok: Optional[jax.Array]) -> Dict[str, jax.Array]:
    """``ActorSpec.rates_for`` with the hoisted control function."""
    one = jnp.int32(1)
    if not a.is_dynamic:
        return {p: one for p in (*a.in_ports, *a.out_ports)}
    raw = fns.control.call(
        (ctrl_tok,), [consts[i] for i in fns.control.const_ids])
    return {k: jnp.asarray(v, jnp.int32) for k, v in raw.items()}


def _can_fire(network: Network, layout: MegakernelLayout, row: FiringRow,
              fns: _ActorFns, consts: List[jax.Array], store: _ChannelStore,
              wins: Tuple[jax.Array, ...], curs: Tuple[jax.Array, ...],
              actors: Tuple[Any, ...]) -> jax.Array:
    """Blocking predicate of paper §2.2 on channel-store occupancies —
    mirrors ``executor._can_fire`` (same and-tree order, control token
    peeked).  Occupancies of crossing channels come from the shared
    cursor block — the in-kernel semaphore poll."""
    a = network.actors[row.name]
    specs = layout.fifo_specs
    slot = store.cursor_slot
    ok = jnp.bool_(True)
    if row.has_ready:
        ok = jnp.logical_and(ok, fns.ready.call(
            (actors[row.index],), [consts[i] for i in fns.ready.const_ids]))
    if row.control is not None:
        ci = row.control
        ok = jnp.logical_and(ok, _cur(curs, slot[ci], _OCC) >= 1)  # can_peek
        rates = _rates_for(a, fns, consts, _chan_peek(store, wins, curs, ci))
    else:
        rates = _rates_for(a, fns, consts, None)
    for pb in row.inputs:
        spec = specs[pb.fifo]
        have = _cur(curs, slot[pb.fifo], _OCC) >= spec.rate
        ok = jnp.logical_and(ok, jnp.logical_or(rates[pb.port] == 0, have))
    for pb in row.outputs:
        spec = specs[pb.fifo]
        room = (_cur(curs, slot[pb.fifo], _OCC) + spec.rate
                <= spec.writable_occupancy_bound)
        ok = jnp.logical_and(ok, jnp.logical_or(rates[pb.port] == 0, room))
    return ok


def _max_fireable(layout: MegakernelLayout, row: FiringRow,
                  store: _ChannelStore,
                  curs: Tuple[jax.Array, ...]) -> jax.Array:
    """Occupancy-derived multi-firing bound — mirrors
    ``executor._max_fireable`` (PRUNE-style decidable bound)."""
    slot = store.cursor_slot
    if row.control is not None:
        return jnp.minimum(jnp.int32(_MAX_FIRINGS_PER_VISIT),
                           _cur(curs, slot[row.control], _OCC))
    specs = layout.fifo_specs
    k = jnp.int32(_MAX_FIRINGS_PER_VISIT)
    for pb in row.inputs:
        k = jnp.minimum(k, _cur(curs, slot[pb.fifo], _OCC)
                        // specs[pb.fifo].rate)
    for pb in row.outputs:
        spec = specs[pb.fifo]
        room = spec.writable_occupancy_bound - _cur(curs, slot[pb.fifo], _OCC)
        k = jnp.minimum(k, room // spec.rate)
    return k


def _fire(network: Network, layout: MegakernelLayout, row: FiringRow,
          fns: _ActorFns, consts: List[jax.Array], store: _ChannelStore,
          wins: Tuple[jax.Array, ...], curs: Tuple[jax.Array, ...],
          actors: Tuple[Any, ...], hlth: Optional[HealthState] = None
          ) -> Tuple[Tuple[jax.Array, ...], Tuple[jax.Array, ...],
                     Tuple[Any, ...], Optional[HealthState]]:
    """One firing against the channel store — mirrors
    ``executor.fire_actor``'s masked (phase=None) path step for step:
    control consume, rates, masked input reads, predicated body, masked
    output writes.  With ``hlth`` (guards on) every channel op routes
    through its ``_guarded`` wrapper, accumulating fault bits and
    high-water marks; ``hlth=None`` traces the exact pre-health ops."""
    a = network.actors[row.name]

    ctrl_tok = None
    if row.control is not None:
        if hlth is None:
            ctok, curs = _chan_read(store, wins, curs, row.control)
        else:
            ctok, curs, hlth = _chan_read_guarded(store, wins, curs,
                                                  row.control, hlth)
        ctrl_tok = ctok[0]
    rates = _rates_for(a, fns, consts, ctrl_tok)

    windows: Dict[str, jax.Array] = {}
    for pb in row.inputs:
        if hlth is None:
            windows[pb.port], curs = _chan_read_masked(
                store, wins, curs, pb.fifo, rates[pb.port] > 0)
        else:
            windows[pb.port], curs, hlth = _chan_read_masked_guarded(
                store, wins, curs, pb.fifo, rates[pb.port] > 0, hlth)

    enabled_list = [rates[p] for p in (*a.in_ports, *a.out_ports)]
    concrete_on = any(_is_concrete(e) and int(e) > 0 for e in enabled_list)
    if enabled_list:
        any_enabled = functools.reduce(
            jnp.logical_or, [e > 0 for e in enabled_list])
    else:
        any_enabled = jnp.bool_(True)

    out_specs = {pb.port: layout.fifo_specs[pb.fifo] for pb in row.outputs}

    def run_body(operand):
        st, wins = operand
        new_st, outs = fns.fire.call(
            (st, wins, rates), [consts[i] for i in fns.fire.const_ids])
        missing = set(a.out_ports) - set(outs)
        if missing:
            raise ValueError(
                f"actor {row.name}: fire() missing outputs {sorted(missing)}")
        outs = {
            p: jnp.asarray(outs[p], out_specs[p].dtype).reshape(
                (out_specs[p].rate,) + tuple(out_specs[p].token_shape))
            for p in a.out_ports
        }
        return new_st, outs

    def skip_body(operand):
        st, _ = operand
        zeros = {
            p: jnp.zeros((s.rate,) + tuple(s.token_shape), s.dtype)
            for p, s in out_specs.items()
        }
        return st, zeros

    if a.is_dynamic and not concrete_on:
        new_actor_state, outputs = jax.lax.cond(
            any_enabled, run_body, skip_body, (actors[row.index], windows))
    else:
        new_actor_state, outputs = run_body((actors[row.index], windows))

    for pb in row.outputs:
        if hlth is None:
            wins, curs = _chan_write_masked(
                store, wins, curs, pb.fifo, outputs[pb.port],
                rates[pb.port] > 0)
        else:
            wins, curs, hlth = _chan_write_masked_guarded(
                store, wins, curs, pb.fifo, outputs[pb.port],
                rates[pb.port] > 0, hlth)

    actors = actors[:row.index] + (new_actor_state,) + actors[row.index + 1:]
    return wins, curs, actors, hlth


# --------------------------------------------------------------------------- #
# Kernel body construction.
# --------------------------------------------------------------------------- #
def _build_kernel(network: Network, layout: MegakernelLayout,
                  fns: Dict[str, _ActorFns],
                  actor_treedef, scalar_leaf: List[bool],
                  scalar_const: List[bool],
                  multi_firing: bool, max_sweeps: int,
                  partition: GridPartition,
                  fwd_list: Tuple[int, ...],
                  buffered: Tuple[int, ...],
                  guards: bool = False,
                  trace_capacity: Optional[int] = None) -> Callable:
    n_fifos = len(layout.fifo_specs)
    n_actors = len(network.actors)
    n_leaves = len(scalar_leaf)
    n_consts = len(scalar_const)
    # Channel storage split: forwarded channels (loop-carried windows,
    # no HBM input / no scratch) vs buffered ones (staged scratch rings).
    # `fwd_list`/`buffered` come from compile_megakernel — the SAME
    # tuples that ordered the pallas_call's input operands and scratch
    # shapes, so ring_pos indexing cannot drift from the operand order.
    fwd_pos = {fi: p for p, fi in enumerate(fwd_list)}
    ring_pos = {fi: p for p, fi in enumerate(buffered)}
    # Per-core cursor blocks + the shared semaphore block; `cursor_order`
    # flattens the blocks, `inv_order` scatters them back into the packed
    # (n_fifos, 3) HBM layout at exit.
    cursor_rows = partition.cursor_rows
    cursor_slot = [None] * n_fifos
    for b, rows in enumerate(cursor_rows):
        for r, fi in enumerate(rows):
            cursor_slot[fi] = (b, r)
    cursor_slot = tuple(cursor_slot)

    def kernel(*refs):
        n_bufs = len(buffered)
        buf_in = refs[:n_bufs]
        cur_in = refs[n_bufs]
        leaf_in = refs[n_bufs + 1:n_bufs + 1 + n_leaves]
        const_in = refs[n_bufs + 1 + n_leaves:
                        n_bufs + 1 + n_leaves + n_consts]
        o = n_bufs + 1 + n_leaves + n_consts
        buf_out = refs[o:o + n_fifos]
        cur_out = refs[o + n_fifos]
        leaf_out = refs[o + n_fifos + 1:o + n_fifos + 1 + n_leaves]
        counts_ref = refs[o + n_fifos + 1 + n_leaves]
        sweeps_ref = refs[o + n_fifos + 2 + n_leaves]
        flags_ref = refs[o + n_fifos + 3 + n_leaves]
        extra = 4
        if guards:
            fault_ref = refs[o + n_fifos + extra + n_leaves]
            hw_ref = refs[o + n_fifos + extra + 1 + n_leaves]
            extra += 2
        if trace_capacity:
            # The device-side trace ring + its monotonic event counter —
            # extra output refs exactly like the fault refs above: absent
            # (no ref, no HLO) when tracing is off.
            trace_ref = refs[o + n_fifos + extra + n_leaves]
            tcount_ref = refs[o + n_fifos + extra + 1 + n_leaves]
            extra += 2
        rings = refs[o + n_fifos + extra + n_leaves:]
        assert len(rings) == n_bufs

        # 1. Stage the buffered Eq. 1 rings into device scratch; read the
        #    packed cursor block and split it into the per-core blocks +
        #    the shared semaphore block; actor states and hoisted closure
        #    arrays become loop-carried / trace-bound values.  Forwarded
        #    channels start from init_state's zeros (the dead-slot
        #    carve-out): their HBM buffers are not kernel inputs at all.
        for p in range(n_bufs):
            rings[p][...] = buf_in[p][...]
        store = _ChannelStore(specs=layout.fifo_specs, rings=tuple(rings),
                              ring_pos=ring_pos, fwd_pos=fwd_pos,
                              cursor_slot=cursor_slot)
        # Static per-row stacking (NOT a fancy-index gather: a constant
        # index array would become a captured jaxpr const, which
        # pallas_call rejects — the same constraint _hoist_consts works
        # around for actor closures).
        cursors_packed = cur_in[...]
        curs0 = tuple(
            jnp.stack([cursors_packed[fi] for fi in rows]) if rows
            else jnp.zeros((0, CURSOR_FIELDS), jnp.int32)
            for rows in cursor_rows)
        wins0 = tuple(
            jnp.zeros((layout.fifo_specs[fi].capacity_tokens,)
                      + tuple(layout.fifo_specs[fi].token_shape),
                      layout.fifo_specs[fi].dtype)
            for fi in fwd_list)
        leaves0 = [leaf_in[j][...].reshape(()) if scalar_leaf[j]
                   else leaf_in[j][...] for j in range(n_leaves)]
        actors0 = tuple(jax.tree.unflatten(actor_treedef, leaves0))
        consts = [const_in[j][...].reshape(()) if scalar_const[j]
                  else const_in[j][...] for j in range(n_consts)]
        if trace_capacity:
            # Output refs start undefined: zero the ring so undropped
            # slots decode deterministically even on short runs.
            trace_ref[...] = jnp.zeros((trace_capacity, 3 + n_fifos),
                                       jnp.int32)

        # 2. Device-resident sweep loop (mirrors executor._compile_dynamic:
        #    same visit order, same per-visit multi-firing bound, same
        #    quiescence condition, same sweep accounting).
        def attempt(row, wins, curs, actors, counts, hlth, tcnt, sweeps):
            ready = _can_fire(network, layout, row, fns[row.name], consts,
                              store, wins, curs, actors)

            def do(c):
                wins, curs, actors, counts, hlth = c
                wins, curs, actors, hlth = _fire(network, layout, row,
                                                 fns[row.name], consts,
                                                 store, wins, curs, actors,
                                                 hlth)
                return wins, curs, actors, counts.at[row.index].add(1), hlth

            wins, curs, actors, counts, hlth = jax.lax.cond(
                ready, do, lambda c: c, (wins, curs, actors, counts, hlth))
            if tcnt is not None:
                # One event per attempt with post-attempt occupancies —
                # written straight into the trace output ref (only the
                # scalar event counter rides the loop carry).  Static
                # per-row stacking, same constraint as the cursor blocks.
                occs = jnp.stack([_cur(curs, cursor_slot[i], _OCC)
                                  for i in range(n_fifos)])
                ev = jnp.concatenate([
                    jnp.stack([jnp.int32(row.index),
                               jnp.asarray(sweeps, jnp.int32),
                               ready.astype(jnp.int32)]),
                    occs])
                trace_ref[pl.ds(tcnt % trace_capacity, 1)] = ev[None]
                tcnt = tcnt + 1
            return wins, curs, actors, counts, hlth, tcnt, ready

        # The grid-parallel sweep (paper §3.3 actor-to-core mapping): each
        # core runs its own occupancy-bounded firing loop over its
        # partition slice of the firing table.  A core's private channels
        # keep their cursor rows in that core's own block; only crossing
        # channels sit in the shared block, so a cross-partition
        # `_can_fire` polls the remote ring's monotonic rd/wr counters
        # there — the in-kernel semaphore analogue of
        # `heterogeneous_split`'s boundary actors, now isolated to
        # exactly `GridPartition.semaphore_bytes()` of state.  The core
        # loop is traced in fixed partition order (the interpret-mode /
        # sequential-grid tie-break, which makes the schedule — and thus
        # every ring byte — deterministic by construction); a genuinely
        # parallel grid mapping only changes the interleaving, which Kahn
        # determinism keeps invisible in the final state.  Quiescence is
        # global: the sweep ends when ALL partitions report no progress.
        def sweep(carry):
            wins, curs, actors, counts, hlth, tcnt, _, sweeps = carry
            core_progress = []
            for rows_ix in partition.core_rows:
                core_fired = jnp.bool_(False)
                for ri in rows_ix:
                    row = layout.firing_table[ri]
                    if multi_firing:
                        k = _max_fireable(layout, row, store, curs)

                        def body(_, c, row=row):
                            wins, curs, actors, counts, hlth, tcnt, \
                                fired = c
                            wins, curs, actors, counts, hlth, tcnt, \
                                ready = attempt(row, wins, curs, actors,
                                                counts, hlth, tcnt, sweeps)
                            return (wins, curs, actors, counts, hlth,
                                    tcnt, jnp.logical_or(fired, ready))

                        wins, curs, actors, counts, hlth, tcnt, fired = \
                            jax.lax.fori_loop(
                                0, k, body,
                                (wins, curs, actors, counts, hlth, tcnt,
                                 jnp.bool_(False)))
                    else:
                        wins, curs, actors, counts, hlth, tcnt, fired = \
                            attempt(row, wins, curs, actors, counts, hlth,
                                    tcnt, sweeps)
                    core_fired = jnp.logical_or(core_fired, fired)
                core_progress.append(core_fired)
            fired_any = functools.reduce(jnp.logical_or, core_progress,
                                         jnp.bool_(False))
            return (wins, curs, actors, counts, hlth, tcnt, fired_any,
                    sweeps + 1)

        def cond(carry):
            _, _, _, _, _, _, fired_any, sweeps = carry
            return jnp.logical_and(fired_any, sweeps < max_sweeps)

        hlth0 = init_health(n_fifos) if guards else None
        tcnt0 = jnp.int32(0) if trace_capacity else None
        carry = (wins0, curs0, actors0,
                 jnp.zeros((n_actors,), jnp.int32), hlth0, tcnt0,
                 jnp.bool_(True), jnp.int32(0))
        wins, curs, actors, counts, hlth, tcnt, fired_any, sweeps = \
            jax.lax.while_loop(cond, sweep, carry)

        # 3. Copy the buffered rings back out of scratch and the carried
        #    windows of forwarded channels into their buffer outputs;
        #    repack the split cursor blocks; emit actor states, fire
        #    counts and the sweep count.
        for i in range(n_fifos):
            p = fwd_pos.get(i)
            if p is not None:
                buf_out[i][...] = wins[p]
            else:
                buf_out[i][...] = rings[ring_pos[i]][...]
        packed_rows = [None] * n_fifos
        for b, rows in enumerate(cursor_rows):
            for r, fi in enumerate(rows):
                packed_rows[fi] = curs[b][r]
        cur_out[...] = jnp.stack(packed_rows)
        leaves = jax.tree.leaves(actors)
        assert len(leaves) == n_leaves
        for j in range(n_leaves):
            leaf_out[j][...] = (leaves[j].reshape(1) if scalar_leaf[j]
                                else leaves[j])
        counts_ref[...] = counts
        sweeps_ref[0] = sweeps
        # Run-level STALL forensics feed: the loop left through the sweep
        # budget with work remaining (fired_any still set), not
        # quiescence.  Emitted unconditionally so even guards-off runs
        # can warn instead of silently returning partial state.
        stalled = jnp.logical_and(fired_any, sweeps >= max_sweeps)
        flags_ref[0] = stalled.astype(jnp.int32)
        if guards:
            fault_ref[...] = hlth.fault
            hw_ref[...] = hlth.high_water
        if trace_capacity:
            tcount_ref[0] = tcnt

    return kernel


# --------------------------------------------------------------------------- #
# Public entrypoint.
# --------------------------------------------------------------------------- #
class _MegaResult(tuple):
    """``(final_state, fire_counts, n_sweeps)`` — the megakernel runner's
    historical 3-tuple — extended with the health layer's host-visible
    record as attributes so existing ``s, c, sw = runner(state)`` unpacks
    keep working unchanged.

    ``stalled``  bool jax scalar: sweep loop exited via the ``max_sweeps``
                 budget with work remaining (always computed).
    ``health``   :class:`repro.core.health.HealthState` fault / high-water
                 vectors when compiled with ``guards=True``, else None.
    ``trace``    :class:`repro.core.trace.TraceState` device trace ring
                 when compiled with ``trace_capacity=N``, else None.
    """

    def __new__(cls, state, counts, sweeps, stalled, health, trace=None):
        self = tuple.__new__(cls, (state, counts, sweeps))
        self.stalled = stalled
        self.health = health
        self.trace = trace
        return self


def compile_megakernel(network: Network, max_sweeps: int = 1_000_000,
                       mode: RuntimeMode = RuntimeMode.PROPOSED,
                       multi_firing: bool = True,
                       interpret: Optional[bool] = None,
                       layout: Optional[MegakernelLayout] = None,
                       cores: int = 1,
                       assign: Optional[Dict[str, int]] = None,
                       partition: Optional[GridPartition] = None,
                       cut_objective: str = "crossing",
                       forward_transients: bool = True,
                       guards: bool = False,
                       trace_capacity: Optional[int] = None) -> Callable:
    """Compile the network into one persistent Pallas kernel.

    Returns ``runner(state) -> (final_state, fire_counts, n_sweeps)`` with
    the exact signature and bit-exact results of the token-driven dynamic
    executor (``executor._compile_dynamic(..., return_sweeps=True)``) —
    modulo the forwarded-channel dead-slot carve-out (module docstring).
    The result is a :class:`_MegaResult`: the same 3-tuple, plus
    ``.stalled`` (sweep-budget exit) and ``.health`` (the in-kernel fault
    flags and high-water marks when ``guards=True``, else None).
    ``guards=True`` arms the per-channel overflow / underflow / cursor /
    non-finite guards inside the kernel's sweep loop; guards observe the
    channel ops without changing them, so clean guarded runs stay
    bit-identical, and ``guards=False`` traces the exact pre-health
    kernel (the health slot is the empty pytree ``None``).

    ``trace_capacity=N`` threads a fixed-capacity device-side trace ring
    through the sweep loop — one ``[actor, sweep, fired, occ...]`` int32
    row per firing attempt, written to an extra output ref with only the
    scalar event counter loop-carried.  Same off-path contract as the
    fault refs: ``trace_capacity=None`` adds no refs and no carry slots,
    so the untraced kernel lowers to the identical HLO, and traced runs
    stay bit-identical in states / cursors / fire counts / sweeps on
    every path (single-core, grid, forwarded windows).  The decoded
    :class:`repro.core.trace.TraceState` rides the result as ``.trace``.

    ``interpret=None`` auto-selects Pallas interpret mode on non-TPU
    backends (the tier-1 CPU fallback); pass an explicit bool to force
    either path.  ``layout`` lets a caller that already lowered the
    network (``Program``) pass its :class:`MegakernelLayout` instead of
    lowering twice.

    ``cores`` > 1 partitions the firing table across grid partitions
    (:func:`partition_layout`; ``assign`` pins actors to cores, default
    is the contiguous ``cut_objective`` cut): each core sweeps only its
    slice and quiescence becomes global (no partition fired).  Final
    states, live ring bytes, cursors and fire counts stay bit-identical
    to the single-core kernel for every core count (Kahn determinism plus
    the fixed partition-order tie-break); the sweep count is the number
    of global rounds.  ``partition`` lets ``Program`` pass a prebuilt
    :class:`GridPartition` instead of partitioning twice (in which case
    ``cut_objective`` / ``forward_transients`` are already baked in).

    ``forward_transients=False`` disables the transient-forwarding pass:
    every channel keeps a scratch ring and the kernel is bit-identical
    to the dynamic executor with no carve-out at all (the pre-forwarding
    behaviour; also the escape hatch for resuming states whose transient
    channels are not drained).
    """
    assert_mode_allows(network, mode)
    if layout is None:
        layout = lower_network(network)
    if partition is None:
        partition = partition_layout(network, layout, cores, assign,
                                     objective=cut_objective,
                                     forward_transients=forward_transients)
    fns, const_arrays = _hoist_consts(network, layout)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n_fifos = len(layout.fifo_specs)
    n_actors = len(network.actors)
    actor_names = tuple(network.actors)
    fwd_list = tuple(partition.forwarded_fifos)
    fwd_set = frozenset(fwd_list)
    buffered = tuple(i for i in range(n_fifos) if i not in fwd_set)
    scalar_const = [c.ndim == 0 for c in const_arrays]
    kernel_consts = [c.reshape(1) if s else c
                     for c, s in zip(const_arrays, scalar_const)]

    def run(state):
        if not isinstance(state, NetworkState):
            state = network.state_from_dict(state)
        # Forwarded channels enter as loop-carried windows, not HBM
        # operands: only the buffered rings are kernel inputs.
        bufs = [state.fifos[i].buf for i in buffered]
        cursors = jnp.stack(
            [jnp.stack([jnp.asarray(f.rd, jnp.int32),
                        jnp.asarray(f.wr, jnp.int32),
                        jnp.asarray(f.occ, jnp.int32)])
             for f in state.fifos])
        leaves, treedef = jax.tree.flatten(tuple(state.actors))
        leaves = [jnp.asarray(leaf) for leaf in leaves]
        scalar_leaf = [leaf.ndim == 0 for leaf in leaves]
        kernel_leaves = [leaf.reshape(1) if s else leaf
                         for leaf, s in zip(leaves, scalar_leaf)]

        kernel = _build_kernel(network, layout, fns, treedef, scalar_leaf,
                               scalar_const, multi_firing, max_sweeps,
                               partition, fwd_list, buffered, guards,
                               trace_capacity)
        out_shape = (
            [jax.ShapeDtypeStruct(f.buf.shape, f.buf.dtype)
             for f in state.fifos]
            + [jax.ShapeDtypeStruct((n_fifos, 3), jnp.int32)]
            + [jax.ShapeDtypeStruct(l.shape, l.dtype) for l in kernel_leaves]
            + [jax.ShapeDtypeStruct((n_actors,), jnp.int32),
               jax.ShapeDtypeStruct((1,), jnp.int32),
               jax.ShapeDtypeStruct((1,), jnp.int32)]   # stall flag
        )
        if guards:
            out_shape += [jax.ShapeDtypeStruct((n_fifos,), jnp.int32),
                          jax.ShapeDtypeStruct((n_fifos,), jnp.int32)]
        if trace_capacity:
            out_shape += [jax.ShapeDtypeStruct(
                              (trace_capacity, 3 + n_fifos), jnp.int32),
                          jax.ShapeDtypeStruct((1,), jnp.int32)]
        scratch_shapes = [
            pltpu.VMEM(layout.scratch_shape(i), layout.fifo_specs[i].dtype)
            for i in buffered
        ]
        outs = pl.pallas_call(
            kernel,
            out_shape=out_shape,
            scratch_shapes=scratch_shapes,
            interpret=interpret,
        )(*bufs, cursors, *kernel_leaves, *kernel_consts)

        bufs_o = outs[:n_fifos]
        cur_o = outs[n_fifos]
        leaves_o = outs[n_fifos + 1:n_fifos + 1 + len(kernel_leaves)]
        base = n_fifos + 1 + len(kernel_leaves)
        counts_vec = outs[base]
        sweeps = outs[base + 1][0]
        stalled = outs[base + 2][0] != 0
        nxt = base + 3
        health = None
        if guards:
            health = HealthState(fault=outs[nxt], high_water=outs[nxt + 1])
            nxt += 2
        trace = (TraceState(ring=outs[nxt], count=outs[nxt + 1][0])
                 if trace_capacity else None)
        leaves_o = [l.reshape(()) if s else l
                    for l, s in zip(leaves_o, scalar_leaf)]
        actors = tuple(jax.tree.unflatten(treedef, leaves_o))
        fifos = tuple(
            FifoState(buf=bufs_o[i], rd=cur_o[i, _RD], wr=cur_o[i, _WR],
                      occ=cur_o[i, _OCC])
            for i in range(n_fifos))
        final = NetworkState(fifos=fifos, actors=actors,
                             fifo_names=state.fifo_names,
                             actor_names=state.actor_names)
        counts = {nm: counts_vec[i] for i, nm in enumerate(actor_names)}
        return final, counts, sweeps, stalled, health, trace

    jitted = jax.jit(run)

    def runner(state):
        if fwd_list:
            st = (state if isinstance(state, NetworkState)
                  else network.state_from_dict(state))
            # The static specializer's drained-entry rule, per run: a
            # forwarded channel's window starts from dead-slot zeros, so
            # live tokens entering on it would be dropped.  Checked only
            # when cursors are concrete (callers jitting the runner keep
            # the contract by construction of the states they thread).
            for fi in fwd_list:
                occ = st.fifos[fi].occ
                if _is_concrete(occ) and int(occ):
                    raise ValueError(
                        f"megakernel transient forwarding: fifo "
                        f"{layout.fifo_names[fi]!r} enters with occupancy "
                        f"{int(occ)}; forwarded channels must be drained "
                        "(start from Network.init_state, or compile with "
                        "ExecutionPlan(specialize=False) to keep every "
                        "ring in scratch)")
        return _MegaResult(*jitted(state))

    # Exposed for Program.stats: the hoisted closure arrays are kernel
    # operands living in HBM alongside the state pytree, and the grid
    # partition drives the per-core scratch/occupancy telemetry.
    runner.hoisted_const_bytes = int(sum(
        c.size * c.dtype.itemsize for c in const_arrays))
    runner.grid_partition = partition
    return runner
