"""The persistent Pallas megakernel: device-resident dynamic scheduling.

One ``pl.pallas_call`` executes the whole network to quiescence:

  * every Eq. 1 ring buffer is staged into a **scratch** allocation
    (``pltpu.VMEM`` shapes from :meth:`MegakernelLayout.scratch_shape`)
    at kernel entry and copied back to the HBM outputs at exit — between
    those two copies no channel traffic leaves the device's fast memory;
  * FIFO cursors (rd / wr / occ per channel) and actor states are
    **loop-carried values** of the in-kernel sweep ``lax.while_loop`` —
    the register-resident analogue of ``FifoState``'s scalars;
  * the sweep loop itself is the paper's §3.3 device-resident scheduler:
    each sweep visits every actor in declaration order, peeks its control
    token straight out of scratch, and predicates up to
    ``_max_fireable``-many firings on ring occupancy via ``lax.cond`` —
    the exact blocking semantics of the host-side token-driven executor,
    with no host round trip per dispatch decision.

**Closure hoisting.**  Actor functions close over arrays staged at graph
build time (DPD's reconfiguration schedule, the MoE layer weights).
``pallas_call`` requires every array a kernel touches to be an explicit
operand, so :func:`_hoist_consts` traces each actor's ``fire`` /
``control`` / ``ready`` once at compile time, lifts the captured arrays
out of the jaxpr, and the runner passes them as extra kernel inputs —
weights enter the megakernel the same way they would enter any other
accelerator kernel.

**Bit-identity contract.**  The ring helpers (``_ring_read_masked``,
``_ring_write_masked``, ``_ring_peek``) mirror ``FifoSpec.read_masked`` /
``write_masked`` / ``peek`` operation for operation — same offsets, same
masked-window rewrite (disabled writes rewrite the current bytes, no
``lax.cond`` identity arm), same predicated slot-0 delay copy-back — and
``_fire`` / ``_can_fire`` / ``_max_fireable`` mirror their
``repro.core.executor`` namesakes.  Final states, fire counts and sweep
counts are therefore bit-identical to ``compile_dynamic`` (pinned by
``tests/test_megakernel.py``; the ring helpers alone are pinned against
the queue oracle in ``tests/test_megakernel_ring.py``).

**Interpret fallback.**  ``interpret=None`` auto-selects Pallas interpret
mode off-TPU so tier-1 runs the kernel on CPU; the Mosaic (non-interpret)
TPU path is a ROADMAP open item — actor bodies may use ops Mosaic cannot
lower yet (MoE's top_k/scatter), so on TPU pass ``interpret=True`` to
fall back deliberately.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.executor import (_MAX_FIRINGS_PER_VISIT, RuntimeMode,
                                 _is_concrete, assert_mode_allows)
from repro.core.fifo import FifoSpec, FifoState
from repro.core.megakernel.lower import (FiringRow, GridPartition,
                                         MegakernelLayout, lower_network,
                                         partition_layout)
from repro.core.network import Network, NetworkState

# Cursor row layout inside the packed (n_fifos, 3) block.
_RD, _WR, _OCC = 0, 1, 2


# --------------------------------------------------------------------------- #
# Scratch ring-buffer ops — FifoSpec's masked API, re-expressed on a Pallas
# ref + a packed cursor row.  Each mirrors its fifo.py namesake bit for bit;
# the phase-offset arithmetic is *shared* with FifoSpec (_read_offset /
# _write_offset) so a future phase-scheme change cannot diverge silently.
# --------------------------------------------------------------------------- #
def _ring_peek(spec: FifoSpec, ring, cursors: jax.Array,
               fi: int) -> jax.Array:
    """``FifoSpec.peek``: next single token, cursor untouched."""
    off = spec._read_offset(cursors[fi, _RD])
    return ring[pl.ds(off, 1)][0]


def _ring_read(spec: FifoSpec, ring, cursors: jax.Array,
               fi: int) -> Tuple[jax.Array, jax.Array]:
    """``FifoSpec.read``: unconditional window consume (control ports)."""
    off = spec._read_offset(cursors[fi, _RD])
    window = ring[pl.ds(off, spec.rate)]
    cursors = (cursors.at[fi, _RD].add(1)
                      .at[fi, _OCC].add(-spec.rate))
    return window, cursors


def _ring_read_masked(spec: FifoSpec, ring, cursors: jax.Array, fi: int,
                      enabled: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """``FifoSpec.read_masked``: static-shaped window, masked cursor
    advance; disabled reads return the current (stale) slots exactly as
    the functional API does, so gated consumers see identical bytes."""
    off = spec._read_offset(cursors[fi, _RD])
    window = ring[pl.ds(off, spec.rate)]
    e = enabled.astype(jnp.int32)
    cursors = (cursors.at[fi, _RD].add(e)
                      .at[fi, _OCC].add(-e * spec.rate))
    return window, cursors


def _ring_write_masked(spec: FifoSpec, ring, cursors: jax.Array, fi: int,
                       tokens: jax.Array, enabled: jax.Array) -> jax.Array:
    """``FifoSpec.write_masked``: the window slot is rewritten
    unconditionally with either the new tokens or its current content
    (no cond identity arm), and delay channels fold the Fig. 2 copy-back
    into a predicated single-token rewrite of slot 0."""
    e = enabled.astype(jnp.int32)
    off = spec._write_offset(cursors[fi, _WR])
    cur = ring[pl.ds(off, spec.rate)]
    eff = jnp.where(enabled, jnp.asarray(tokens, spec.dtype), cur)
    ring[pl.ds(off, spec.rate)] = eff
    if spec.delay:
        do_copy = jnp.logical_and(
            enabled, (cursors[fi, _WR] % spec.n_write_phases) == 2)
        slot0 = jnp.where(do_copy, ring[3 * spec.rate], ring[0])
        ring[pl.ds(0, 1)] = slot0[None]
    return (cursors.at[fi, _WR].add(e)
                   .at[fi, _OCC].add(e * spec.rate))


# --------------------------------------------------------------------------- #
# Closure hoisting: actor fns -> (jaxpr-eval callable, captured arrays).
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class _HoistedFn:
    """One actor function with its closure arrays lifted out.

    ``call(args, const_values)`` evaluates the traced jaxpr with the
    hoisted arrays substituted back in as inputs; ``const_ids`` index into
    the layout-wide deduplicated const table.  When ``const_ids`` is empty
    the original Python callable is used directly (preserving trace-time
    constant folding on concrete rates, exactly like the host executors).
    """

    call: Callable
    const_ids: Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class _ActorFns:
    fire: _HoistedFn
    control: Optional[_HoistedFn]
    ready: Optional[_HoistedFn]


def _hoist_fn(fn: Callable, example_args: Tuple[Any, ...],
              register: Callable[[List[Any]], Tuple[int, ...]]) -> _HoistedFn:
    """Trace ``fn`` once against abstract example args; lift the jaxpr's
    captured concrete arrays into the shared const table."""
    closed, out_shape = jax.make_jaxpr(fn, return_shape=True)(*example_args)
    if not closed.consts:
        return _HoistedFn(call=lambda args, _consts: fn(*args),
                          const_ids=())
    in_tree = jax.tree.structure(example_args)
    out_tree = jax.tree.structure(out_shape)
    const_ids = register(list(closed.consts))
    jaxpr = closed.jaxpr

    def call(args: Tuple[Any, ...], const_values: List[jax.Array]) -> Any:
        flat, tree = jax.tree.flatten(args)
        if tree != in_tree:
            raise ValueError(
                f"megakernel hoisted call: argument structure {tree} does "
                f"not match the traced structure {in_tree}")
        outs = jax.core.eval_jaxpr(jaxpr, const_values, *flat)
        return jax.tree.unflatten(out_tree, outs)

    return _HoistedFn(call=call, const_ids=const_ids)


def _hoist_consts(network: Network, layout: MegakernelLayout
                  ) -> Tuple[Dict[str, _ActorFns], List[jax.Array]]:
    """Build per-actor hoisted fire/control/ready callables plus the
    deduplicated table of every array any actor closure captures."""
    example = jax.eval_shape(network.init_state)
    consts: List[jax.Array] = []
    seen: Dict[int, int] = {}
    # The dedup key is id(original); jnp.asarray may *copy* (numpy
    # consts), so the original must be kept alive for as long as `seen`
    # is consulted or a recycled id could alias a later actor's const to
    # the wrong operand.
    keepalive: List[Any] = []

    def register(arrs: List[Any]) -> Tuple[int, ...]:
        ids = []
        for arr in arrs:
            key = id(arr)
            if key not in seen:
                seen[key] = len(consts)
                consts.append(jnp.asarray(arr))
                keepalive.append(arr)
            ids.append(seen[key])
        return tuple(ids)

    fns: Dict[str, _ActorFns] = {}
    for row in layout.firing_table:
        a = network.actors[row.name]
        st_ex = example.actors[row.index]
        wins_ex = {
            pb.port: jax.ShapeDtypeStruct(
                (layout.fifo_specs[pb.fifo].rate,)
                + tuple(layout.fifo_specs[pb.fifo].token_shape),
                layout.fifo_specs[pb.fifo].dtype)
            for pb in row.inputs
        }
        control = None
        if row.control is not None:
            cspec = layout.fifo_specs[row.control]
            tok_ex = jax.ShapeDtypeStruct(tuple(cspec.token_shape),
                                          cspec.dtype)
            rate_keys = list(jax.eval_shape(a.control, tok_ex))
            missing = (set(a.in_ports) | set(a.out_ports)) - set(rate_keys)
            if missing:
                raise ValueError(
                    f"actor {row.name}: control() must set a rate for every "
                    f"regular port; missing {sorted(missing)}")
            control = _hoist_fn(a.control, (tok_ex,), register)
        else:
            rate_keys = list(a.in_ports) + list(a.out_ports)
        rates_ex = {k: jax.ShapeDtypeStruct((), jnp.int32)
                    for k in rate_keys}
        fire = _hoist_fn(a.fire, (st_ex, wins_ex, rates_ex), register)
        ready = (_hoist_fn(a.ready, (st_ex,), register)
                 if row.has_ready else None)
        fns[row.name] = _ActorFns(fire=fire, control=control, ready=ready)
    return fns, consts


# --------------------------------------------------------------------------- #
# In-kernel firing protocol — mirrors executor.fire_actor's masked path.
# --------------------------------------------------------------------------- #
def _rates_for(a, fns: _ActorFns, consts: List[jax.Array],
               ctrl_tok: Optional[jax.Array]) -> Dict[str, jax.Array]:
    """``ActorSpec.rates_for`` with the hoisted control function."""
    one = jnp.int32(1)
    if not a.is_dynamic:
        return {p: one for p in (*a.in_ports, *a.out_ports)}
    raw = fns.control.call(
        (ctrl_tok,), [consts[i] for i in fns.control.const_ids])
    return {k: jnp.asarray(v, jnp.int32) for k, v in raw.items()}


def _can_fire(network: Network, layout: MegakernelLayout, row: FiringRow,
              fns: _ActorFns, consts: List[jax.Array], rings,
              cursors: jax.Array, actors: Tuple[Any, ...]) -> jax.Array:
    """Blocking predicate of paper §2.2 on scratch occupancies — mirrors
    ``executor._can_fire`` (same and-tree order, control token peeked)."""
    a = network.actors[row.name]
    specs = layout.fifo_specs
    ok = jnp.bool_(True)
    if row.has_ready:
        ok = jnp.logical_and(ok, fns.ready.call(
            (actors[row.index],), [consts[i] for i in fns.ready.const_ids]))
    if row.control is not None:
        ci = row.control
        ok = jnp.logical_and(ok, cursors[ci, _OCC] >= 1)  # can_peek
        rates = _rates_for(a, fns, consts,
                           _ring_peek(specs[ci], rings[ci], cursors, ci))
    else:
        rates = _rates_for(a, fns, consts, None)
    for pb in row.inputs:
        spec = specs[pb.fifo]
        have = cursors[pb.fifo, _OCC] >= spec.rate
        ok = jnp.logical_and(ok, jnp.logical_or(rates[pb.port] == 0, have))
    for pb in row.outputs:
        spec = specs[pb.fifo]
        room = (cursors[pb.fifo, _OCC] + spec.rate
                <= spec.writable_occupancy_bound)
        ok = jnp.logical_and(ok, jnp.logical_or(rates[pb.port] == 0, room))
    return ok


def _max_fireable(layout: MegakernelLayout, row: FiringRow,
                  cursors: jax.Array) -> jax.Array:
    """Occupancy-derived multi-firing bound — mirrors
    ``executor._max_fireable`` (PRUNE-style decidable bound)."""
    if row.control is not None:
        return jnp.minimum(jnp.int32(_MAX_FIRINGS_PER_VISIT),
                           cursors[row.control, _OCC])
    specs = layout.fifo_specs
    k = jnp.int32(_MAX_FIRINGS_PER_VISIT)
    for pb in row.inputs:
        k = jnp.minimum(k, cursors[pb.fifo, _OCC] // specs[pb.fifo].rate)
    for pb in row.outputs:
        spec = specs[pb.fifo]
        room = spec.writable_occupancy_bound - cursors[pb.fifo, _OCC]
        k = jnp.minimum(k, room // spec.rate)
    return k


def _fire(network: Network, layout: MegakernelLayout, row: FiringRow,
          fns: _ActorFns, consts: List[jax.Array], rings,
          cursors: jax.Array,
          actors: Tuple[Any, ...]) -> Tuple[jax.Array, Tuple[Any, ...]]:
    """One firing against the scratch rings — mirrors
    ``executor.fire_actor``'s masked (phase=None) path step for step:
    control consume, rates, masked input reads, predicated body, masked
    output writes."""
    a = network.actors[row.name]
    specs = layout.fifo_specs

    ctrl_tok = None
    if row.control is not None:
        ci = row.control
        ctok, cursors = _ring_read(specs[ci], rings[ci], cursors, ci)
        ctrl_tok = ctok[0]
    rates = _rates_for(a, fns, consts, ctrl_tok)

    windows: Dict[str, jax.Array] = {}
    for pb in row.inputs:
        windows[pb.port], cursors = _ring_read_masked(
            specs[pb.fifo], rings[pb.fifo], cursors, pb.fifo,
            rates[pb.port] > 0)

    enabled_list = [rates[p] for p in (*a.in_ports, *a.out_ports)]
    concrete_on = any(_is_concrete(e) and int(e) > 0 for e in enabled_list)
    if enabled_list:
        any_enabled = functools.reduce(
            jnp.logical_or, [e > 0 for e in enabled_list])
    else:
        any_enabled = jnp.bool_(True)

    out_specs = {pb.port: specs[pb.fifo] for pb in row.outputs}

    def run_body(operand):
        st, wins = operand
        new_st, outs = fns.fire.call(
            (st, wins, rates), [consts[i] for i in fns.fire.const_ids])
        missing = set(a.out_ports) - set(outs)
        if missing:
            raise ValueError(
                f"actor {row.name}: fire() missing outputs {sorted(missing)}")
        outs = {
            p: jnp.asarray(outs[p], out_specs[p].dtype).reshape(
                (out_specs[p].rate,) + tuple(out_specs[p].token_shape))
            for p in a.out_ports
        }
        return new_st, outs

    def skip_body(operand):
        st, _ = operand
        zeros = {
            p: jnp.zeros((s.rate,) + tuple(s.token_shape), s.dtype)
            for p, s in out_specs.items()
        }
        return st, zeros

    if a.is_dynamic and not concrete_on:
        new_actor_state, outputs = jax.lax.cond(
            any_enabled, run_body, skip_body, (actors[row.index], windows))
    else:
        new_actor_state, outputs = run_body((actors[row.index], windows))

    for pb in row.outputs:
        cursors = _ring_write_masked(
            specs[pb.fifo], rings[pb.fifo], cursors, pb.fifo,
            outputs[pb.port], rates[pb.port] > 0)

    actors = actors[:row.index] + (new_actor_state,) + actors[row.index + 1:]
    return cursors, actors


# --------------------------------------------------------------------------- #
# Kernel body construction.
# --------------------------------------------------------------------------- #
def _build_kernel(network: Network, layout: MegakernelLayout,
                  fns: Dict[str, _ActorFns],
                  actor_treedef, scalar_leaf: List[bool],
                  scalar_const: List[bool],
                  multi_firing: bool, max_sweeps: int,
                  partition: GridPartition) -> Callable:
    n_fifos = len(layout.fifo_specs)
    n_actors = len(network.actors)
    n_leaves = len(scalar_leaf)
    n_consts = len(scalar_const)

    def kernel(*refs):
        buf_in = refs[:n_fifos]
        cur_in = refs[n_fifos]
        leaf_in = refs[n_fifos + 1:n_fifos + 1 + n_leaves]
        const_in = refs[n_fifos + 1 + n_leaves:
                        n_fifos + 1 + n_leaves + n_consts]
        o = n_fifos + 1 + n_leaves + n_consts
        buf_out = refs[o:o + n_fifos]
        cur_out = refs[o + n_fifos]
        leaf_out = refs[o + n_fifos + 1:o + n_fifos + 1 + n_leaves]
        counts_ref = refs[o + n_fifos + 1 + n_leaves]
        sweeps_ref = refs[o + n_fifos + 2 + n_leaves]
        rings = refs[o + n_fifos + 3 + n_leaves:]
        assert len(rings) == n_fifos

        # 1. Stage every Eq. 1 ring buffer into device scratch; read the
        #    cursor block, actor states and hoisted closure arrays into
        #    loop-carried / trace-bound values.
        for i in range(n_fifos):
            rings[i][...] = buf_in[i][...]
        cursors0 = cur_in[...]
        leaves0 = [leaf_in[j][...].reshape(()) if scalar_leaf[j]
                   else leaf_in[j][...] for j in range(n_leaves)]
        actors0 = tuple(jax.tree.unflatten(actor_treedef, leaves0))
        consts = [const_in[j][...].reshape(()) if scalar_const[j]
                  else const_in[j][...] for j in range(n_consts)]

        # 2. Device-resident sweep loop (mirrors executor._compile_dynamic:
        #    same visit order, same per-visit multi-firing bound, same
        #    quiescence condition, same sweep accounting).
        def attempt(row, cursors, actors, counts):
            ready = _can_fire(network, layout, row, fns[row.name], consts,
                              rings, cursors, actors)

            def do(c):
                cursors, actors, counts = c
                cursors, actors = _fire(network, layout, row, fns[row.name],
                                        consts, rings, cursors, actors)
                return cursors, actors, counts.at[row.index].add(1)

            cursors, actors, counts = jax.lax.cond(
                ready, do, lambda c: c, (cursors, actors, counts))
            return cursors, actors, counts, ready

        # The grid-parallel sweep (paper §3.3 actor-to-core mapping): each
        # core runs its own occupancy-bounded firing loop over its
        # partition slice of the firing table; `cursors` is the SHARED
        # cursor block, so a cross-partition `_can_fire` polls the remote
        # ring's monotonic rd/wr counters — the in-kernel semaphore
        # analogue of `heterogeneous_split`'s boundary actors.  The core
        # loop is traced in fixed partition order (the interpret-mode /
        # sequential-grid tie-break, which makes the schedule — and thus
        # every ring byte — deterministic by construction); a genuinely
        # parallel grid mapping only changes the interleaving, which Kahn
        # determinism keeps invisible in the final state.  Quiescence is
        # global: the sweep ends when ALL partitions report no progress.
        def sweep(carry):
            cursors, actors, counts, _, sweeps = carry
            core_progress = []
            for rows_ix in partition.core_rows:
                core_fired = jnp.bool_(False)
                for ri in rows_ix:
                    row = layout.firing_table[ri]
                    if multi_firing:
                        k = _max_fireable(layout, row, cursors)

                        def body(_, c, row=row):
                            cursors, actors, counts, fired = c
                            cursors, actors, counts, ready = attempt(
                                row, cursors, actors, counts)
                            return (cursors, actors, counts,
                                    jnp.logical_or(fired, ready))

                        cursors, actors, counts, fired = jax.lax.fori_loop(
                            0, k, body,
                            (cursors, actors, counts, jnp.bool_(False)))
                    else:
                        cursors, actors, counts, fired = attempt(
                            row, cursors, actors, counts)
                    core_fired = jnp.logical_or(core_fired, fired)
                core_progress.append(core_fired)
            fired_any = functools.reduce(jnp.logical_or, core_progress,
                                         jnp.bool_(False))
            return cursors, actors, counts, fired_any, sweeps + 1

        def cond(carry):
            _, _, _, fired_any, sweeps = carry
            return jnp.logical_and(fired_any, sweeps < max_sweeps)

        carry = (cursors0, actors0, jnp.zeros((n_actors,), jnp.int32),
                 jnp.bool_(True), jnp.int32(0))
        cursors, actors, counts, _, sweeps = jax.lax.while_loop(
            cond, sweep, carry)

        # 3. Copy the rings back out of scratch; emit cursors, actor
        #    states, fire counts and the sweep count.
        for i in range(n_fifos):
            buf_out[i][...] = rings[i][...]
        cur_out[...] = cursors
        leaves = jax.tree.leaves(actors)
        assert len(leaves) == n_leaves
        for j in range(n_leaves):
            leaf_out[j][...] = (leaves[j].reshape(1) if scalar_leaf[j]
                                else leaves[j])
        counts_ref[...] = counts
        sweeps_ref[0] = sweeps

    return kernel


# --------------------------------------------------------------------------- #
# Public entrypoint.
# --------------------------------------------------------------------------- #
def compile_megakernel(network: Network, max_sweeps: int = 1_000_000,
                       mode: RuntimeMode = RuntimeMode.PROPOSED,
                       multi_firing: bool = True,
                       interpret: Optional[bool] = None,
                       layout: Optional[MegakernelLayout] = None,
                       cores: int = 1,
                       assign: Optional[Dict[str, int]] = None,
                       partition: Optional[GridPartition] = None) -> Callable:
    """Compile the network into one persistent Pallas kernel.

    Returns ``runner(state) -> (final_state, fire_counts, n_sweeps)`` with
    the exact signature and bit-exact results of the token-driven dynamic
    executor (``executor._compile_dynamic(..., return_sweeps=True)``).

    ``interpret=None`` auto-selects Pallas interpret mode on non-TPU
    backends (the tier-1 CPU fallback); pass an explicit bool to force
    either path.  ``layout`` lets a caller that already lowered the
    network (``Program``) pass its :class:`MegakernelLayout` instead of
    lowering twice.

    ``cores`` > 1 partitions the firing table across grid partitions
    (:func:`partition_layout`; ``assign`` pins actors to cores, default
    is the load-balanced contiguous cut): each core sweeps only its
    slice and quiescence becomes global (no partition fired).  Final
    states, ring bytes, cursors and fire counts stay bit-identical to
    the single-core kernel for every core count (Kahn determinism plus
    the fixed partition-order tie-break); the sweep count is the number
    of global rounds.  ``partition`` lets ``Program`` pass a prebuilt
    :class:`GridPartition` instead of partitioning twice.
    """
    assert_mode_allows(network, mode)
    if layout is None:
        layout = lower_network(network)
    if partition is None:
        partition = partition_layout(network, layout, cores, assign)
    fns, const_arrays = _hoist_consts(network, layout)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n_fifos = len(layout.fifo_specs)
    n_actors = len(network.actors)
    actor_names = tuple(network.actors)
    scalar_const = [c.ndim == 0 for c in const_arrays]
    kernel_consts = [c.reshape(1) if s else c
                     for c, s in zip(const_arrays, scalar_const)]

    def run(state):
        if not isinstance(state, NetworkState):
            state = network.state_from_dict(state)
        bufs = [f.buf for f in state.fifos]
        cursors = jnp.stack(
            [jnp.stack([jnp.asarray(f.rd, jnp.int32),
                        jnp.asarray(f.wr, jnp.int32),
                        jnp.asarray(f.occ, jnp.int32)])
             for f in state.fifos])
        leaves, treedef = jax.tree.flatten(tuple(state.actors))
        leaves = [jnp.asarray(leaf) for leaf in leaves]
        scalar_leaf = [leaf.ndim == 0 for leaf in leaves]
        kernel_leaves = [leaf.reshape(1) if s else leaf
                         for leaf, s in zip(leaves, scalar_leaf)]

        kernel = _build_kernel(network, layout, fns, treedef, scalar_leaf,
                               scalar_const, multi_firing, max_sweeps,
                               partition)
        out_shape = (
            [jax.ShapeDtypeStruct(b.shape, b.dtype) for b in bufs]
            + [jax.ShapeDtypeStruct((n_fifos, 3), jnp.int32)]
            + [jax.ShapeDtypeStruct(l.shape, l.dtype) for l in kernel_leaves]
            + [jax.ShapeDtypeStruct((n_actors,), jnp.int32),
               jax.ShapeDtypeStruct((1,), jnp.int32)]
        )
        scratch_shapes = [
            pltpu.VMEM(layout.scratch_shape(i), layout.fifo_specs[i].dtype)
            for i in range(n_fifos)
        ]
        outs = pl.pallas_call(
            kernel,
            out_shape=out_shape,
            scratch_shapes=scratch_shapes,
            interpret=interpret,
        )(*bufs, cursors, *kernel_leaves, *kernel_consts)

        bufs_o = outs[:n_fifos]
        cur_o = outs[n_fifos]
        leaves_o = outs[n_fifos + 1:n_fifos + 1 + len(kernel_leaves)]
        counts_vec = outs[-2]
        sweeps = outs[-1][0]
        leaves_o = [l.reshape(()) if s else l
                    for l, s in zip(leaves_o, scalar_leaf)]
        actors = tuple(jax.tree.unflatten(treedef, leaves_o))
        fifos = tuple(
            FifoState(buf=bufs_o[i], rd=cur_o[i, _RD], wr=cur_o[i, _WR],
                      occ=cur_o[i, _OCC])
            for i in range(n_fifos))
        final = NetworkState(fifos=fifos, actors=actors,
                             fifo_names=state.fifo_names,
                             actor_names=state.actor_names)
        counts = {nm: counts_vec[i] for i, nm in enumerate(actor_names)}
        return final, counts, sweeps

    jitted = jax.jit(run)

    def runner(state):
        return jitted(state)

    # Exposed for Program.stats: the hoisted closure arrays are kernel
    # operands living in HBM alongside the state pytree, and the grid
    # partition drives the per-core scratch/occupancy telemetry.
    runner.hoisted_const_bytes = int(sum(
        c.size * c.dtype.itemsize for c in const_arrays))
    runner.grid_partition = partition
    return runner
