"""repro.core.megakernel — device-resident dynamic actor scheduling.

The third real execution backend (``ExecutionPlan(mode=Mode.MEGAKERNEL)``):
the whole accelerated subnetwork lowers into a single persistent Pallas
kernel whose buffered Eq. 1 rings live in scratch memory — transient
channels forward as loop-carried token windows instead — and whose
token-driven sweep loop runs on the device (paper §3.3), with cursors
split into per-core blocks plus a shared semaphore block under grid
partitioning.  See ``lower.py`` for the build-time layout / firing-table
/ partition-cut pass and ``kernel.py`` for the kernel itself.
"""
from repro.core.megakernel.kernel import compile_megakernel
from repro.core.megakernel.lower import (CUT_OBJECTIVES, SHARED, FiringRow,
                                         GridPartition, MegakernelLayout,
                                         PortBinding, default_assignment,
                                         entry_staging_bytes, lower_network,
                                         partition_layout, state_hbm_bytes)

__all__ = [
    "CUT_OBJECTIVES", "SHARED", "FiringRow", "GridPartition",
    "MegakernelLayout", "PortBinding", "compile_megakernel",
    "default_assignment", "entry_staging_bytes", "lower_network",
    "partition_layout", "state_hbm_bytes",
]
