"""repro.core.megakernel — device-resident dynamic actor scheduling.

The third real execution backend (``ExecutionPlan(mode=Mode.MEGAKERNEL)``):
the whole accelerated subnetwork lowers into a single persistent Pallas
kernel whose Eq. 1 ring buffers live in scratch memory and whose
token-driven sweep loop runs on the device (paper §3.3).  See
``lower.py`` for the build-time layout/firing-table pass and ``kernel.py``
for the kernel itself.
"""
from repro.core.megakernel.kernel import compile_megakernel
from repro.core.megakernel.lower import (SHARED, FiringRow, GridPartition,
                                         MegakernelLayout, PortBinding,
                                         default_assignment, lower_network,
                                         partition_layout, state_hbm_bytes)

__all__ = [
    "SHARED", "FiringRow", "GridPartition", "MegakernelLayout",
    "PortBinding", "compile_megakernel", "default_assignment",
    "lower_network", "partition_layout", "state_hbm_bytes",
]
