"""Lowering pass: Network -> megakernel scratch layout + firing table.

The megakernel backend runs a whole accelerated subnetwork as ONE
persistent Pallas kernel (paper §3.3 made literal): every Eq. 1 FIFO ring
buffer lives in device scratch memory for the kernel's entire lifetime,
and the token-driven sweep loop — the part the paper keeps resident on the
device instead of round-tripping dispatch decisions through the host —
runs *inside* the kernel.  This module is the build-time half: it flattens
the validated :class:`~repro.core.network.Network` into the static tables
the kernel body is traced from.

Outputs of :func:`lower_network`:

  * **scratch layout** — one ring-buffer scratch allocation per channel,
    shaped ``(capacity_tokens, *token_shape)`` straight from the Eq. 1
    capacity law (``FifoSpec.capacity_tokens``), plus one packed
    ``(n_fifos, 3)`` int32 cursor block (rd / wr / occ per channel, the
    kernel's register-resident analogue of ``FifoState``'s scalars);
  * **firing table** — one :class:`FiringRow` per actor in network
    declaration order (the same visit order as the token-driven host
    scheduler, so sweep counts and final states match bit for bit), each
    row resolving the actor's control / input / output ports to flat
    channel indices at build time so the traced kernel never touches a
    name-keyed dict;
  * reused analyses — ``Network.register_fifos`` (channels the static
    specializer proves transient; the megakernel keeps them ring-buffered
    for bit-identity with the dynamic executor but reports them as the
    candidates a future in-kernel forwarding pass would keep VMEM-only)
    and :func:`~repro.core.schedule.phase_unroll_period` (the unroll
    period a static in-kernel prologue would use; recorded for the stats
    table and the ROADMAP follow-on, not yet acted on).
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Mapping, Optional, Tuple

import jax
import numpy as np

from repro.core.fifo import FifoSpec
from repro.core.network import Network
from repro.core.schedule import phase_unroll_period

# One packed cursor row per channel: (rd, wr, occ) int32.
CURSOR_FIELDS = 3
_CURSOR_ITEMSIZE = 4

#: ``GridPartition.fifo_cores`` value for a partition-crossing channel:
#: its ring lives in the shared block and its cursor row acts as the
#: cross-core semaphore (monotonic rd/wr counters polled in-kernel).
SHARED = -1


@dataclasses.dataclass(frozen=True)
class PortBinding:
    """One regular port resolved to its flat channel index."""

    port: str
    fifo: int


@dataclasses.dataclass(frozen=True)
class FiringRow:
    """One actor's row in the firing table.

    ``control`` is the flat index of the control channel (None for static
    actors); ``inputs`` / ``outputs`` are the regular ports in declaration
    order — the same order ``fire_actor`` consumes them, which the kernel
    must preserve for bit-identical cursor arithmetic.
    """

    name: str
    index: int
    control: Optional[int]
    inputs: Tuple[PortBinding, ...]
    outputs: Tuple[PortBinding, ...]
    is_dynamic: bool
    has_ready: bool


@dataclasses.dataclass(frozen=True)
class MegakernelLayout:
    """Static layout of one lowered network (everything the kernel trace
    needs, nothing resolved per sweep)."""

    fifo_names: Tuple[str, ...]
    fifo_specs: Tuple[FifoSpec, ...]
    firing_table: Tuple[FiringRow, ...]
    # Channels the specialized static executor would register-allocate
    # (Network.register_fifos).  Kept ring-buffered here for bit-identity
    # with compile_dynamic; reported so stats can show how much of the
    # scratch footprint a forwarding pass would reclaim.
    transient_fifos: frozenset
    # phase_unroll_period over the buffered channels — the unroll a static
    # in-kernel prologue would use (ROADMAP follow-on; diagnostic today).
    unroll_period: int

    # -- scratch accounting (the paper's Table 1, device-side) ---------- #
    @property
    def ring_scratch_bytes(self) -> int:
        """Eq. 1 capacities summed — bytes of ring buffer held in scratch."""
        return sum(s.capacity_bytes for s in self.fifo_specs)

    @property
    def cursor_bytes(self) -> int:
        return len(self.fifo_specs) * CURSOR_FIELDS * _CURSOR_ITEMSIZE

    @property
    def scratch_bytes(self) -> int:
        return self.ring_scratch_bytes + self.cursor_bytes

    @property
    def transient_scratch_bytes(self) -> int:
        """Scratch bytes a forwarding pass over transient channels would
        reclaim (they would become traced values, not buffers)."""
        return sum(s.capacity_bytes for s in self.fifo_specs
                   if s.name in self.transient_fifos)

    def scratch_shape(self, fifo_index: int) -> Tuple[int, ...]:
        """Ring scratch shape of one channel: Eq. 1 capacity x token."""
        spec = self.fifo_specs[fifo_index]
        return (spec.capacity_tokens,) + tuple(spec.token_shape)


def lower_network(network: Network) -> MegakernelLayout:
    """Flatten a validated network into the megakernel's static tables.

    Pure build-time work: reuses the port->spec tables the network
    precomputes (``in_port_specs`` / ``out_port_specs`` /
    ``control_specs``) and the ``register_fifos`` / phase-cycle analyses,
    so lowering adds no per-run cost and no new validation rules — any
    network the dynamic executor accepts lowers.
    """
    fifo_names = tuple(network.fifos)
    fifo_specs = tuple(network.fifos[n] for n in fifo_names)
    rows = []
    for index, (name, actor) in enumerate(network.actors.items()):
        ctl = network.control_specs[name]
        rows.append(FiringRow(
            name=name,
            index=index,
            control=None if ctl is None else ctl[1],
            inputs=tuple(PortBinding(p, fi)
                         for p, _, fi in network.in_port_specs[name]),
            outputs=tuple(PortBinding(p, fi)
                          for p, _, fi in network.out_port_specs[name]),
            is_dynamic=actor.is_dynamic,
            has_ready=actor.ready is not None,
        ))
    period = phase_unroll_period(
        [spec.n_write_phases for name, spec in network.fifos.items()
         if name not in network.register_fifos])
    return MegakernelLayout(
        fifo_names=fifo_names,
        fifo_specs=fifo_specs,
        firing_table=tuple(rows),
        transient_fifos=frozenset(network.register_fifos),
        unroll_period=period,
    )


# --------------------------------------------------------------------------- #
# Grid partitioning: actors -> cores (paper §3.3 actor-to-core mapping).
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class GridPartition:
    """Actor-to-core mapping of one lowered network (paper §3.3).

    ``assignment[i]`` is the core owning actor ``i`` (firing-table
    index); ``core_rows[c]`` are core ``c``'s firing-table indices in
    visit order — each core's occupancy-bounded firing loop iterates
    exactly that slice.  ``fifo_cores[f]`` is the core whose *private*
    scratch block holds channel ``f``'s ring (both endpoints on that
    core), or :data:`SHARED` for a partition-crossing channel: its ring
    lives in the shared block and its packed cursor row (monotonic
    rd / wr / occ counters) doubles as the cross-core semaphore the
    remote ``_can_fire`` polls — the device-resident analogue of
    ``heterogeneous_split``'s boundary feed/fetch actors.

    Built by :func:`partition_layout`; the default assignment is a
    load-balanced contiguous cut of the dynamic visit order with the
    endpoints of window-uncovered delay channels glued together
    (``Network.delay_partition_constraints``).
    """

    n_cores: int
    assignment: Tuple[int, ...]
    core_rows: Tuple[Tuple[int, ...], ...]
    fifo_cores: Tuple[int, ...]

    @property
    def shared_fifos(self) -> Tuple[int, ...]:
        """Flat indices of partition-crossing channels (semaphore-guarded)."""
        return tuple(i for i, c in enumerate(self.fifo_cores) if c == SHARED)

    def private_fifos(self, core: int) -> Tuple[int, ...]:
        return tuple(i for i, c in enumerate(self.fifo_cores) if c == core)

    # -- scratch accounting (per-core Table 1, device-side) ------------- #
    def private_ring_bytes(self, layout: "MegakernelLayout") -> Tuple[int, ...]:
        """Ring bytes held in each core's private scratch block."""
        return tuple(
            sum(layout.fifo_specs[i].capacity_bytes
                for i in self.private_fifos(core))
            for core in range(self.n_cores))

    def shared_ring_bytes(self, layout: "MegakernelLayout") -> int:
        """Ring bytes of the shared (partition-crossing) block."""
        return sum(layout.fifo_specs[i].capacity_bytes
                   for i in self.shared_fifos)

    def semaphore_bytes(self) -> int:
        """Bytes of shared cursor rows polled as cross-core semaphores."""
        return len(self.shared_fifos) * CURSOR_FIELDS * _CURSOR_ITEMSIZE


def _glued_units(network: Network) -> List[List[int]]:
    """Actor indices grouped into partition units, in first-member order.

    Union-find over :meth:`Network.delay_partition_constraints`: the two
    endpoints of a delay channel whose initial tokens do not cover a
    read window must land on one core, so they form one indivisible
    unit in the contiguous cut.
    """
    names = list(network.actors)
    idx = {n: i for i, n in enumerate(names)}
    parent = list(range(len(names)))

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for _, src, dst in network.delay_partition_constraints():
        a, b = find(idx[src]), find(idx[dst])
        if a != b:
            parent[max(a, b)] = min(a, b)
    units: List[List[int]] = []
    unit_of_root: dict = {}
    for i in range(len(names)):
        r = find(i)
        if r not in unit_of_root:
            unit_of_root[r] = len(units)
            units.append([])
        units[unit_of_root[r]].append(i)
    return units


def _balanced_cut(weights: List[int], cores: int) -> List[int]:
    """Contiguous cut of ``weights`` into ``cores`` groups minimizing the
    maximum group weight (classic linear-partition DP; deterministic —
    ties break toward earlier cuts).  Returns the group index per unit.
    """
    n = len(weights)
    prefix = [0]
    for w in weights:
        prefix.append(prefix[-1] + w)

    def span(i: int, j: int) -> int:          # weight of units [i, j)
        return prefix[j] - prefix[i]

    INF = float("inf")
    # best[c][j]: minimal max-group-weight cutting units [0, j) into c groups.
    best = [[INF] * (n + 1) for _ in range(cores + 1)]
    cut = [[0] * (n + 1) for _ in range(cores + 1)]
    best[0][0] = 0
    for c in range(1, cores + 1):
        for j in range(c, n + 1):
            for i in range(c - 1, j):
                cand = max(best[c - 1][i], span(i, j))
                if cand < best[c][j]:
                    best[c][j] = cand
                    cut[c][j] = i
    groups = [0] * n
    j = n
    for c in range(cores, 0, -1):
        i = cut[c][j]
        for u in range(i, j):
            groups[u] = c - 1
        j = i
    return groups


def default_assignment(network: Network, cores: int) -> dict:
    """Load-balanced actor -> core map: a contiguous cut of the dynamic
    visit order (declaration order), weighted by ``cost_flops`` (floor 1
    per actor so zero-cost sources/sinks still count as schedulable
    work), with window-uncovered delay-channel endpoints glued into one
    unit.  Contiguity keeps the multi-core visit order equal to the
    single-core sweep's, so the interpret-mode tie-break (partition
    order) reproduces the single-core schedule exactly.
    """
    names = list(network.actors)
    units = _glued_units(network)
    if cores > len(units):
        raise ValueError(
            f"cores={cores} exceeds the {len(units)} partition units of "
            f"this network ({len(names)} actors after gluing delay-channel "
            "endpoints); pass fewer cores or an explicit assign= that "
            "leaves no core empty")
    weights = [
        sum(max(1, int(network.actors[names[i]].cost_flops)) for i in u)
        for u in units
    ]
    groups = _balanced_cut(weights, cores)
    out = {}
    for ui, unit in enumerate(units):
        for i in unit:
            out[names[i]] = groups[ui]
    return out


def partition_layout(network: Network, layout: MegakernelLayout,
                     cores: int = 1,
                     assign: Optional[Mapping[str, int]] = None
                     ) -> GridPartition:
    """Partition the firing table across ``cores`` grid partitions.

    ``assign`` (actor name -> core) overrides the default load-balanced
    cut; it must cover every actor and respect the delay-channel
    constraint (``Network.validate_partition``).  Intra-partition
    channels are placed in the owning core's private scratch block;
    partition-crossing channels go :data:`SHARED` with their cursor rows
    acting as the polled semaphores.
    """
    if cores < 1:
        raise ValueError(f"cores must be >= 1, got {cores}")
    if assign is None:
        assign = default_assignment(network, cores)
    network.validate_partition(assign, cores)
    names = list(network.actors)
    assignment = tuple(int(assign[n]) for n in names)
    core_rows = tuple(
        tuple(i for i, n in enumerate(names) if assignment[i] == core)
        for core in range(cores))
    fifo_cores = []
    for fname in layout.fifo_names:
        e = network.edge_of(fname)
        src = assignment[names.index(e.src_actor)]
        dst = assignment[names.index(e.dst_actor)]
        fifo_cores.append(src if src == dst else SHARED)
    return GridPartition(n_cores=cores, assignment=assignment,
                         core_rows=core_rows,
                         fifo_cores=tuple(fifo_cores))


def state_hbm_bytes(state: Any) -> int:
    """Total bytes of a state pytree as it sits in HBM (kernel in/out
    operands: ring buffers, cursors, actor states) — the 'HBM' column of
    the scratch-vs-HBM table in EXPERIMENTS.md §Megakernel."""
    total = 0
    for leaf in jax.tree.leaves(state):
        total += (int(np.prod(np.shape(leaf), dtype=np.int64))
                  * np.dtype(leaf.dtype).itemsize)
    return total
