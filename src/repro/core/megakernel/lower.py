"""Lowering pass: Network -> megakernel scratch layout + firing table.

The megakernel backend runs a whole accelerated subnetwork as ONE
persistent Pallas kernel (paper §3.3 made literal): every Eq. 1 FIFO ring
buffer lives in device scratch memory for the kernel's entire lifetime,
and the token-driven sweep loop — the part the paper keeps resident on the
device instead of round-tripping dispatch decisions through the host —
runs *inside* the kernel.  This module is the build-time half: it flattens
the validated :class:`~repro.core.network.Network` into the static tables
the kernel body is traced from.

Outputs of :func:`lower_network`:

  * **scratch layout** — one ring-buffer scratch allocation per channel,
    shaped ``(capacity_tokens, *token_shape)`` straight from the Eq. 1
    capacity law (``FifoSpec.capacity_tokens``), plus one packed
    ``(n_fifos, 3)`` int32 cursor block (rd / wr / occ per channel, the
    kernel's register-resident analogue of ``FifoState``'s scalars);
  * **firing table** — one :class:`FiringRow` per actor in network
    declaration order (the same visit order as the token-driven host
    scheduler, so sweep counts and final states match bit for bit), each
    row resolving the actor's control / input / output ports to flat
    channel indices at build time so the traced kernel never touches a
    name-keyed dict;
  * reused analyses — ``Network.register_fifos`` (channels the static
    specializer proves transient; the megakernel keeps them ring-buffered
    for bit-identity with the dynamic executor but reports them as the
    candidates a future in-kernel forwarding pass would keep VMEM-only)
    and :func:`~repro.core.schedule.phase_unroll_period` (the unroll
    period a static in-kernel prologue would use; recorded for the stats
    table and the ROADMAP follow-on, not yet acted on).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import numpy as np

from repro.core.fifo import FifoSpec
from repro.core.network import Network
from repro.core.schedule import phase_unroll_period

# One packed cursor row per channel: (rd, wr, occ) int32.
CURSOR_FIELDS = 3
_CURSOR_ITEMSIZE = 4


@dataclasses.dataclass(frozen=True)
class PortBinding:
    """One regular port resolved to its flat channel index."""

    port: str
    fifo: int


@dataclasses.dataclass(frozen=True)
class FiringRow:
    """One actor's row in the firing table.

    ``control`` is the flat index of the control channel (None for static
    actors); ``inputs`` / ``outputs`` are the regular ports in declaration
    order — the same order ``fire_actor`` consumes them, which the kernel
    must preserve for bit-identical cursor arithmetic.
    """

    name: str
    index: int
    control: Optional[int]
    inputs: Tuple[PortBinding, ...]
    outputs: Tuple[PortBinding, ...]
    is_dynamic: bool
    has_ready: bool


@dataclasses.dataclass(frozen=True)
class MegakernelLayout:
    """Static layout of one lowered network (everything the kernel trace
    needs, nothing resolved per sweep)."""

    fifo_names: Tuple[str, ...]
    fifo_specs: Tuple[FifoSpec, ...]
    firing_table: Tuple[FiringRow, ...]
    # Channels the specialized static executor would register-allocate
    # (Network.register_fifos).  Kept ring-buffered here for bit-identity
    # with compile_dynamic; reported so stats can show how much of the
    # scratch footprint a forwarding pass would reclaim.
    transient_fifos: frozenset
    # phase_unroll_period over the buffered channels — the unroll a static
    # in-kernel prologue would use (ROADMAP follow-on; diagnostic today).
    unroll_period: int

    # -- scratch accounting (the paper's Table 1, device-side) ---------- #
    @property
    def ring_scratch_bytes(self) -> int:
        """Eq. 1 capacities summed — bytes of ring buffer held in scratch."""
        return sum(s.capacity_bytes for s in self.fifo_specs)

    @property
    def cursor_bytes(self) -> int:
        return len(self.fifo_specs) * CURSOR_FIELDS * _CURSOR_ITEMSIZE

    @property
    def scratch_bytes(self) -> int:
        return self.ring_scratch_bytes + self.cursor_bytes

    @property
    def transient_scratch_bytes(self) -> int:
        """Scratch bytes a forwarding pass over transient channels would
        reclaim (they would become traced values, not buffers)."""
        return sum(s.capacity_bytes for s in self.fifo_specs
                   if s.name in self.transient_fifos)

    def scratch_shape(self, fifo_index: int) -> Tuple[int, ...]:
        """Ring scratch shape of one channel: Eq. 1 capacity x token."""
        spec = self.fifo_specs[fifo_index]
        return (spec.capacity_tokens,) + tuple(spec.token_shape)


def lower_network(network: Network) -> MegakernelLayout:
    """Flatten a validated network into the megakernel's static tables.

    Pure build-time work: reuses the port->spec tables the network
    precomputes (``in_port_specs`` / ``out_port_specs`` /
    ``control_specs``) and the ``register_fifos`` / phase-cycle analyses,
    so lowering adds no per-run cost and no new validation rules — any
    network the dynamic executor accepts lowers.
    """
    fifo_names = tuple(network.fifos)
    fifo_specs = tuple(network.fifos[n] for n in fifo_names)
    rows = []
    for index, (name, actor) in enumerate(network.actors.items()):
        ctl = network.control_specs[name]
        rows.append(FiringRow(
            name=name,
            index=index,
            control=None if ctl is None else ctl[1],
            inputs=tuple(PortBinding(p, fi)
                         for p, _, fi in network.in_port_specs[name]),
            outputs=tuple(PortBinding(p, fi)
                          for p, _, fi in network.out_port_specs[name]),
            is_dynamic=actor.is_dynamic,
            has_ready=actor.ready is not None,
        ))
    period = phase_unroll_period(
        [spec.n_write_phases for name, spec in network.fifos.items()
         if name not in network.register_fifos])
    return MegakernelLayout(
        fifo_names=fifo_names,
        fifo_specs=fifo_specs,
        firing_table=tuple(rows),
        transient_fifos=frozenset(network.register_fifos),
        unroll_period=period,
    )


def state_hbm_bytes(state: Any) -> int:
    """Total bytes of a state pytree as it sits in HBM (kernel in/out
    operands: ring buffers, cursors, actor states) — the 'HBM' column of
    the scratch-vs-HBM table in EXPERIMENTS.md §Megakernel."""
    total = 0
    for leaf in jax.tree.leaves(state):
        total += (int(np.prod(np.shape(leaf), dtype=np.int64))
                  * np.dtype(leaf.dtype).itemsize)
    return total
